//! Federated algorithms: pFed1BS (the paper's contribution, Algorithm 1)
//! and every baseline from Table 1/2 — FedAvg, OBDA, OBCSAA, zSignFed,
//! EDEN, FedBAT — plus a no-communication LocalOnly ablation.
//!
//! All algorithms share the same client compute (the AOT HLO artifacts)
//! and the same metered transport, so accuracy and communication numbers
//! are directly comparable. Each file documents the fidelity of its
//! re-implementation relative to the cited paper.
//!
//! # The phased round protocol (DESIGN.md §3)
//!
//! A communication round is an explicit message-passing protocol, not a
//! monolithic callback. The coordinator owns the transport; algorithms
//! implement four phases:
//!
//! 1. [`Algorithm::server_broadcast`] — compose the round's [`Downlink`]
//!    (or `None`: pFed1BS round 0, OBDA, LocalOnly). The coordinator
//!    transports one copy per participant through that client's channel,
//!    so each recipient gets independently metered (and, under a noisy
//!    channel, independently corrupted) delivery. The server's own state
//!    is never routed through a channel.
//! 2. [`Algorithm::client_round`] — one client's local work, `&self` +
//!    an owned per-client RNG stream, so the coordinator executes all
//!    participants data-parallel with results bit-identical to serial.
//!    Returns a [`ClientOutput`]: optional [`Uplink`], optional updated
//!    personalized state, and [`ClientStats`].
//! 3. Streaming aggregation (DESIGN.md §9):
//!    [`Algorithm::begin_aggregate`] hands the round engine an O(m)
//!    [`RoundAggregator`]; the engine absorbs each *delivered* uplink in
//!    arrival order (the cohort is never stored) and
//!    [`Algorithm::finish_aggregate`] folds the closed aggregator into
//!    server state (`&mut self`), reporting the [`RoundOutcome`].
//! 4. [`Algorithm::server_notify`] — optional end-of-round broadcast
//!    (OBDA ships the majority vote back so clients stay in sync).
//!
//! To add an algorithm, implement the phases plus `model_for`, pick the
//! [`AggKind`] that matches your uplink payload, keep every byte you
//! logically transmit inside a `Payload`, and register it in [`build`].
//! See DESIGN.md §4 for a walkthrough.

pub mod aggregate;
pub mod common;
pub mod eden;
pub mod fedavg;
pub mod fedbat;
pub mod local_only;
pub mod obcsaa;
pub mod obda;
pub mod pfed1bs;
pub mod zsignfed;

use anyhow::Result;

pub use crate::algorithms::aggregate::{AggKind, CarriedUplink, RoundAggregator};
pub use crate::comm::{Downlink, Uplink};
use crate::config::RunConfig;
use crate::data::FederatedData;
use crate::runtime::ModelRuntime;
use crate::sketch::Projection;
use crate::util::rng::Rng;

/// Table 1 capability matrix row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capabilities {
    /// uplink is dimension-reduced (m < n)
    pub upload_dim_reduction: bool,
    /// uplink is one bit per coordinate
    pub upload_one_bit: bool,
    /// downlink is dimension-reduced
    pub download_dim_reduction: bool,
    /// downlink is one bit per coordinate
    pub download_one_bit: bool,
    /// keeps per-client personalized models
    pub personalization: bool,
}

/// One-time-setup context: everything visible once geometry is known.
pub struct InitCtx<'a> {
    /// compiled model runtime (geometry + HLO executables)
    pub model: &'a ModelRuntime,
    /// the generated federated dataset
    pub data: &'a FederatedData,
    /// the run configuration
    pub cfg: &'a RunConfig,
    /// rust-side mirror of Φ (baselines + the dense-Gaussian ablation)
    pub projection: &'a Projection,
}

/// Per-client execution context for the data-parallel client phase.
/// Owns this client's RNG stream (forked by the coordinator in selection
/// order before the parallel section, so results are independent of
/// thread count and scheduling).
pub struct ClientCtx<'a> {
    /// compiled model runtime (shared, `&self` execution)
    pub model: &'a ModelRuntime,
    /// the generated federated dataset
    pub data: &'a FederatedData,
    /// the run configuration
    pub cfg: &'a RunConfig,
    /// rust-side mirror of Φ
    pub projection: &'a Projection,
    /// this client's own pre-forked RNG stream
    pub rng: Rng,
}

/// One client's inputs to a device-batched client phase: the same
/// (client id, pre-forked RNG stream, delivered downlink) triple the
/// coordinator hands to [`Algorithm::client_round`] through a
/// [`ClientCtx`], but owned so a whole group can be passed at once.
pub struct BatchTask {
    /// client id
    pub k: usize,
    /// this client's own pre-forked RNG stream (forked by the coordinator
    /// in selection order, identical to the per-client path)
    pub rng: Rng,
    /// the downlink copy this client's channel delivered
    pub downlink: Option<Downlink>,
}

/// Shared (RNG-free) context for a device-batched client phase; per-client
/// RNG streams ride in each [`BatchTask`].
pub struct BatchCtx<'a> {
    /// compiled model runtime (shared, `&self` execution)
    pub model: &'a ModelRuntime,
    /// the generated federated dataset
    pub data: &'a FederatedData,
    /// the run configuration
    pub cfg: &'a RunConfig,
    /// rust-side mirror of Φ
    pub projection: &'a Projection,
}

/// Server-side aggregation context. Deliberately excludes the model
/// runtime: server math is pure rust, which keeps the aggregation phase
/// unit-testable without PJRT artifacts.
pub struct ServerCtx<'a> {
    /// the run configuration
    pub cfg: &'a RunConfig,
    /// rust-side mirror of Φ (server-side reconstruction)
    pub projection: &'a Projection,
}

/// Per-client statistics reported from the client phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClientStats {
    /// round-start task loss on this client (Fig. 4 metric)
    pub loss: f64,
}

/// Everything one client hands back at the end of its round phase.
#[derive(Clone, Debug)]
pub struct ClientOutput {
    /// which client produced this (selection order is preserved by the
    /// coordinator, so `outputs[i].client == selected[i]`)
    pub client: usize,
    /// message to the server; `None` = silent round (LocalOnly). The
    /// round engine replaces the payload with the channel-delivered copy
    /// before absorbing it into the round's [`RoundAggregator`].
    pub uplink: Option<Uplink>,
    /// updated personalized state for algorithms that keep per-client
    /// models; written back by `finish_aggregate` (even for stragglers
    /// whose uplink was cut — their local model really advanced), never
    /// transmitted
    pub state: Option<Vec<f32>>,
    /// per-client round statistics (loss)
    pub stats: ClientStats,
}

/// Per-round result reported back to the coordinator. Built by
/// [`RoundAggregator::into_parts`]: the mean round-start loss over the
/// round's *delivered* set (0.0 when nothing was delivered — empty
/// cohorts are rejected by `RunConfig::validate` before any round runs,
/// but a fully dropped-out round can legitimately deliver nothing).
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundOutcome {
    /// mean task loss over all local steps this round (Fig. 4 metric)
    pub train_loss: f64,
}

/// A federated learning algorithm under test, expressed as the phased
/// message protocol of Algorithm 1 (module docs above). `Send + Sync`
/// because the client phase runs data-parallel over `&self`.
pub trait Algorithm: Send + Sync {
    fn name(&self) -> &'static str;
    fn capabilities(&self) -> Capabilities;

    /// One-time setup once geometry is known.
    fn init(&mut self, ctx: &InitCtx) -> Result<()>;

    /// Phase 1: compose round `t`'s broadcast (`None` = no downlink).
    fn server_broadcast(&self, t: usize) -> Option<Downlink>;

    /// Phase 2: client `k`'s local round. `downlink` is the copy this
    /// client's channel delivered (possibly corrupted; `None` when the
    /// server sent nothing). Must not touch state of other clients.
    fn client_round(
        &self,
        t: usize,
        k: usize,
        downlink: Option<&Downlink>,
        ctx: &mut ClientCtx,
    ) -> Result<ClientOutput>;

    /// True when this algorithm's [`Self::client_round_batched`] can pack
    /// a whole group into the model runtime's cohort-batched executables
    /// (one device dispatch per local step for up to B clients). The
    /// coordinator only takes the batched path when this returns true AND
    /// the loaded runtime carries batched executables
    /// (`ModelRuntime::device_batch() > 1`); results must be bit-identical
    /// to per-client execution.
    fn supports_batched_rounds(&self) -> bool {
        false
    }

    /// Phase 2 (batched): run a group of up to `device_batch` clients'
    /// local rounds. The default just loops [`Self::client_round`] —
    /// algorithms opting in via [`Self::supports_batched_rounds`] override
    /// this with a stacked-dispatch implementation. Must return one
    /// [`ClientOutput`] per task, in task order.
    fn client_round_batched(
        &self,
        t: usize,
        tasks: Vec<BatchTask>,
        ctx: &BatchCtx,
    ) -> Result<Vec<ClientOutput>> {
        tasks
            .into_iter()
            .map(|task| {
                let mut cctx = ClientCtx {
                    model: ctx.model,
                    data: ctx.data,
                    cfg: ctx.cfg,
                    projection: ctx.projection,
                    rng: task.rng,
                };
                self.client_round(t, task.k, task.downlink.as_ref(), &mut cctx)
            })
            .collect()
    }

    /// Phase 3a: create round `t`'s empty streaming aggregator (O(m) /
    /// O(n) state — DESIGN.md §9). `&self` because the engine begins
    /// folding while the client phase may still be running; the engine
    /// then absorbs every delivered uplink in arrival order with its
    /// delivered-set weight (p_k renormalized over what actually
    /// arrived), so algorithms never see — and the server never stores —
    /// the uplink stream itself.
    fn begin_aggregate(&self, t: usize) -> RoundAggregator;

    /// Phase 3b: fold the closed aggregator into server state. Called
    /// exactly once per round, after the last delivery (or the
    /// deadline). Implementations must gate consensus/model updates on
    /// `absorbed() > 0`: a fully dropped-out round leaves server state
    /// untouched.
    fn finish_aggregate(
        &mut self,
        t: usize,
        agg: RoundAggregator,
        ctx: &ServerCtx,
    ) -> Result<RoundOutcome>;

    /// Phase 4 (optional): end-of-round broadcast, metered per recipient
    /// like the pre-round broadcast. Delivered copies are discarded by
    /// the simulated stateless clients (OBDA uses this to ship the
    /// majority vote back).
    fn server_notify(&self, _t: usize) -> Option<Downlink> {
        None
    }

    /// The parameter vector used to evaluate client k (personalized
    /// algorithms return per-client models; global ones return the shared
    /// model).
    fn model_for(&self, k: usize) -> &[f32];

    /// Optional: the current consensus vector as ±1/0 f32 lanes (the
    /// compute-boundary form the HLO diagnostics need).
    fn consensus(&self) -> Option<&[f32]> {
        None
    }

    /// Optional: the current consensus in its packed one-bit form — the
    /// representation the server actually votes into. The coordinator
    /// uses it for the per-round consensus-flip metric
    /// (`hamming_packed`, DESIGN.md §8) without any unpack.
    fn consensus_packed(&self) -> Option<&crate::sketch::bitpack::SignVec> {
        None
    }

    /// Checkpoint snapshot: (per-client or single-global models,
    /// consensus). Empty models = checkpointing unsupported.
    fn snapshot(&self) -> (Vec<Vec<f32>>, Vec<f32>) {
        (Vec::new(), Vec::new())
    }

    /// Restore from a snapshot produced by `snapshot`.
    fn restore(&mut self, _models: Vec<Vec<f32>>, _consensus: Vec<f32>) -> Result<()> {
        anyhow::bail!("{} does not support checkpoint restore", self.name())
    }

    /// Auxiliary per-client checkpoint state beyond the models: pFed1BS
    /// returns its error-feedback residuals here (DESIGN.md §16), rides
    /// in checkpoint format v3. Empty = none, and the checkpoint stays
    /// byte-identical to the v2 layout.
    fn snapshot_aux(&self) -> Vec<Vec<f32>> {
        Vec::new()
    }

    /// Restore the auxiliary state produced by `snapshot_aux`. The
    /// default accepts only an empty vector (v2-and-earlier
    /// checkpoints); algorithms with auxiliary state override it.
    fn restore_aux(&mut self, aux: Vec<Vec<f32>>) -> Result<()> {
        anyhow::ensure!(
            aux.is_empty(),
            "{} carries no auxiliary checkpoint state, got {} vectors",
            self.name(),
            aux.len()
        );
        Ok(())
    }
}

/// All registered algorithm names, in Table-2 row order.
pub fn all_names() -> [&'static str; 7] {
    ["fedavg", "obda", "obcsaa", "zsignfed", "eden", "fedbat", "pfed1bs"]
}

/// Construct an algorithm by name.
pub fn build(name: &str) -> Result<Box<dyn Algorithm>> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "pfed1bs" => Box::new(pfed1bs::PFed1BS::new()),
        "fedavg" => Box::new(fedavg::FedAvg::new()),
        "obda" => Box::new(obda::Obda::new()),
        "obcsaa" => Box::new(obcsaa::Obcsaa::new()),
        "zsignfed" => Box::new(zsignfed::ZSignFed::new()),
        "eden" => Box::new(eden::Eden::new()),
        "fedbat" => Box::new(fedbat::FedBat::new()),
        "local" | "local-only" | "localonly" => Box::new(local_only::LocalOnly::new()),
        other => anyhow::bail!(
            "unknown algorithm `{other}` (pfed1bs|fedavg|obda|obcsaa|zsignfed|eden|fedbat|local)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_all_known_names() {
        for name in all_names() {
            let alg = build(name).unwrap();
            assert_eq!(alg.name(), name);
        }
        assert!(build("nope").is_err());
    }

    #[test]
    fn capability_matrix_matches_table1() {
        // Table 1 of the paper, row by row.
        let caps = |n: &str| build(n).unwrap().capabilities();
        let fedavg = caps("fedavg");
        assert!(!fedavg.upload_one_bit && !fedavg.personalization);
        let obda = caps("obda");
        assert!(obda.upload_one_bit && obda.download_one_bit && !obda.personalization);
        assert!(!obda.upload_dim_reduction);
        let obcsaa = caps("obcsaa");
        assert!(obcsaa.upload_dim_reduction && obcsaa.upload_one_bit);
        assert!(!obcsaa.download_one_bit && !obcsaa.personalization);
        let zsign = caps("zsignfed");
        assert!(zsign.upload_one_bit && !zsign.upload_dim_reduction);
        assert!(!zsign.download_one_bit);
        let p = caps("pfed1bs");
        assert!(
            p.upload_dim_reduction
                && p.upload_one_bit
                && p.download_dim_reduction
                && p.download_one_bit
                && p.personalization
        );
    }

    #[test]
    fn round_outcome_mean_loss_via_aggregator() {
        let out = |client, loss: f64| ClientOutput {
            client,
            uplink: None,
            state: None,
            stats: ClientStats { loss },
        };
        let mut agg = RoundAggregator::new(AggKind::Passthrough);
        agg.absorb(out(0, 1.0), 0.5).unwrap();
        agg.absorb(out(1, 3.0), 0.5).unwrap();
        let (_, _, absorbed, o) = agg.into_parts();
        assert_eq!(absorbed, 2);
        assert!((o.train_loss - 2.0).abs() < 1e-12);
        let empty = RoundAggregator::new(AggKind::Passthrough);
        assert_eq!(empty.into_parts().3.train_loss, 0.0);
    }
}
