//! Federated algorithms: pFed1BS (the paper's contribution, Algorithm 1)
//! and every baseline from Table 1/2 — FedAvg, OBDA, OBCSAA, zSignFed,
//! EDEN, FedBAT — plus a no-communication LocalOnly ablation.
//!
//! All algorithms share the same client compute (the AOT HLO artifacts)
//! and the same metered transport, so accuracy and communication numbers
//! are directly comparable. Each file documents the fidelity of its
//! re-implementation relative to the cited paper.

pub mod common;
pub mod eden;
pub mod fedavg;
pub mod fedbat;
pub mod local_only;
pub mod obcsaa;
pub mod obda;
pub mod pfed1bs;
pub mod zsignfed;

use anyhow::Result;

use crate::comm::SimNetwork;
use crate::config::RunConfig;
use crate::data::FederatedData;
use crate::runtime::ModelRuntime;
use crate::sketch::Projection;
use crate::util::rng::Rng;

/// Table 1 capability matrix row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capabilities {
    pub upload_dim_reduction: bool,
    pub upload_one_bit: bool,
    pub download_dim_reduction: bool,
    pub download_one_bit: bool,
    pub personalization: bool,
}

/// Everything an algorithm touches during a round. The coordinator owns
/// all of it; algorithms keep only their model state.
pub struct Ctx<'a> {
    pub model: &'a ModelRuntime,
    pub data: &'a FederatedData,
    pub cfg: &'a RunConfig,
    pub net: &'a mut SimNetwork,
    pub rng: &'a mut Rng,
    /// rust-side mirror of Φ (baselines + the dense-Gaussian ablation)
    pub projection: &'a Projection,
}

/// Per-round result reported back to the coordinator.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundOutcome {
    /// mean task loss over all local steps this round (Fig. 4 metric)
    pub train_loss: f64,
}

/// A federated learning algorithm under test.
pub trait Algorithm {
    fn name(&self) -> &'static str;
    fn capabilities(&self) -> Capabilities;

    /// One-time setup once geometry is known.
    fn init(&mut self, ctx: &mut Ctx) -> Result<()>;

    /// Run communication round `t` over `selected` client ids with
    /// aggregation weights `weights` (p_k normalized over the subset).
    fn round(
        &mut self,
        t: usize,
        selected: &[usize],
        weights: &[f32],
        ctx: &mut Ctx,
    ) -> Result<RoundOutcome>;

    /// The parameter vector used to evaluate client k (personalized
    /// algorithms return per-client models; global ones return the shared
    /// model).
    fn model_for(&self, k: usize) -> &[f32];

    /// Optional: the current consensus vector (pFed1BS diagnostics).
    fn consensus(&self) -> Option<&[f32]> {
        None
    }

    /// Checkpoint snapshot: (per-client or single-global models,
    /// consensus). Empty models = checkpointing unsupported.
    fn snapshot(&self) -> (Vec<Vec<f32>>, Vec<f32>) {
        (Vec::new(), Vec::new())
    }

    /// Restore from a snapshot produced by `snapshot`.
    fn restore(&mut self, _models: Vec<Vec<f32>>, _consensus: Vec<f32>) -> Result<()> {
        anyhow::bail!("{} does not support checkpoint restore", self.name())
    }
}

/// All registered algorithm names, in Table-2 row order.
pub fn all_names() -> [&'static str; 7] {
    ["fedavg", "obda", "obcsaa", "zsignfed", "eden", "fedbat", "pfed1bs"]
}

/// Construct an algorithm by name.
pub fn build(name: &str) -> Result<Box<dyn Algorithm>> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "pfed1bs" => Box::new(pfed1bs::PFed1BS::new()),
        "fedavg" => Box::new(fedavg::FedAvg::new()),
        "obda" => Box::new(obda::Obda::new()),
        "obcsaa" => Box::new(obcsaa::Obcsaa::new()),
        "zsignfed" => Box::new(zsignfed::ZSignFed::new()),
        "eden" => Box::new(eden::Eden::new()),
        "fedbat" => Box::new(fedbat::FedBat::new()),
        "local" | "local-only" | "localonly" => Box::new(local_only::LocalOnly::new()),
        other => anyhow::bail!(
            "unknown algorithm `{other}` (pfed1bs|fedavg|obda|obcsaa|zsignfed|eden|fedbat|local)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_all_known_names() {
        for name in all_names() {
            let alg = build(name).unwrap();
            assert_eq!(alg.name(), name);
        }
        assert!(build("nope").is_err());
    }

    #[test]
    fn capability_matrix_matches_table1() {
        // Table 1 of the paper, row by row.
        let caps = |n: &str| build(n).unwrap().capabilities();
        let fedavg = caps("fedavg");
        assert!(!fedavg.upload_one_bit && !fedavg.personalization);
        let obda = caps("obda");
        assert!(obda.upload_one_bit && obda.download_one_bit && !obda.personalization);
        assert!(!obda.upload_dim_reduction);
        let obcsaa = caps("obcsaa");
        assert!(obcsaa.upload_dim_reduction && obcsaa.upload_one_bit);
        assert!(!obcsaa.download_one_bit && !obcsaa.personalization);
        let zsign = caps("zsignfed");
        assert!(zsign.upload_one_bit && !zsign.upload_dim_reduction);
        assert!(!zsign.download_one_bit);
        let p = caps("pfed1bs");
        assert!(
            p.upload_dim_reduction
                && p.upload_one_bit
                && p.download_dim_reduction
                && p.download_one_bit
                && p.personalization
        );
    }
}
