//! LocalOnly ablation: pure local training, zero communication.
//!
//! Upper-bounds what personalization alone achieves without any
//! collaboration — pFed1BS should beat it when the consensus carries
//! useful signal (and must never pay more communication).

use anyhow::Result;

use crate::algorithms::common::{init_params, local_sgd};
use crate::algorithms::{Algorithm, Capabilities, Ctx, RoundOutcome};

pub struct LocalOnly {
    wks: Vec<Vec<f32>>,
}

impl LocalOnly {
    pub fn new() -> Self {
        LocalOnly { wks: Vec::new() }
    }
}

impl Default for LocalOnly {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for LocalOnly {
    fn name(&self) -> &'static str {
        "local"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: false,
            upload_one_bit: false,
            download_dim_reduction: false,
            download_one_bit: false,
            personalization: true,
        }
    }

    fn init(&mut self, ctx: &mut Ctx) -> Result<()> {
        let w0 = init_params(ctx.model.geom.n, ctx.cfg.seed);
        self.wks = (0..ctx.data.num_clients()).map(|_| w0.clone()).collect();
        Ok(())
    }

    fn round(
        &mut self,
        t: usize,
        selected: &[usize],
        _weights: &[f32],
        ctx: &mut Ctx,
    ) -> Result<RoundOutcome> {
        let mut loss_sum = 0.0f64;
        for &k in selected {
            let mut w = std::mem::take(&mut self.wks[k]);
            loss_sum += local_sgd(ctx, k, &mut w, t as u64)?;
            self.wks[k] = w;
        }
        Ok(RoundOutcome {
            train_loss: loss_sum / selected.len() as f64,
        })
    }

    fn model_for(&self, k: usize) -> &[f32] {
        &self.wks[k]
    }
}
