//! LocalOnly ablation: pure local training, zero communication.
//!
//! Upper-bounds what personalization alone achieves without any
//! collaboration — pFed1BS should beat it when the consensus carries
//! useful signal (and must never pay more communication). In protocol
//! terms: no downlink, no uplink — the client phase only advances the
//! personalized state, which the aggregate phase writes back.

use anyhow::Result;

use crate::algorithms::common::{init_params, local_sgd};
use crate::algorithms::{
    AggKind, Algorithm, Capabilities, ClientCtx, ClientOutput, ClientStats, Downlink,
    InitCtx, RoundAggregator, RoundOutcome, ServerCtx,
};

/// No-communication ablation: every client trains alone; uplinks are
/// silent, so all accuracy comes from personalization.
pub struct LocalOnly {
    wks: Vec<Vec<f32>>,
}

impl LocalOnly {
    /// Fresh instance; state is sized at `init`.
    pub fn new() -> Self {
        LocalOnly { wks: Vec::new() }
    }
}

impl Default for LocalOnly {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for LocalOnly {
    fn name(&self) -> &'static str {
        "local"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: false,
            upload_one_bit: false,
            download_dim_reduction: false,
            download_one_bit: false,
            personalization: true,
        }
    }

    fn init(&mut self, ctx: &InitCtx) -> Result<()> {
        let w0 = init_params(ctx.model.geom.n, ctx.cfg.seed);
        self.wks = (0..ctx.data.num_clients()).map(|_| w0.clone()).collect();
        Ok(())
    }

    fn server_broadcast(&self, _t: usize) -> Option<Downlink> {
        None
    }

    fn client_round(
        &self,
        t: usize,
        k: usize,
        _downlink: Option<&Downlink>,
        ctx: &mut ClientCtx,
    ) -> Result<ClientOutput> {
        let mut w = self.wks[k].clone();
        let loss = local_sgd(ctx, k, &mut w, t as u64)?;
        Ok(ClientOutput {
            client: k,
            uplink: None,
            state: Some(w),
            stats: ClientStats { loss },
        })
    }

    fn begin_aggregate(&self, _t: usize) -> RoundAggregator {
        // nothing to accumulate: only personalized write-backs flow
        RoundAggregator::new(AggKind::Passthrough)
    }

    fn finish_aggregate(
        &mut self,
        _t: usize,
        agg: RoundAggregator,
        _ctx: &ServerCtx,
    ) -> Result<RoundOutcome> {
        let (_, states, _, outcome) = agg.into_parts();
        for (k, w) in states {
            self.wks[k] = w;
        }
        Ok(outcome)
    }

    fn model_for(&self, k: usize) -> &[f32] {
        &self.wks[k]
    }
}
