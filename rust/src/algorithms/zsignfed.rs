//! zSignFed / z-SignFedAvg (Tang, Wang & Chang 2024): stochastic
//! sign-based compression stabilized by a zero-mean perturbation
//! (Table 1 row 4 — 1-bit uplink only).
//!
//! Re-implementation fidelity: each client uploads sign(Δ_k + u) with
//! u ~ Uniform(−c, c) i.i.d. per coordinate; then E[sign(Δ+u)] = Δ/c for
//! |Δ| ≤ c, so the server's c·(weighted mean of signs) is an unbiased
//! estimate of the clamped update. c is set per client to
//! `zsign_noise · max|Δ_k|` and shipped as one f32. Downlink is the
//! full-precision model (as in the paper's comparison setting). The
//! perturbation draws come from the client's own RNG stream, so the
//! parallel client phase stays deterministic.

use anyhow::Result;

use crate::algorithms::common::{axpy, delta, init_params, local_sgd, mean_abs};
use crate::algorithms::{
    AggKind, Algorithm, Capabilities, ClientCtx, ClientOutput, ClientStats, Downlink,
    InitCtx, RoundAggregator, RoundOutcome, ServerCtx, Uplink,
};
use crate::comm::Payload;
use crate::sketch::bitpack::{SignVec, VoteAccumulator};

/// zSignFed: perturbed-sign aggregation — stochastic sign uplinks
/// around a noise scale, server averages the signs — global model.
pub struct ZSignFed {
    w: Vec<f32>,
}

impl ZSignFed {
    /// Fresh instance; state is sized at `init`.
    pub fn new() -> Self {
        ZSignFed { w: Vec::new() }
    }
}

impl Default for ZSignFed {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for ZSignFed {
    fn name(&self) -> &'static str {
        "zsignfed"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: false,
            upload_one_bit: true,
            download_dim_reduction: false,
            download_one_bit: false,
            personalization: false,
        }
    }

    fn init(&mut self, ctx: &InitCtx) -> Result<()> {
        self.w = init_params(ctx.model.geom.n, ctx.cfg.seed);
        Ok(())
    }

    fn server_broadcast(&self, t: usize) -> Option<Downlink> {
        Some(Downlink::new(t, Payload::Dense(self.w.clone())))
    }

    fn client_round(
        &self,
        t: usize,
        k: usize,
        downlink: Option<&Downlink>,
        ctx: &mut ClientCtx,
    ) -> Result<ClientOutput> {
        let Some(Downlink { payload: Payload::Dense(w0), .. }) = downlink else {
            anyhow::bail!("zsignfed requires a dense model downlink");
        };
        let mut wk = w0.clone();
        let loss = local_sgd(ctx, k, &mut wk, t as u64)?;
        let d = delta(&wk, w0);
        // perturbation scale from the MEAN |Δ|: with c = max|Δ| the
        // unbiased estimator's per-coordinate variance is c², which
        // for ~10^5-dim updates is ~400× the signal and diverges —
        // mean-based c keeps E[sign(Δ+u)]·c ≈ Δ on the bulk of the
        // coordinates at bounded variance (clipped tail bias).
        let c = (ctx.cfg.zsign_noise * mean_abs(&d)).max(1e-12);
        // packed directly: from_fn draws in ascending coordinate order,
        // so the perturbation stream matches a ±1-lane construction
        let signs = SignVec::from_fn(d.len(), |i| {
            let u = ctx.rng.range_f32(-c, c);
            d[i] + u >= 0.0
        });
        Ok(ClientOutput {
            client: k,
            uplink: Some(Uplink::new(t, Payload::ScaledSigns { signs, scale: c })),
            state: None,
            stats: ClientStats { loss },
        })
    }

    fn begin_aggregate(&self, _t: usize) -> RoundAggregator {
        // linear one-bit estimator: each delivered sketch folds into the
        // tally with weight p_k·c_k (the unbiased estimate Σ p_k·c_k·z_k)
        RoundAggregator::new(AggKind::SignSum(VoteAccumulator::new(self.w.len())))
    }

    fn finish_aggregate(
        &mut self,
        _t: usize,
        agg: RoundAggregator,
        _ctx: &ServerCtx,
    ) -> Result<RoundOutcome> {
        let (kind, _, _, outcome) = agg.into_parts();
        let AggKind::SignSum(tally) = kind else {
            anyhow::bail!("zsignfed aggregator must be the linear sign estimator");
        };
        // an empty tally reads back as zeros — a delivered-nothing round
        // leaves the model where it was
        axpy(&mut self.w, 1.0, &tally.finish_sum());
        Ok(outcome)
    }

    fn model_for(&self, _k: usize) -> &[f32] {
        &self.w
    }
}
