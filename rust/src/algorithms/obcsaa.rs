//! OBCSAA (Fan et al. 2022): 1-bit compressed-sensing uplink, full-
//! precision downlink (Table 1 row 3).
//!
//! Re-implementation fidelity: clients upload the one-bit compressed
//! sketch sign(Φ Δ_k) (m bits) plus a 32-bit magnitude; the server
//! reconstructs with the adjoint estimator Δ̂ ∝ Φᵀ(Σ p_k z_k) — the first
//! iterate of BIHT and the standard one-bit-CS proxy when the support is
//! unknown — rescaled to the clients' reported update norm, then applies
//! it and broadcasts the full-precision model (uncompressed downlink, as
//! in the paper's table row).

use anyhow::Result;

use crate::algorithms::common::{axpy, delta, init_params, local_sgd};
use crate::algorithms::{Algorithm, Capabilities, Ctx, RoundOutcome};
use crate::comm::Payload;
use crate::util::stats::l2_norm;

pub struct Obcsaa {
    w: Vec<f32>,
}

impl Obcsaa {
    pub fn new() -> Self {
        Obcsaa { w: Vec::new() }
    }
}

impl Default for Obcsaa {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for Obcsaa {
    fn name(&self) -> &'static str {
        "obcsaa"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: true,
            upload_one_bit: true,
            download_dim_reduction: false,
            download_one_bit: false,
            personalization: false,
        }
    }

    fn init(&mut self, ctx: &mut Ctx) -> Result<()> {
        self.w = init_params(ctx.model.geom.n, ctx.cfg.seed);
        Ok(())
    }

    fn round(
        &mut self,
        t: usize,
        selected: &[usize],
        weights: &[f32],
        ctx: &mut Ctx,
    ) -> Result<RoundOutcome> {
        let m = ctx.model.geom.m;
        // downlink: full-precision model to each participant
        ctx.net
            .broadcast_downlink(&Payload::Dense(self.w.clone()), selected.len())?;

        let mut agg = vec![0.0f32; m];
        let mut norm_acc = 0.0f64;
        let mut loss_sum = 0.0f64;
        for (&k, &p) in selected.iter().zip(weights) {
            let mut wk = self.w.clone();
            loss_sum += local_sgd(ctx, k, &mut wk, t as u64)?;
            let d = delta(&wk, &self.w);
            let z = ctx.projection.sketch_sign(&d);
            let norm = l2_norm(&d) as f32;
            let delivered = ctx
                .net
                .send_uplink(&Payload::ScaledSigns { signs: z, scale: norm })?;
            let Payload::ScaledSigns { signs, scale } = delivered else {
                anyhow::bail!("payload type changed in transit")
            };
            norm_acc += (p * scale) as f64;
            for (a, &s) in agg.iter_mut().zip(&signs) {
                *a += p * s;
            }
        }

        // one-bit CS reconstruction: adjoint estimate, rescaled to the
        // weighted-mean update norm
        let mut dhat = ctx.projection.adjoint(&agg);
        let dn = l2_norm(&dhat);
        if dn > 0.0 {
            let s = (norm_acc / dn) as f32;
            for v in dhat.iter_mut() {
                *v *= s;
            }
        }
        axpy(&mut self.w, 1.0, &dhat);

        Ok(RoundOutcome {
            train_loss: loss_sum / selected.len() as f64,
        })
    }

    fn model_for(&self, _k: usize) -> &[f32] {
        &self.w
    }
}
