//! OBCSAA (Fan et al. 2022): 1-bit compressed-sensing uplink, full-
//! precision downlink (Table 1 row 3).
//!
//! Re-implementation fidelity: clients upload the one-bit compressed
//! sketch sign(Φ Δ_k) (m bits) plus a 32-bit magnitude; the server
//! reconstructs with the adjoint estimator Δ̂ ∝ Φᵀ(Σ p_k z_k) — the first
//! iterate of BIHT and the standard one-bit-CS proxy when the support is
//! unknown — rescaled to the clients' reported update norm, then applies
//! it and broadcasts the full-precision model (uncompressed downlink, as
//! in the paper's table row).

use anyhow::Result;

use crate::algorithms::common::{axpy, delta, init_params, local_sgd};
use crate::algorithms::{
    AggKind, Algorithm, Capabilities, ClientCtx, ClientOutput, ClientStats, Downlink,
    InitCtx, RoundAggregator, RoundOutcome, ServerCtx, Uplink,
};
use crate::comm::Payload;
use crate::coordinator::parallel::thread_count;
use crate::sketch::bitpack::{ScalarTally, VoteAccumulator};
use crate::util::stats::l2_norm;

/// OBCS-AA (one-bit compressed sensing with adaptive aggregation):
/// sketched one-bit uplinks, server-side reconstruction — global model.
pub struct Obcsaa {
    w: Vec<f32>,
    /// sketch dimension m, fixed at init (sizes the per-round tally)
    m: usize,
}

impl Obcsaa {
    /// Fresh instance; state is sized at `init`.
    pub fn new() -> Self {
        Obcsaa { w: Vec::new(), m: 0 }
    }
}

impl Default for Obcsaa {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for Obcsaa {
    fn name(&self) -> &'static str {
        "obcsaa"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: true,
            upload_one_bit: true,
            download_dim_reduction: false,
            download_one_bit: false,
            personalization: false,
        }
    }

    fn init(&mut self, ctx: &InitCtx) -> Result<()> {
        self.w = init_params(ctx.model.geom.n, ctx.cfg.seed);
        self.m = ctx.projection.m();
        Ok(())
    }

    fn server_broadcast(&self, t: usize) -> Option<Downlink> {
        // full-precision model to each participant
        Some(Downlink::new(t, Payload::Dense(self.w.clone())))
    }

    fn client_round(
        &self,
        t: usize,
        k: usize,
        downlink: Option<&Downlink>,
        ctx: &mut ClientCtx,
    ) -> Result<ClientOutput> {
        let Some(Downlink { payload: Payload::Dense(w0), .. }) = downlink else {
            anyhow::bail!("obcsaa requires a dense model downlink");
        };
        let mut wk = w0.clone();
        let loss = local_sgd(ctx, k, &mut wk, t as u64)?;
        let d = delta(&wk, w0);
        let z = ctx.projection.sketch_sign_packed(&d);
        let norm = l2_norm(&d) as f32;
        Ok(ClientOutput {
            client: k,
            uplink: Some(Uplink::new(t, Payload::ScaledSigns { signs: z, scale: norm })),
            state: None,
            stats: ClientStats { loss },
        })
    }

    fn begin_aggregate(&self, _t: usize) -> RoundAggregator {
        // m-dim sketch tally (weight p_k per sketch) + the weighted
        // update-norm scalar the reconstruction rescales to
        RoundAggregator::new(AggKind::SketchSum {
            tally: VoteAccumulator::new(self.m),
            norm: ScalarTally::new(),
        })
    }

    fn finish_aggregate(
        &mut self,
        _t: usize,
        agg: RoundAggregator,
        ctx: &ServerCtx,
    ) -> Result<RoundOutcome> {
        let (kind, _, absorbed, outcome) = agg.into_parts();
        let AggKind::SketchSum { tally, norm } = kind else {
            anyhow::bail!("obcsaa aggregator must be the sketch-sum tally");
        };
        if absorbed > 0 {
            // one-bit CS reconstruction: adjoint estimate, rescaled to
            // the weighted-mean update norm. The aggregation phase is
            // serial, so the adjoint's n'-point transform runs on the
            // worker pool — bit-identical for any thread count
            // (DESIGN.md §10).
            let threads = thread_count(ctx.cfg.client_threads);
            let mut dhat = ctx.projection.adjoint_threaded(&tally.finish_sum(), threads);
            let dn = l2_norm(&dhat);
            if dn > 0.0 {
                let s = (norm.value() / dn) as f32;
                for v in dhat.iter_mut() {
                    *v *= s;
                }
            }
            axpy(&mut self.w, 1.0, &dhat);
        }
        Ok(outcome)
    }

    fn model_for(&self, _k: usize) -> &[f32] {
        &self.w
    }
}
