//! FedAvg (McMahan et al. 2017) — the uncompressed reference point.
//!
//! Per round: full-precision model broadcast to each participant (32n
//! bits each), R local SGD steps from the *delivered* copy, full-
//! precision upload, weighted server average over the delivered uploads.

use anyhow::Result;

use crate::algorithms::common::{init_params, local_sgd};
use crate::algorithms::{
    AggKind, Algorithm, Capabilities, ClientCtx, ClientOutput, ClientStats, Downlink,
    InitCtx, RoundAggregator, RoundOutcome, ServerCtx, Uplink,
};
use crate::comm::Payload;

/// FedAvg (McMahan et al.): the uncompressed full-precision
/// baseline every Table-2 cost reduction is measured against.
pub struct FedAvg {
    w: Vec<f32>,
}

impl FedAvg {
    /// Fresh instance; state is sized at `init`.
    pub fn new() -> Self {
        FedAvg { w: Vec::new() }
    }
}

impl Default for FedAvg {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: false,
            upload_one_bit: false,
            download_dim_reduction: false,
            download_one_bit: false,
            personalization: false,
        }
    }

    fn init(&mut self, ctx: &InitCtx) -> Result<()> {
        self.w = init_params(ctx.model.geom.n, ctx.cfg.seed);
        Ok(())
    }

    fn server_broadcast(&self, t: usize) -> Option<Downlink> {
        // full model to each participant, every round
        Some(Downlink::new(t, Payload::Dense(self.w.clone())))
    }

    fn client_round(
        &self,
        t: usize,
        k: usize,
        downlink: Option<&Downlink>,
        ctx: &mut ClientCtx,
    ) -> Result<ClientOutput> {
        let Some(Downlink { payload: Payload::Dense(w0), .. }) = downlink else {
            anyhow::bail!("fedavg requires a dense model downlink");
        };
        let mut wk = w0.clone();
        let loss = local_sgd(ctx, k, &mut wk, t as u64)?;
        Ok(ClientOutput {
            client: k,
            uplink: Some(Uplink::new(t, Payload::Dense(wk))),
            state: None,
            stats: ClientStats { loss },
        })
    }

    fn begin_aggregate(&self, _t: usize) -> RoundAggregator {
        // dense running sum Σ p_k w_k: one n-vector of state, each
        // delivered model folded on arrival and dropped
        RoundAggregator::new(AggKind::DenseSum(vec![0.0f32; self.w.len()]))
    }

    fn finish_aggregate(
        &mut self,
        _t: usize,
        agg: RoundAggregator,
        _ctx: &ServerCtx,
    ) -> Result<RoundOutcome> {
        let (kind, _, absorbed, outcome) = agg.into_parts();
        let AggKind::DenseSum(sum) = kind else {
            anyhow::bail!("fedavg aggregator must be the dense running sum");
        };
        // w ← Σ p_k w_k over the delivered set; a round that delivered
        // nothing keeps the current global model
        if absorbed > 0 {
            self.w = sum;
        }
        Ok(outcome)
    }

    fn model_for(&self, _k: usize) -> &[f32] {
        &self.w
    }

    fn snapshot(&self) -> (Vec<Vec<f32>>, Vec<f32>) {
        (vec![self.w.clone()], Vec::new())
    }

    fn restore(&mut self, models: Vec<Vec<f32>>, _consensus: Vec<f32>) -> Result<()> {
        anyhow::ensure!(models.len() == 1, "fedavg checkpoint holds one global model");
        self.w = models.into_iter().next().unwrap();
        Ok(())
    }
}
