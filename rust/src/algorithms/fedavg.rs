//! FedAvg (McMahan et al. 2017) — the uncompressed reference point.
//!
//! Per round: full-precision model broadcast to each participant (32n
//! bits each), R local SGD steps from the *delivered* copy, full-
//! precision upload, weighted server average over the delivered uploads.

use anyhow::Result;

use crate::algorithms::common::{init_params, local_sgd, weighted_mean};
use crate::algorithms::{
    Algorithm, Capabilities, ClientCtx, ClientOutput, ClientStats, Downlink, InitCtx,
    RoundOutcome, ServerCtx, Uplink,
};
use crate::comm::Payload;

pub struct FedAvg {
    w: Vec<f32>,
}

impl FedAvg {
    pub fn new() -> Self {
        FedAvg { w: Vec::new() }
    }
}

impl Default for FedAvg {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: false,
            upload_one_bit: false,
            download_dim_reduction: false,
            download_one_bit: false,
            personalization: false,
        }
    }

    fn init(&mut self, ctx: &InitCtx) -> Result<()> {
        self.w = init_params(ctx.model.geom.n, ctx.cfg.seed);
        Ok(())
    }

    fn server_broadcast(&self, t: usize) -> Option<Downlink> {
        // full model to each participant, every round
        Some(Downlink::new(t, Payload::Dense(self.w.clone())))
    }

    fn client_round(
        &self,
        t: usize,
        k: usize,
        downlink: Option<&Downlink>,
        ctx: &mut ClientCtx,
    ) -> Result<ClientOutput> {
        let Some(Downlink { payload: Payload::Dense(w0), .. }) = downlink else {
            anyhow::bail!("fedavg requires a dense model downlink");
        };
        let mut wk = w0.clone();
        let loss = local_sgd(ctx, k, &mut wk, t as u64)?;
        Ok(ClientOutput {
            client: k,
            uplink: Some(Uplink::new(t, Payload::Dense(wk))),
            state: None,
            stats: ClientStats { loss },
        })
    }

    fn server_aggregate(
        &mut self,
        _t: usize,
        _selected: &[usize],
        weights: &[f32],
        mut outputs: Vec<ClientOutput>,
        _ctx: &ServerCtx,
    ) -> Result<RoundOutcome> {
        let mut locals: Vec<Vec<f32>> = Vec::with_capacity(outputs.len());
        for out in outputs.iter_mut() {
            let Some(Uplink { payload: Payload::Dense(wk), .. }) = out.uplink.take() else {
                anyhow::bail!("fedavg uplink must be a dense payload");
            };
            locals.push(wk);
        }
        // server: w ← Σ p_k w_k
        self.w = weighted_mean(&locals, weights);
        Ok(RoundOutcome::from_outputs(&outputs))
    }

    fn model_for(&self, _k: usize) -> &[f32] {
        &self.w
    }

    fn snapshot(&self) -> (Vec<Vec<f32>>, Vec<f32>) {
        (vec![self.w.clone()], Vec::new())
    }

    fn restore(&mut self, models: Vec<Vec<f32>>, _consensus: Vec<f32>) -> Result<()> {
        anyhow::ensure!(models.len() == 1, "fedavg checkpoint holds one global model");
        self.w = models.into_iter().next().unwrap();
        Ok(())
    }
}
