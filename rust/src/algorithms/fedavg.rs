//! FedAvg (McMahan et al. 2017) — the uncompressed reference point.
//!
//! Per round: full-precision model broadcast to each participant (32n
//! bits each), R local SGD steps, full-precision upload, weighted server
//! average over the participants.

use anyhow::Result;

use crate::algorithms::common::{init_params, local_sgd, weighted_mean};
use crate::algorithms::{Algorithm, Capabilities, Ctx, RoundOutcome};
use crate::comm::Payload;

pub struct FedAvg {
    w: Vec<f32>,
}

impl FedAvg {
    pub fn new() -> Self {
        FedAvg { w: Vec::new() }
    }
}

impl Default for FedAvg {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: false,
            upload_one_bit: false,
            download_dim_reduction: false,
            download_one_bit: false,
            personalization: false,
        }
    }

    fn init(&mut self, ctx: &mut Ctx) -> Result<()> {
        self.w = init_params(ctx.model.geom.n, ctx.cfg.seed);
        Ok(())
    }

    fn round(
        &mut self,
        t: usize,
        selected: &[usize],
        weights: &[f32],
        ctx: &mut Ctx,
    ) -> Result<RoundOutcome> {
        let _ = t;
        // downlink: full model to each participant
        ctx.net
            .broadcast_downlink(&Payload::Dense(self.w.clone()), selected.len())?;

        let mut locals: Vec<Vec<f32>> = Vec::with_capacity(selected.len());
        let mut loss_sum = 0.0f64;
        for &k in selected {
            let mut wk = self.w.clone();
            loss_sum += local_sgd(ctx, k, &mut wk, t as u64)?;
            // uplink: full model back
            let delivered = ctx.net.send_uplink(&Payload::Dense(wk))?;
            let Payload::Dense(wk) = delivered else {
                anyhow::bail!("payload type changed in transit")
            };
            locals.push(wk);
        }

        // server: w ← Σ p_k w_k
        self.w = weighted_mean(&locals, weights);
        Ok(RoundOutcome {
            train_loss: loss_sum / selected.len() as f64,
        })
    }

    fn model_for(&self, _k: usize) -> &[f32] {
        &self.w
    }

    fn snapshot(&self) -> (Vec<Vec<f32>>, Vec<f32>) {
        (vec![self.w.clone()], Vec::new())
    }

    fn restore(&mut self, models: Vec<Vec<f32>>, _consensus: Vec<f32>) -> Result<()> {
        anyhow::ensure!(models.len() == 1, "fedavg checkpoint holds one global model");
        self.w = models.into_iter().next().unwrap();
        Ok(())
    }
}
