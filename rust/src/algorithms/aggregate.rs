//! Streaming per-round aggregation (DESIGN.md §9).
//!
//! The server never holds the cohort: [`Algorithm::begin_aggregate`]
//! hands the round engine a [`RoundAggregator`] whose state is O(m) (or
//! O(n) for the dense baseline), the engine [`absorb`]s each delivered
//! uplink the moment it arrives — dropping the payload immediately — and
//! [`Algorithm::finish_aggregate`] folds the closed aggregator into
//! server state. Sibling shards [`merge`]; the one-bit tallies are exact
//! 64.64 fixed point ([`VoteAccumulator`]), so absorb/merge order cannot
//! change a single bit of the vote.
//!
//! Who owns what: algorithms choose the [`AggKind`] and interpret it at
//! finish; the engine owns the absorb loop (arrival order), the
//! delivered-set weights, and the cut/write-back distinction
//! ([`absorb_cut`] keeps a straggler's personalized state — its local
//! model really did advance — while its late uplink never enters server
//! state).
//!
//! [`absorb`]: RoundAggregator::absorb
//! [`absorb_cut`]: RoundAggregator::absorb_cut
//! [`merge`]: RoundAggregator::merge
//! [`Algorithm::begin_aggregate`]: crate::algorithms::Algorithm::begin_aggregate
//! [`Algorithm::finish_aggregate`]: crate::algorithms::Algorithm::finish_aggregate

use anyhow::{bail, ensure, Result};

use crate::algorithms::common::axpy;
use crate::algorithms::{ClientOutput, RoundOutcome};
use crate::comm::codec::{GroupFrame, TallyFrame, TallyFrameView};
use crate::comm::Payload;
use crate::sketch::bitpack::{GroupedTally, ScalarTally, VoteAccumulator};

/// The algorithm-specific accumulation state, O(payload length) each.
pub enum AggKind {
    /// No server-side accumulation: uplinks are silent, only
    /// personalized write-backs flow (LocalOnly).
    Passthrough,
    /// Weighted majority tally over `Signs` sketches (pFed1BS): the
    /// finish is the Lemma-1 vote.
    Vote(VoteAccumulator),
    /// Majority tally over `ScaledSigns` plus the exact weighted step
    /// scale Σ pₖ·cₖ (OBDA).
    ScaledVote { tally: VoteAccumulator, scale: ScalarTally },
    /// Linear one-bit estimator Σ pₖ·cₖ·zₖ over `ScaledSigns`
    /// (zSignFed, FedBAT, EDEN) — the scale folds into the tally weight.
    SignSum(VoteAccumulator),
    /// `SignSum` over the m-dim sketch plus the weighted update-norm
    /// scalar the reconstruction rescales to (OBCSAA).
    SketchSum { tally: VoteAccumulator, norm: ScalarTally },
    /// Dense weighted running sum Σ pₖ·wₖ over `Dense` uplinks (FedAvg).
    /// f32 lanes: NOT order-invariant — the engine's canonical arrival
    /// order is what makes this deterministic (DESIGN.md §9).
    DenseSum(Vec<f32>),
    /// Byzantine-robust vote over `Signs` sketches (DESIGN.md §16): each
    /// client's contribution lands in its identity bucket and the finish
    /// is the per-coordinate trimmed sum over the active buckets'
    /// exact i128 quanta. `trim_frac = 0` is bit-for-bit `Vote`.
    TrimmedVote {
        /// identity-bucketed group partials (one bucket per fleet
        /// client when built by pFed1BS, so trimming is per-client)
        tally: GroupedTally,
        /// fraction trimmed from each end of the sorted per-coordinate
        /// values at finish
        trim_frac: f64,
    },
    /// Median-of-means vote over `Signs` sketches (DESIGN.md §16): the
    /// finish signs the per-coordinate median of the group tallies.
    /// One group is bit-for-bit `Vote`.
    MedianOfMeans {
        /// the identity-bucketed group partials (client k → k mod G)
        groups: GroupedTally,
    },
}

/// A late uplink buffered across a round boundary (quorum mode,
/// DESIGN.md §13): the payload-plus-loss output (its personalized
/// write-back was already applied in its home round), the un-normalized
/// staleness-decayed mass it will carry, and how late it was.
#[derive(Debug)]
pub struct CarriedUplink {
    /// the late client's output: uplink payload + loss bookkeeping, with
    /// `state` already stripped (the write-back landed in the home round)
    pub out: ClientOutput,
    /// un-normalized carry mass `p_k · staleness_decay^age`; the
    /// coordinator divides by the next round's `norm_total` before
    /// absorbing
    pub raw_weight: f32,
    /// rounds late when it arrived (1 = missed its round's close by at
    /// most one deadline window)
    pub age: usize,
}

/// One round's streaming aggregation: the algorithm-specific tally plus
/// the bookkeeping every algorithm shares (delivered count, loss mean,
/// personalized write-backs) and the carry buffer of late uplinks bound
/// for round t+1.
pub struct RoundAggregator {
    kind: AggKind,
    /// personalized model write-backs (simulation bookkeeping, never
    /// transmitted): (client id, new local state)
    states: Vec<(usize, Vec<f32>)>,
    loss_sum: f64,
    absorbed: usize,
    /// late uplinks buffered for the NEXT round (DESIGN.md §13); the
    /// coordinator drains this via [`RoundAggregator::take_carry`]
    /// before the finish consumes the aggregator
    carry: Vec<CarriedUplink>,
}

impl RoundAggregator {
    /// Empty aggregator of the given kind (what `begin_aggregate` hands
    /// the round engine).
    pub fn new(kind: AggKind) -> RoundAggregator {
        RoundAggregator {
            kind,
            states: Vec::new(),
            loss_sum: 0.0,
            absorbed: 0,
            carry: Vec::new(),
        }
    }

    /// Sketches folded so far (delivered uplinks; cut stragglers and
    /// dropouts never count).
    pub fn absorbed(&self) -> usize {
        self.absorbed
    }

    /// Fold one *delivered* output with its delivered-set weight. The
    /// payload is consumed here and never stored; O(payload length).
    /// On `Err` the aggregator is untouched (no partial bookkeeping), so
    /// a caller may skip a malformed uplink and keep the round going.
    pub fn absorb(&mut self, out: ClientOutput, weight: f32) -> Result<()> {
        let ClientOutput { client, uplink, state, stats } = out;
        let payload = uplink.map(|u| u.payload);
        match (&mut self.kind, payload) {
            (AggKind::Passthrough, None) => {}
            (AggKind::Vote(tally), Some(Payload::Signs(z))) => {
                tally.absorb(&z, weight as f64);
            }
            (AggKind::TrimmedVote { tally, .. }, Some(Payload::Signs(z))) => {
                tally.absorb(client, &z, weight as f64);
            }
            (AggKind::MedianOfMeans { groups }, Some(Payload::Signs(z))) => {
                groups.absorb(client, &z, weight as f64);
            }
            (
                AggKind::ScaledVote { tally, scale },
                Some(Payload::ScaledSigns { signs, scale: c }),
            ) => {
                tally.absorb(&signs, weight as f64);
                scale.add(weight as f64 * c as f64);
            }
            (AggKind::SignSum(tally), Some(Payload::ScaledSigns { signs, scale: c })) => {
                tally.absorb(&signs, weight as f64 * c as f64);
            }
            (
                AggKind::SketchSum { tally, norm },
                Some(Payload::ScaledSigns { signs, scale: c }),
            ) => {
                // the sketch enters with its vote weight p_k alone; the
                // reported magnitude only shapes the rescale target
                tally.absorb(&signs, weight as f64);
                norm.add(weight as f64 * c as f64);
            }
            (AggKind::DenseSum(sum), Some(Payload::Dense(w))) => {
                ensure!(
                    w.len() == sum.len(),
                    "dense uplink length {} != aggregator length {}",
                    w.len(),
                    sum.len()
                );
                axpy(sum, weight, &w);
            }
            (_, payload) => bail!(
                "client {client}: uplink {} does not match this round's aggregator",
                payload.as_ref().map_or("<none>", payload_name)
            ),
        }
        // shared bookkeeping only after the payload was accepted, so an
        // Err above cannot inflate absorbed() or plant a phantom loss
        if let Some(w) = state {
            self.states.push((client, w));
        }
        self.loss_sum += stats.loss;
        self.absorbed += 1;
        Ok(())
    }

    /// A straggler cut by the deadline (or an arrival past the target
    /// count): its uplink never enters server state — but the client's
    /// own local model did advance, so the personalized write-back is
    /// kept. The payload is dropped (it was metered on the channel).
    pub fn absorb_cut(&mut self, out: ClientOutput) {
        if let Some(w) = out.state {
            self.states.push((out.client, w));
        }
    }

    /// A late-but-inside-`max_staleness` uplink (DESIGN.md §13): the
    /// personalized write-back is applied NOW — the client's local model
    /// really advanced this round — while the payload and loss wait in
    /// the carry buffer, to be absorbed into round t+1's aggregator at
    /// weight `raw_weight / norm_total(t+1)`. Like [`absorb_cut`], this
    /// touches none of the round's tally bookkeeping.
    ///
    /// [`absorb_cut`]: RoundAggregator::absorb_cut
    pub fn buffer_late(&mut self, mut out: ClientOutput, raw_weight: f32, age: usize) {
        if let Some(w) = out.state.take() {
            self.states.push((out.client, w));
        }
        self.carry.push(CarriedUplink { out, raw_weight, age });
    }

    /// Drain the buffered late uplinks (the coordinator stashes them for
    /// round t+1 after the shard merge, before the finish consumes the
    /// aggregator).
    pub fn take_carry(&mut self) -> Vec<CarriedUplink> {
        std::mem::take(&mut self.carry)
    }

    /// Σ un-normalized carry mass awaiting the next round.
    pub fn carry_mass(&self) -> f32 {
        self.carry.iter().map(|c| c.raw_weight).sum()
    }

    /// Encode this shard's server-state content as its edge→root merge
    /// frame (DESIGN.md §11): the fixed-point tally quanta plus the
    /// shard's round bookkeeping for the exact kinds
    /// ([`Payload::TallyFrame`]), the raw partial sum for `DenseSum`
    /// (`Payload::Dense`), `None` for `Passthrough` (an edge with
    /// nothing to report stays silent). Personalized write-backs are
    /// simulation bookkeeping and never travel in frames.
    pub fn merge_payload(&self) -> Option<Payload> {
        let tally_frame = |tally: &VoteAccumulator, scalar: i128| {
            Payload::TallyFrame(TallyFrame {
                absorbed: self.absorbed as u32,
                loss_sum: self.loss_sum,
                scalar,
                quanta: tally.quanta().to_vec(),
                groups: Vec::new(),
            })
        };
        // the robust kinds ship their per-group partials instead of the
        // flat quanta (tag-5 frames, DESIGN.md §16) — the root needs the
        // groups, not their sum, to trim or take medians exactly
        let grouped_frame = |tally: &GroupedTally| {
            Payload::TallyFrame(TallyFrame {
                absorbed: self.absorbed as u32,
                loss_sum: self.loss_sum,
                scalar: 0,
                quanta: Vec::new(),
                groups: tally
                    .groups()
                    .iter()
                    .map(|g| GroupFrame {
                        absorbed: g.absorbed() as u32,
                        quanta: g.quanta().to_vec(),
                    })
                    .collect(),
            })
        };
        match &self.kind {
            AggKind::Passthrough => None,
            AggKind::Vote(t) => Some(tally_frame(t, 0)),
            AggKind::ScaledVote { tally, scale } => Some(tally_frame(tally, scale.quanta())),
            AggKind::SignSum(t) => Some(tally_frame(t, 0)),
            AggKind::SketchSum { tally, norm } => Some(tally_frame(tally, norm.quanta())),
            AggKind::DenseSum(sum) => Some(Payload::Dense(sum.clone())),
            AggKind::TrimmedVote { tally, .. } => Some(grouped_frame(tally)),
            AggKind::MedianOfMeans { groups } => Some(grouped_frame(groups)),
        }
    }

    /// The root's side of [`RoundAggregator::merge_payload`] for the
    /// exact kinds: fold a decoded edge merge frame into this aggregator.
    /// Merging frames in canonical edge order is bit-identical to having
    /// absorbed every edge's uplinks locally — the same exactness
    /// argument as [`RoundAggregator::merge`]. `DenseSum` frames carry
    /// only the partial sum (no absorbed/loss bookkeeping), so they are
    /// rejected here; the in-process engine merges dense shards
    /// in-memory.
    pub fn absorb_frame(&mut self, payload: Payload) -> Result<()> {
        let Payload::TallyFrame(f) = payload else {
            bail!("absorb_frame needs a TallyFrame merge payload");
        };
        let adopt = |tally: &mut VoteAccumulator, f: &TallyFrame| -> Result<()> {
            ensure!(
                f.groups.is_empty(),
                "plain tally kinds do not accept grouped merge frames"
            );
            ensure!(
                f.quanta.len() == tally.m(),
                "merge frame has {} tallies, aggregator expects {}",
                f.quanta.len(),
                tally.m()
            );
            tally.merge(VoteAccumulator::from_quanta(
                f.quanta.clone(),
                f.absorbed as usize,
            ));
            Ok(())
        };
        // grouped (tag-5) frames fold group-by-group; all shape checks
        // run before any merge so an Err leaves the tally untouched
        let adopt_grouped = |tally: &mut GroupedTally, f: &TallyFrame| -> Result<()> {
            ensure!(f.scalar == 0, "unexpected scalar tally in grouped merge frame");
            ensure!(
                f.groups.len() == tally.group_count(),
                "merge frame has {} groups, aggregator expects {}",
                f.groups.len(),
                tally.group_count()
            );
            ensure!(
                f.groups.iter().all(|g| g.quanta.len() == tally.m()),
                "merge frame group length does not match aggregator m {}",
                tally.m()
            );
            for (g, grp) in f.groups.iter().enumerate() {
                tally.merge_group_quanta(g, grp.absorbed as usize, |i| grp.quanta[i]);
            }
            Ok(())
        };
        match &mut self.kind {
            AggKind::Vote(t) | AggKind::SignSum(t) => {
                ensure!(f.scalar == 0, "unexpected scalar tally in merge frame");
                adopt(t, &f)?;
            }
            AggKind::ScaledVote { tally, scale } => {
                adopt(tally, &f)?;
                scale.merge(ScalarTally::from_quanta(f.scalar));
            }
            AggKind::SketchSum { tally, norm } => {
                adopt(tally, &f)?;
                norm.merge(ScalarTally::from_quanta(f.scalar));
            }
            AggKind::TrimmedVote { tally, .. } => adopt_grouped(tally, &f)?,
            AggKind::MedianOfMeans { groups } => adopt_grouped(groups, &f)?,
            AggKind::Passthrough | AggKind::DenseSum(_) => {
                bail!("this aggregator kind does not accept tally merge frames")
            }
        }
        self.loss_sum += f.loss_sum;
        self.absorbed += f.absorbed as usize;
        Ok(())
    }

    /// Zero-copy twin of [`RoundAggregator::absorb_frame`]: fold an edge
    /// merge frame straight off its borrowed wire view, decoding each
    /// i128 quantum in place instead of materializing the quanta vector.
    /// Bit-identical to `absorb_frame(view.to_owned())` — both add
    /// exactly `quantum(i)` to tally slot i and the same scalar/loss/
    /// absorbed bookkeeping.
    pub fn absorb_frame_view(&mut self, f: &TallyFrameView<'_>) -> Result<()> {
        let adopt = |tally: &mut VoteAccumulator, f: &TallyFrameView<'_>| -> Result<()> {
            ensure!(
                f.group_count() == 0,
                "plain tally kinds do not accept grouped merge frames"
            );
            ensure!(
                f.quanta_len() == tally.m(),
                "merge frame has {} tallies, aggregator expects {}",
                f.quanta_len(),
                tally.m()
            );
            tally.merge_quanta(f.absorbed as usize, |i| f.quantum(i));
            Ok(())
        };
        let adopt_grouped = |tally: &mut GroupedTally, f: &TallyFrameView<'_>| -> Result<()> {
            ensure!(f.scalar == 0, "unexpected scalar tally in grouped merge frame");
            ensure!(
                f.group_count() == tally.group_count(),
                "merge frame has {} groups, aggregator expects {}",
                f.group_count(),
                tally.group_count()
            );
            ensure!(
                f.group_count() > 0 && f.m() == tally.m(),
                "merge frame group length {} does not match aggregator m {}",
                f.m(),
                tally.m()
            );
            for g in 0..f.group_count() {
                tally.merge_group_quanta(g, f.group_absorbed(g) as usize, |i| {
                    f.group_quantum(g, i)
                });
            }
            Ok(())
        };
        match &mut self.kind {
            AggKind::Vote(t) | AggKind::SignSum(t) => {
                ensure!(f.scalar == 0, "unexpected scalar tally in merge frame");
                adopt(t, f)?;
            }
            AggKind::ScaledVote { tally, scale } => {
                adopt(tally, f)?;
                scale.merge(ScalarTally::from_quanta(f.scalar));
            }
            AggKind::SketchSum { tally, norm } => {
                adopt(tally, f)?;
                norm.merge(ScalarTally::from_quanta(f.scalar));
            }
            AggKind::TrimmedVote { tally, .. } => adopt_grouped(tally, f)?,
            AggKind::MedianOfMeans { groups } => adopt_grouped(groups, f)?,
            AggKind::Passthrough | AggKind::DenseSum(_) => {
                bail!("this aggregator kind does not accept tally merge frames")
            }
        }
        self.loss_sum += f.loss_sum;
        self.absorbed += f.absorbed as usize;
        Ok(())
    }

    /// Fold a sibling shard of the same round. Exact for the fixed-point
    /// tallies; `DenseSum` shards add in call order (callers that need
    /// bit-reproducibility merge in canonical order — DESIGN.md §9).
    pub fn merge(&mut self, other: RoundAggregator) -> Result<()> {
        match (&mut self.kind, other.kind) {
            (AggKind::Passthrough, AggKind::Passthrough) => {}
            (AggKind::Vote(a), AggKind::Vote(b)) => a.merge(b),
            (
                AggKind::ScaledVote { tally: a, scale: sa },
                AggKind::ScaledVote { tally: b, scale: sb },
            ) => {
                a.merge(b);
                sa.merge(sb);
            }
            (AggKind::SignSum(a), AggKind::SignSum(b)) => a.merge(b),
            (
                AggKind::SketchSum { tally: a, norm: na },
                AggKind::SketchSum { tally: b, norm: nb },
            ) => {
                a.merge(b);
                na.merge(nb);
            }
            (AggKind::DenseSum(a), AggKind::DenseSum(b)) => {
                ensure!(a.len() == b.len(), "merging dense sums of different lengths");
                axpy(a, 1.0, &b);
            }
            (
                AggKind::TrimmedVote { tally: a, trim_frac: fa },
                AggKind::TrimmedVote { tally: b, trim_frac: fb },
            ) => {
                ensure!(
                    fa.to_bits() == fb.to_bits(),
                    "merging trimmed votes with different trim fractions"
                );
                ensure!(
                    a.group_count() == b.group_count(),
                    "merging grouped tallies with different group counts"
                );
                a.merge(b);
            }
            (AggKind::MedianOfMeans { groups: a }, AggKind::MedianOfMeans { groups: b }) => {
                ensure!(
                    a.group_count() == b.group_count(),
                    "merging grouped tallies with different group counts"
                );
                a.merge(b);
            }
            _ => bail!("merging aggregators of different kinds"),
        }
        self.states.extend(other.states);
        self.loss_sum += other.loss_sum;
        self.absorbed += other.absorbed;
        // carry buffers concatenate; merging shards in canonical edge
        // order keeps the carried absorb order deterministic next round
        self.carry.extend(other.carry);
        Ok(())
    }

    /// Decompose for the finish phase: (tally, personalized write-backs,
    /// delivered count, round outcome). The outcome's `train_loss` is
    /// the mean round-start loss over the *delivered* set — the server's
    /// honest view (0.0 when nothing was delivered).
    pub fn into_parts(self) -> (AggKind, Vec<(usize, Vec<f32>)>, usize, RoundOutcome) {
        let outcome = RoundOutcome {
            train_loss: if self.absorbed == 0 {
                0.0
            } else {
                self.loss_sum / self.absorbed as f64
            },
        };
        (self.kind, self.states, self.absorbed, outcome)
    }
}

fn payload_name(p: &Payload) -> &'static str {
    match p {
        Payload::Dense(_) => "Dense",
        Payload::Signs(_) => "Signs",
        Payload::ScaledSigns { .. } => "ScaledSigns",
        Payload::TallyFrame(_) => "TallyFrame",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{ClientStats, Uplink};
    use crate::sketch::bitpack::{majority_vote_weighted, SignVec};

    fn out(client: usize, payload: Option<Payload>, loss: f64) -> ClientOutput {
        ClientOutput {
            client,
            uplink: payload.map(|p| Uplink::new(0, p)),
            state: Some(vec![client as f32]),
            stats: ClientStats { loss },
        }
    }

    #[test]
    fn vote_aggregator_streams_and_reports() {
        let z0 = SignVec::from_signs(&[1.0, -1.0, 1.0]);
        let z1 = SignVec::from_signs(&[-1.0, -1.0, 1.0]);
        let mut agg = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(3)));
        agg.absorb(out(0, Some(Payload::Signs(z0.clone())), 1.0), 0.75).unwrap();
        agg.absorb(out(1, Some(Payload::Signs(z1.clone())), 3.0), 0.25).unwrap();
        assert_eq!(agg.absorbed(), 2);
        let (kind, states, absorbed, outcome) = agg.into_parts();
        assert_eq!(absorbed, 2);
        assert!((outcome.train_loss - 2.0).abs() < 1e-12);
        assert_eq!(states, vec![(0, vec![0.0]), (1, vec![1.0])]);
        let AggKind::Vote(tally) = kind else { panic!("wrong kind") };
        assert_eq!(
            tally.finish(),
            majority_vote_weighted(&[z0, z1], &[0.75, 0.25], 3)
        );
    }

    #[test]
    fn mismatched_payload_is_an_error_and_leaves_the_aggregator_untouched() {
        let mut agg = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(3)));
        let dense = out(0, Some(Payload::Dense(vec![1.0, 2.0, 3.0])), 5.0);
        assert!(agg.absorb(dense, 1.0).is_err());
        // no partial bookkeeping: the rejected client must not count
        assert_eq!(agg.absorbed(), 0);
        let (_, states, _, outcome) = agg.into_parts();
        assert!(states.is_empty(), "rejected uplink planted a write-back");
        assert_eq!(outcome.train_loss, 0.0, "rejected uplink planted a loss");
        let mut pass = RoundAggregator::new(AggKind::Passthrough);
        let signs = out(0, Some(Payload::Signs(SignVec::from_signs(&[1.0]))), 0.0);
        assert!(pass.absorb(signs, 1.0).is_err());
    }

    #[test]
    fn cut_stragglers_keep_write_backs_only() {
        let mut agg = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        agg.absorb_cut(out(7, Some(Payload::Signs(SignVec::from_signs(&[1.0, 1.0]))), 5.0));
        assert_eq!(agg.absorbed(), 0);
        let (kind, states, absorbed, outcome) = agg.into_parts();
        assert_eq!((absorbed, outcome.train_loss), (0, 0.0));
        assert_eq!(states, vec![(7, vec![7.0])]);
        let AggKind::Vote(tally) = kind else { panic!() };
        assert_eq!(tally.absorbed(), 0, "cut uplink must not enter the tally");
    }

    #[test]
    fn buffered_late_uplinks_keep_write_backs_now_and_payloads_for_later() {
        let z = SignVec::from_signs(&[1.0, -1.0]);
        let mut agg = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        agg.buffer_late(out(7, Some(Payload::Signs(z.clone())), 5.0), 0.125, 1);
        // nothing entered this round's tally or loss bookkeeping …
        assert_eq!(agg.absorbed(), 0);
        assert!((agg.carry_mass() - 0.125).abs() < 1e-9);
        let carried = agg.take_carry();
        assert_eq!(agg.carry_mass(), 0.0, "take_carry drains the buffer");
        let (kind, states, absorbed, outcome) = agg.into_parts();
        assert_eq!((absorbed, outcome.train_loss), (0, 0.0));
        // … but the write-back landed in the home round
        assert_eq!(states, vec![(7, vec![7.0])]);
        let AggKind::Vote(tally) = kind else { panic!() };
        assert_eq!(tally.absorbed(), 0);

        // the carried output absorbs into a FRESH aggregator exactly
        // like a direct absorb at the same weight (state stays stripped)
        let [c] = carried.try_into().unwrap_or_else(|_| panic!("one carried uplink"));
        assert_eq!((c.raw_weight, c.age), (0.125, 1));
        assert!(c.out.state.is_none(), "write-back must not replay next round");
        let mut next = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        next.absorb(c.out, 0.25).unwrap();
        let mut direct = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        let mut d = out(7, Some(Payload::Signs(z)), 5.0);
        d.state = None;
        direct.absorb(d, 0.25).unwrap();
        let (AggKind::Vote(ta), _, 1, oa) = next.into_parts() else { panic!() };
        let (AggKind::Vote(tb), _, 1, ob) = direct.into_parts() else { panic!() };
        assert_eq!(ta.quanta(), tb.quanta(), "carried absorb must be the same quanta");
        assert_eq!(oa.train_loss.to_bits(), ob.train_loss.to_bits());
    }

    #[test]
    fn merging_shards_concatenates_carry_buffers() {
        let z = SignVec::from_signs(&[1.0, -1.0]);
        let mut a = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        a.buffer_late(out(1, Some(Payload::Signs(z.clone())), 0.0), 0.5, 1);
        let mut b = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        b.buffer_late(out(2, Some(Payload::Signs(z)), 0.0), 0.25, 2);
        a.merge(b).unwrap();
        let carried = a.take_carry();
        let ids: Vec<usize> = carried.iter().map(|c| c.out.client).collect();
        assert_eq!(ids, vec![1, 2], "canonical merge order is preserved");
        assert_eq!(carried[1].age, 2);
    }

    #[test]
    fn merge_requires_matching_kinds_and_is_exact() {
        let z = SignVec::from_signs(&[1.0, -1.0]);
        let mut a = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        a.absorb(out(0, Some(Payload::Signs(z.clone())), 1.0), 0.5).unwrap();
        let mut b = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        b.absorb(out(1, Some(Payload::Signs(z.clone())), 2.0), 0.5).unwrap();
        a.merge(b).unwrap();
        assert_eq!(a.absorbed(), 2);
        let c = RoundAggregator::new(AggKind::Passthrough);
        assert!(a.merge(c).is_err());
    }

    #[test]
    fn merge_frame_round_trip_is_bit_identical_to_in_memory_merge() {
        use crate::comm::codec::{decode, encode};
        use crate::sketch::bitpack::ScalarTally;
        // an edge shard absorbs two scaled uplinks; the root folds the
        // shard's DECODED wire frame and must land on exactly the state
        // an in-memory merge produces
        let mk = |c: usize, s: &[f32], scale: f32, loss: f64| ClientOutput {
            client: c,
            uplink: Some(Uplink::new(
                0,
                Payload::ScaledSigns { signs: SignVec::from_signs(s), scale },
            )),
            state: None,
            stats: ClientStats { loss },
        };
        let fresh = || {
            RoundAggregator::new(AggKind::ScaledVote {
                tally: VoteAccumulator::new(3),
                scale: ScalarTally::new(),
            })
        };
        let mut shard = fresh();
        shard.absorb(mk(0, &[1.0, -1.0, 1.0], 0.5, 2.0), 0.75).unwrap();
        shard.absorb(mk(1, &[-1.0, -1.0, 1.0], 2.0, 4.0), 0.25).unwrap();

        let frame = shard.merge_payload().expect("scaled vote ships a frame");
        let delivered = decode(&encode(&frame)).unwrap();

        let mut via_frame = fresh();
        via_frame.absorb_frame(delivered).unwrap();
        let mut via_merge = fresh();
        via_merge.merge(shard).unwrap();

        assert_eq!(via_frame.absorbed(), 2);
        let (AggKind::ScaledVote { tally: ta, scale: sa }, _, 2, oa) =
            via_frame.into_parts()
        else {
            panic!("kind changed")
        };
        let (AggKind::ScaledVote { tally: tb, scale: sb }, _, 2, ob) =
            via_merge.into_parts()
        else {
            panic!("kind changed")
        };
        assert_eq!(ta.quanta(), tb.quanta(), "wire frame altered the tally");
        assert_eq!(sa.quanta(), sb.quanta());
        assert_eq!(oa.train_loss.to_bits(), ob.train_loss.to_bits());
    }

    #[test]
    fn absorb_frame_view_is_bit_identical_to_owned_absorb_frame() {
        use crate::comm::codec::{encode, PayloadView};
        use crate::sketch::bitpack::ScalarTally;
        let mk = |c: usize, s: &[f32], scale: f32, loss: f64| ClientOutput {
            client: c,
            uplink: Some(Uplink::new(
                0,
                Payload::ScaledSigns { signs: SignVec::from_signs(s), scale },
            )),
            state: None,
            stats: ClientStats { loss },
        };
        let fresh = || {
            RoundAggregator::new(AggKind::ScaledVote {
                tally: VoteAccumulator::new(3),
                scale: ScalarTally::new(),
            })
        };
        let mut shard = fresh();
        shard.absorb(mk(0, &[1.0, -1.0, 1.0], 0.5, 2.0), 0.75).unwrap();
        shard.absorb(mk(1, &[-1.0, -1.0, 1.0], 2.0, 4.0), 0.25).unwrap();
        let bytes = encode(&shard.merge_payload().unwrap());

        let mut via_owned = fresh();
        via_owned.absorb_frame(crate::comm::codec::decode(&bytes).unwrap()).unwrap();
        let mut via_view = fresh();
        let Ok(PayloadView::TallyFrame(view)) = Payload::decode_borrowed(&bytes) else {
            panic!("merge frame must decode as a tally view")
        };
        via_view.absorb_frame_view(&view).unwrap();

        let (AggKind::ScaledVote { tally: ta, scale: sa }, _, 2, oa) =
            via_owned.into_parts()
        else {
            panic!("kind changed")
        };
        let (AggKind::ScaledVote { tally: tb, scale: sb }, _, 2, ob) =
            via_view.into_parts()
        else {
            panic!("kind changed")
        };
        assert_eq!(ta.quanta(), tb.quanta(), "view absorb altered the tally");
        assert_eq!((ta.absorbed(), sa.quanta()), (tb.absorbed(), sb.quanta()));
        assert_eq!(oa.train_loss.to_bits(), ob.train_loss.to_bits());

        // the view path enforces the same guards as the owned path
        let mut wrong_m = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(7)));
        assert!(wrong_m.absorb_frame_view(&view).is_err());
        assert_eq!(wrong_m.absorbed(), 0, "failed adopt must stay untouched");
        let mut dense = RoundAggregator::new(AggKind::DenseSum(vec![0.0; 3]));
        assert!(dense.absorb_frame_view(&view).is_err());
    }

    #[test]
    fn merge_frames_reject_mismatched_kinds_and_passthrough_is_silent() {
        let pass = RoundAggregator::new(AggKind::Passthrough);
        assert!(pass.merge_payload().is_none(), "nothing to report");
        // dense shards ship raw sums, which absorb_frame cannot adopt
        let dense = RoundAggregator::new(AggKind::DenseSum(vec![0.5, 1.5]));
        let Some(Payload::Dense(sum)) = dense.merge_payload() else {
            panic!("dense shard must ship its partial sum")
        };
        assert_eq!(sum, vec![0.5, 1.5]);
        let mut root = RoundAggregator::new(AggKind::DenseSum(vec![0.0, 0.0]));
        let vote_shard = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        let frame = vote_shard.merge_payload().unwrap();
        assert!(root.absorb_frame(frame.clone()).is_err());
        // length mismatch is an error, and the failed adopt leaves the
        // receiving aggregator's bookkeeping untouched
        let mut short = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(5)));
        assert!(short.absorb_frame(frame).is_err());
        assert_eq!(short.absorbed(), 0);
    }

    #[test]
    fn robust_kinds_stream_and_reduce_to_vote_when_disarmed() {
        // trim=0 and groups=1 must leave the robust kinds bit-for-bit
        // equal to today's Vote on the same uplinks
        let zs: Vec<SignVec> = [
            &[1.0f32, -1.0, 1.0][..],
            &[-1.0, -1.0, 1.0],
            &[1.0, 1.0, -1.0],
        ]
        .iter()
        .map(|s| SignVec::from_signs(s))
        .collect();
        let weights = [0.5f32, 0.25, 0.25];

        let mut vote = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(3)));
        let mut trimmed = RoundAggregator::new(AggKind::TrimmedVote {
            tally: GroupedTally::new(3, 3),
            trim_frac: 0.0,
        });
        let mut mom = RoundAggregator::new(AggKind::MedianOfMeans {
            groups: GroupedTally::new(3, 1),
        });
        for (k, (z, &w)) in zs.iter().zip(&weights).enumerate() {
            for agg in [&mut vote, &mut trimmed, &mut mom] {
                agg.absorb(out(k, Some(Payload::Signs(z.clone())), 1.0), w).unwrap();
            }
        }
        let (AggKind::Vote(v), _, 3, _) = vote.into_parts() else { panic!() };
        let (AggKind::TrimmedVote { tally: t, .. }, _, 3, _) = trimmed.into_parts() else {
            panic!()
        };
        let (AggKind::MedianOfMeans { groups: g }, _, 3, _) = mom.into_parts() else {
            panic!()
        };
        assert_eq!(t.total_quanta(), v.quanta(), "grouped total != vote quanta");
        assert_eq!(t.finish_trimmed(0.0), v.finish());
        assert_eq!(g.finish_median(), v.finish());
    }

    #[test]
    fn grouped_merge_frame_round_trip_is_bit_identical_to_in_memory_merge() {
        use crate::comm::codec::{decode, encode, PayloadView};
        // an edge shard absorbs three clients into a 2-group tally; the
        // root folding the shard's wire frame (owned AND borrowed) must
        // land on exactly the in-memory merge's per-group quanta
        let zs: Vec<SignVec> = [
            &[1.0f32, -1.0, 1.0][..],
            &[-1.0, -1.0, 1.0],
            &[1.0, 1.0, -1.0],
        ]
        .iter()
        .map(|s| SignVec::from_signs(s))
        .collect();
        let fresh = || {
            RoundAggregator::new(AggKind::TrimmedVote {
                tally: GroupedTally::new(3, 2),
                trim_frac: 0.25,
            })
        };
        let mut shard = fresh();
        for (k, z) in zs.iter().enumerate() {
            let mut o = out(k, Some(Payload::Signs(z.clone())), 2.0);
            o.state = None;
            shard.absorb(o, 0.25 + k as f32 * 0.25).unwrap();
        }
        let frame = shard.merge_payload().expect("robust kinds ship a frame");
        let bytes = encode(&frame);

        let mut via_owned = fresh();
        via_owned.absorb_frame(decode(&bytes).unwrap()).unwrap();
        let mut via_view = fresh();
        let Ok(PayloadView::TallyFrame(view)) = Payload::decode_borrowed(&bytes) else {
            panic!("grouped merge frame must decode as a tally view")
        };
        via_view.absorb_frame_view(&view).unwrap();
        let mut via_merge = fresh();
        via_merge.merge(shard).unwrap();

        let unpack = |agg: RoundAggregator| {
            let (AggKind::TrimmedVote { tally, .. }, _, 3, o) = agg.into_parts() else {
                panic!("kind changed")
            };
            (tally, o)
        };
        let (ta, oa) = unpack(via_owned);
        let (tb, ob) = unpack(via_merge);
        let (tc, oc) = unpack(via_view);
        for (x, y) in [(&ta, &tb), (&tc, &tb)] {
            for (ga, gb) in x.groups().iter().zip(y.groups()) {
                assert_eq!(ga.quanta(), gb.quanta(), "wire frame altered a group");
                assert_eq!(ga.absorbed(), gb.absorbed());
            }
        }
        assert_eq!(oa.train_loss.to_bits(), ob.train_loss.to_bits());
        assert_eq!(oc.train_loss.to_bits(), ob.train_loss.to_bits());

        // shape guards: wrong group count, wrong m, plain kinds
        let mut wrong_g = RoundAggregator::new(AggKind::MedianOfMeans {
            groups: GroupedTally::new(3, 5),
        });
        assert!(wrong_g.absorb_frame_view(&view).is_err());
        assert_eq!(wrong_g.absorbed(), 0, "failed adopt must stay untouched");
        let mut wrong_m = RoundAggregator::new(AggKind::TrimmedVote {
            tally: GroupedTally::new(7, 2),
            trim_frac: 0.25,
        });
        assert!(wrong_m.absorb_frame(decode(&bytes).unwrap()).is_err());
        let mut plain = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(3)));
        assert!(plain.absorb_frame(decode(&bytes).unwrap()).is_err());
        assert!(plain.absorb_frame_view(&view).is_err());
        assert_eq!(plain.absorbed(), 0);
    }

    #[test]
    fn robust_merges_require_matching_shapes() {
        let a = || AggKind::TrimmedVote {
            tally: GroupedTally::new(2, 3),
            trim_frac: 0.2,
        };
        let mut base = RoundAggregator::new(a());
        base.merge(RoundAggregator::new(a())).unwrap();
        // a different trim fraction is a config split, not a shard
        let other = RoundAggregator::new(AggKind::TrimmedVote {
            tally: GroupedTally::new(2, 3),
            trim_frac: 0.3,
        });
        assert!(base.merge(other).is_err());
        // a different group count can't fold group-by-group
        let wrong_g = RoundAggregator::new(AggKind::MedianOfMeans {
            groups: GroupedTally::new(2, 4),
        });
        let mut mom = RoundAggregator::new(AggKind::MedianOfMeans {
            groups: GroupedTally::new(2, 3),
        });
        assert!(mom.merge(wrong_g).is_err());
        // and robust kinds never merge into plain Vote
        let mut vote = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        assert!(vote.merge(RoundAggregator::new(a())).is_err());
    }

    #[test]
    fn dense_sum_accumulates_weighted_models() {
        let mut agg = RoundAggregator::new(AggKind::DenseSum(vec![0.0f32; 2]));
        let mk = |c, v: Vec<f32>| ClientOutput {
            client: c,
            uplink: Some(Uplink::new(0, Payload::Dense(v))),
            state: None,
            stats: ClientStats::default(),
        };
        agg.absorb(mk(0, vec![1.0, 0.0]), 0.25).unwrap();
        agg.absorb(mk(1, vec![0.0, 1.0]), 0.75).unwrap();
        let (AggKind::DenseSum(sum), _, 2, _) = agg.into_parts() else { panic!() };
        assert_eq!(sum, vec![0.25, 0.75]);
    }
}
