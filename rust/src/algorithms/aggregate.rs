//! Streaming per-round aggregation (DESIGN.md §9).
//!
//! The server never holds the cohort: [`Algorithm::begin_aggregate`]
//! hands the round engine a [`RoundAggregator`] whose state is O(m) (or
//! O(n) for the dense baseline), the engine [`absorb`]s each delivered
//! uplink the moment it arrives — dropping the payload immediately — and
//! [`Algorithm::finish_aggregate`] folds the closed aggregator into
//! server state. Sibling shards [`merge`]; the one-bit tallies are exact
//! 64.64 fixed point ([`VoteAccumulator`]), so absorb/merge order cannot
//! change a single bit of the vote.
//!
//! Who owns what: algorithms choose the [`AggKind`] and interpret it at
//! finish; the engine owns the absorb loop (arrival order), the
//! delivered-set weights, and the cut/write-back distinction
//! ([`absorb_cut`] keeps a straggler's personalized state — its local
//! model really did advance — while its late uplink never enters server
//! state).
//!
//! [`absorb`]: RoundAggregator::absorb
//! [`absorb_cut`]: RoundAggregator::absorb_cut
//! [`merge`]: RoundAggregator::merge
//! [`Algorithm::begin_aggregate`]: crate::algorithms::Algorithm::begin_aggregate
//! [`Algorithm::finish_aggregate`]: crate::algorithms::Algorithm::finish_aggregate

use anyhow::{bail, ensure, Result};

use crate::algorithms::common::axpy;
use crate::algorithms::{ClientOutput, RoundOutcome};
use crate::comm::Payload;
use crate::sketch::bitpack::{ScalarTally, VoteAccumulator};

/// The algorithm-specific accumulation state, O(payload length) each.
pub enum AggKind {
    /// No server-side accumulation: uplinks are silent, only
    /// personalized write-backs flow (LocalOnly).
    Passthrough,
    /// Weighted majority tally over `Signs` sketches (pFed1BS): the
    /// finish is the Lemma-1 vote.
    Vote(VoteAccumulator),
    /// Majority tally over `ScaledSigns` plus the exact weighted step
    /// scale Σ pₖ·cₖ (OBDA).
    ScaledVote { tally: VoteAccumulator, scale: ScalarTally },
    /// Linear one-bit estimator Σ pₖ·cₖ·zₖ over `ScaledSigns`
    /// (zSignFed, FedBAT, EDEN) — the scale folds into the tally weight.
    SignSum(VoteAccumulator),
    /// `SignSum` over the m-dim sketch plus the weighted update-norm
    /// scalar the reconstruction rescales to (OBCSAA).
    SketchSum { tally: VoteAccumulator, norm: ScalarTally },
    /// Dense weighted running sum Σ pₖ·wₖ over `Dense` uplinks (FedAvg).
    /// f32 lanes: NOT order-invariant — the engine's canonical arrival
    /// order is what makes this deterministic (DESIGN.md §9).
    DenseSum(Vec<f32>),
}

/// One round's streaming aggregation: the algorithm-specific tally plus
/// the bookkeeping every algorithm shares (delivered count, loss mean,
/// personalized write-backs).
pub struct RoundAggregator {
    kind: AggKind,
    /// personalized model write-backs (simulation bookkeeping, never
    /// transmitted): (client id, new local state)
    states: Vec<(usize, Vec<f32>)>,
    loss_sum: f64,
    absorbed: usize,
}

impl RoundAggregator {
    pub fn new(kind: AggKind) -> RoundAggregator {
        RoundAggregator { kind, states: Vec::new(), loss_sum: 0.0, absorbed: 0 }
    }

    /// Sketches folded so far (delivered uplinks; cut stragglers and
    /// dropouts never count).
    pub fn absorbed(&self) -> usize {
        self.absorbed
    }

    /// Fold one *delivered* output with its delivered-set weight. The
    /// payload is consumed here and never stored; O(payload length).
    /// On `Err` the aggregator is untouched (no partial bookkeeping), so
    /// a caller may skip a malformed uplink and keep the round going.
    pub fn absorb(&mut self, out: ClientOutput, weight: f32) -> Result<()> {
        let ClientOutput { client, uplink, state, stats } = out;
        let payload = uplink.map(|u| u.payload);
        match (&mut self.kind, payload) {
            (AggKind::Passthrough, None) => {}
            (AggKind::Vote(tally), Some(Payload::Signs(z))) => {
                tally.absorb(&z, weight as f64);
            }
            (
                AggKind::ScaledVote { tally, scale },
                Some(Payload::ScaledSigns { signs, scale: c }),
            ) => {
                tally.absorb(&signs, weight as f64);
                scale.add(weight as f64 * c as f64);
            }
            (AggKind::SignSum(tally), Some(Payload::ScaledSigns { signs, scale: c })) => {
                tally.absorb(&signs, weight as f64 * c as f64);
            }
            (
                AggKind::SketchSum { tally, norm },
                Some(Payload::ScaledSigns { signs, scale: c }),
            ) => {
                // the sketch enters with its vote weight p_k alone; the
                // reported magnitude only shapes the rescale target
                tally.absorb(&signs, weight as f64);
                norm.add(weight as f64 * c as f64);
            }
            (AggKind::DenseSum(sum), Some(Payload::Dense(w))) => {
                ensure!(
                    w.len() == sum.len(),
                    "dense uplink length {} != aggregator length {}",
                    w.len(),
                    sum.len()
                );
                axpy(sum, weight, &w);
            }
            (_, payload) => bail!(
                "client {client}: uplink {} does not match this round's aggregator",
                payload.as_ref().map_or("<none>", payload_name)
            ),
        }
        // shared bookkeeping only after the payload was accepted, so an
        // Err above cannot inflate absorbed() or plant a phantom loss
        if let Some(w) = state {
            self.states.push((client, w));
        }
        self.loss_sum += stats.loss;
        self.absorbed += 1;
        Ok(())
    }

    /// A straggler cut by the deadline (or an arrival past the target
    /// count): its uplink never enters server state — but the client's
    /// own local model did advance, so the personalized write-back is
    /// kept. The payload is dropped (it was metered on the channel).
    pub fn absorb_cut(&mut self, out: ClientOutput) {
        if let Some(w) = out.state {
            self.states.push((out.client, w));
        }
    }

    /// Fold a sibling shard of the same round. Exact for the fixed-point
    /// tallies; `DenseSum` shards add in call order (callers that need
    /// bit-reproducibility merge in canonical order — DESIGN.md §9).
    pub fn merge(&mut self, other: RoundAggregator) -> Result<()> {
        match (&mut self.kind, other.kind) {
            (AggKind::Passthrough, AggKind::Passthrough) => {}
            (AggKind::Vote(a), AggKind::Vote(b)) => a.merge(b),
            (
                AggKind::ScaledVote { tally: a, scale: sa },
                AggKind::ScaledVote { tally: b, scale: sb },
            ) => {
                a.merge(b);
                sa.merge(sb);
            }
            (AggKind::SignSum(a), AggKind::SignSum(b)) => a.merge(b),
            (
                AggKind::SketchSum { tally: a, norm: na },
                AggKind::SketchSum { tally: b, norm: nb },
            ) => {
                a.merge(b);
                na.merge(nb);
            }
            (AggKind::DenseSum(a), AggKind::DenseSum(b)) => {
                ensure!(a.len() == b.len(), "merging dense sums of different lengths");
                axpy(a, 1.0, &b);
            }
            _ => bail!("merging aggregators of different kinds"),
        }
        self.states.extend(other.states);
        self.loss_sum += other.loss_sum;
        self.absorbed += other.absorbed;
        Ok(())
    }

    /// Decompose for the finish phase: (tally, personalized write-backs,
    /// delivered count, round outcome). The outcome's `train_loss` is
    /// the mean round-start loss over the *delivered* set — the server's
    /// honest view (0.0 when nothing was delivered).
    pub fn into_parts(self) -> (AggKind, Vec<(usize, Vec<f32>)>, usize, RoundOutcome) {
        let outcome = RoundOutcome {
            train_loss: if self.absorbed == 0 {
                0.0
            } else {
                self.loss_sum / self.absorbed as f64
            },
        };
        (self.kind, self.states, self.absorbed, outcome)
    }
}

fn payload_name(p: &Payload) -> &'static str {
    match p {
        Payload::Dense(_) => "Dense",
        Payload::Signs(_) => "Signs",
        Payload::ScaledSigns { .. } => "ScaledSigns",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{ClientStats, Uplink};
    use crate::sketch::bitpack::{majority_vote_weighted, SignVec};

    fn out(client: usize, payload: Option<Payload>, loss: f64) -> ClientOutput {
        ClientOutput {
            client,
            uplink: payload.map(|p| Uplink::new(0, p)),
            state: Some(vec![client as f32]),
            stats: ClientStats { loss },
        }
    }

    #[test]
    fn vote_aggregator_streams_and_reports() {
        let z0 = SignVec::from_signs(&[1.0, -1.0, 1.0]);
        let z1 = SignVec::from_signs(&[-1.0, -1.0, 1.0]);
        let mut agg = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(3)));
        agg.absorb(out(0, Some(Payload::Signs(z0.clone())), 1.0), 0.75).unwrap();
        agg.absorb(out(1, Some(Payload::Signs(z1.clone())), 3.0), 0.25).unwrap();
        assert_eq!(agg.absorbed(), 2);
        let (kind, states, absorbed, outcome) = agg.into_parts();
        assert_eq!(absorbed, 2);
        assert!((outcome.train_loss - 2.0).abs() < 1e-12);
        assert_eq!(states, vec![(0, vec![0.0]), (1, vec![1.0])]);
        let AggKind::Vote(tally) = kind else { panic!("wrong kind") };
        assert_eq!(
            tally.finish(),
            majority_vote_weighted(&[z0, z1], &[0.75, 0.25], 3)
        );
    }

    #[test]
    fn mismatched_payload_is_an_error_and_leaves_the_aggregator_untouched() {
        let mut agg = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(3)));
        let dense = out(0, Some(Payload::Dense(vec![1.0, 2.0, 3.0])), 5.0);
        assert!(agg.absorb(dense, 1.0).is_err());
        // no partial bookkeeping: the rejected client must not count
        assert_eq!(agg.absorbed(), 0);
        let (_, states, _, outcome) = agg.into_parts();
        assert!(states.is_empty(), "rejected uplink planted a write-back");
        assert_eq!(outcome.train_loss, 0.0, "rejected uplink planted a loss");
        let mut pass = RoundAggregator::new(AggKind::Passthrough);
        let signs = out(0, Some(Payload::Signs(SignVec::from_signs(&[1.0]))), 0.0);
        assert!(pass.absorb(signs, 1.0).is_err());
    }

    #[test]
    fn cut_stragglers_keep_write_backs_only() {
        let mut agg = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        agg.absorb_cut(out(7, Some(Payload::Signs(SignVec::from_signs(&[1.0, 1.0]))), 5.0));
        assert_eq!(agg.absorbed(), 0);
        let (kind, states, absorbed, outcome) = agg.into_parts();
        assert_eq!((absorbed, outcome.train_loss), (0, 0.0));
        assert_eq!(states, vec![(7, vec![7.0])]);
        let AggKind::Vote(tally) = kind else { panic!() };
        assert_eq!(tally.absorbed(), 0, "cut uplink must not enter the tally");
    }

    #[test]
    fn merge_requires_matching_kinds_and_is_exact() {
        let z = SignVec::from_signs(&[1.0, -1.0]);
        let mut a = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        a.absorb(out(0, Some(Payload::Signs(z.clone())), 1.0), 0.5).unwrap();
        let mut b = RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(2)));
        b.absorb(out(1, Some(Payload::Signs(z.clone())), 2.0), 0.5).unwrap();
        a.merge(b).unwrap();
        assert_eq!(a.absorbed(), 2);
        let c = RoundAggregator::new(AggKind::Passthrough);
        assert!(a.merge(c).is_err());
    }

    #[test]
    fn dense_sum_accumulates_weighted_models() {
        let mut agg = RoundAggregator::new(AggKind::DenseSum(vec![0.0f32; 2]));
        let mk = |c, v: Vec<f32>| ClientOutput {
            client: c,
            uplink: Some(Uplink::new(0, Payload::Dense(v))),
            state: None,
            stats: ClientStats::default(),
        };
        agg.absorb(mk(0, vec![1.0, 0.0]), 0.25).unwrap();
        agg.absorb(mk(1, vec![0.0, 1.0]), 0.75).unwrap();
        let (AggKind::DenseSum(sum), _, 2, _) = agg.into_parts() else { panic!() };
        assert_eq!(sum, vec![0.25, 0.75]);
    }
}
