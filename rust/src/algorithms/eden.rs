//! EDEN (Vargaftik et al. 2022): communication-efficient distributed mean
//! estimation via random rotation + scalar quantization.
//!
//! Re-implementation fidelity (1-bit configuration): each client rotates
//! its update with the shared structured rotation H·D (the same FWHT
//! substrate as the paper's sketch, no subsampling), quantizes every
//! rotated coordinate to ±1, and computes the scale that makes the
//! estimate unbiased for a rotation-invariant distribution:
//!     α = E|y| (mean absolute rotated coordinate)
//! so  E[α·sign(y)] ≈ y  coordinate-wise after averaging. The server
//! de-rotates the scaled signs and averages. Uplink: n' bits + one f32.
//! Downlink: full-precision model (EDEN is a DME/uplink scheme).

use anyhow::Result;

use crate::algorithms::common::{axpy, delta, init_params, local_sgd, mean_abs};
use crate::algorithms::{
    AggKind, Algorithm, Capabilities, ClientCtx, ClientOutput, ClientStats, Downlink,
    InitCtx, RoundAggregator, RoundOutcome, ServerCtx, Uplink,
};
use crate::comm::Payload;
use crate::coordinator::parallel::thread_count;
use crate::sketch::bitpack::{SignVec, VoteAccumulator};
use crate::sketch::SrhtOperator;

/// EDEN (Vargaftik et al.): unbiased one-bit DME over a shared
/// random rotation — global model, rotated scaled-sign uplinks.
pub struct Eden {
    w: Vec<f32>,
    /// shared rotation (built at init from the run seed)
    rot: Option<SrhtOperator>,
}

impl Eden {
    /// Fresh instance; state is sized at `init`.
    pub fn new() -> Self {
        Eden { w: Vec::new(), rot: None }
    }

    fn rot(&self) -> &SrhtOperator {
        self.rot.as_ref().expect("init not called")
    }
}

impl Default for Eden {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for Eden {
    fn name(&self) -> &'static str {
        "eden"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: false,
            upload_one_bit: true,
            download_dim_reduction: false,
            download_one_bit: false,
            personalization: false,
        }
    }

    fn init(&mut self, ctx: &InitCtx) -> Result<()> {
        let n = ctx.model.geom.n;
        self.w = init_params(n, ctx.cfg.seed);
        // m is irrelevant for the rotation; reuse the SRHT plumbing
        self.rot = Some(SrhtOperator::from_seed(
            ctx.cfg.seed ^ 0xEDE7,
            n,
            1.max(n / 10),
        ));
        Ok(())
    }

    fn server_broadcast(&self, t: usize) -> Option<Downlink> {
        Some(Downlink::new(t, Payload::Dense(self.w.clone())))
    }

    fn client_round(
        &self,
        t: usize,
        k: usize,
        downlink: Option<&Downlink>,
        ctx: &mut ClientCtx,
    ) -> Result<ClientOutput> {
        let Some(Downlink { payload: Payload::Dense(w0), .. }) = downlink else {
            anyhow::bail!("eden requires a dense model downlink");
        };
        let mut wk = w0.clone();
        let loss = local_sgd(ctx, k, &mut wk, t as u64)?;
        let d = delta(&wk, w0);
        // H·D·pad(Δ) (length n') borrowed straight from the plan
        // scratch — the rotated vector is never materialized here
        let (alpha, signs) = self
            .rot()
            .rotate_with(&d, |y| (mean_abs(y), SignVec::from_signs(y)));
        Ok(ClientOutput {
            client: k,
            uplink: Some(Uplink::new(t, Payload::ScaledSigns { signs, scale: alpha })),
            state: None,
            stats: ClientStats { loss },
        })
    }

    fn begin_aggregate(&self, _t: usize) -> RoundAggregator {
        // rotated-domain linear estimator over n' = npad coordinates
        RoundAggregator::new(AggKind::SignSum(VoteAccumulator::new(self.rot().npad)))
    }

    fn finish_aggregate(
        &mut self,
        _t: usize,
        agg: RoundAggregator,
        ctx: &ServerCtx,
    ) -> Result<RoundOutcome> {
        let (kind, _, absorbed, outcome) = agg.into_parts();
        let AggKind::SignSum(tally) = kind else {
            anyhow::bail!("eden aggregator must be the linear sign estimator");
        };
        if absorbed > 0 {
            // server: de-rotate the streamed estimate and step. The
            // aggregation phase is serial, so the n'-point de-rotation
            // runs on the worker pool — bit-identical for any thread
            // count (DESIGN.md §10).
            let threads = thread_count(ctx.cfg.client_threads);
            let dhat = self.rot().rotate_inverse_threaded(&tally.finish_sum(), threads);
            axpy(&mut self.w, 1.0, &dhat);
        }
        Ok(outcome)
    }

    fn model_for(&self, _k: usize) -> &[f32] {
        &self.w
    }
}
