//! Shared client-side routines: local SGD loops over the AOT artifacts,
//! parameter initialization, and update-vector helpers used by several
//! baselines.

use anyhow::Result;

use crate::algorithms::ClientCtx;
use crate::data::BatchIter;
use crate::util::rng::Rng;

/// Glorot-style init of the flat parameter vector. All algorithms start
/// from the same seed-derived w⁰ so comparisons share initial conditions.
pub fn init_params(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x494E_4954); // "INIT"
    let mut w = vec![0.0f32; n];
    // layer-agnostic small init; the MLP layers slice this buffer
    rng.fill_normal(&mut w, 0.05);
    w
}

/// R plain local SGD steps from `w` on client `k`'s data (every baseline's
/// ClientUpdate), with w device-resident across the steps (§Perf).
/// Batches draw from a sub-stream forked off the client's own RNG, so the
/// trajectory is a pure function of (seed, k, round) — parallel-safe.
/// Returns the round-start task loss (batch 0) — the Fig.-4 metric.
pub fn local_sgd(ctx: &mut ClientCtx, k: usize, w: &mut Vec<f32>, round: u64) -> Result<f64> {
    let cfg = ctx.cfg;
    let client = &ctx.data.clients[k];
    let mut batches = BatchIter::new(
        client,
        ctx.model.geom.train_batch,
        ctx.rng.fork(hash3(k as u64, round, 0x5347_4400)),
    );
    let (w_new, loss) = ctx.model.sgd_round(
        w,
        || {
            let (x, y) = batches.next_batch();
            (x.to_vec(), y.to_vec())
        },
        cfg.local_steps,
        cfg.eta,
        cfg.mu,
    )?;
    *w = w_new;
    Ok(loss as f64)
}

/// R pFed1BS local steps (Algorithm 1 lines 11–17): SGD on the smoothed
/// personalized objective F̃_k(·; v), w device-resident across the steps.
/// `v` is the current consensus in {−1,0,+1}^m (0s only in round 0).
/// Returns the round-start task loss (batch 0).
pub fn local_pfed_steps(
    ctx: &mut ClientCtx,
    k: usize,
    w: &mut Vec<f32>,
    v: &[f32],
    round: u64,
) -> Result<f64> {
    let cfg = ctx.cfg;
    let client = &ctx.data.clients[k];
    let mut batches = BatchIter::new(
        client,
        ctx.model.geom.train_batch,
        ctx.rng.fork(hash3(k as u64, round, 0x5046_4544)),
    );
    let (w_new, loss) = ctx.model.client_round(
        w,
        || {
            let (x, y) = batches.next_batch();
            (x.to_vec(), y.to_vec())
        },
        cfg.local_steps,
        v,
        cfg.eta,
        cfg.lambda,
        cfg.mu,
        cfg.gamma,
    )?;
    *w = w_new;
    Ok(loss as f64)
}

/// Δ = a − b elementwise.
pub fn delta(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x - y).collect()
}

/// w += s · u elementwise.
pub fn axpy(w: &mut [f32], s: f32, u: &[f32]) {
    debug_assert_eq!(w.len(), u.len());
    for (wi, &ui) in w.iter_mut().zip(u) {
        *wi += s * ui;
    }
}

/// mean of |x|.
pub fn mean_abs(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    (x.iter().map(|&v| v.abs() as f64).sum::<f64>() / x.len() as f64) as f32
}

/// Mix three words into one stream tag (client id × round × purpose).
pub fn hash3(a: u64, b: u64, c: u64) -> u64 {
    let mut h = a ^ 0x9E37_79B9_7F4A_7C15;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9) ^ b.rotate_left(17);
    h = h.wrapping_mul(0x94D0_49BB_1331_11EB) ^ c.rotate_left(31);
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic_and_scaled() {
        let a = init_params(1000, 7);
        let b = init_params(1000, 7);
        assert_eq!(a, b);
        let c = init_params(1000, 8);
        assert_ne!(a, c);
        let rms =
            (a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / a.len() as f64).sqrt();
        assert!((rms - 0.05).abs() < 0.01, "rms {rms}");
    }

    #[test]
    fn vector_helpers() {
        let a = [3.0f32, 4.0, 5.0];
        let b = [1.0f32, 1.0, 1.0];
        assert_eq!(delta(&a, &b), vec![2.0, 3.0, 4.0]);
        let mut w = [0.0f32; 3];
        axpy(&mut w, 2.0, &b);
        assert_eq!(w, [2.0, 2.0, 2.0]);
        assert!((mean_abs(&[-2.0, 2.0]) - 2.0).abs() < 1e-6);
        assert_eq!(mean_abs(&[]), 0.0);
    }

}
