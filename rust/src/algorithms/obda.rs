//! OBDA (Zhu et al. 2020): one-bit digital aggregation — symmetric 1-bit
//! quantization on BOTH links (Table 1 row 2), no dimensionality
//! reduction, single global model.
//!
//! Re-implementation fidelity: OBDA's over-the-air majority-vote
//! aggregation is realized digitally — clients upload sign(Δ_k) (n bits),
//! the server takes the weighted majority vote (the same decision rule as
//! the paper's analog sign aggregation) and applies a *scaled* sign step,
//! with the scale estimated from the clients' mean |Δ| (each client adds
//! one f32 — 32 bits — to its uplink; without this, fixed-lr signSGD is a
//! strawman). The server then ships the n-bit vote back through
//! `server_notify` so clients stay in sync — the 1-bit downlink of
//! Table 1. There is no pre-round broadcast: clients start each round
//! from the model they reconstructed at the previous round's end.

use anyhow::Result;

use crate::algorithms::common::{delta, init_params, local_sgd, mean_abs};
use crate::algorithms::{
    AggKind, Algorithm, Capabilities, ClientCtx, ClientOutput, ClientStats, Downlink,
    InitCtx, RoundAggregator, RoundOutcome, ServerCtx, Uplink,
};
use crate::comm::Payload;
use crate::sketch::bitpack::{ScalarTally, SignVec, VoteAccumulator};

/// OBDA (one-bit digital aggregation): majority-vote signSGD with a
/// per-client scale and a one-bit vote downlink — global model.
pub struct Obda {
    w: Vec<f32>,
    /// last round's (packed vote, scale), broadcast via `server_notify`
    /// without re-packing
    last_vote: Option<(SignVec, f32)>,
}

impl Obda {
    /// Fresh instance; state is sized at `init`.
    pub fn new() -> Self {
        Obda { w: Vec::new(), last_vote: None }
    }
}

impl Default for Obda {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for Obda {
    fn name(&self) -> &'static str {
        "obda"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: false,
            upload_one_bit: true,
            download_dim_reduction: false,
            download_one_bit: true,
            personalization: false,
        }
    }

    fn init(&mut self, ctx: &InitCtx) -> Result<()> {
        self.w = init_params(ctx.model.geom.n, ctx.cfg.seed);
        self.last_vote = None;
        Ok(())
    }

    fn server_broadcast(&self, _t: usize) -> Option<Downlink> {
        None // the 1-bit downlink is the post-round vote (server_notify)
    }

    fn client_round(
        &self,
        t: usize,
        k: usize,
        _downlink: Option<&Downlink>,
        ctx: &mut ClientCtx,
    ) -> Result<ClientOutput> {
        let mut wk = self.w.clone();
        let loss = local_sgd(ctx, k, &mut wk, t as u64)?;
        let d = delta(&wk, &self.w);
        let signs = SignVec::from_signs(&d);
        // uplink: n-bit packed sign vector + one f32 magnitude estimate
        Ok(ClientOutput {
            client: k,
            uplink: Some(Uplink::new(
                t,
                Payload::ScaledSigns { signs, scale: mean_abs(&d) },
            )),
            state: None,
            stats: ClientStats { loss },
        })
    }

    fn begin_aggregate(&self, _t: usize) -> RoundAggregator {
        // n-bit vote tally + the exact weighted scale estimate Σ p_k·c_k
        RoundAggregator::new(AggKind::ScaledVote {
            tally: VoteAccumulator::new(self.w.len()),
            scale: ScalarTally::new(),
        })
    }

    fn finish_aggregate(
        &mut self,
        _t: usize,
        agg: RoundAggregator,
        _ctx: &ServerCtx,
    ) -> Result<RoundOutcome> {
        let (kind, _, absorbed, outcome) = agg.into_parts();
        let AggKind::ScaledVote { tally, scale } = kind else {
            anyhow::bail!("obda aggregator must be the scaled-vote tally");
        };
        if absorbed > 0 {
            // weighted majority vote off the streamed tally, scaled sign
            // step applied straight off the packed vote bits
            let vote = tally.finish();
            let scale_acc = scale.value() as f32;
            for (wi, s) in self.w.iter_mut().zip(vote.iter_signs()) {
                *wi += scale_acc * s;
            }
            self.last_vote = Some((vote, scale_acc));
        } else {
            // no delivered votes: nothing to step on, nothing to notify
            self.last_vote = None;
        }
        Ok(outcome)
    }

    fn server_notify(&self, t: usize) -> Option<Downlink> {
        // broadcast the n-bit packed vote (clients apply the same step)
        self.last_vote.as_ref().map(|(vote, scale)| {
            Downlink::new(t, Payload::ScaledSigns { signs: vote.clone(), scale: *scale })
        })
    }

    fn model_for(&self, _k: usize) -> &[f32] {
        &self.w
    }
}
