//! OBDA (Zhu et al. 2020): one-bit digital aggregation — symmetric 1-bit
//! quantization on BOTH links (Table 1 row 2), no dimensionality
//! reduction, single global model.
//!
//! Re-implementation fidelity: OBDA's over-the-air majority-vote
//! aggregation is realized digitally — clients upload sign(Δ_k) (n bits),
//! the server takes the weighted majority vote (the same decision rule as
//! the paper's analog sign aggregation) and applies a *scaled* sign step,
//! with the scale estimated from the clients' mean |Δ| (each client adds
//! one f32 — 32 bits — to its uplink; without this, fixed-lr signSGD is a
//! strawman). The server then broadcasts the n-bit vote so clients stay
//! in sync — the 1-bit downlink of Table 1.

use anyhow::Result;

use crate::algorithms::common::{axpy, delta, init_params, local_sgd, mean_abs};
use crate::algorithms::{Algorithm, Capabilities, Ctx, RoundOutcome};
use crate::comm::Payload;
use crate::sketch::bitpack::{majority_vote_weighted, pack_signs, unpack_signs};

pub struct Obda {
    w: Vec<f32>,
}

impl Obda {
    pub fn new() -> Self {
        Obda { w: Vec::new() }
    }
}

impl Default for Obda {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for Obda {
    fn name(&self) -> &'static str {
        "obda"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: false,
            upload_one_bit: true,
            download_dim_reduction: false,
            download_one_bit: true,
            personalization: false,
        }
    }

    fn init(&mut self, ctx: &mut Ctx) -> Result<()> {
        self.w = init_params(ctx.model.geom.n, ctx.cfg.seed);
        Ok(())
    }

    fn round(
        &mut self,
        t: usize,
        selected: &[usize],
        weights: &[f32],
        ctx: &mut Ctx,
    ) -> Result<RoundOutcome> {
        let n = ctx.model.geom.n;
        let mut sketches: Vec<Vec<u64>> = Vec::with_capacity(selected.len());
        let mut scale_acc = 0.0f32;
        let mut loss_sum = 0.0f64;
        for (&k, &p) in selected.iter().zip(weights) {
            let mut wk = self.w.clone();
            loss_sum += local_sgd(ctx, k, &mut wk, t as u64)?;
            let d = delta(&wk, &self.w);
            let signs: Vec<f32> = d.iter().map(|&x| if x >= 0.0 { 1.0 } else { -1.0 }).collect();
            // uplink: n-bit sign vector + one f32 magnitude estimate
            let delivered = ctx
                .net
                .send_uplink(&Payload::ScaledSigns { signs, scale: mean_abs(&d) })?;
            let Payload::ScaledSigns { signs, scale } = delivered else {
                anyhow::bail!("payload type changed in transit")
            };
            scale_acc += p * scale;
            sketches.push(pack_signs(&signs));
        }

        // server: weighted majority vote, scaled sign step
        let vote = unpack_signs(&majority_vote_weighted(&sketches, weights, n), n);
        axpy(&mut self.w, scale_acc, &vote);

        // downlink: broadcast the n-bit vote (clients apply the same step)
        ctx.net
            .broadcast_downlink(&Payload::ScaledSigns { signs: vote, scale: scale_acc }, selected.len())?;

        Ok(RoundOutcome {
            train_loss: loss_sum / selected.len() as f64,
        })
    }

    fn model_for(&self, _k: usize) -> &[f32] {
        &self.w
    }
}
