//! pFed1BS — the paper's Algorithm 1, phrased as the phased protocol.
//!
//! Per round t:
//!   1. `server_broadcast`: the m-bit consensus v^t goes out to the S^t
//!      participants (one-bit, dimension-reduced downlink); the
//!      coordinator delivers each participant an independent copy
//!      through its own channel. The server's v is NEVER replaced by a
//!      channel-corrupted delivery — under the noisy-channel mode each
//!      client trains against the copy *it* received, while the server
//!      keeps the clean v (the bug the monolithic round() had);
//!   2. `client_round`: R local SGD steps on the smoothed personalized
//!      objective F̃_k(w; v^t) (HLO `client_step`, whose regularizer
//!      gradient is the fused Pallas SRHT kernel; the rust mirror of
//!      that kernel is the planned blocked FWHT of DESIGN.md §10), then
//!      upload z_k = sign(Φ w_k^{t+1}) — m bits;
//!   3. streaming aggregation: v^{t+1} = sign(Σ p_k z_k) — the exact
//!      minimizer of the server objective (Lemma 1). The round engine
//!      absorbs each *delivered* (possibly noisy) uplink into an O(m)
//!      [`VoteAccumulator`] tally the moment it arrives — the server
//!      never stores the cohort — and `finish_aggregate` signs the
//!      closed tally into the next packed consensus (DESIGN.md §9).
//!
//! v⁰ = 0 (Algorithm 1 line 2): round 0 has no meaningful consensus, so
//! the broadcast is skipped (the paper's initialization makes the
//! regularizer's ⟨v,Φw⟩ term vanish; h_γ still regularizes).
//!
//! The `--projection dense` ablation (Appendix Fig. 3) swaps the SRHT for
//! a dense Gaussian Φ: the local step then decomposes into the plain HLO
//! `sgd_step` plus the regularizer gradient computed through the rust
//! dense operator — mathematically the same single-step update (both
//! gradients evaluated at the same iterate).

use anyhow::Result;

use crate::algorithms::common::{axpy, hash3, init_params, local_pfed_steps};
use crate::algorithms::{
    AggKind, Algorithm, BatchCtx, BatchTask, Capabilities, ClientCtx, ClientOutput,
    ClientStats, Downlink, InitCtx, RoundAggregator, RoundOutcome, ServerCtx, Uplink,
};
use crate::comm::Payload;
use crate::config::ProjectionKind;
use crate::data::BatchIter;
use crate::sketch::bitpack::{GroupedTally, SignVec, VoteAccumulator};
use crate::sketch::Projection;

/// The paper's Algorithm 1: personalized models with one-bit,
/// dimension-reduced traffic in BOTH directions (see module docs).
pub struct PFed1BS {
    /// personalized models w_k, all K clients
    wks: Vec<Vec<f32>>,
    /// consensus vector v^t ∈ {−1,0,+1}^m (0 only at t=0) as f32 lanes
    /// — the compute-boundary form the HLO client step consumes;
    /// server-side state, never overwritten by a channel delivery
    v: Vec<f32>,
    /// the same consensus in packed form: the majority vote's direct
    /// output, broadcast without any per-round re-pack (DESIGN.md §8).
    /// Note v⁰ = 0 packs to all-+1 bits — irrelevant because round 0
    /// never broadcasts.
    v_packed: SignVec,
    projection_kind: ProjectionKind,
    /// coordinate-wise trimmed vote when > 0 (DESIGN.md §16): each
    /// client is its own group, the `trim_frac` tails of per-client
    /// weighted quanta are dropped per bit. 0.0 = plain vote.
    trim_frac: f64,
    /// median-of-means groups when > 1 (DESIGN.md §16): clients bucket
    /// by `k % groups`, the per-bit median of group tallies is signed.
    /// 1 = plain vote.
    mom_groups: usize,
    /// one-bit error feedback (DESIGN.md §16): each client sketches
    /// s_k = Φw_k + e_k and carries forward e_k' = s_k − α·sign(s_k),
    /// the residual of its one-bit quantization (α = mean |s_k|)
    error_feedback: bool,
    /// per-client residuals e_k, length m once client k has uplinked
    /// under error feedback (empty before, and the whole vec is empty —
    /// zero bytes in checkpoints — while the knob is off)
    efs: Vec<Vec<f32>>,
}

impl PFed1BS {
    /// Fresh instance; state is sized at `init`.
    pub fn new() -> Self {
        PFed1BS {
            wks: Vec::new(),
            v: Vec::new(),
            v_packed: SignVec::default(),
            projection_kind: ProjectionKind::Fht,
            trim_frac: 0.0,
            mom_groups: 1,
            error_feedback: false,
            efs: Vec::new(),
        }
    }

    /// Construct with explicit protocol state: the server-phase methods
    /// (`server_broadcast`, `begin_aggregate`/`finish_aggregate`) are
    /// pure rust, so tests can drive them against hand-built state
    /// without the PJRT `init` path.
    pub fn with_state(wks: Vec<Vec<f32>>, v: Vec<f32>) -> Self {
        let v_packed = SignVec::from_signs(&v);
        PFed1BS {
            wks,
            v,
            v_packed,
            projection_kind: ProjectionKind::Fht,
            trim_frac: 0.0,
            mom_groups: 1,
            error_feedback: false,
            efs: Vec::new(),
        }
    }

    /// Select a robust tally for the server phase (DESIGN.md §16):
    /// `trim_frac > 0` arms the coordinate-wise trimmed vote,
    /// `mom_groups > 1` the median-of-means. Both zeroed/one = the plain
    /// vote, bit-for-bit. Tests drive the hand-built state path through
    /// this; real runs set it from the config in `init`.
    pub fn set_robust_aggregation(&mut self, trim_frac: f64, mom_groups: usize) {
        self.trim_frac = trim_frac;
        self.mom_groups = mom_groups.max(1);
    }

    /// Decode the consensus a client's channel delivered (f32 lanes at the
    /// compute boundary); zeros when nothing came. Shared by the
    /// per-client and batched client phases.
    fn decode_v(&self, downlink: Option<&Downlink>) -> Result<Vec<f32>> {
        match downlink {
            Some(d) => {
                let Payload::Signs(v) = &d.payload else {
                    anyhow::bail!("pfed1bs downlink must be a sign payload");
                };
                Ok(v.to_signs())
            }
            None => Ok(vec![0.0f32; self.v.len()]),
        }
    }

    /// One stacked group (≤ B tasks) through the cohort-batched
    /// executables: one dispatch per local step + one for the sketches.
    /// Each lane forks the SAME batch sub-stream tag off its task RNG as
    /// `local_pfed_steps` does — that, plus vmap lane independence, is
    /// what makes this bit-identical to the per-client path
    /// (DESIGN.md §15).
    fn run_batched_group(
        &self,
        t: usize,
        group: Vec<BatchTask>,
        ctx: &BatchCtx,
    ) -> Result<Vec<ClientOutput>> {
        let cfg = ctx.cfg;
        let tb = ctx.model.geom.train_batch;
        let ws: Vec<Vec<f32>> = group.iter().map(|task| self.wks[task.k].clone()).collect();
        let vs: Vec<Vec<f32>> = group
            .iter()
            .map(|task| self.decode_v(task.downlink.as_ref()))
            .collect::<Result<_>>()?;
        let mut iters: Vec<BatchIter> = group
            .iter()
            .map(|task| {
                let mut rng = task.rng.clone();
                BatchIter::new(
                    &ctx.data.clients[task.k],
                    tb,
                    rng.fork(hash3(task.k as u64, t as u64, 0x5046_4544)),
                )
            })
            .collect();
        let w_refs: Vec<&[f32]> = ws.iter().map(|w| w.as_slice()).collect();
        let v_refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
        let results = ctx.model.client_round_batched(
            &w_refs,
            &v_refs,
            |lane| {
                let (x, y) = iters[lane].next_batch();
                (x.to_vec(), y.to_vec())
            },
            cfg.local_steps,
            cfg.eta,
            cfg.lambda,
            cfg.mu,
            cfg.gamma,
        )?;
        let w_new_refs: Vec<&[f32]> = results.iter().map(|(w, _)| w.as_slice()).collect();
        let zs = ctx.model.sketch_sign_batched_packed(&w_new_refs)?;
        Ok(group
            .into_iter()
            .zip(results)
            .zip(zs)
            .map(|((task, (w, loss)), z)| ClientOutput {
                client: task.k,
                uplink: Some(Uplink::new(t, Payload::Signs(z))),
                state: Some(w),
                stats: ClientStats { loss: loss as f64 },
            })
            .collect())
    }
}

impl Default for PFed1BS {
    fn default() -> Self {
        Self::new()
    }
}

/// Dense-Gaussian ablation local loop (Appendix Fig. 3): the update
///   w ← w − η(∇f̂ + μw) − ηλ·Φᵀ(tanh(γΦw) − v)
/// with both gradients at the same iterate — identical semantics to the
/// fused HLO step, different Φ. `forward`/`adjoint` here stay on the
/// serial operator paths deliberately: this runs inside the
/// data-parallel client phase, where the workers are already saturated
/// (the `*_threaded` kernel variants are for the serial server
/// context — DESIGN.md §10).
fn dense_reg_steps(
    ctx: &mut ClientCtx,
    k: usize,
    w: &mut Vec<f32>,
    v: &[f32],
    round: u64,
) -> Result<f64> {
    let cfg = ctx.cfg;
    let client = &ctx.data.clients[k];
    let mut batches = BatchIter::new(
        client,
        ctx.model.geom.train_batch,
        ctx.rng.fork(round.wrapping_mul(0x9E37).wrapping_add(k as u64)),
    );
    let mut loss_sum = 0.0f64;
    for _ in 0..cfg.local_steps {
        let (x, y) = batches.next_batch();
        // regularizer gradient at the current iterate (before the step)
        let z = ctx.projection.forward(w);
        let resid: Vec<f32> = z
            .iter()
            .zip(v)
            .map(|(&zi, &vi)| (cfg.gamma * zi).tanh() - vi)
            .collect();
        let reg = ctx.projection.adjoint(&resid);
        let (mut w_new, loss) = ctx.model.sgd_step(w, x, y, cfg.eta, cfg.mu)?;
        axpy(&mut w_new, -cfg.eta * cfg.lambda, &reg);
        *w = w_new;
        loss_sum += loss as f64;
    }
    Ok(loss_sum / cfg.local_steps as f64)
}

impl Algorithm for PFed1BS {
    fn name(&self) -> &'static str {
        "pfed1bs"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: true,
            upload_one_bit: true,
            download_dim_reduction: true,
            download_one_bit: true,
            personalization: true,
        }
    }

    fn init(&mut self, ctx: &InitCtx) -> Result<()> {
        let n = ctx.model.geom.n;
        let m = ctx.model.geom.m;
        self.projection_kind = ctx.cfg.projection;
        if let (ProjectionKind::DenseGaussian, Projection::Srht(_)) =
            (self.projection_kind, ctx.projection)
        {
            anyhow::bail!("config says dense projection but ctx carries SRHT");
        }
        let w0 = init_params(n, ctx.cfg.seed);
        self.wks = (0..ctx.data.num_clients()).map(|_| w0.clone()).collect();
        self.v = vec![0.0f32; m]; // v^0 = 0 (Algorithm 1 line 2)
        self.v_packed = SignVec::from_signs(&self.v);
        self.trim_frac = ctx.cfg.trim_frac;
        self.mom_groups = ctx.cfg.mom_groups.max(1);
        self.error_feedback = ctx.cfg.error_feedback;
        // empty per-client residuals until first uplink; fully empty
        // (zero checkpoint bytes) while the knob is off
        self.efs = if self.error_feedback {
            vec![Vec::new(); self.wks.len()]
        } else {
            Vec::new()
        };
        Ok(())
    }

    fn server_broadcast(&self, t: usize) -> Option<Downlink> {
        // skip at t=0 where v=0 by init; the payload is a CLONE of the
        // packed server state (a word-level memcpy), so no delivery can
        // corrupt self.v
        (t > 0).then(|| Downlink::new(t, Payload::Signs(self.v_packed.clone())))
    }

    fn client_round(
        &self,
        t: usize,
        k: usize,
        downlink: Option<&Downlink>,
        ctx: &mut ClientCtx,
    ) -> Result<ClientOutput> {
        // the consensus THIS client received (its own channel's delivery,
        // independently corrupted under noise); zeros when nothing came.
        // The one unpack on the client side happens here, at the compute
        // boundary: the HLO client step consumes f32 lanes.
        let v: Vec<f32> = match downlink {
            Some(d) => {
                let Payload::Signs(v) = &d.payload else {
                    anyhow::bail!("pfed1bs downlink must be a sign payload");
                };
                v.to_signs()
            }
            None => vec![0.0f32; self.v.len()],
        };
        let v = v.as_slice();
        let mut w = self.wks[k].clone();
        let loss = match self.projection_kind {
            ProjectionKind::Fht => {
                // fused HLO path: regularizer inside client_step
                local_pfed_steps(ctx, k, &mut w, v, t as u64)?
            }
            ProjectionKind::DenseGaussian => {
                // ablation path: task+l2 step via HLO, dense reg grad in rust
                dense_reg_steps(ctx, k, &mut w, v, t as u64)?
            }
        };
        if self.error_feedback {
            // error-feedback sketch (DESIGN.md §16): quantize the
            // residual-compensated sketch s = Φw + e and carry forward
            // what the one bit lost, e' = s − α·sign(s) with α = mean|s|
            // (the per-round scale EDEN/FedBAT-style quantizers fit).
            // Uses the rust projection operator for BOTH projection
            // kinds — the EF mode needs the pre-sign lanes, which the
            // fused HLO sketch never materializes.
            let mut s = ctx.projection.forward(&w);
            if let Some(e) = self.efs.get(k) {
                for (si, &ei) in s.iter_mut().zip(e) {
                    *si += ei;
                }
            }
            let z = SignVec::from_signs(&s);
            let alpha = s.iter().map(|x| x.abs()).sum::<f32>() / s.len().max(1) as f32;
            let residual: Vec<f32> =
                s.iter().enumerate().map(|(i, &si)| si - alpha * z.sign(i)).collect();
            // the residual rides home inside the write-back state
            // (w ++ e', split back apart in finish_aggregate) — the
            // uplink payload itself stays the same m bits
            let mut state = w;
            state.extend_from_slice(&residual);
            return Ok(ClientOutput {
                client: k,
                uplink: Some(Uplink::new(t, Payload::Signs(z))),
                state: Some(state),
                stats: ClientStats { loss },
            });
        }
        // one-bit sketch of the updated personalized model, packed at
        // the compression boundary — the payload ships as u64 words
        let z = match self.projection_kind {
            ProjectionKind::Fht => ctx.model.sketch_sign_packed(&w)?,
            ProjectionKind::DenseGaussian => ctx.projection.sketch_sign_packed(&w),
        };
        Ok(ClientOutput {
            client: k,
            uplink: Some(Uplink::new(t, Payload::Signs(z))),
            state: Some(w),
            stats: ClientStats { loss },
        })
    }

    fn supports_batched_rounds(&self) -> bool {
        // the dense-Gaussian ablation computes its regularizer in rust
        // per client and has no stacked artifact — FHT only. Error
        // feedback needs the pre-sign sketch lanes per client, which the
        // stacked sketch dispatch never materializes, so it stays on the
        // per-client path too.
        self.projection_kind == ProjectionKind::Fht && !self.error_feedback
    }

    fn client_round_batched(
        &self,
        t: usize,
        tasks: Vec<BatchTask>,
        ctx: &BatchCtx,
    ) -> Result<Vec<ClientOutput>> {
        let b = ctx.model.device_batch();
        if self.projection_kind != ProjectionKind::Fht || b <= 1 {
            // no stacked path available — fall back to the per-client loop
            return tasks
                .into_iter()
                .map(|task| {
                    let mut cctx = ClientCtx {
                        model: ctx.model,
                        data: ctx.data,
                        cfg: ctx.cfg,
                        projection: ctx.projection,
                        rng: task.rng,
                    };
                    self.client_round(t, task.k, task.downlink.as_ref(), &mut cctx)
                })
                .collect();
        }
        let mut outputs = Vec::with_capacity(tasks.len());
        let mut remaining = tasks;
        while !remaining.is_empty() {
            let tail = remaining.split_off(b.min(remaining.len()));
            let group = std::mem::replace(&mut remaining, tail);
            outputs.extend(self.run_batched_group(t, group, ctx)?);
        }
        Ok(outputs)
    }

    fn begin_aggregate(&self, _t: usize) -> RoundAggregator {
        // O(m) tally state, however many clients end up delivering.
        // The robust knobs swap in the grouped exact tallies
        // (DESIGN.md §16); disarmed they ARE the plain vote bit-for-bit,
        // but the plain accumulator stays the default so honest-fleet
        // rounds keep today's state layout and wire frames byte-for-byte.
        let m = self.v.len();
        if self.trim_frac > 0.0 {
            // one group per client: the coordinate-wise trimmed mean
            // over per-client weighted sign quanta (Yin et al. style)
            RoundAggregator::new(AggKind::TrimmedVote {
                tally: GroupedTally::new(m, self.wks.len().max(1)),
                trim_frac: self.trim_frac,
            })
        } else if self.mom_groups > 1 {
            RoundAggregator::new(AggKind::MedianOfMeans {
                groups: GroupedTally::new(m, self.mom_groups),
            })
        } else {
            RoundAggregator::new(AggKind::Vote(VoteAccumulator::new(m)))
        }
    }

    fn finish_aggregate(
        &mut self,
        _t: usize,
        agg: RoundAggregator,
        _ctx: &ServerCtx,
    ) -> Result<RoundOutcome> {
        let (kind, states, absorbed, outcome) = agg.into_parts();
        for (k, w) in states {
            if self.error_feedback {
                // split the ridden-along residual back off the
                // personalized write-back (w ++ e', length n + m)
                let n = self.wks[k].len();
                if w.len() == n + self.v.len() {
                    let mut w = w;
                    self.efs[k] = w.split_off(n);
                    self.wks[k] = w;
                    continue;
                }
            }
            self.wks[k] = w;
        }
        // sign the streamed tally into the next consensus (Lemma 1 for
        // the plain vote; its trimmed / median-of-means robustification
        // under attack — DESIGN.md §16); a round that delivered nothing
        // keeps v^{t} — voting over zero sketches would fabricate an
        // all-+1 consensus
        let vote = match kind {
            AggKind::Vote(tally) => (absorbed > 0).then(|| tally.finish()),
            AggKind::TrimmedVote { tally, trim_frac } => {
                (absorbed > 0).then(|| tally.finish_trimmed(trim_frac))
            }
            AggKind::MedianOfMeans { groups } => {
                (absorbed > 0).then(|| groups.finish_median())
            }
            _ => anyhow::bail!("pfed1bs aggregator must be a sign-tally kind"),
        };
        if let Some(vote) = vote {
            self.v = vote.to_signs();
            self.v_packed = vote;
        }
        Ok(outcome)
    }

    fn model_for(&self, k: usize) -> &[f32] {
        &self.wks[k]
    }

    fn consensus(&self) -> Option<&[f32]> {
        Some(&self.v)
    }

    fn consensus_packed(&self) -> Option<&SignVec> {
        (!self.v_packed.is_empty()).then_some(&self.v_packed)
    }

    fn snapshot(&self) -> (Vec<Vec<f32>>, Vec<f32>) {
        (self.wks.clone(), self.v.clone())
    }

    fn restore(&mut self, models: Vec<Vec<f32>>, consensus: Vec<f32>) -> Result<()> {
        anyhow::ensure!(
            models.len() == self.wks.len(),
            "checkpoint has {} client models, run has {}",
            models.len(),
            self.wks.len()
        );
        anyhow::ensure!(
            consensus.len() == self.v.len(),
            "checkpoint consensus length {} != m {}",
            consensus.len(),
            self.v.len()
        );
        self.wks = models;
        self.v_packed = SignVec::from_signs(&consensus);
        self.v = consensus;
        Ok(())
    }

    fn snapshot_aux(&self) -> Vec<Vec<f32>> {
        self.efs.clone()
    }

    fn restore_aux(&mut self, aux: Vec<Vec<f32>>) -> Result<()> {
        if aux.is_empty() {
            // pre-v3 checkpoint (or error feedback was off when saved):
            // resume with cold residuals
            if self.error_feedback {
                self.efs = vec![Vec::new(); self.wks.len()];
            }
            return Ok(());
        }
        anyhow::ensure!(
            aux.len() == self.wks.len(),
            "checkpoint has {} residuals, run has {} clients",
            aux.len(),
            self.wks.len()
        );
        anyhow::ensure!(
            aux.iter().all(|e| e.is_empty() || e.len() == self.v.len()),
            "checkpoint residual length != m {}",
            self.v.len()
        );
        self.efs = aux;
        Ok(())
    }
}
