//! FedBAT (Li et al. 2024): communication-efficient FL via learnable
//! binarization of the model update (Table 2 baseline).
//!
//! Re-implementation fidelity: FedBAT learns a binarization of the update
//! during local training; the error-minimizing closed form for a fixed
//! sign pattern is α* = mean|Δ| with pattern sign(Δ) (the classic BWN
//! solution that FedBAT's learnable scheme converges toward). We use the
//! closed form with *stochastic* sign assignment near zero (FedBAT's
//! stochastic binarization), preserving unbiasedness:
//!     P[+α] = (1 + Δ/α_clip)/2   for |Δ| ≤ α_clip.
//! Uplink: n bits + one f32 scale. Downlink: full-precision model. The
//! stochastic draws use the client's own RNG stream (parallel-safe).

use anyhow::Result;

use crate::algorithms::common::{axpy, delta, init_params, local_sgd, mean_abs};
use crate::algorithms::{
    AggKind, Algorithm, Capabilities, ClientCtx, ClientOutput, ClientStats, Downlink,
    InitCtx, RoundAggregator, RoundOutcome, ServerCtx, Uplink,
};
use crate::comm::Payload;
use crate::sketch::bitpack::{SignVec, VoteAccumulator};

/// FedBAT-style stochastic binarization: clipped-probability sign
/// uplinks around a learned scale — global model.
pub struct FedBat {
    w: Vec<f32>,
}

impl FedBat {
    /// Fresh instance; state is sized at `init`.
    pub fn new() -> Self {
        FedBat { w: Vec::new() }
    }
}

impl Default for FedBat {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for FedBat {
    fn name(&self) -> &'static str {
        "fedbat"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: false,
            upload_one_bit: true,
            download_dim_reduction: false,
            download_one_bit: false,
            personalization: false,
        }
    }

    fn init(&mut self, ctx: &InitCtx) -> Result<()> {
        self.w = init_params(ctx.model.geom.n, ctx.cfg.seed);
        Ok(())
    }

    fn server_broadcast(&self, t: usize) -> Option<Downlink> {
        Some(Downlink::new(t, Payload::Dense(self.w.clone())))
    }

    fn client_round(
        &self,
        t: usize,
        k: usize,
        downlink: Option<&Downlink>,
        ctx: &mut ClientCtx,
    ) -> Result<ClientOutput> {
        let Some(Downlink { payload: Payload::Dense(w0), .. }) = downlink else {
            anyhow::bail!("fedbat requires a dense model downlink");
        };
        let mut wk = w0.clone();
        let loss = local_sgd(ctx, k, &mut wk, t as u64)?;
        let d = delta(&wk, w0);
        let alpha = mean_abs(&d).max(1e-12);
        // stochastic binarization: unbiased for |Δ| ≤ clip
        let clip = 2.0 * alpha;
        // packed directly: from_fn draws in ascending coordinate order,
        // so the stochastic-binarization stream is unchanged
        let signs = SignVec::from_fn(d.len(), |i| {
            let xc = d[i].clamp(-clip, clip);
            let p_plus = 0.5 * (1.0 + xc / clip);
            ctx.rng.f32() < p_plus
        });
        // scale `clip` makes E[clip·sign] = Δ (clamped)
        Ok(ClientOutput {
            client: k,
            uplink: Some(Uplink::new(t, Payload::ScaledSigns { signs, scale: clip })),
            state: None,
            stats: ClientStats { loss },
        })
    }

    fn begin_aggregate(&self, _t: usize) -> RoundAggregator {
        // linear one-bit estimator Σ p_k·clip_k·z_k, streamed per arrival
        RoundAggregator::new(AggKind::SignSum(VoteAccumulator::new(self.w.len())))
    }

    fn finish_aggregate(
        &mut self,
        _t: usize,
        agg: RoundAggregator,
        _ctx: &ServerCtx,
    ) -> Result<RoundOutcome> {
        let (kind, _, _, outcome) = agg.into_parts();
        let AggKind::SignSum(tally) = kind else {
            anyhow::bail!("fedbat aggregator must be the linear sign estimator");
        };
        axpy(&mut self.w, 1.0, &tally.finish_sum());
        Ok(outcome)
    }

    fn model_for(&self, _k: usize) -> &[f32] {
        &self.w
    }
}
