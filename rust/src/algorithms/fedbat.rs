//! FedBAT (Li et al. 2024): communication-efficient FL via learnable
//! binarization of the model update (Table 2 baseline).
//!
//! Re-implementation fidelity: FedBAT learns a binarization of the update
//! during local training; the error-minimizing closed form for a fixed
//! sign pattern is α* = mean|Δ| with pattern sign(Δ) (the classic BWN
//! solution that FedBAT's learnable scheme converges toward). We use the
//! closed form with *stochastic* sign assignment near zero (FedBAT's
//! stochastic binarization), preserving unbiasedness:
//!     P[+α] = (1 + Δ/α_clip)/2   for |Δ| ≤ α_clip.
//! Uplink: n bits + one f32 scale. Downlink: full-precision model.

use anyhow::Result;

use crate::algorithms::common::{axpy, delta, init_params, local_sgd, mean_abs};
use crate::algorithms::{Algorithm, Capabilities, Ctx, RoundOutcome};
use crate::comm::Payload;

pub struct FedBat {
    w: Vec<f32>,
}

impl FedBat {
    pub fn new() -> Self {
        FedBat { w: Vec::new() }
    }
}

impl Default for FedBat {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for FedBat {
    fn name(&self) -> &'static str {
        "fedbat"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            upload_dim_reduction: false,
            upload_one_bit: true,
            download_dim_reduction: false,
            download_one_bit: false,
            personalization: false,
        }
    }

    fn init(&mut self, ctx: &mut Ctx) -> Result<()> {
        self.w = init_params(ctx.model.geom.n, ctx.cfg.seed);
        Ok(())
    }

    fn round(
        &mut self,
        t: usize,
        selected: &[usize],
        weights: &[f32],
        ctx: &mut Ctx,
    ) -> Result<RoundOutcome> {
        let n = ctx.model.geom.n;
        ctx.net
            .broadcast_downlink(&Payload::Dense(self.w.clone()), selected.len())?;

        let mut est = vec![0.0f32; n];
        let mut loss_sum = 0.0f64;
        for (&k, &p) in selected.iter().zip(weights) {
            let mut wk = self.w.clone();
            loss_sum += local_sgd(ctx, k, &mut wk, t as u64)?;
            let d = delta(&wk, &self.w);
            let alpha = mean_abs(&d).max(1e-12);
            // stochastic binarization: unbiased for |Δ| ≤ clip
            let clip = 2.0 * alpha;
            let signs: Vec<f32> = d
                .iter()
                .map(|&x| {
                    let xc = x.clamp(-clip, clip);
                    let p_plus = 0.5 * (1.0 + xc / clip);
                    if ctx.rng.f32() < p_plus {
                        1.0
                    } else {
                        -1.0
                    }
                })
                .collect();
            // scale `clip` makes E[clip·sign] = Δ (clamped)
            let delivered = ctx
                .net
                .send_uplink(&Payload::ScaledSigns { signs, scale: clip })?;
            let Payload::ScaledSigns { signs, scale } = delivered else {
                anyhow::bail!("payload type changed in transit")
            };
            for (e, &s) in est.iter_mut().zip(&signs) {
                *e += p * scale * s;
            }
        }

        axpy(&mut self.w, 1.0, &est);
        Ok(RoundOutcome {
            train_loss: loss_sum / selected.len() as f64,
        })
    }

    fn model_for(&self, _k: usize) -> &[f32] {
        &self.w
    }
}
