//! Theory-to-code bridge: the constants and bounds of the paper's
//! convergence analysis (Lemmas 2, 4, 5 and Theorem 1), computed from a
//! concrete `RunConfig` + model geometry.
//!
//! This makes the theoretical claims *executable*: `pfed1bs bound` prints
//! the predicted stationary neighborhood for the current configuration,
//! and `fig3-4 --diagnostics` logs the measured left-hand side
//! (Σₖ pₖ‖∇F̃ₖ‖², via the `grad_norm` artifact) so the two can be
//! compared on the same axes. The unit tests double as checks that the
//! paper's algebra is internally consistent (e.g. the λ = O(1/n) remark).

use crate::config::RunConfig;
use crate::runtime::Geometry;

/// All constants appearing in Theorem 1, derived from one configuration.
#[derive(Clone, Copy, Debug)]
pub struct TheoryConstants {
    /// C_Φ = √(n′/m) — exact spectral norm of the SRHT (Lemma 2)
    pub c_phi: f64,
    /// L_F = L + λγC_Φ² + μ — smoothness of the client objective (Lemma 4)
    pub l_f: f64,
    /// α = 1 − ημ(1 − 3ημ) — per-step contraction factor (Lemma 5)
    pub alpha: f64,
    /// C′ — additive constant of the norm recursion (Lemma 5)
    pub c_prime: f64,
    /// W² — uniform bound on E‖wₖ‖² (Lemma 5)
    pub w_sq: f64,
    /// Δ_max = 2λ(√m·C_Φ·W + m) — one-bit server-update error (Thm 1)
    pub delta_max: f64,
    /// c₁ = ηR(1 − ηL_F/2) — descent coefficient (Thm 1)
    pub c1: f64,
    /// E_S upper bound — client-sampling error with ‖zₖ−z̄‖² ≤ 4m (Thm 1)
    pub e_s_max: f64,
}

/// Inputs not derivable from the config: smoothness / gradient bounds of
/// the task loss. Defaults are loose empirical values for the MLP +
/// synthetic-cluster tasks (cross-entropy on bounded inputs).
#[derive(Clone, Copy, Debug)]
pub struct TaskAssumptions {
    /// L — smoothness of f_k (Assumption 1)
    pub l_smooth: f64,
    /// G² — second moment of the stochastic task gradient (Assumption 4)
    pub g_sq: f64,
    /// σ² — stochastic-gradient variance (Assumption 3)
    pub sigma_sq: f64,
    /// ‖w⁰‖² — initial parameter norm
    pub w0_sq: f64,
}

impl Default for TaskAssumptions {
    fn default() -> Self {
        TaskAssumptions {
            l_smooth: 10.0,
            g_sq: 25.0,
            sigma_sq: 1.0,
            w0_sq: 300.0,
        }
    }
}

/// Compute every Theorem-1 constant for (cfg, geometry, assumptions).
pub fn constants(cfg: &RunConfig, geom: &Geometry, a: &TaskAssumptions) -> TheoryConstants {
    let n_pad = geom.npad as f64;
    let m = geom.m as f64;
    let (eta, lam, mu, gamma) = (
        cfg.eta as f64,
        cfg.lambda as f64,
        cfg.mu as f64,
        cfg.gamma as f64,
    );
    let r = cfg.local_steps as f64;

    let c_phi = (n_pad / m).sqrt();
    let l_f = a.l_smooth + lam * gamma * c_phi * c_phi + mu;

    // Lemma 5 (requires eta < 1/(3 mu); with the paper's mu = 1e-5 any
    // practical eta qualifies)
    let alpha = 1.0 - eta * mu * (1.0 - 3.0 * eta * mu);
    let c_g = 2.0 * c_phi * m.sqrt();
    let c_prime = (eta / mu + 3.0 * eta * eta) * a.g_sq + 3.0 * eta * eta * lam * lam * c_g * c_g;
    let fixed_point = c_prime / ((1.0 - alpha).max(f64::MIN_POSITIVE)
        * (1.0 - alpha.powf(r)).max(f64::MIN_POSITIVE));
    let w_sq = a.w0_sq.max(fixed_point);

    let delta_max = 2.0 * lam * (m.sqrt() * c_phi * w_sq.sqrt() + m);
    let c1 = eta * r * (1.0 - eta * l_f / 2.0);

    // E_S with the coarse bound ||z_k - zbar||^2 <= 4m (entries in ±1):
    // E_S <= 2 sqrt(m) sqrt( (K-S)/(S K (K-1)) * K * 4m )
    let k = cfg.clients as f64;
    let s = cfg.participating as f64;
    let e_s_max = if cfg.participating == cfg.clients || cfg.clients == 1 {
        0.0
    } else {
        2.0 * m.sqrt() * ((k - s) / (s * k * (k - 1.0)) * k * 4.0 * m).sqrt()
    };

    TheoryConstants {
        c_phi,
        l_f,
        alpha,
        c_prime,
        w_sq,
        delta_max,
        c1,
        e_s_max,
    }
}

/// The Theorem-1 right-hand side: the bound on the time-averaged
/// stationarity measure after T rounds.
///
///   (Ψ⁰ − F*)/(c₁T) + η²RL_Fσ²/(2c₁) + Δ_max/c₁ + λE_S/c₁
pub fn theorem1_bound(
    cfg: &RunConfig,
    geom: &Geometry,
    a: &TaskAssumptions,
    psi0_minus_fstar: f64,
) -> f64 {
    let c = constants(cfg, geom, a);
    let t = cfg.rounds as f64;
    let r = cfg.local_steps as f64;
    let eta = cfg.eta as f64;
    psi0_minus_fstar / (c.c1 * t)
        + eta * eta * r * c.l_f * a.sigma_sq / (2.0 * c.c1)
        + c.delta_max / c.c1
        + cfg.lambda as f64 * c.e_s_max / c.c1
}

/// Validity checks on the configuration against the theory's conditions.
/// Returns human-readable violations (empty = all satisfied).
pub fn check_conditions(cfg: &RunConfig, geom: &Geometry, a: &TaskAssumptions) -> Vec<String> {
    let mut out = Vec::new();
    let c = constants(cfg, geom, a);
    if (cfg.eta as f64) > 1.0 / c.l_f {
        out.push(format!(
            "eta = {} violates eta <= 1/L_F = {:.3e} (Theorem 1)",
            cfg.eta,
            1.0 / c.l_f
        ));
    }
    if (cfg.eta as f64) >= 1.0 / (3.0 * cfg.mu as f64) {
        out.push(format!(
            "eta = {} violates eta < 1/(3 mu) = {:.3e} (Lemma 5)",
            cfg.eta,
            1.0 / (3.0 * cfg.mu as f64)
        ));
    }
    // Remark 1: lambda = O(1/n) keeps the neighborhood bounded
    let n = geom.n as f64;
    if (cfg.lambda as f64) * n > 1000.0 {
        out.push(format!(
            "lambda·n = {:.1} — Remark 1 suggests lambda = O(1/n); the \
             sign-alignment term may dominate",
            cfg.lambda as f64 * n
        ));
    }
    out
}

/// Pretty report for the `pfed1bs bound` subcommand.
pub fn report(cfg: &RunConfig, geom: &Geometry) -> String {
    let a = TaskAssumptions::default();
    let c = constants(cfg, geom, &a);
    let bound = theorem1_bound(cfg, geom, &a, 10.0);
    let mut s = String::new();
    s.push_str(&format!("Theorem-1 constants for: {}\n", cfg.summary()));
    s.push_str(&format!("  C_Phi   = sqrt(n'/m)            = {:.4}\n", c.c_phi));
    s.push_str(&format!("  L_F     = L + lam*gam*C_Phi^2+mu= {:.4e}\n", c.l_f));
    s.push_str(&format!("  alpha   (Lemma 5 contraction)   = {:.8}\n", c.alpha));
    s.push_str(&format!("  W^2     (model-norm bound)      = {:.4e}\n", c.w_sq));
    s.push_str(&format!("  Delta_max (1-bit server error)  = {:.4e}\n", c.delta_max));
    s.push_str(&format!("  c_1     = eta*R*(1-eta*L_F/2)   = {:.4e}\n", c.c1));
    s.push_str(&format!("  E_S max (sampling error)        = {:.4e}\n", c.e_s_max));
    s.push_str(&format!(
        "  Theorem-1 RHS (Psi0-F* = 10)    = {:.4e}\n",
        bound
    ));
    let viol = check_conditions(cfg, geom, &a);
    if viol.is_empty() {
        s.push_str("  conditions: eta <= 1/L_F and eta < 1/(3mu) satisfied\n");
    } else {
        for v in viol {
            s.push_str(&format!("  WARNING: {v}\n"));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetName;

    fn geom() -> Geometry {
        Geometry {
            n: 101_770,
            npad: 1 << 17,
            m: 10_177,
            input_dim: 784,
            classes: 10,
            train_batch: 32,
            eval_batch: 256,
        }
    }

    #[test]
    fn c_phi_matches_lemma2() {
        let cfg = RunConfig::preset(DatasetName::Mnist);
        let c = constants(&cfg, &geom(), &TaskAssumptions::default());
        let want = ((1 << 17) as f64 / 10_177.0).sqrt();
        assert!((c.c_phi - want).abs() < 1e-12);
    }

    #[test]
    fn paper_preset_violates_theory_step_size_and_tool_detects_it() {
        // An honest finding this tool makes executable: with the paper's
        // own grid-searched hyperparameters (gamma = 1e4, lambda = 5e-4),
        // L_F = L + lambda*gamma*C_Phi^2 + mu ≈ 74, so Theorem 1's
        // eta <= 1/L_F requires eta <= 0.013 — while the practical eta
        // (0.08–0.1) exceeds it. The theory's constants are loose; the
        // checker must surface this rather than hide it.
        let cfg = RunConfig::preset(DatasetName::Mnist);
        let viol = check_conditions(&cfg, &geom(), &TaskAssumptions::default());
        assert!(
            viol.iter().any(|v| v.contains("1/L_F")),
            "expected eta <= 1/L_F violation to be detected: {viol:?}"
        );
    }

    #[test]
    fn conforming_config_passes_conditions() {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.eta = 0.01; // below 1/L_F ≈ 0.0134
        let viol = check_conditions(&cfg, &geom(), &TaskAssumptions::default());
        assert!(viol.is_empty(), "{viol:?}");
    }

    #[test]
    fn contraction_factor_in_unit_interval() {
        let cfg = RunConfig::preset(DatasetName::Mnist);
        let c = constants(&cfg, &geom(), &TaskAssumptions::default());
        assert!(c.alpha > 0.0 && c.alpha < 1.0, "alpha {}", c.alpha);
    }

    #[test]
    fn sampling_error_vanishes_at_full_participation() {
        // Remark 2: E_S = 0 when S = K
        let cfg = RunConfig::preset(DatasetName::Mnist); // S = K = 20
        let c = constants(&cfg, &geom(), &TaskAssumptions::default());
        assert_eq!(c.e_s_max, 0.0);
        let mut cfg2 = cfg.clone();
        cfg2.participating = 5;
        let c2 = constants(&cfg2, &geom(), &TaskAssumptions::default());
        assert!(c2.e_s_max > 0.0);
    }

    #[test]
    fn sampling_error_decreases_with_more_participants() {
        let mut prev = f64::INFINITY;
        for s in [5usize, 10, 15, 19] {
            let mut cfg = RunConfig::preset(DatasetName::Mnist);
            cfg.participating = s;
            let c = constants(&cfg, &geom(), &TaskAssumptions::default());
            assert!(c.e_s_max < prev, "E_S not monotone at S={s}");
            prev = c.e_s_max;
        }
    }

    #[test]
    fn bound_decreases_with_rounds() {
        let a = TaskAssumptions::default();
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.eta = 0.01; // within eta <= 1/L_F so c1 > 0 and the bound is meaningful
        cfg.rounds = 10;
        let b10 = theorem1_bound(&cfg, &geom(), &a, 10.0);
        cfg.rounds = 1000;
        let b1000 = theorem1_bound(&cfg, &geom(), &a, 10.0);
        assert!(b1000 < b10);
        // ... but converges to the neighborhood, not zero (Remark 1)
        cfg.rounds = usize::MAX / 2;
        let b_inf = theorem1_bound(&cfg, &geom(), &a, 10.0);
        assert!(b_inf > 0.0);
    }

    #[test]
    fn larger_lambda_inflates_neighborhood() {
        // Remark 1: lambda controls L_F, Delta_max, E_S simultaneously
        let a = TaskAssumptions::default();
        let cfg1 = RunConfig::preset(DatasetName::Mnist);
        let mut cfg2 = cfg1.clone();
        cfg2.lambda = cfg1.lambda * 100.0;
        let c1 = constants(&cfg1, &geom(), &a);
        let c2 = constants(&cfg2, &geom(), &a);
        assert!(c2.l_f > c1.l_f);
        assert!(c2.delta_max > c1.delta_max);
    }

    #[test]
    fn report_is_complete() {
        let cfg = RunConfig::preset(DatasetName::Cifar10);
        let g = Geometry {
            n: 453_682,
            npad: 1 << 19,
            m: 45_368,
            input_dim: 3072,
            classes: 10,
            train_batch: 32,
            eval_batch: 256,
        };
        let r = report(&cfg, &g);
        for key in ["C_Phi", "L_F", "Delta_max", "c_1", "Theorem-1 RHS"] {
            assert!(r.contains(key), "missing {key} in report");
        }
    }
}
