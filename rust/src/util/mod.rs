//! Tooling substrates built in-tree because the offline crate mirror only
//! carries the `xla` dependency closure (DESIGN.md §2): PRNG, CLI parsing,
//! statistics, logging, property-test driver.

pub mod cli;
pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;
