//! Minimal argv parser (clap is unavailable offline — DESIGN.md §2).
//!
//! Grammar: `pfed1bs <subcommand> [--key value | --key=value | --flag] ...`
//! Unknown keys are an error (catches typos in experiment scripts).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: one positional subcommand + key/value options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// the positional subcommand, when one was given
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    /// keys the program has read — for unknown-option detection
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of argv tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, val) = if let Some((k, v)) = stripped.split_once('=') {
                    (k.to_string(), v.to_string())
                } else if it
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    (stripped.to_string(), it.next().unwrap())
                } else {
                    // bare flag
                    (stripped.to_string(), "true".to_string())
                };
                if key.is_empty() {
                    bail!("empty option name in `{tok}`");
                }
                if args.opts.insert(key.clone(), val).is_some() {
                    bail!("duplicate option --{key}");
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                bail!("unexpected positional argument `{tok}`");
            }
        }
        Ok(args)
    }

    /// Parse the process arguments (argv[0] excluded).
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    /// Look up an option's raw value (marks the key as known).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with a default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Parsed option with a default; a present-but-unparsable value is
    /// an error naming the key.
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    /// Option that must be present.
    pub fn required(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("missing required --{key}"))
    }

    /// Boolean flag: `--key`, `--key=true`, `--key 1`, `--key yes`.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error if any provided option was never read by the program.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .filter(|k| !seen.iter().any(|s| s == *k))
            .collect();
        if !unknown.is_empty() {
            bail!(
                "unknown option(s): {}",
                unknown
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            );
        }
        Ok(())
    }

    /// All options as (key, value). Marks every key as seen: callers of
    /// `all()` (e.g. `RunConfig::apply_args`) do their own unknown-key
    /// validation.
    pub fn all(&self) -> impl Iterator<Item = (&str, &str)> {
        for k in self.opts.keys() {
            self.mark(k);
        }
        self.opts.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Result<Args> {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--alg", "pfed1bs", "--rounds=30", "--verbose"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("alg"), Some("pfed1bs"));
        assert_eq!(a.parse_or("rounds", 0usize).unwrap(), 30);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn negative_number_values() {
        // a value starting with '-' but not '--' is consumed as a value
        let a = parse(&["x", "--shift", "-0.5"]).unwrap();
        assert_eq!(a.parse_or("shift", 0.0f64).unwrap(), -0.5);
    }

    #[test]
    fn duplicate_option_rejected() {
        assert!(parse(&["x", "--a", "1", "--a", "2"]).is_err());
    }

    #[test]
    fn extra_positional_rejected() {
        assert!(parse(&["x", "y"]).is_err());
    }

    #[test]
    fn defaults_and_required() {
        let a = parse(&["t"]).unwrap();
        assert_eq!(a.str_or("missing", "dflt"), "dflt");
        assert!(a.required("gone").is_err());
        assert_eq!(a.parse_or("k", 7u32).unwrap(), 7);
    }

    #[test]
    fn bad_parse_reports_key() {
        let a = parse(&["t", "--rounds", "abc"]).unwrap();
        let err = a.parse_or("rounds", 0usize).unwrap_err().to_string();
        assert!(err.contains("rounds"), "{err}");
    }

    #[test]
    fn reject_unknown_flags_typos() {
        let a = parse(&["t", "--roundz", "5"]).unwrap();
        let _ = a.parse_or("rounds", 0usize);
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn flag_forms() {
        let a = parse(&["t", "--x=true", "--y=yes", "--z=false"]).unwrap();
        assert!(a.flag("x"));
        assert!(a.flag("y"));
        assert!(!a.flag("z"));
    }
}
