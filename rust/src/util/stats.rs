//! Small statistics toolkit used by metrics, experiments, and the bench
//! harness (criterion is unavailable offline — see DESIGN.md §2).

/// Streaming mean/variance (Welford). Numerically stable, O(1) memory.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observations folded so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation (+∞ when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation (−∞ when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation of a slice.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// q-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// q-th percentile (0..=100) by the nearest-rank rule on a sorted copy:
/// the smallest value with at least ⌈q/100·n⌉ observations at or below
/// it. Unlike [`percentile`]'s interpolation this never manufactures a
/// value between samples — for tail quantiles over small latency
/// populations (a loadgen run that collected < 100 ACKs) interpolation
/// aliases p99 toward the interior, while nearest-rank degrades
/// honestly: n = 1 reports the only sample for every q, n = 2 reports
/// the max for any q > 50. Empty input returns 0.0.
pub fn percentile_nearest_rank(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    // ceil(q/100 · n), clamped to [1, n] (q = 0 still needs rank 1)
    let rank = ((q / 100.0) * n as f64).ceil() as usize;
    v[rank.clamp(1, n) - 1]
}

/// Median absolute deviation — robust spread estimate for bench timings.
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let med = percentile(xs, 50.0);
    let dev: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    percentile(&dev, 50.0)
}

/// l2 norm of an f32 slice (f64 accumulation).
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Dot product with f64 accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Cosine similarity; 0.0 when either vector is zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let na = l2_norm(a);
    let nb = l2_norm(b);
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot(a, b) / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 100.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        assert_eq!(s.count(), 6);
    }

    #[test]
    fn percentile_endpoints_and_median() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn nearest_rank_holds_at_the_issue_boundary_sizes() {
        // n = 0: defined as 0.0, no panic
        assert_eq!(percentile_nearest_rank(&[], 50.0), 0.0);
        assert_eq!(percentile_nearest_rank(&[], 99.0), 0.0);
        // n = 1: the only sample answers every quantile
        assert_eq!(percentile_nearest_rank(&[7.5], 0.0), 7.5);
        assert_eq!(percentile_nearest_rank(&[7.5], 50.0), 7.5);
        assert_eq!(percentile_nearest_rank(&[7.5], 99.0), 7.5);
        // n = 2: p99 is the max — interpolation would alias it toward
        // the midpoint (0.99·(n-1) lands between the two samples)
        assert_eq!(percentile_nearest_rank(&[1.0, 9.0], 99.0), 9.0);
        assert_eq!(percentile_nearest_rank(&[1.0, 9.0], 50.0), 1.0);
        assert_eq!(percentile_nearest_rank(&[1.0, 9.0], 100.0), 9.0);
        // n = 100: rank = ceil(0.99·100) = 99 → sorted[98]
        let v100: Vec<f64> = (0..100).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&v100, 99.0), 98.0);
        assert_eq!(percentile_nearest_rank(&v100, 50.0), 49.0);
        assert_eq!(percentile_nearest_rank(&v100, 100.0), 99.0);
        // n = 101: rank = ceil(0.5·101) = 51 → sorted[50], the true
        // median; p99 rank = ceil(0.99·101) = 100 → sorted[99]
        let v101: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&v101, 50.0), 50.0);
        assert_eq!(percentile_nearest_rank(&v101, 99.0), 99.0);
        // order-independence: the rule sorts internally
        assert_eq!(percentile_nearest_rank(&[9.0, 1.0, 5.0], 99.0), 9.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 50.0];
        assert!(mad(&xs) < 0.2);
    }

    #[test]
    fn norms_and_dot() {
        let a = [3.0f32, 4.0];
        let b = [4.0f32, 3.0];
        assert!((l2_norm(&a) - 5.0).abs() < 1e-9);
        assert!((dot(&a, &b) - 24.0).abs() < 1e-9);
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-9);
        assert_eq!(cosine(&a, &[0.0, 0.0]), 0.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        let s = OnlineStats::new();
        assert_eq!(s.sem(), 0.0);
    }
}
