//! Leveled stderr logger controlled by `PFED1BS_LOG` (error|warn|info|debug|trace).
//!
//! Deliberately tiny: no timestamps by default (experiments capture their
//! own timings), one global level read once.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// unrecoverable problems
    Error = 0,
    /// suspicious-but-survivable conditions
    Warn = 1,
    /// round/run progress (the default level)
    Info = 2,
    /// verbose diagnostics
    Debug = 3,
    /// per-step firehose
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: Once = Once::new();

/// Read `PFED1BS_LOG` once and set the global level accordingly.
pub fn init_from_env() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("PFED1BS_LOG") {
            set_level(match v.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "info" => Level::Info,
                "debug" => Level::Debug,
                "trace" => Level::Trace,
                _ => Level::Info,
            });
        }
    });
}

/// Set the global log level.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Would a message at level `l` currently be emitted?
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one message to stderr if the level is enabled (the macros below
/// route through here).
pub fn log(l: Level, msg: std::fmt::Arguments) {
    if enabled(l) {
        eprintln!("[{}] {}", tag(l), msg);
    }
}

fn tag(l: Level) -> &'static str {
    match l {
        Level::Error => "error",
        Level::Warn => "warn",
        Level::Info => "info",
        Level::Debug => "debug",
        Level::Trace => "trace",
    }
}

/// Log at [`Level::Info`] with `format!` syntax.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*)) };
}
/// Log at [`Level::Warn`] (`warn_` — `warn` collides with the built-in
/// lint attribute namespace in some positions).
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}
/// Log at [`Level::Debug`] with `format!` syntax.
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
