//! Deterministic PRNG substrate (no `rand` crate in the offline mirror).
//!
//! `Rng` is xoshiro256++ seeded through SplitMix64 — the standard
//! construction: SplitMix64 whitens an arbitrary u64 seed into the four
//! xoshiro words. Deterministic across platforms, so every experiment in
//! EXPERIMENTS.md is reproducible from its `--seed`.

/// SplitMix64 step — used for seeding and for cheap stateless streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed a generator (any u64; SplitMix64 whitens it).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-client / per-round RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(splitmix64(&mut sm))
    }

    /// Next 64 uniform bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 uniform bits (the generator's high half).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) — Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return (u * (-2.0 * s.ln() / s).sqrt()) as f32;
            }
        }
    }

    /// Fill with i.i.d. N(0, sigma^2).
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for x in out.iter_mut() {
            *x = sigma * self.normal();
        }
    }

    /// Rademacher vector (+-1 with equal probability) — the diagonal D of
    /// the SRHT operator.
    pub fn rademacher(&mut self, len: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(len);
        let mut bits = 0u64;
        for i in 0..len {
            if i % 64 == 0 {
                bits = self.next_u64();
            }
            out.push(if bits & 1 == 1 { 1.0 } else { -1.0 });
            bits >>= 1;
        }
        out
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices sampled uniformly from [0, n) without
    /// replacement (partial Fisher–Yates; O(n) memory, O(n) time).
    /// Used for the subsampling matrix S and for client sampling S^t.
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} of {n} without replacement");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample from a categorical distribution given (unnormalized) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Symmetric Dirichlet(alpha) draw of dimension k (via Gamma(alpha,1)
    /// Marsaglia–Tsang; for alpha < 1 uses the boost U^(1/alpha) trick).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in g.iter_mut() {
            *x /= s;
        }
        g
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal() as f64;
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn rademacher_balanced() {
        let mut rng = Rng::new(13);
        let v = rng.rademacher(100_000);
        assert!(v.iter().all(|&x| x == 1.0 || x == -1.0));
        let sum: f32 = v.iter().sum();
        assert!(sum.abs() < 1_500.0, "sum {sum}");
    }

    #[test]
    fn sample_without_replacement_distinct_and_in_range() {
        let mut rng = Rng::new(17);
        for _ in 0..50 {
            let k = rng.below(20) + 1;
            let s = rng.sample_without_replacement(20, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in {s:?}");
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn sample_full_is_permutation() {
        let mut rng = Rng::new(19);
        let mut s = rng.sample_without_replacement(10, 10);
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut rng = Rng::new(23);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = rng.dirichlet(alpha, 8);
            assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(29);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
