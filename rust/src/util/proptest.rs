//! Tiny property-testing driver (proptest is unavailable offline).
//!
//! `check(seed, cases, |rng| ...)` runs a closure over many seeded RNG
//! streams; on failure it reports the failing case index and the child
//! seed so the case can be replayed deterministically:
//!
//! ```
//! use pfed1bs::util::proptest::check;
//! check("sort_idempotent", 100, |rng| {
//!     let mut v: Vec<u32> = (0..rng.below(50)).map(|_| rng.next_u32()).collect();
//!     v.sort_unstable();
//!     let w = { let mut w = v.clone(); w.sort_unstable(); w };
//!     if v == w { Ok(()) } else { Err("not idempotent".into()) }
//! });
//! ```

use super::rng::Rng;

/// Run `cases` random trials of `prop`; panic with replay info on failure.
///
/// The per-case RNG is derived from the property name so adding cases to
/// one property does not shift the random streams of another. The case
/// count can be capped globally (`PFED1BS_PROPTEST_CASES`) and is
/// clamped automatically under Miri — see [`effective_cases`].
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let cases = effective_cases(cases);
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let child_seed = base ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(child_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property `{name}` failed at case {case}/{cases} \
                 (replay seed {child_seed:#x}): {msg}"
            );
        }
    }
}

/// The case count [`check`] actually runs: `PFED1BS_PROPTEST_CASES`
/// caps every property when set (first, so a forwarded env var can
/// raise a Miri run too); otherwise Miri runs are clamped to 3 cases —
/// the interpreter is ~1000× slower, and the UB check the Miri CI job
/// exists for needs each unsafe path walked, not many random repeats.
pub fn effective_cases(cases: usize) -> usize {
    if let Some(cap) =
        std::env::var("PFED1BS_PROPTEST_CASES").ok().and_then(|v| v.parse::<usize>().ok())
    {
        return cases.min(cap.max(1));
    }
    if cfg!(miri) {
        return cases.min(3);
    }
    cases
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut prop: F) -> Result<(), String>
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    prop(&mut rng)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        check("always_ok", 25, |_| {
            ran += 1;
            Ok(())
        });
        // the clamp applies under Miri / a global case cap
        assert_eq!(ran, effective_cases(25));
        assert!(ran > 0);
    }

    #[test]
    fn case_clamp_shape() {
        // 0 stays 0 regardless of environment; Miri clamps to a handful
        assert_eq!(effective_cases(0), 0);
        if cfg!(miri) {
            assert!(effective_cases(1000) <= 3);
        }
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always_fails", 3, |_| Err("boom".into()));
    }

    #[test]
    fn replay_reproduces_stream() {
        let mut first: Option<u64> = None;
        let _ = replay(0xdead_beef, |rng| {
            first = Some(rng.next_u64());
            Ok(())
        });
        let mut second: Option<u64> = None;
        let _ = replay(0xdead_beef, |rng| {
            second = Some(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
