//! Mini-batch iteration over a client's local shard.
//!
//! Matches the paper's local SGD loop: every epoch reshuffles the shard
//! and deals fixed-size batches (wrapping into the next epoch so the HLO
//! artifact's static batch shape is always filled).

use crate::data::synth::ClientData;
use crate::util::rng::Rng;

/// Infinite shuffled batch stream over one client's training data.
pub struct BatchIter<'a> {
    data: &'a ClientData,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    /// scratch reused across `next_batch` calls (no per-step allocation)
    x_buf: Vec<f32>,
    y_buf: Vec<i32>,
}

impl<'a> BatchIter<'a> {
    /// Shuffled batch stream over `data` with the given batch size.
    pub fn new(data: &'a ClientData, batch: usize, rng: Rng) -> Self {
        assert!(batch > 0);
        assert!(data.train_len() > 0, "client has no training data");
        let mut it = BatchIter {
            data,
            batch,
            order: (0..data.train_len()).collect(),
            cursor: 0,
            rng,
            x_buf: vec![0.0; batch * data.input_dim],
            y_buf: vec![0; batch],
        };
        it.reshuffle();
        it
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next batch as (x: `[batch * d]`, y: `[batch]`) borrowed from
    /// internal scratch — valid until the next call.
    pub fn next_batch(&mut self) -> (&[f32], &[i32]) {
        let d = self.data.input_dim;
        for slot in 0..self.batch {
            if self.cursor == self.order.len() {
                self.reshuffle();
            }
            let idx = self.order[self.cursor];
            self.cursor += 1;
            self.x_buf[slot * d..(slot + 1) * d]
                .copy_from_slice(&self.data.train_x[idx * d..(idx + 1) * d]);
            self.y_buf[slot] = self.data.train_y[idx];
        }
        (&self.x_buf, &self.y_buf)
    }
}

/// Fixed-size eval batches over test data, zero-padding the final batch
/// (padding rows carry label -1 which can never be predicted, and the
/// evaluator subtracts the padding from the denominator).
pub struct EvalBatches<'a> {
    data: &'a ClientData,
    batch: usize,
    cursor: usize,
}

impl<'a> EvalBatches<'a> {
    /// Sequential eval batches over `data`'s test shard.
    pub fn new(data: &'a ClientData, batch: usize) -> Self {
        EvalBatches { data, batch, cursor: 0 }
    }

    /// (x, y, valid_rows) or None when exhausted.
    pub fn next_batch(&mut self) -> Option<(Vec<f32>, Vec<i32>, usize)> {
        let d = self.data.input_dim;
        let total = self.data.test_len();
        if self.cursor >= total {
            return None;
        }
        let valid = (total - self.cursor).min(self.batch);
        let mut x = vec![0.0f32; self.batch * d];
        let mut y = vec![-1i32; self.batch];
        for slot in 0..valid {
            let idx = self.cursor + slot;
            x[slot * d..(slot + 1) * d]
                .copy_from_slice(&self.data.test_x[idx * d..(idx + 1) * d]);
            y[slot] = self.data.test_y[idx];
        }
        self.cursor += valid;
        Some((x, y, valid))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::Partition;
    use crate::data::synth::{generate, DatasetName, DatasetSpec};

    fn client() -> ClientData {
        let spec = DatasetSpec {
            name: DatasetName::Mnist,
            input_dim: 4,
            classes: 3,
            noise: 0.1,
            proto_scale: 1.0,
            shift_scale: 0.1,
            train_per_client: 10,
            test_per_client: 7,
        };
        generate(&spec, 1, &Partition::Iid, 0).clients.remove(0)
    }

    #[test]
    fn batches_have_fixed_shape() {
        let c = client();
        let mut it = BatchIter::new(&c, 4, Rng::new(0));
        for _ in 0..10 {
            let (x, y) = it.next_batch();
            assert_eq!(x.len(), 16);
            assert_eq!(y.len(), 4);
            assert!(y.iter().all(|&l| (0..3).contains(&l)));
        }
    }

    #[test]
    fn epoch_covers_every_sample() {
        let c = client(); // 10 samples
        let mut it = BatchIter::new(&c, 5, Rng::new(1));
        let mut seen = std::collections::HashSet::new();
        for _ in 0..2 {
            let (x, _) = it.next_batch();
            for row in 0..5 {
                // identify sample by its bytes
                let key: Vec<u32> = x[row * 4..(row + 1) * 4]
                    .iter()
                    .map(|f| f.to_bits())
                    .collect();
                seen.insert(key);
            }
        }
        assert_eq!(seen.len(), 10, "one epoch must cover all samples");
    }

    #[test]
    fn batch_labels_match_rows() {
        let c = client();
        let mut it = BatchIter::new(&c, 3, Rng::new(2));
        let (x, y) = it.next_batch();
        // find each row in the training set and check its label
        for row in 0..3 {
            let bytes = &x[row * 4..(row + 1) * 4];
            let found = (0..c.train_len()).find(|&i| {
                c.train_x[i * 4..(i + 1) * 4]
                    .iter()
                    .zip(bytes)
                    .all(|(a, b)| a == b)
            });
            let idx = found.expect("batch row not found in training data");
            assert_eq!(c.train_y[idx], y[row]);
        }
    }

    #[test]
    fn eval_batches_cover_exactly_once_with_padding() {
        let c = client(); // 7 test samples
        let mut it = EvalBatches::new(&c, 4);
        let b1 = it.next_batch().unwrap();
        assert_eq!(b1.2, 4);
        let b2 = it.next_batch().unwrap();
        assert_eq!(b2.2, 3);
        assert_eq!(b2.1[3], -1, "padding label must be -1");
        assert!(it.next_batch().is_none());
    }

    #[test]
    fn deterministic_batches_for_same_rng() {
        let c = client();
        let mut a = BatchIter::new(&c, 4, Rng::new(9));
        let mut b = BatchIter::new(&c, 4, Rng::new(9));
        for _ in 0..5 {
            let (xa, ya) = { let (x, y) = a.next_batch(); (x.to_vec(), y.to_vec()) };
            let (xb, yb) = b.next_batch();
            assert_eq!(xa, xb);
            assert_eq!(ya, yb);
        }
    }
}
