//! Non-i.i.d. federated data substrate: synthetic dataset family
//! (paper-dataset stand-ins, DESIGN.md §2), label-skew/Dirichlet
//! partitioners, and batch iteration.

pub mod loader;
pub mod partition;
pub mod synth;

pub use loader::{BatchIter, EvalBatches};
pub use partition::Partition;
pub use synth::{generate, ClientData, DatasetName, DatasetSpec, FederatedData};
