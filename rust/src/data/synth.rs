//! Synthetic federated dataset family — the stand-in for MNIST / FMNIST /
//! CIFAR-10 / CIFAR-100 / SVHN (no network access in this environment;
//! DESIGN.md §2 documents the substitution).
//!
//! Generative model (prototype clusters):
//!   * every class c has a global prototype  p_c ~ proto_scale · N(0, I/√d)
//!   * every client k has a domain shift     s_k ~ shift_scale · N(0, I/√d)
//!     (the paper's "diverse user behaviors and environments")
//!   * a sample of class c on client k is    x = p_c + s_k + noise · N(0, I)
//!
//! The paper's phenomenon needs exactly two ingredients, both present:
//! label-skew across clients (partition.rs) and per-client distribution
//! shift — under these, a single global model (especially a 1-bit
//! compressed one) underperforms personalized models on each client's own
//! test distribution. The five presets form the same difficulty ladder as
//! the real datasets (higher noise / more classes / higher dim ⇒ harder).

use crate::data::partition::Partition;
use crate::util::rng::Rng;

/// Which paper dataset a synthetic workload emulates.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DatasetName {
    /// handwritten digits (784-d, 10 classes) — the easiest preset
    Mnist,
    /// fashion articles (784-d, 10 classes)
    Fmnist,
    /// natural images (3072-d, 10 classes)
    Cifar10,
    /// natural images (3072-d, 100 classes) — the hardest preset
    Cifar100,
    /// street-view digits (3072-d, 10 classes)
    Svhn,
}

impl DatasetName {
    /// Parse a dataset name (common synonyms accepted).
    pub fn parse(s: &str) -> Option<DatasetName> {
        Some(match s.to_ascii_lowercase().as_str() {
            "mnist" => DatasetName::Mnist,
            "fmnist" | "fashion-mnist" | "fashionmnist" => DatasetName::Fmnist,
            "cifar10" | "cifar-10" => DatasetName::Cifar10,
            "cifar100" | "cifar-100" => DatasetName::Cifar100,
            "svhn" => DatasetName::Svhn,
            _ => return None,
        })
    }

    /// Canonical lowercase name (inverse of [`DatasetName::parse`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            DatasetName::Mnist => "mnist",
            DatasetName::Fmnist => "fmnist",
            DatasetName::Cifar10 => "cifar10",
            DatasetName::Cifar100 => "cifar100",
            DatasetName::Svhn => "svhn",
        }
    }

    /// Every dataset, in Table-2 column order.
    pub fn all() -> [DatasetName; 5] {
        [
            DatasetName::Mnist,
            DatasetName::Fmnist,
            DatasetName::Cifar10,
            DatasetName::Cifar100,
            DatasetName::Svhn,
        ]
    }

    /// Which AOT model variant serves this dataset (DESIGN.md §6).
    pub fn model_variant(&self) -> &'static str {
        match self {
            DatasetName::Mnist | DatasetName::Fmnist => "mlp784",
            DatasetName::Cifar10 | DatasetName::Svhn => "mlp3072",
            DatasetName::Cifar100 => "mlp3072c100",
        }
    }

    /// The synthetic generative parameters emulating this dataset.
    pub fn spec(&self) -> DatasetSpec {
        match self {
            // difficulty ladder: mnist easiest … cifar100 hardest
            DatasetName::Mnist => DatasetSpec {
                name: *self,
                input_dim: 784,
                classes: 10,
                noise: 0.90,
                proto_scale: 3.2,
                shift_scale: 0.55,
                train_per_client: 300,
                test_per_client: 200,
            },
            DatasetName::Fmnist => DatasetSpec {
                name: *self,
                input_dim: 784,
                classes: 10,
                noise: 1.35,
                proto_scale: 2.2,
                shift_scale: 0.65,
                train_per_client: 300,
                test_per_client: 200,
            },
            DatasetName::Svhn => DatasetSpec {
                name: *self,
                input_dim: 3072,
                classes: 10,
                noise: 1.00,
                proto_scale: 2.9,
                shift_scale: 0.55,
                train_per_client: 300,
                test_per_client: 120,
            },
            DatasetName::Cifar10 => DatasetSpec {
                name: *self,
                input_dim: 3072,
                classes: 10,
                noise: 1.50,
                proto_scale: 2.0,
                shift_scale: 0.75,
                train_per_client: 300,
                test_per_client: 120,
            },
            DatasetName::Cifar100 => DatasetSpec {
                name: *self,
                input_dim: 3072,
                classes: 100,
                noise: 1.20,
                proto_scale: 2.0,
                shift_scale: 0.55,
                train_per_client: 400,
                test_per_client: 120,
            },
        }
    }
}

/// Geometry + generative parameters for a synthetic dataset.
#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    /// which paper dataset this spec emulates
    pub name: DatasetName,
    /// input feature dimension d
    pub input_dim: usize,
    /// number of classes
    pub classes: usize,
    /// per-coordinate sample noise sigma
    pub noise: f32,
    /// prototype magnitude (inter-class margin)
    pub proto_scale: f32,
    /// per-client domain-shift magnitude (drives personalization gains)
    pub shift_scale: f32,
    /// training samples per client
    pub train_per_client: usize,
    /// held-out test samples per client
    pub test_per_client: usize,
}

/// One client's private shard: train + held-out test from the SAME local
/// distribution (the paper's personalized evaluation protocol).
#[derive(Clone, Debug)]
pub struct ClientData {
    /// row-major [samples, input_dim]
    pub train_x: Vec<f32>,
    /// training labels
    pub train_y: Vec<i32>,
    /// row-major test features
    pub test_x: Vec<f32>,
    /// test labels
    pub test_y: Vec<i32>,
    /// classes this client observes (label-skew partition)
    pub classes: Vec<usize>,
    /// input feature dimension d
    pub input_dim: usize,
}

impl ClientData {
    /// Number of training samples.
    pub fn train_len(&self) -> usize {
        self.train_y.len()
    }

    /// Number of test samples.
    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }
}

/// A fully materialized federated dataset.
#[derive(Clone, Debug)]
pub struct FederatedData {
    /// the generative spec this dataset was drawn from
    pub spec: DatasetSpec,
    /// every client's private shard
    pub clients: Vec<ClientData>,
    /// aggregation weights p_k = N_k / Σ N_i (paper's convention)
    pub weights: Vec<f32>,
}

impl FederatedData {
    /// Number of clients K.
    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }
}

/// Generate the federated dataset for `num_clients` under `partition`.
pub fn generate(
    spec: &DatasetSpec,
    num_clients: usize,
    partition: &Partition,
    seed: u64,
) -> FederatedData {
    let mut rng = Rng::new(seed ^ 0x4441_5441_u64); // "DATA"
    let d = spec.input_dim;
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();

    // global class prototypes
    let mut protos: Vec<Vec<f32>> = Vec::with_capacity(spec.classes);
    for _ in 0..spec.classes {
        let mut p = vec![0.0f32; d];
        rng.fill_normal(&mut p, spec.proto_scale * inv_sqrt_d);
        protos.push(p);
    }

    let class_assignment = partition.assign(num_clients, spec.classes, &mut rng);

    let mut clients = Vec::with_capacity(num_clients);
    for k in 0..num_clients {
        let mut crng = rng.fork(k as u64);
        // client domain shift
        let mut shift = vec![0.0f32; d];
        crng.fill_normal(&mut shift, spec.shift_scale * inv_sqrt_d);

        let classes = &class_assignment[k];
        assert!(!classes.is_empty(), "client {k} got no classes");

        let gen_split = |crng: &mut Rng, count: usize| -> (Vec<f32>, Vec<i32>) {
            let mut xs = Vec::with_capacity(count * d);
            let mut ys = Vec::with_capacity(count);
            for i in 0..count {
                // round-robin over the client's classes keeps shards
                // class-balanced (paper partitions whole label shards)
                let c = classes[i % classes.len()];
                let proto = &protos[c];
                for j in 0..d {
                    // isotropic noise: its projection on any discriminant
                    // direction has std = spec.noise, comparable to the
                    // O(proto_scale) class separation — the ratio sets the
                    // Bayes error, i.e. the dataset's difficulty rung
                    xs.push(proto[j] + shift[j] + spec.noise * crng.normal());
                }
                ys.push(c as i32);
            }
            (xs, ys)
        };

        let (train_x, train_y) = gen_split(&mut crng, spec.train_per_client);
        let (test_x, test_y) = gen_split(&mut crng, spec.test_per_client);
        clients.push(ClientData {
            train_x,
            train_y,
            test_x,
            test_y,
            classes: classes.clone(),
            input_dim: d,
        });
    }

    let total: f32 = clients.iter().map(|c| c.train_len() as f32).sum();
    let weights = clients
        .iter()
        .map(|c| c.train_len() as f32 / total)
        .collect();
    FederatedData {
        spec: *spec,
        clients,
        weights,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::Partition;

    fn small_spec() -> DatasetSpec {
        DatasetSpec {
            name: DatasetName::Mnist,
            input_dim: 16,
            classes: 10,
            noise: 0.5,
            proto_scale: 2.0,
            shift_scale: 0.5,
            train_per_client: 40,
            test_per_client: 10,
        }
    }

    #[test]
    fn dataset_name_parsing() {
        assert_eq!(DatasetName::parse("MNIST"), Some(DatasetName::Mnist));
        assert_eq!(DatasetName::parse("cifar-100"), Some(DatasetName::Cifar100));
        assert_eq!(DatasetName::parse("bogus"), None);
        for n in DatasetName::all() {
            assert_eq!(DatasetName::parse(n.as_str()), Some(n));
        }
    }

    #[test]
    fn variant_mapping_matches_design() {
        assert_eq!(DatasetName::Mnist.model_variant(), "mlp784");
        assert_eq!(DatasetName::Fmnist.model_variant(), "mlp784");
        assert_eq!(DatasetName::Cifar10.model_variant(), "mlp3072");
        assert_eq!(DatasetName::Svhn.model_variant(), "mlp3072");
        assert_eq!(DatasetName::Cifar100.model_variant(), "mlp3072c100");
    }

    #[test]
    fn shapes_and_weights() {
        let spec = small_spec();
        let fd = generate(&spec, 8, &Partition::LabelShards { per_client: 2 }, 1);
        assert_eq!(fd.num_clients(), 8);
        for c in &fd.clients {
            assert_eq!(c.train_x.len(), 40 * 16);
            assert_eq!(c.train_y.len(), 40);
            assert_eq!(c.test_x.len(), 10 * 16);
            assert_eq!(c.classes.len(), 2);
        }
        let wsum: f32 = fd.weights.iter().sum();
        assert!((wsum - 1.0).abs() < 1e-5);
    }

    #[test]
    fn labels_respect_partition() {
        let spec = small_spec();
        let fd = generate(&spec, 10, &Partition::LabelShards { per_client: 2 }, 2);
        for c in &fd.clients {
            for &y in c.train_y.iter().chain(&c.test_y) {
                assert!(c.classes.contains(&(y as usize)), "label {y} not in {:?}", c.classes);
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = small_spec();
        let a = generate(&spec, 4, &Partition::LabelShards { per_client: 2 }, 3);
        let b = generate(&spec, 4, &Partition::LabelShards { per_client: 2 }, 3);
        assert_eq!(a.clients[0].train_x, b.clients[0].train_x);
        assert_eq!(a.clients[3].test_y, b.clients[3].test_y);
        let c = generate(&spec, 4, &Partition::LabelShards { per_client: 2 }, 4);
        assert_ne!(a.clients[0].train_x, c.clients[0].train_x);
    }

    #[test]
    fn class_separation_exceeds_noise() {
        // prototypes should be separated enough that a local model can
        // learn: mean intra-class distance < mean inter-class distance
        let spec = small_spec();
        let fd = generate(&spec, 2, &Partition::LabelShards { per_client: 2 }, 5);
        let c = &fd.clients[0];
        let d = c.input_dim;
        let sample = |i: usize| &c.train_x[i * d..(i + 1) * d];
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter()
                .zip(b)
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let mut intra = (0.0, 0usize);
        let mut inter = (0.0, 0usize);
        for i in 0..c.train_len() {
            for j in (i + 1)..c.train_len() {
                let dd = dist(sample(i), sample(j));
                if c.train_y[i] == c.train_y[j] {
                    intra = (intra.0 + dd, intra.1 + 1);
                } else {
                    inter = (inter.0 + dd, inter.1 + 1);
                }
            }
        }
        let intra_mean = intra.0 / intra.1 as f64;
        let inter_mean = inter.0 / inter.1 as f64;
        assert!(
            inter_mean > 1.15 * intra_mean,
            "separation too weak: intra {intra_mean} inter {inter_mean}"
        );
    }

    #[test]
    fn client_shift_differentiates_clients() {
        // same class on two clients should differ by more than noise alone
        let spec = small_spec();
        let fd = generate(&spec, 10, &Partition::LabelShards { per_client: 10 }, 6);
        let d = spec.input_dim;
        // class 0 mean on each client
        let mean_of = |k: usize| -> Vec<f64> {
            let c = &fd.clients[k];
            let mut acc = vec![0.0f64; d];
            let mut cnt = 0;
            for (i, &y) in c.train_y.iter().enumerate() {
                if y == 0 {
                    for j in 0..d {
                        acc[j] += c.train_x[i * d + j] as f64;
                    }
                    cnt += 1;
                }
            }
            acc.iter_mut().for_each(|a| *a /= cnt.max(1) as f64);
            acc
        };
        let m0 = mean_of(0);
        let m1 = mean_of(1);
        let shift_dist: f64 = m0
            .iter()
            .zip(&m1)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(shift_dist > 0.05, "client means too close: {shift_dist}");
    }
}
