//! Non-i.i.d. partitioners: which classes each client observes.
//!
//! The paper's main experiments use hard label-skew ("partitioning data
//! among 20 clients based on labels" — 2 classes per client for the
//! 10-class datasets). The Dirichlet partitioner parameterizes a
//! *continuum* of heterogeneity for the `heterogeneity_sweep` example
//! (α → 0 approaches one-class clients, α → ∞ approaches i.i.d.).

use crate::util::rng::Rng;

/// How classes are assigned to clients (the heterogeneity knob).
#[derive(Clone, Debug)]
pub enum Partition {
    /// Every client receives `per_client` distinct classes; shards are
    /// dealt so all classes are covered as evenly as possible.
    LabelShards { per_client: usize },
    /// Client k observes class c with probability from a symmetric
    /// Dirichlet(alpha) draw; classes below `min_share` are dropped, and
    /// every client keeps at least one class.
    Dirichlet { alpha: f64, min_share: f64 },
    /// Every client sees every class (i.i.d. control).
    Iid,
}

impl Partition {
    /// Returns, for each client, the sorted list of classes it observes.
    /// Guarantees: non-empty per client; classes < `classes`; under
    /// LabelShards the global shard multiset is balanced.
    pub fn assign(&self, num_clients: usize, classes: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        match self {
            Partition::Iid => (0..num_clients).map(|_| (0..classes).collect()).collect(),
            Partition::LabelShards { per_client } => {
                label_shards(num_clients, classes, *per_client, rng)
            }
            Partition::Dirichlet { alpha, min_share } => {
                dirichlet(num_clients, classes, *alpha, *min_share, rng)
            }
        }
    }

    /// One-line description for run summaries.
    pub fn describe(&self) -> String {
        match self {
            Partition::LabelShards { per_client } => format!("label-shards({per_client}/client)"),
            Partition::Dirichlet { alpha, .. } => format!("dirichlet(alpha={alpha})"),
            Partition::Iid => "iid".to_string(),
        }
    }
}

fn label_shards(
    num_clients: usize,
    classes: usize,
    per_client: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let per_client = per_client.min(classes).max(1);
    let total_shards = num_clients * per_client;
    // balanced shard pool: each class appears floor or ceil(total/classes)
    let mut pool: Vec<usize> = (0..total_shards).map(|i| i % classes).collect();
    rng.shuffle(&mut pool);

    let mut out: Vec<Vec<usize>> = vec![Vec::new(); num_clients];
    // deal avoiding duplicate classes within a client where possible
    for k in 0..num_clients {
        for _ in 0..per_client {
            // find first pool entry not already held by this client
            let pos = pool
                .iter()
                .position(|c| !out[k].contains(c))
                .unwrap_or(0);
            out[k].push(pool.swap_remove(pos));
        }
        out[k].sort_unstable();
        out[k].dedup();
    }
    out
}

fn dirichlet(
    num_clients: usize,
    classes: usize,
    alpha: f64,
    min_share: f64,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    (0..num_clients)
        .map(|_| {
            let probs = rng.dirichlet(alpha, classes);
            let mut kept: Vec<usize> = probs
                .iter()
                .enumerate()
                .filter(|(_, &p)| p >= min_share)
                .map(|(c, _)| c)
                .collect();
            if kept.is_empty() {
                // keep the argmax class
                let argmax = probs
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(c, _)| c)
                    .unwrap_or(0);
                kept.push(argmax);
            }
            kept
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn label_shards_paper_setting() {
        // 20 clients x 2 classes over 10 classes: every class appears 4x
        let mut rng = Rng::new(1);
        let assign = Partition::LabelShards { per_client: 2 }.assign(20, 10, &mut rng);
        assert_eq!(assign.len(), 20);
        let mut counts = vec![0usize; 10];
        for a in &assign {
            assert!(!a.is_empty() && a.len() <= 2);
            for &c in a {
                counts[c] += 1;
            }
        }
        // balanced pool ⇒ every class appears; dedup within client can
        // shave at most a few
        assert!(counts.iter().all(|&c| c >= 2), "{counts:?}");
    }

    #[test]
    fn label_shards_properties() {
        check("label_shards_valid", 40, |rng| {
            let k = rng.below(30) + 1;
            let classes = rng.below(20) + 1;
            let pc = rng.below(classes) + 1;
            let assign =
                Partition::LabelShards { per_client: pc }.assign(k, classes, rng);
            if assign.len() != k {
                return Err("wrong client count".into());
            }
            for a in &assign {
                if a.is_empty() {
                    return Err("empty client".into());
                }
                let mut s = a.clone();
                s.dedup();
                if s.len() != a.len() {
                    return Err("duplicate class within client".into());
                }
                if a.iter().any(|&c| c >= classes) {
                    return Err("class out of range".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dirichlet_small_alpha_is_skewed() {
        let mut rng = Rng::new(2);
        let assign = Partition::Dirichlet { alpha: 0.1, min_share: 0.05 }
            .assign(50, 10, &mut rng);
        let avg: f64 = assign.iter().map(|a| a.len() as f64).sum::<f64>() / 50.0;
        assert!(avg < 5.0, "alpha=0.1 should be skewed, avg classes {avg}");
        assert!(assign.iter().all(|a| !a.is_empty()));
    }

    #[test]
    fn dirichlet_large_alpha_is_broad() {
        let mut rng = Rng::new(3);
        let assign = Partition::Dirichlet { alpha: 100.0, min_share: 0.02 }
            .assign(50, 10, &mut rng);
        let avg: f64 = assign.iter().map(|a| a.len() as f64).sum::<f64>() / 50.0;
        assert!(avg > 8.0, "alpha=100 should be near-iid, avg classes {avg}");
    }

    #[test]
    fn iid_sees_everything() {
        let mut rng = Rng::new(4);
        let assign = Partition::Iid.assign(5, 7, &mut rng);
        for a in assign {
            assert_eq!(a, (0..7).collect::<Vec<_>>());
        }
    }

    #[test]
    fn describe_strings() {
        assert!(Partition::LabelShards { per_client: 2 }.describe().contains("2"));
        assert!(Partition::Dirichlet { alpha: 0.5, min_share: 0.0 }
            .describe()
            .contains("0.5"));
    }
}
