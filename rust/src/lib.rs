//! # pFed1BS — Personalized Federated Learning via One-Bit Random Sketching
//!
//! Rust implementation of the AAAI 2026 paper's system: the L3
//! coordinator (federated orchestration, one-bit bidirectional transport,
//! Lemma-1 server aggregation, all baselines) over AOT-compiled JAX/Pallas
//! compute artifacts executed through PJRT (the `xla` crate).
//!
//! Layer map (DESIGN.md §1):
//! * [`runtime`] — loads `artifacts/*.hlo.txt` (L2/L1 output) and executes
//!   client steps / sketches / eval on the CPU PJRT client.
//! * [`algorithms`] — pFed1BS (Algorithm 1) plus FedAvg, OBDA, OBCSAA,
//!   zSignFed, EDEN, FedBAT baselines behind the phased client/server
//!   message protocol (DESIGN.md §3).
//! * [`coordinator`] — round loop and transport owner: partial
//!   participation, data-parallel client phase, personalized
//!   evaluation, metrics.
//! * [`sketch`] — rust mirror of the SRHT operator, bit packing, majority
//!   vote.
//! * [`comm`] — wire codecs, byte ledger, and the [`comm::transport`]
//!   subsystem: a `Transport` trait over the simulated network and a
//!   socket-backed `StreamTransport` (DESIGN.md §12).
//! * [`serve`] — multi-process roles (`pfed1bs serve` / `edge` /
//!   `client-fleet` / `loadgen`) running real rounds over TCP or
//!   Unix-domain sockets with deterministic mock clients.
//! * [`data`] — synthetic non-i.i.d. federated datasets (DESIGN.md §2).
//! * [`experiments`] — regenerators for every table/figure in the paper.
//! * [`analysis`] — the paper's Theorem-1 constants/bounds made
//!   executable (`pfed1bs bound`).
//! * Substrates in [`util`], [`config`], [`bench_harness`] replace crates
//!   unavailable in the offline mirror (clap/criterion/serde/proptest).

// Every public item must carry rustdoc; CI builds the docs with
// `RUSTDOCFLAGS="-D warnings"`, so a missing doc fails the pipeline
// instead of rotting silently.
#![warn(missing_docs)]
// Unsafe code (the explicit-SIMD butterflies in `sketch::kernel`, the
// unaligned word reads in `sketch::bitpack`) must scope each unsafe
// operation in its own block with its own `// SAFETY:` argument — an
// `unsafe fn` body gives no blanket license.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod algorithms;
pub mod analysis;
pub mod bench_harness;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod runtime;
pub mod serve;
pub mod sketch;
pub mod util;
