//! Table 2 regenerator: Top-1 accuracy (%) and per-round communication
//! cost (MB) for every algorithm × dataset, with the ↓% reduction column
//! computed against FedAvg exactly as the paper prints it.

use std::io::Write;

use anyhow::Result;

use crate::algorithms::all_names;
use crate::config::RunConfig;
use crate::data::DatasetName;
use crate::experiments::runner::{aggregate, seed_list, Aggregate, Lab};

/// One (algorithm, dataset) cell.
#[derive(Clone, Debug)]
pub struct Cell {
    /// algorithm row
    pub algorithm: String,
    /// dataset column
    pub dataset: DatasetName,
    /// mean ± std accuracy/cost across the seeds
    pub agg: Aggregate,
}

/// Knobs for the Table 2 regenerator.
pub struct Table2Options {
    /// dataset columns (defaults to all five)
    pub datasets: Vec<DatasetName>,
    /// algorithm rows (defaults to every registered name)
    pub algorithms: Vec<String>,
    /// seeds per cell
    pub seeds: usize,
    /// override preset rounds (0 = keep preset)
    pub rounds: usize,
    /// where to write table2.csv / table2.md
    pub results_dir: String,
}

impl Default for Table2Options {
    fn default() -> Self {
        Table2Options {
            datasets: DatasetName::all().to_vec(),
            algorithms: all_names().iter().map(|s| s.to_string()).collect(),
            seeds: 3,
            rounds: 0,
            results_dir: "results".into(),
        }
    }
}

/// Run every (algorithm × dataset × seed) cell and write the CSV +
/// markdown outputs.
pub fn run(lab: &Lab, opts: &Table2Options) -> Result<Vec<Cell>> {
    let mut cells = Vec::new();
    for &dataset in &opts.datasets {
        for alg in &opts.algorithms {
            let mut cfg = RunConfig::preset(dataset);
            cfg.algorithm = alg.clone();
            if opts.rounds > 0 {
                cfg.rounds = opts.rounds;
            }
            let seeds = seed_list(cfg.seed, opts.seeds);
            eprintln!("[table2] {} × {} ({} seeds)…", alg, dataset.as_str(), seeds.len());
            let results = lab.run_seeds(&cfg, &seeds)?;
            cells.push(Cell {
                algorithm: alg.clone(),
                dataset,
                agg: aggregate(&results),
            });
        }
    }
    write_outputs(&cells, opts)?;
    Ok(cells)
}

fn cost_of(cells: &[Cell], alg: &str, ds: DatasetName) -> Option<f64> {
    cells
        .iter()
        .find(|c| c.algorithm == alg && c.dataset == ds)
        .map(|c| c.agg.cost_mb_mean)
}

/// Render the markdown table (the paper's Table 2 layout).
pub fn render_markdown(cells: &[Cell], datasets: &[DatasetName], algorithms: &[String]) -> String {
    let mut out = String::new();
    out.push_str("| Method |");
    for d in datasets {
        out.push_str(&format!(" {} Acc. (%) | Cost (MB) |", d.as_str()));
    }
    out.push('\n');
    out.push_str("|---|");
    for _ in datasets {
        out.push_str("---|---|");
    }
    out.push('\n');
    for alg in algorithms {
        out.push_str(&format!("| {alg} |"));
        for &d in datasets {
            let Some(cell) = cells
                .iter()
                .find(|c| &c.algorithm == alg && c.dataset == d)
            else {
                out.push_str(" – | – |");
                continue;
            };
            let fed = cost_of(cells, "fedavg", d);
            let reduction = fed
                .filter(|&f| f > 0.0 && alg != "fedavg")
                .map(|f| format!(" ↓{:.2}%", 100.0 * (1.0 - cell.agg.cost_mb_mean / f)))
                .unwrap_or_default();
            out.push_str(&format!(
                " {:.2} ± {:.2} | {:.2}{} |",
                100.0 * cell.agg.acc_mean,
                100.0 * cell.agg.acc_std,
                cell.agg.cost_mb_mean,
                reduction
            ));
        }
        out.push('\n');
    }
    out
}

fn write_outputs(cells: &[Cell], opts: &Table2Options) -> Result<()> {
    std::fs::create_dir_all(&opts.results_dir).ok();
    // CSV
    let csv_path = format!("{}/table2.csv", opts.results_dir);
    let mut f = std::fs::File::create(&csv_path)?;
    writeln!(f, "algorithm,dataset,acc_mean,acc_std,cost_mb,runs")?;
    for c in cells {
        writeln!(
            f,
            "{},{},{:.6},{:.6},{:.6},{}",
            c.algorithm,
            c.dataset.as_str(),
            c.agg.acc_mean,
            c.agg.acc_std,
            c.agg.cost_mb_mean,
            c.agg.runs
        )?;
    }
    // Markdown
    let datasets: Vec<DatasetName> = {
        let mut ds = Vec::new();
        for c in cells {
            if !ds.contains(&c.dataset) {
                ds.push(c.dataset);
            }
        }
        ds
    };
    let algorithms: Vec<String> = {
        let mut al = Vec::new();
        for c in cells {
            if !al.contains(&c.algorithm) {
                al.push(c.algorithm.clone());
            }
        }
        al
    };
    let md = render_markdown(cells, &datasets, &algorithms);
    std::fs::write(format!("{}/table2.md", opts.results_dir), &md)?;
    println!("\n=== Table 2 (accuracy % / cost MB per round) ===\n{md}");
    println!("written: {csv_path} and table2.md");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::runner::Aggregate;

    fn cell(alg: &str, ds: DatasetName, acc: f64, cost: f64) -> Cell {
        Cell {
            algorithm: alg.into(),
            dataset: ds,
            agg: Aggregate { acc_mean: acc, acc_std: 0.01, cost_mb_mean: cost, runs: 3 },
        }
    }

    #[test]
    fn markdown_contains_reduction_vs_fedavg() {
        let cells = vec![
            cell("fedavg", DatasetName::Mnist, 0.97, 32.0),
            cell("pfed1bs", DatasetName::Mnist, 0.975, 0.1),
        ];
        let md = render_markdown(
            &cells,
            &[DatasetName::Mnist],
            &["fedavg".into(), "pfed1bs".into()],
        );
        assert!(md.contains("↓99.69%"), "{md}");
        assert!(md.contains("97.50"), "{md}");
    }

    #[test]
    fn missing_cells_render_dashes() {
        let cells = vec![cell("fedavg", DatasetName::Mnist, 0.9, 32.0)];
        let md = render_markdown(
            &cells,
            &[DatasetName::Mnist],
            &["fedavg".into(), "pfed1bs".into()],
        );
        assert!(md.contains("–"));
    }
}
