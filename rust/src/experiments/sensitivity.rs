//! Appendix Table 1 regenerator: hyperparameter sensitivity of pFed1BS
//! (λ across six orders of magnitude, μ, γ) on CIFAR-10 (non-i.i.d.).
//! Hyperparameters are runtime scalars in the AOT artifacts, so the whole
//! sweep reuses one compiled executable set.

use std::io::Write;

use anyhow::Result;

use crate::config::RunConfig;
use crate::data::DatasetName;
use crate::experiments::runner::{aggregate, seed_list, Lab};

/// Knobs for the Appendix Table 1 sensitivity sweep.
pub struct SensitivityOptions {
    /// dataset to sweep on (the paper uses CIFAR-10)
    pub dataset: DatasetName,
    /// override preset rounds (0 = keep preset)
    pub rounds: usize,
    /// seeds per grid cell
    pub seeds: usize,
    /// base seed the per-cell seed list derives from
    pub seed: u64,
    /// where to write the sensitivity CSV
    pub results_dir: String,
}

impl Default for SensitivityOptions {
    fn default() -> Self {
        SensitivityOptions {
            dataset: DatasetName::Cifar10,
            rounds: 0,
            seeds: 2,
            seed: 17,
            results_dir: "results".into(),
        }
    }
}

/// The paper's grid (Appendix Table 1).
pub fn paper_grid() -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    (
        vec![5e-7, 5e-6, 5e-5, 5e-4, 5e-2, 5e-1], // lambda
        vec![1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1], // mu
        vec![1e1, 1e2, 1e3, 1e4, 1e5, 1e6],       // gamma
    )
}

/// Sweep λ/μ/γ over the paper's grid and write the sensitivity CSV.
pub fn run(lab: &Lab, opts: &SensitivityOptions) -> Result<()> {
    let (lambdas, mus, gammas) = paper_grid();
    let dir = format!("{}/table_a1", opts.results_dir);
    std::fs::create_dir_all(&dir).ok();

    let mut csv = String::from("param,value,acc_mean,acc_std,runs\n");
    for (param, values) in [("lambda", lambdas), ("mu", mus), ("gamma", gammas)] {
        for &v in &values {
            let mut cfg = RunConfig::preset(opts.dataset);
            cfg.algorithm = "pfed1bs".into();
            if opts.rounds > 0 {
                cfg.rounds = opts.rounds;
            }
            match param {
                "lambda" => cfg.lambda = v,
                "mu" => cfg.mu = v,
                "gamma" => cfg.gamma = v,
                _ => unreachable!(),
            }
            let seeds = seed_list(opts.seed, opts.seeds);
            eprintln!("[table-a1] {param}={v:e} ({} seeds)…", seeds.len());
            let results = lab.run_seeds(&cfg, &seeds)?;
            let agg = aggregate(&results);
            csv.push_str(&format!(
                "{param},{v:e},{:.6},{:.6},{}\n",
                agg.acc_mean, agg.acc_std, agg.runs
            ));
        }
    }
    std::fs::File::create(format!("{dir}/sensitivity.csv"))?.write_all(csv.as_bytes())?;
    println!("\n=== Appendix Table 1 (sensitivity, {}) ===\n{csv}", opts.dataset.as_str());
    Ok(())
}
