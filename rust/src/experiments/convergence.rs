//! Figures 3 & 4 regenerator: per-round test accuracy and training loss
//! on MNIST (non-i.i.d.) for every method, written as one CSV per
//! algorithm (results/fig3_4/<alg>.csv) plus a combined summary.

use std::io::Write;

use anyhow::Result;

use crate::algorithms::all_names;
use crate::config::RunConfig;
use crate::data::DatasetName;
use crate::experiments::runner::Lab;

/// Knobs for the Fig. 3/4 convergence-curve regenerator.
pub struct ConvergenceOptions {
    /// dataset the curves are drawn on (the paper uses MNIST)
    pub dataset: DatasetName,
    /// which algorithms to run (defaults to every Table-2 row)
    pub algorithms: Vec<String>,
    /// override preset rounds (0 = keep preset)
    pub rounds: usize,
    /// run seed
    pub seed: u64,
    /// record the Theorem-1 gradient-norm diagnostic for pFed1BS
    pub diagnostics: bool,
    /// where to write the per-algorithm CSVs
    pub results_dir: String,
}

impl Default for ConvergenceOptions {
    fn default() -> Self {
        ConvergenceOptions {
            dataset: DatasetName::Mnist,
            algorithms: all_names().iter().map(|s| s.to_string()).collect(),
            rounds: 0,
            seed: 17,
            diagnostics: false,
            results_dir: "results".into(),
        }
    }
}

/// Run every configured algorithm and write the per-round curves plus a
/// combined summary CSV.
pub fn run(lab: &Lab, opts: &ConvergenceOptions) -> Result<()> {
    let dir = format!("{}/fig3_4", opts.results_dir);
    std::fs::create_dir_all(&dir).ok();

    let mut summary = String::from("algorithm,final_acc,best_acc,final_train_loss,mean_round_mb\n");
    for alg in &opts.algorithms {
        let mut cfg = RunConfig::preset(opts.dataset);
        cfg.algorithm = alg.clone();
        cfg.seed = opts.seed;
        cfg.eval_every = 1; // per-round curves
        if opts.rounds > 0 {
            cfg.rounds = opts.rounds;
        }
        eprintln!("[fig3-4] {} on {}…", alg, opts.dataset.as_str());
        let result = lab.run_with_diagnostics(cfg.clone(), opts.diagnostics && alg == "pfed1bs")?;
        result
            .history
            .write_csv(format!("{dir}/{alg}.csv"), &cfg.summary())?;
        let final_train = result
            .history
            .records
            .last()
            .map(|r| r.train_loss)
            .unwrap_or(f64::NAN);
        summary.push_str(&format!(
            "{alg},{:.6},{:.6},{:.6},{:.6}\n",
            result.final_accuracy,
            result.history.best_accuracy().unwrap_or(0.0),
            final_train,
            result.mean_round_mb
        ));
    }
    let mut f = std::fs::File::create(format!("{dir}/summary.csv"))?;
    f.write_all(summary.as_bytes())?;
    println!("\n=== Fig 3/4 ({}) ===\n{summary}", opts.dataset.as_str());
    println!("per-round curves: {dir}/<algorithm>.csv");
    Ok(())
}
