//! Appendix ablations:
//!   Fig. A1 — participating clients S ∈ {5, 10, 15, 20}
//!   Fig. A2 — local steps R ∈ {5, 10, 20, 25, 30}
//!   Fig. A3 — FHT (SRHT) vs dense Gaussian projection
//! Each writes per-round CSVs (curves) + a summary table.

use std::io::Write;

use anyhow::Result;

use crate::config::{ProjectionKind, RunConfig};
use crate::data::DatasetName;
use crate::experiments::runner::Lab;

/// Shared knobs for the appendix-figure ablation sweeps.
pub struct AblationOptions {
    /// dataset to ablate on (appendix figures use MNIST)
    pub dataset: DatasetName,
    /// override preset rounds (0 = keep preset)
    pub rounds: usize,
    /// run seed
    pub seed: u64,
    /// where to write the per-sweep CSVs
    pub results_dir: String,
}

impl Default for AblationOptions {
    fn default() -> Self {
        AblationOptions {
            dataset: DatasetName::Mnist,
            rounds: 0,
            seed: 17,
            results_dir: "results".into(),
        }
    }
}

fn base_cfg(opts: &AblationOptions) -> RunConfig {
    let mut cfg = RunConfig::preset(opts.dataset);
    cfg.seed = opts.seed;
    cfg.eval_every = 1;
    if opts.rounds > 0 {
        cfg.rounds = opts.rounds;
    }
    cfg
}

/// Appendix Fig. 1: effect of the number of participating clients S.
pub fn participation(lab: &Lab, opts: &AblationOptions, values: &[usize]) -> Result<()> {
    let dir = format!("{}/fig_a1", opts.results_dir);
    std::fs::create_dir_all(&dir).ok();
    let mut summary = String::from("S,final_acc,final_train_loss\n");
    for &s in values {
        let mut cfg = base_cfg(opts);
        cfg.participating = s.min(cfg.clients);
        eprintln!("[fig-a1] S={}…", cfg.participating);
        let r = lab.run(cfg.clone())?;
        r.history.write_csv(format!("{dir}/S{}.csv", cfg.participating), &cfg.summary())?;
        summary.push_str(&format!(
            "{},{:.6},{:.6}\n",
            cfg.participating,
            r.final_accuracy,
            r.history.records.last().map(|x| x.train_loss).unwrap_or(f64::NAN)
        ));
    }
    std::fs::File::create(format!("{dir}/summary.csv"))?.write_all(summary.as_bytes())?;
    println!("\n=== Appendix Fig. 1 (participation) ===\n{summary}");
    Ok(())
}

/// Appendix Fig. 2: effect of local steps R.
pub fn local_steps(lab: &Lab, opts: &AblationOptions, values: &[usize]) -> Result<()> {
    let dir = format!("{}/fig_a2", opts.results_dir);
    std::fs::create_dir_all(&dir).ok();
    let mut summary = String::from("R,final_acc,final_train_loss\n");
    for &r_steps in values {
        let mut cfg = base_cfg(opts);
        cfg.local_steps = r_steps;
        eprintln!("[fig-a2] R={r_steps}…");
        let r = lab.run(cfg.clone())?;
        r.history.write_csv(format!("{dir}/R{r_steps}.csv"), &cfg.summary())?;
        summary.push_str(&format!(
            "{},{:.6},{:.6}\n",
            r_steps,
            r.final_accuracy,
            r.history.records.last().map(|x| x.train_loss).unwrap_or(f64::NAN)
        ));
    }
    std::fs::File::create(format!("{dir}/summary.csv"))?.write_all(summary.as_bytes())?;
    println!("\n=== Appendix Fig. 2 (local steps) ===\n{summary}");
    Ok(())
}

/// Appendix Fig. 3: FHT-structured vs dense-Gaussian projection — the
/// paper's claim is that the curves are nearly identical.
///
/// The dense path costs O(mn) per regularizer gradient (~10⁹ MACs at
/// mlp784 scale, on one core) — that cost *is* the paper's motivation
/// for the FHT. The comparison therefore runs at a reduced federation
/// scale (fewer clients/rounds/steps, identical per-client problem);
/// accuracy parity is unaffected by the federation size.
pub fn projection(lab: &Lab, opts: &AblationOptions) -> Result<()> {
    let dir = format!("{}/fig_a3", opts.results_dir);
    std::fs::create_dir_all(&dir).ok();
    let mut summary = String::from("projection,final_acc,final_train_loss\n");
    for kind in [ProjectionKind::Fht, ProjectionKind::DenseGaussian] {
        let mut cfg = base_cfg(opts);
        cfg.projection = kind;
        cfg.clients = 6;
        cfg.participating = 6;
        cfg.local_steps = 4;
        if opts.rounds == 0 {
            cfg.rounds = 12;
        }
        eprintln!("[fig-a3] projection={}…", kind.as_str());
        let r = lab.run(cfg.clone())?;
        r.history
            .write_csv(format!("{dir}/{}.csv", kind.as_str()), &cfg.summary())?;
        summary.push_str(&format!(
            "{},{:.6},{:.6}\n",
            kind.as_str(),
            r.final_accuracy,
            r.history.records.last().map(|x| x.train_loss).unwrap_or(f64::NAN)
        ));
    }
    std::fs::File::create(format!("{dir}/summary.csv"))?.write_all(summary.as_bytes())?;
    println!("\n=== Appendix Fig. 3 (FHT vs dense Gaussian) ===\n{summary}");
    Ok(())
}
