//! Regenerators for every table and figure in the paper's evaluation
//! (experiment index in DESIGN.md §7):
//!
//! | paper artifact | module | CLI |
//! |---|---|---|
//! | Table 1 (capability matrix)   | `table1`      | `pfed1bs table1` |
//! | Table 2 (acc + MB/round)      | `table2`      | `pfed1bs table2` |
//! | Fig. 3/4 (MNIST curves)       | `convergence` | `pfed1bs fig3-4` |
//! | Appendix Fig. 1 (S sweep)     | `ablations`   | `pfed1bs fig-a1` |
//! | Appendix Fig. 2 (R sweep)     | `ablations`   | `pfed1bs fig-a2` |
//! | Appendix Fig. 3 (FHT/dense)   | `ablations`   | `pfed1bs fig-a3` |
//! | Appendix Table 1 (λ/μ/γ)      | `sensitivity` | `pfed1bs table-a1` |

pub mod ablations;
pub mod convergence;
pub mod runner;
pub mod sensitivity;
pub mod table2;

use crate::algorithms;

/// Table 1: print the capability matrix straight from the algorithms'
/// self-declared capabilities (kept in sync by the unit test in
/// `algorithms::tests::capability_matrix_matches_table1`).
pub fn print_table1() {
    let check = |b: bool| if b { "✓" } else { "×" };
    println!("| Algorithm | Up Dim.Red. | Up 1-bit | Down Dim.Red. | Down 1-bit | Personalization |");
    println!("|---|---|---|---|---|---|");
    for name in algorithms::all_names() {
        let alg = algorithms::build(name).expect("registered");
        let c = alg.capabilities();
        println!(
            "| {name} | {} | {} | {} | {} | {} |",
            check(c.upload_dim_reduction),
            check(c.upload_one_bit),
            check(c.download_dim_reduction),
            check(c.download_one_bit),
            check(c.personalization),
        );
    }
}

pub use runner::{aggregate, seed_list, Lab};
