//! Experiment lab: shares one PJRT client and per-variant compiled
//! executables across a sweep, binding a fresh SRHT realization per run
//! seed (two device uploads instead of a multi-second recompile).

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::algorithms;
use crate::config::RunConfig;
use crate::coordinator::{Coordinator, RunResult};
use crate::runtime::{ModelExecutables, ModelRuntime, Runtime};
use crate::sketch::SrhtOperator;
use crate::util::stats::{mean, stddev};

/// Shared experiment context: one PJRT client plus a per-variant cache
/// of compiled executables.
pub struct Lab {
    /// the underlying PJRT client + artifact manifest
    pub runtime: Runtime,
    cache: RefCell<HashMap<String, Arc<ModelExecutables>>>,
}

impl Lab {
    /// Open the artifacts directory and create the PJRT CPU client.
    pub fn new(artifacts_dir: &str) -> Result<Lab> {
        Ok(Lab {
            runtime: Runtime::new(artifacts_dir)?,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Compiled executables for a variant (cached).
    pub fn executables(&self, variant: &str) -> Result<Arc<ModelExecutables>> {
        self.executables_batched(variant, 1)
    }

    /// Compiled executables for a variant at a cohort batch width
    /// (cached per `(variant, device_batch)` so a sweep mixing batched
    /// and unbatched runs never recompiles).
    pub fn executables_batched(
        &self,
        variant: &str,
        device_batch: usize,
    ) -> Result<Arc<ModelExecutables>> {
        let key = format!("{variant}#b{device_batch}");
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        crate::info!("compiling artifacts for variant `{variant}` (device-batch {device_batch}) …");
        let exes = self.runtime.load_variant_batched(variant, device_batch)?;
        self.cache.borrow_mut().insert(key, exes.clone());
        Ok(exes)
    }

    /// A model runtime bound to the run's seed-derived SRHT operator.
    pub fn model_for(&self, cfg: &RunConfig) -> Result<ModelRuntime> {
        let exes = self.executables_batched(
            cfg.dataset.model_variant(),
            cfg.effective_device_batch(),
        )?;
        let op = SrhtOperator::from_seed(cfg.seed, exes.geom.n, exes.geom.m);
        ModelRuntime::bind(exes, &op)
    }

    /// One full training run.
    pub fn run(&self, cfg: RunConfig) -> Result<RunResult> {
        self.run_with_diagnostics(cfg, false)
    }

    /// One full training run, optionally recording the Theorem-1
    /// gradient-norm diagnostic every eval round.
    pub fn run_with_diagnostics(&self, cfg: RunConfig, diag: bool) -> Result<RunResult> {
        let model = self.model_for(&cfg)?;
        let mut alg = algorithms::build(&cfg.algorithm)?;
        let mut coord = Coordinator::new(cfg, &model);
        coord.run_with_diagnostics(alg.as_mut(), diag)
    }

    /// Repeat a run across seeds; returns per-seed results.
    pub fn run_seeds(&self, base: &RunConfig, seeds: &[u64]) -> Result<Vec<RunResult>> {
        seeds
            .iter()
            .map(|&s| {
                let mut cfg = base.clone();
                cfg.seed = s;
                self.run(cfg)
            })
            .collect()
    }
}

/// mean ± std accuracy/cost across seeds.
#[derive(Clone, Debug)]
pub struct Aggregate {
    /// mean final accuracy across the seeds
    pub acc_mean: f64,
    /// sample standard deviation of the final accuracies
    pub acc_std: f64,
    /// mean per-round communication cost in MB
    pub cost_mb_mean: f64,
    /// how many runs went into this aggregate
    pub runs: usize,
}

/// Collapse per-seed results into the mean ± std cells Table 2 prints.
pub fn aggregate(results: &[RunResult]) -> Aggregate {
    let accs: Vec<f64> = results.iter().map(|r| r.final_accuracy).collect();
    let costs: Vec<f64> = results.iter().map(|r| r.mean_round_mb).collect();
    Aggregate {
        acc_mean: mean(&accs),
        acc_std: stddev(&accs),
        cost_mb_mean: mean(&costs),
        runs: results.len(),
    }
}

/// Default seed list for `--seeds k`.
pub fn seed_list(base: u64, k: usize) -> Vec<u64> {
    (0..k as u64).map(|i| base.wrapping_add(100 * i + 1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_list_distinct() {
        let s = seed_list(17, 5);
        assert_eq!(s.len(), 5);
        let mut d = s.clone();
        d.dedup();
        assert_eq!(d.len(), 5);
    }
}
