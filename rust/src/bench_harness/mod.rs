//! Micro-benchmark harness (criterion is unavailable offline —
//! DESIGN.md §2): warmup, adaptive iteration count, robust statistics,
//! and a table printer shared by every `benches/bench_*.rs` target.
//!
//! Usage inside a `harness = false` bench:
//! ```no_run
//! use pfed1bs::bench_harness::Bench;
//! let mut b = Bench::new("fwht");
//! let mut x = vec![1.0f32; 1 << 16];
//! b.bench("fwht_64k", || pfed1bs::sketch::fwht_normalized(&mut x));
//! b.report();
//! ```

pub mod compare;

use std::time::{Duration, Instant};

use crate::util::stats::{mad, mean, percentile};

/// One benchmark's timing summary (nanoseconds).
#[derive(Clone, Debug)]
pub struct Measurement {
    /// row name as printed in the table
    pub name: String,
    /// samples collected in the measurement window
    pub iters: usize,
    /// mean per-iteration time, ns
    pub mean_ns: f64,
    /// median per-iteration time, ns
    pub p50_ns: f64,
    /// 99th-percentile per-iteration time, ns
    pub p99_ns: f64,
    /// median absolute deviation, ns (robust spread)
    pub mad_ns: f64,
    /// optional throughput denominator (elements per iteration)
    pub elements: Option<u64>,
}

impl Measurement {
    /// Throughput in mega-elements per second, when `elements` is set.
    pub fn throughput_melem_s(&self) -> Option<f64> {
        self.elements
            .map(|e| e as f64 / (self.mean_ns / 1e9) / 1e6)
    }
}

/// Config + accumulated measurements for one bench binary.
pub struct Bench {
    /// suite name printed in the report header
    pub suite: String,
    /// how long to spin before measuring
    pub warmup: Duration,
    /// measurement window per row
    pub measure: Duration,
    /// hard cap on samples per row
    pub max_iters: usize,
    results: Vec<Measurement>,
}

impl Bench {
    /// New suite; honors `PFED1BS_BENCH_QUICK=1` (CI smoke mode).
    pub fn new(suite: &str) -> Bench {
        // honor a quick mode for CI-ish runs: PFED1BS_BENCH_QUICK=1
        let quick = std::env::var("PFED1BS_BENCH_QUICK").is_ok();
        Bench {
            suite: suite.to_string(),
            warmup: if quick { Duration::from_millis(50) } else { Duration::from_millis(300) },
            measure: if quick { Duration::from_millis(200) } else { Duration::from_secs(2) },
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }

    /// Benchmark a closure; returns the measurement (also stored).
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &Measurement {
        self.bench_with_elements(name, None, f)
    }

    /// Benchmark with a throughput denominator.
    pub fn bench_elems<F: FnMut()>(&mut self, name: &str, elements: u64, f: F) -> &Measurement {
        self.bench_with_elements(name, Some(elements), f)
    }

    fn bench_with_elements<F: FnMut()>(
        &mut self,
        name: &str,
        elements: Option<u64>,
        mut f: F,
    ) -> &Measurement {
        // warmup
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // measure
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples_ns.len() < self.max_iters {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean_ns: mean(&samples_ns),
            p50_ns: percentile(&samples_ns, 50.0),
            p99_ns: percentile(&samples_ns, 99.0),
            mad_ns: mad(&samples_ns),
            elements,
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Every measurement collected so far, in bench order.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Write the accumulated measurements as machine-readable JSON: one
    /// row per measurement with mean/p50/p99/mad in ns, the element
    /// count, and the derived Me/s. Hand-rolled writer — serde is
    /// unavailable offline (DESIGN.md §2).
    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        use std::fmt::Write as _;
        let mut out = String::from("{\n");
        let _ = writeln!(out, "  \"suite\": \"{}\",", json_escape(&self.suite));
        out.push_str("  \"rows\": [\n");
        for (i, m) in self.results.iter().enumerate() {
            let _ = writeln!(
                out,
                "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.1}, \
                 \"p50_ns\": {:.1}, \"p99_ns\": {:.1}, \"mad_ns\": {:.1}, \
                 \"elements\": {}, \"melem_per_s\": {}}}{}",
                json_escape(&m.name),
                m.iters,
                m.mean_ns,
                m.p50_ns,
                m.p99_ns,
                m.mad_ns,
                m.elements.map(|e| e.to_string()).unwrap_or_else(|| "null".into()),
                m.throughput_melem_s()
                    .map(|t| format!("{t:.3}"))
                    .unwrap_or_else(|| "null".into()),
                if i + 1 == self.results.len() { "" } else { "," },
            );
        }
        out.push_str("  ]\n}\n");
        std::fs::write(path, out)
    }

    /// Emit `BENCH_<name>.json` next to the human table so the perf
    /// trajectory is tracked across PRs (best-effort: a read-only CWD
    /// must not fail the bench run).
    pub fn emit_json(&self, name: &str) {
        let path = format!("BENCH_{name}.json");
        match self.write_json(&path) {
            Ok(()) => println!("machine-readable report: {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }

    /// Print an aligned table of all measurements.
    pub fn report(&self) {
        println!("\n== bench suite: {} ==", self.suite);
        println!(
            "{:<40} {:>10} {:>12} {:>12} {:>12} {:>12}",
            "name", "iters", "mean", "p50", "p99", "throughput"
        );
        for m in &self.results {
            println!(
                "{:<40} {:>10} {:>12} {:>12} {:>12} {:>12}",
                m.name,
                m.iters,
                fmt_ns(m.mean_ns),
                fmt_ns(m.p50_ns),
                fmt_ns(m.p99_ns),
                m.throughput_melem_s()
                    .map(|t| format!("{t:.1} Me/s"))
                    .unwrap_or_else(|| "-".into()),
            );
        }
    }
}

/// Minimal JSON string escape for the code-controlled names this
/// harness emits (backslash, quote, and control characters).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write as _;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Human-format nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("PFED1BS_BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        let mut acc = 0u64;
        let m = b.bench_elems("noop_loop", 1000, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(m.iters > 0);
        assert!(m.mean_ns > 0.0);
        assert!(m.throughput_melem_s().unwrap() > 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn json_report_is_well_formed() {
        std::env::set_var("PFED1BS_BENCH_QUICK", "1");
        let mut b = Bench::new("json\"suite");
        let mut acc = 0u64;
        b.bench_elems("row_a", 10, || acc = acc.wrapping_add(black_box(1)));
        b.bench("row_b", || acc = acc.wrapping_add(black_box(2)));
        // pid-unique name: concurrent `cargo test` runs on one machine
        // must not race on a shared temp file
        let path = std::env::temp_dir()
            .join(format!("pfed1bs_bench_json_test_{}.json", std::process::id()));
        b.write_json(&path).expect("write json");
        let text = std::fs::read_to_string(&path).expect("read back");
        let _ = std::fs::remove_file(&path);
        // escaped suite name, both rows, null elements on the bare row
        assert!(text.contains("\"suite\": \"json\\\"suite\""), "{text}");
        assert!(text.contains("\"name\": \"row_a\""));
        assert!(text.contains("\"elements\": 10"));
        assert!(text.contains("\"elements\": null"));
        // crude structural sanity: balanced braces/brackets, one row comma
        assert_eq!(text.matches('{').count(), text.matches('}').count());
        assert_eq!(text.matches('[').count(), text.matches(']').count());
        assert!(!text.contains("NaN"), "numbers must be finite: {text}");
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
