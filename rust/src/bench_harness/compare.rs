//! The perf regression gate over the `BENCH_*.json` ledger
//! (DESIGN.md §14): a committed per-machine-class baseline
//! (`BENCH_BASELINE.json`) of named rows, compared against the reports a
//! bench run just wrote. A row fails the gate only when its baseline
//! mean is a recorded positive number AND the current mean exceeds it by
//! more than the budget (default 15%); `null` baselines ("row exists,
//! mean not pinned yet") and absent reports (artifact-gated benches that
//! skipped themselves) pass with a note, so the gate never blocks on a
//! machine that cannot run every suite.
//!
//! `PFED1BS_UPDATE_BASELINE=1 pfed1bs perf-compare` rewrites the current
//! machine class's means from the reports on disk — the intended way to
//! (re)pin the baseline after an accepted perf change.
//!
//! JSON is parsed by a small recursive-descent parser over the subset
//! this crate emits — serde is unavailable offline (DESIGN.md §2).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::{fmt_ns, json_escape};

/// A parsed JSON value (the minimal subset the ledger uses).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any number, held as `f64`
    Num(f64),
    /// a string, escapes resolved
    Str(String),
    /// an array
    Arr(Vec<Json>),
    /// an object, fields in document order
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (rejects trailing bytes).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { s: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.i == p.s.len(), "trailing bytes after JSON document");
        Ok(v)
    }

    /// Object field lookup; `None` on non-objects and absent keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        if let Json::Num(v) = self {
            Some(*v)
        } else {
            None
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        if let Json::Str(s) = self {
            Some(s)
        } else {
            None
        }
    }

    /// The items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        if let Json::Arr(items) = self {
            Some(items)
        } else {
            None
        }
    }
}

/// Recursive-descent JSON parser state (byte cursor over the input).
struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.s.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.s.get(self.i).copied().context("unexpected end of JSON")
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        ensure!(self.peek()? == b, "expected `{}` at byte {}", b as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.word("true", Json::Bool(true)),
            b'f' => self.word("false", Json::Bool(false)),
            b'n' => self.word("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected `{}` at byte {}", c as char, self.i),
        }
    }

    fn word(&mut self, w: &str, v: Json) -> Result<Json> {
        ensure!(self.s[self.i..].starts_with(w.as_bytes()), "bad literal at byte {}", self.i);
        self.i += w.len();
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while matches!(self.s.get(self.i), Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.i]).expect("ASCII number bytes");
        let v: f64 = text.parse().with_context(|| format!("bad number `{text}`"))?;
        Ok(Json::Num(v))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek()? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            ensure!(self.s.len() >= self.i + 4, "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4])
                                .ok()
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .context("bad \\u escape")?;
                            out.push(char::from_u32(hex).context("bad \\u code point")?);
                            self.i += 4;
                        }
                        c => bail!("unknown escape `\\{}`", c as char),
                    }
                }
                _ => {
                    // multi-byte UTF-8 passes through unmodified
                    let c = std::str::from_utf8(&self.s[self.i..])
                        .ok()
                        .and_then(|r| r.chars().next())
                        .context("invalid UTF-8 in string")?;
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected `,` or `]`, got `{}` at byte {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            fields.push((key, self.value()?));
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                c => bail!("expected `,` or `}}`, got `{}` at byte {}", c as char, self.i),
            }
        }
    }
}

/// One named row of the committed baseline.
#[derive(Clone, Debug, PartialEq)]
pub struct BaselineRow {
    /// suite the row belongs to (`"fwht"`, `"codec"`, …)
    pub suite: String,
    /// row name inside the suite (must match the bench's row name)
    pub name: String,
    /// pinned mean, ns; `None` (JSON `null`) = tracked but not pinned
    pub mean_ns: Option<f64>,
}

/// The committed perf baseline: a gate budget plus the named rows each
/// machine class is held to.
#[derive(Clone, Debug, PartialEq)]
pub struct Baseline {
    /// per-row regression budget, percent over the pinned mean
    pub gate_pct: f64,
    /// machine class (`"x86_64"`, `"aarch64"`) → its tracked rows
    pub classes: BTreeMap<String, Vec<BaselineRow>>,
}

impl Baseline {
    /// Parse `BENCH_BASELINE.json`.
    pub fn parse(text: &str) -> Result<Baseline> {
        let doc = Json::parse(text).context("parsing the perf baseline")?;
        let gate_pct = doc.get("gate_pct").and_then(Json::as_f64).unwrap_or(15.0);
        ensure!(gate_pct > 0.0, "gate_pct must be positive");
        let mut classes = BTreeMap::new();
        if let Some(Json::Obj(cls)) = doc.get("classes") {
            for (class, rows_v) in cls {
                let rows_j = rows_v.as_arr().context("baseline class must hold a row array")?;
                let mut rows = Vec::with_capacity(rows_j.len());
                for r in rows_j {
                    rows.push(BaselineRow {
                        suite: r
                            .get("suite")
                            .and_then(Json::as_str)
                            .context("baseline row missing `suite`")?
                            .to_string(),
                        name: r
                            .get("name")
                            .and_then(Json::as_str)
                            .context("baseline row missing `name`")?
                            .to_string(),
                        mean_ns: r.get("mean_ns").and_then(Json::as_f64),
                    });
                }
                classes.insert(class.clone(), rows);
            }
        }
        Ok(Baseline { gate_pct, classes })
    }

    /// Serialize back to the committed on-disk form (deterministic:
    /// classes sorted, rows in stored order).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        let _ = writeln!(out, "  \"gate_pct\": {},", self.gate_pct);
        out.push_str("  \"classes\": {\n");
        for (ci, (class, rows)) in self.classes.iter().enumerate() {
            let _ = writeln!(out, "    \"{}\": [", json_escape(class));
            for (ri, r) in rows.iter().enumerate() {
                let _ = writeln!(
                    out,
                    "      {{\"suite\": \"{}\", \"name\": \"{}\", \"mean_ns\": {}}}{}",
                    json_escape(&r.suite),
                    json_escape(&r.name),
                    r.mean_ns.map(|v| format!("{v:.1}")).unwrap_or_else(|| "null".into()),
                    if ri + 1 == rows.len() { "" } else { "," },
                );
            }
            let _ = writeln!(out, "    ]{}", if ci + 1 == self.classes.len() { "" } else { "," });
        }
        out.push_str("  }\n}\n");
        out
    }
}

/// Collect `(suite, row) → mean_ns` from every `BENCH_*.json` in `dir`
/// that carries the harness schema (a `suite` string and a `rows`
/// array). Foreign-schema reports — `BENCH_loadgen.json`, the baseline
/// itself — are skipped, as are unparseable files (noted on stderr):
/// the gate judges only rows the baseline names, so extra files in the
/// working directory must never fail the step.
pub fn load_reports(dir: impl AsRef<Path>) -> Result<BTreeMap<(String, String), f64>> {
    let dir = dir.as_ref();
    let mut out = BTreeMap::new();
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("reading reports in {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        let fname = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if !(fname.starts_with("BENCH_") && fname.ends_with(".json")) {
            continue;
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let Ok(doc) = Json::parse(&text) else {
            eprintln!("perf-compare: skipping unparseable {}", path.display());
            continue;
        };
        let (Some(suite), Some(rows)) =
            (doc.get("suite").and_then(Json::as_str), doc.get("rows").and_then(Json::as_arr))
        else {
            continue;
        };
        for row in rows {
            if let (Some(name), Some(mean)) = (
                row.get("name").and_then(Json::as_str),
                row.get("mean_ns").and_then(Json::as_f64),
            ) {
                out.insert((suite.to_string(), name.to_string()), mean);
            }
        }
    }
    Ok(out)
}

/// How one tracked row fared against the gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowStatus {
    /// within the budget (or faster than baseline)
    Ok,
    /// slower than the pinned baseline by more than the budget
    Regressed,
    /// baseline mean is `null` — tracked but not pinned, never gates
    Unrecorded,
    /// no current report for this row (e.g. an artifact-gated bench
    /// that skipped itself) — never gates
    Missing,
}

impl RowStatus {
    /// Short table label.
    pub fn label(self) -> &'static str {
        match self {
            RowStatus::Ok => "ok",
            RowStatus::Regressed => "REGRESSED",
            RowStatus::Unrecorded => "unrecorded",
            RowStatus::Missing => "not run",
        }
    }
}

/// One tracked row's baseline-vs-current numbers.
#[derive(Clone, Debug)]
pub struct RowOutcome {
    /// suite the row belongs to
    pub suite: String,
    /// row name inside the suite
    pub name: String,
    /// pinned baseline mean, ns (`None` = unpinned)
    pub baseline_ns: Option<f64>,
    /// this run's mean, ns (`None` = report absent)
    pub current_ns: Option<f64>,
}

impl RowOutcome {
    /// Classify this row against a percent budget.
    pub fn status(&self, gate_pct: f64) -> RowStatus {
        match (self.baseline_ns, self.current_ns) {
            (None, _) => RowStatus::Unrecorded,
            (Some(_), None) => RowStatus::Missing,
            (Some(b), Some(c)) if b > 0.0 && c > b * (1.0 + gate_pct / 100.0) => {
                RowStatus::Regressed
            }
            _ => RowStatus::Ok,
        }
    }
}

/// A full gate evaluation for one machine class.
#[derive(Clone, Debug)]
pub struct CompareReport {
    /// machine class compared (`std::env::consts::ARCH` by default)
    pub class: String,
    /// per-row budget, percent
    pub gate_pct: f64,
    /// outcomes in baseline order (empty if the class is untracked)
    pub rows: Vec<RowOutcome>,
}

impl CompareReport {
    /// The rows that fail the gate.
    pub fn regressions(&self) -> Vec<&RowOutcome> {
        self.rows.iter().filter(|r| r.status(self.gate_pct) == RowStatus::Regressed).collect()
    }

    /// True when any tracked row regressed past the budget.
    pub fn failed(&self) -> bool {
        !self.regressions().is_empty()
    }

    /// The before/after table, GitHub-flavored markdown (pasted into
    /// the CI job summary by the perf-compare step).
    pub fn markdown_table(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "| row | baseline | current | Δ mean | status |");
        let _ = writeln!(out, "|---|---:|---:|---:|---|");
        for r in &self.rows {
            let delta = match (r.baseline_ns, r.current_ns) {
                (Some(b), Some(c)) if b > 0.0 => format!("{:+.1}%", (c / b - 1.0) * 100.0),
                _ => "n/a".into(),
            };
            let _ = writeln!(
                out,
                "| {}/{} | {} | {} | {} | {} |",
                r.suite,
                r.name,
                r.baseline_ns.map(fmt_ns).unwrap_or_else(|| "n/a".into()),
                r.current_ns.map(fmt_ns).unwrap_or_else(|| "not run".into()),
                delta,
                r.status(self.gate_pct).label(),
            );
        }
        out
    }
}

/// Evaluate `current` report means against `baseline`'s rows for one
/// machine class. An untracked class yields an empty (passing) report.
pub fn compare(
    baseline: &Baseline,
    class: &str,
    current: &BTreeMap<(String, String), f64>,
) -> CompareReport {
    let rows = baseline
        .classes
        .get(class)
        .map(|rows| {
            rows.iter()
                .map(|r| RowOutcome {
                    suite: r.suite.clone(),
                    name: r.name.clone(),
                    baseline_ns: r.mean_ns,
                    current_ns: current.get(&(r.suite.clone(), r.name.clone())).copied(),
                })
                .collect()
        })
        .unwrap_or_default();
    CompareReport { class: class.to_string(), gate_pct: baseline.gate_pct, rows }
}

/// Pin `class`'s baseline means to the current report values (rows with
/// no current report keep their old mean). Returns how many rows moved.
pub fn update_class(
    baseline: &mut Baseline,
    class: &str,
    current: &BTreeMap<(String, String), f64>,
) -> usize {
    let mut updated = 0;
    if let Some(rows) = baseline.classes.get_mut(class) {
        for r in rows {
            if let Some(&mean) = current.get(&(r.suite.clone(), r.name.clone())) {
                r.mean_ns = Some(mean);
                updated += 1;
            }
        }
    }
    updated
}

/// The `pfed1bs perf-compare` entry point: load the committed baseline
/// and the `BENCH_*.json` reports, print the before/after table, then
/// either gate (error on any regressed row) or — under
/// `PFED1BS_UPDATE_BASELINE=1` — rewrite this class's pinned means.
pub fn run(baseline_path: &str, reports_dir: &str, class: &str) -> Result<()> {
    let text = std::fs::read_to_string(baseline_path)
        .with_context(|| format!("reading baseline {baseline_path}"))?;
    let mut baseline = Baseline::parse(&text)?;
    let current = load_reports(reports_dir)?;
    let report = compare(&baseline, class, &current);
    println!("perf gate: class `{class}`, +{}% mean-ns budget per row\n", report.gate_pct);
    print!("{}", report.markdown_table());
    if report.rows.is_empty() {
        println!("\nno baseline rows for `{class}` — nothing gated (add them to {baseline_path})");
    }
    if std::env::var("PFED1BS_UPDATE_BASELINE").as_deref() == Ok("1") {
        let n = update_class(&mut baseline, class, &current);
        std::fs::write(baseline_path, baseline.to_json())
            .with_context(|| format!("rewriting {baseline_path}"))?;
        println!("\nbaseline updated: {n} `{class}` row(s) pinned to this run's means");
        return Ok(());
    }
    let bad: Vec<String> =
        report.regressions().iter().map(|r| format!("{}/{}", r.suite, r.name)).collect();
    ensure!(
        bad.is_empty(),
        "perf gate failed: {} row(s) regressed more than {}%: {}",
        bad.len(),
        report.gate_pct,
        bad.join(", ")
    );
    println!("\nperf gate passed");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const BASELINE: &str = r#"{
      "gate_pct": 15,
      "classes": {
        "x86_64": [
          {"suite": "fwht", "name": "a", "mean_ns": 1000.0},
          {"suite": "fwht", "name": "b", "mean_ns": null},
          {"suite": "codec", "name": "c", "mean_ns": 500.0}
        ]
      }
    }"#;

    fn current(pairs: &[(&str, &str, f64)]) -> BTreeMap<(String, String), f64> {
        pairs.iter().map(|(s, n, v)| ((s.to_string(), n.to_string()), *v)).collect()
    }

    #[test]
    fn parser_handles_the_emitted_subset() {
        let doc = Json::parse(
            "{\"suite\": \"x\\\"y\", \"rows\": [{\"name\": \"r\", \"mean_ns\": 12.5, \
             \"elements\": null, \"ok\": true, \"bad\": false, \"e\": 1.5e3}]}",
        )
        .unwrap();
        assert_eq!(doc.get("suite").unwrap().as_str(), Some("x\"y"));
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("mean_ns").unwrap().as_f64(), Some(12.5));
        assert_eq!(rows[0].get("elements"), Some(&Json::Null));
        assert_eq!(rows[0].get("ok"), Some(&Json::Bool(true)));
        assert_eq!(rows[0].get("e").unwrap().as_f64(), Some(1500.0));
        assert!(Json::parse("{\"a\": }").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn gate_fires_only_past_the_budget() {
        let b = Baseline::parse(BASELINE).unwrap();
        assert_eq!(b.gate_pct, 15.0);
        // exactly at the budget passes; one permille past it fails
        let at = compare(&b, "x86_64", &current(&[("fwht", "a", 1150.0), ("codec", "c", 400.0)]));
        assert!(!at.failed());
        let past = compare(&b, "x86_64", &current(&[("fwht", "a", 1151.0)]));
        assert!(past.failed());
        assert_eq!(past.regressions().len(), 1);
        assert_eq!(past.regressions()[0].name, "a");
    }

    #[test]
    fn null_baselines_missing_reports_and_unknown_classes_pass() {
        let b = Baseline::parse(BASELINE).unwrap();
        // row b is unpinned (even a huge current mean is fine); a and c
        // have no report at all
        let r = compare(&b, "x86_64", &current(&[("fwht", "b", 9e9)]));
        assert!(!r.failed());
        let st: Vec<RowStatus> = r.rows.iter().map(|o| o.status(r.gate_pct)).collect();
        assert_eq!(st, vec![RowStatus::Missing, RowStatus::Unrecorded, RowStatus::Missing]);
        assert!(!compare(&b, "riscv64", &current(&[])).failed());
        assert!(compare(&b, "riscv64", &current(&[])).rows.is_empty());
    }

    #[test]
    fn update_pins_current_means_and_round_trips() {
        let mut b = Baseline::parse(BASELINE).unwrap();
        let n = update_class(&mut b, "x86_64", &current(&[("fwht", "b", 42.0)]));
        assert_eq!(n, 1);
        let again = Baseline::parse(&b.to_json()).unwrap();
        assert_eq!(again.classes["x86_64"][1].mean_ns, Some(42.0));
        // rows without a current report keep their pinned mean
        assert_eq!(again.classes["x86_64"][0].mean_ns, Some(1000.0));
        assert_eq!(again.classes["x86_64"][2].mean_ns, Some(500.0));
        assert_eq!(again.gate_pct, 15.0);
    }

    #[test]
    fn markdown_table_lists_every_row_with_status() {
        let b = Baseline::parse(BASELINE).unwrap();
        let r = compare(&b, "x86_64", &current(&[("fwht", "a", 2000.0)]));
        let md = r.markdown_table();
        assert!(md.contains("| fwht/a |"), "{md}");
        assert!(md.contains("REGRESSED"), "{md}");
        assert!(md.contains("+100.0%"), "{md}");
        assert!(md.contains("unrecorded"), "{md}");
        assert!(md.contains("not run"), "{md}");
    }

    #[test]
    fn load_reports_reads_harness_schema_and_skips_foreign_files() {
        let dir = std::env::temp_dir()
            .join(format!("pfed1bs_perf_compare_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("BENCH_fwht.json"),
            "{\"suite\": \"fwht\", \"rows\": [{\"name\": \"a\", \"mean_ns\": 7.0}]}",
        )
        .unwrap();
        // foreign schema (loadgen-style) and the baseline itself: skipped
        std::fs::write(dir.join("BENCH_loadgen.json"), "{\"p99_uplink_to_absorb_ms\": 1.0}")
            .unwrap();
        std::fs::write(dir.join("BENCH_BASELINE.json"), BASELINE).unwrap();
        std::fs::write(dir.join("unrelated.txt"), "not json").unwrap();
        let got = load_reports(&dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(got.len(), 1);
        assert_eq!(got[&("fwht".to_string(), "a".to_string())], 7.0);
    }
}
