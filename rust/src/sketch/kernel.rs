//! Planned, cache-blocked FWHT kernel — the hot-path engine behind every
//! `SrhtOperator` application (DESIGN.md §10).
//!
//! The textbook butterfly (`fwht::scalar`) walks the whole buffer once
//! per stage: log₂ n passes, the later ones striding n′/2 apart — at the
//! model geometries (n′ = 2¹⁷, 2¹⁹) that is 17–19 full sweeps where
//! every cache line is evicted long before its next touch. This module
//! restructures the SAME arithmetic so the data is touched ~2× instead:
//!
//! * **Tiling** — H_{n} = (H_R ⊗ I_C)(I_R ⊗ H_C) with C = one
//!   L1-resident tile: first R independent contiguous tile transforms
//!   (all stages h < C), then the R-point "row" transform applied
//!   column-strip by column-strip so each strip stays resident for all
//!   of its log₂ R stages.
//! * **Radix-4 fusion** — two butterfly stages per memory pass (one
//!   leading radix-2 pass when the stage count is odd), halving sweeps.
//! * **SIMD-friendly lanes** — inner loops are fixed 8×f32 chunks over
//!   contiguous windows obtained by `split_at_mut`, the shape stable
//!   rustc autovectorizes; lane arithmetic is exact per lane.
//! * **Explicit SIMD butterflies** — `std::arch` AVX2 (x86_64) and NEON
//!   (aarch64) paths for every butterfly pass (radix-2, radix-4, and the
//!   fused D·pad first passes), selected once per process by
//!   [`active_isa`] (runtime feature detection with a
//!   `PFED1BS_FORCE_ISA=scalar|avx2|neon` override) and carried on every
//!   [`Schedule`]. SIMD only widens the traversal across *independent*
//!   butterflies — each lane's op DAG is the scalar kernel's, so every
//!   dispatch level stays bit-identical (DESIGN.md §14).
//! * **Fusion with the SRHT** — [`SketchPlan`] folds the D·pad prologue
//!   into each tile's first butterfly pass and the 1/√n′ normalization
//!   into every element's last butterfly write, and serves subsample +
//!   sign straight out of its scratch.
//! * **Batched / threaded** — [`fwht_batch`] over stacked vectors and a
//!   large-n′ mode that farms independent tiles and column bands to the
//!   `coordinator::parallel` scoped workers.
//!
//! BIT-EXACTNESS INVARIANT: every public entry point here produces
//! results bit-identical to the retained scalar reference
//! (`fwht::scalar`) for every input. The restructurings above only
//! reorder traversal across *independent* butterflies — each output
//! element's f32 operation DAG (which values are added/subtracted/
//! multiplied, in which association order) is unchanged, and f32 ops are
//! deterministic. Radix-4 computes exactly the two-pass intermediates;
//! the fused D·pad load computes the same per-element product the
//! prologue loop did; the fused normalization is the same single
//! multiply of each element's final stage value. Property tests in this
//! module and `tests/prop_kernel.rs` pin this across sizes, tile
//! overrides, batch shapes, and thread counts.

use std::cell::RefCell;
use std::sync::OnceLock;

use crate::coordinator::parallel::par_map;

/// Tile length: 2¹² f32 = 16 KiB, half a typical 32 KiB L1d, so a tile
/// plus its streamed source lines stay resident for all intra-tile
/// stages.
pub const TILE_LOG2: usize = 12;
/// Default tile length in f32 lanes.
pub const TILE: usize = 1 << TILE_LOG2;
/// Columns per strip in the cross-tile (row-transform) phase: 16 f32 =
/// one 64-byte line per row, so a strip's working set is rows × 64 B
/// (8 KiB at n′ = 2¹⁹) — L1-resident for all log₂ R row stages.
const STRIP: usize = 16;
/// Fixed SIMD-friendly lane width of the inner butterfly loops.
const LANES: usize = 8;

#[inline]
fn inv_sqrt_scale(n: usize) -> f32 {
    // EXACTLY the expression the scalar reference uses — the fused
    // epilogue must multiply by the identical f32 constant
    1.0 / (n as f32).sqrt()
}

// ---------------------------------------------------------------------
// ISA dispatch: which butterfly lane kernels run (DESIGN.md §14)
// ---------------------------------------------------------------------

/// Instruction-set level of the butterfly lane kernels. Every level is
/// bit-identical to [`Isa::Scalar`] (and therefore to `fwht::scalar`):
/// the SIMD paths only widen the traversal across independent
/// butterflies, never any lane's op DAG. Variants exist only on the
/// architectures that can execute them, so a constructed `Isa` is always
/// runnable on the current machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable fixed-lane loops (the autovectorized shape) — the
    /// always-available reference level.
    Scalar,
    /// 256-bit AVX2 butterflies. Only constructed after
    /// `is_x86_feature_detected!("avx2")` returned true.
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// 128-bit NEON butterflies (baseline on every aarch64 CPU).
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Isa {
    /// Stable lowercase name (`scalar` / `avx2` / `neon`) — the
    /// `PFED1BS_FORCE_ISA` vocabulary and the bench row suffix.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => "neon",
        }
    }

    /// The best level this machine can execute (runtime detection).
    pub fn detect() -> Isa {
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
        // structurally conditional (not just cfg'd) so the scalar tail
        // below stays live for the unreachable-code lint on aarch64
        #[cfg(target_arch = "aarch64")]
        if cfg!(target_arch = "aarch64") {
            return Isa::Neon;
        }
        Isa::Scalar
    }

    /// Every level this machine can execute, scalar first — the sweep
    /// the property tests run against the scalar oracle.
    pub fn available() -> Vec<Isa> {
        match Isa::detect() {
            Isa::Scalar => vec![Isa::Scalar],
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => vec![Isa::Scalar, Isa::Avx2],
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => vec![Isa::Scalar, Isa::Neon],
        }
    }

    /// Parse a `PFED1BS_FORCE_ISA` value; errors name the level when the
    /// machine cannot execute it (never silently falls back — a forced
    /// level that quietly degraded would invalidate every benchmark row
    /// recorded under it).
    pub fn from_env_name(name: &str) -> Result<Isa, String> {
        match name.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(Isa::Scalar),
            #[cfg(target_arch = "x86_64")]
            "avx2" if is_x86_feature_detected!("avx2") => Ok(Isa::Avx2),
            #[cfg(target_arch = "aarch64")]
            "neon" => Ok(Isa::Neon),
            other => Err(format!(
                "PFED1BS_FORCE_ISA={other}: not executable on this machine \
                 (expected scalar|avx2|neon)"
            )),
        }
    }
}

/// The process-wide dispatch level, resolved once on first use:
/// `PFED1BS_FORCE_ISA` when set (panicking on a level this machine
/// cannot execute), otherwise [`Isa::detect`]. Every [`Schedule`] — and
/// therefore every [`SketchPlan`] — captures this value at construction.
pub fn active_isa() -> Isa {
    static ACTIVE: OnceLock<Isa> = OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var("PFED1BS_FORCE_ISA") {
        Ok(v) => Isa::from_env_name(&v).unwrap_or_else(|e| panic!("{e}")),
        Err(_) => Isa::detect(),
    })
}

// ---------------------------------------------------------------------
// lane kernels
// ---------------------------------------------------------------------

/// Radix-2 butterfly over two equal-length contiguous windows. With
/// `SCALED`, the writes (this stage is the element's last) are fused
/// with the normalization multiply.
#[inline(always)]
fn bf2<const SCALED: bool>(a: &mut [f32], b: &mut [f32], s: f32) {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact_mut(LANES);
    let mut cb = b.chunks_exact_mut(LANES);
    for (ka, kb) in ca.by_ref().zip(cb.by_ref()) {
        for l in 0..LANES {
            let (x, y) = (ka[l], kb[l]);
            if SCALED {
                ka[l] = (x + y) * s;
                kb[l] = (x - y) * s;
            } else {
                ka[l] = x + y;
                kb[l] = x - y;
            }
        }
    }
    for (pa, pb) in ca.into_remainder().iter_mut().zip(cb.into_remainder()) {
        let (x, y) = (*pa, *pb);
        if SCALED {
            *pa = (x + y) * s;
            *pb = (x - y) * s;
        } else {
            *pa = x + y;
            *pb = x - y;
        }
    }
}

/// Fused radix-4 butterfly (stages h and 2h in one pass) over four
/// equal-length contiguous windows at offsets 0, h, 2h, 3h. Computes the
/// exact two-pass intermediates, so it is bit-identical to running the
/// radix-2 stages separately.
#[inline(always)]
fn bf4<const SCALED: bool>(
    r0: &mut [f32],
    r1: &mut [f32],
    r2: &mut [f32],
    r3: &mut [f32],
    s: f32,
) {
    debug_assert!(r0.len() == r1.len() && r1.len() == r2.len() && r2.len() == r3.len());
    let mut c0 = r0.chunks_exact_mut(LANES);
    let mut c1 = r1.chunks_exact_mut(LANES);
    let mut c2 = r2.chunks_exact_mut(LANES);
    let mut c3 = r3.chunks_exact_mut(LANES);
    for (((k0, k1), k2), k3) in c0.by_ref().zip(c1.by_ref()).zip(c2.by_ref()).zip(c3.by_ref()) {
        for l in 0..LANES {
            let (a, b, c, d) = (k0[l], k1[l], k2[l], k3[l]);
            let (s0, d0) = (a + b, a - b); // stage h
            let (s1, d1) = (c + d, c - d);
            if SCALED {
                k0[l] = (s0 + s1) * s; // stage 2h, fused epilogue
                k1[l] = (d0 + d1) * s;
                k2[l] = (s0 - s1) * s;
                k3[l] = (d0 - d1) * s;
            } else {
                k0[l] = s0 + s1;
                k1[l] = d0 + d1;
                k2[l] = s0 - s1;
                k3[l] = d0 - d1;
            }
        }
    }
    let t0 = c0.into_remainder().iter_mut();
    let t1 = c1.into_remainder().iter_mut();
    let t2 = c2.into_remainder().iter_mut();
    let t3 = c3.into_remainder().iter_mut();
    for (((p0, p1), p2), p3) in t0.zip(t1).zip(t2).zip(t3) {
        let (a, b, c, d) = (*p0, *p1, *p2, *p3);
        let (s0, d0) = (a + b, a - b);
        let (s1, d1) = (c + d, c - d);
        if SCALED {
            *p0 = (s0 + s1) * s;
            *p1 = (d0 + d1) * s;
            *p2 = (s0 - s1) * s;
            *p3 = (d0 - d1) * s;
        } else {
            *p0 = s0 + s1;
            *p1 = d0 + d1;
            *p2 = s0 - s1;
            *p3 = d0 - d1;
        }
    }
}

/// Two disjoint `w`-wide windows at `base` and `base + stride`.
#[inline(always)]
fn windows2(x: &mut [f32], base: usize, stride: usize, w: usize) -> (&mut [f32], &mut [f32]) {
    debug_assert!(w <= stride);
    let x = &mut x[base..base + stride + w];
    let (a, b) = x.split_at_mut(stride);
    (&mut a[..w], &mut b[..w])
}

/// Four disjoint `w`-wide windows at `base + {0,1,2,3}·stride`.
#[inline(always)]
fn windows4(
    x: &mut [f32],
    base: usize,
    stride: usize,
    w: usize,
) -> (&mut [f32], &mut [f32], &mut [f32], &mut [f32]) {
    debug_assert!(w <= stride);
    let x = &mut x[base..base + 3 * stride + w];
    let (r0, x) = x.split_at_mut(stride);
    let (r1, x) = x.split_at_mut(stride);
    let (r2, r3) = x.split_at_mut(stride);
    (&mut r0[..w], &mut r1[..w], &mut r2[..w], &mut r3[..w])
}

// ---------------------------------------------------------------------
// tile phase: all stages h < tile length, contiguous and L1-resident
// ---------------------------------------------------------------------

/// Fold `(last, scale)` into the `(scaled, s)` pair every lane kernel
/// takes: the epilogue multiply runs iff this pass contains the final
/// stage AND a normalization was requested.
#[inline(always)]
fn scale_flag(last: bool, scale: Option<f32>) -> (bool, f32) {
    match (last, scale) {
        (true, Some(s)) => (true, s),
        _ => (false, 1.0),
    }
}

/// Dispatch one radix-2 pass at the schedule's ISA level, with the
/// epilogue fused iff it is the last stage of the whole transform.
#[inline(always)]
fn bf2_dispatch(isa: Isa, a: &mut [f32], b: &mut [f32], last: bool, scale: Option<f32>) {
    let (scaled, s) = scale_flag(last, scale);
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Isa::Avx2` is only constructed after
        // `is_x86_feature_detected!("avx2")` returned true (detect /
        // from_env_name), so the callee's target-feature contract holds.
        Isa::Avx2 => unsafe { avx2::bf2(a, b, scaled, s) },
        #[cfg(target_arch = "aarch64")]
        // NEON is in the aarch64 baseline feature set, so the call is
        // statically feature-enabled (no unsafe needed).
        Isa::Neon => neon::bf2(a, b, scaled, s),
        Isa::Scalar => {
            if scaled {
                bf2::<true>(a, b, s)
            } else {
                bf2::<false>(a, b, 1.0)
            }
        }
    }
}

#[inline(always)]
fn bf4_dispatch(
    isa: Isa,
    r0: &mut [f32],
    r1: &mut [f32],
    r2: &mut [f32],
    r3: &mut [f32],
    last: bool,
    scale: Option<f32>,
) {
    let (scaled, s) = scale_flag(last, scale);
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Isa::Avx2` implies AVX2 was detected at runtime.
        Isa::Avx2 => unsafe { avx2::bf4(r0, r1, r2, r3, scaled, s) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::bf4(r0, r1, r2, r3, scaled, s),
        Isa::Scalar => {
            if scaled {
                bf4::<true>(r0, r1, r2, r3, s)
            } else {
                bf4::<false>(r0, r1, r2, r3, 1.0)
            }
        }
    }
}

/// Remaining radix-4 passes of a contiguous transform, from stage `h`
/// upward. `scale` is applied by the pass that contains the final stage.
fn tile_rest(isa: Isa, x: &mut [f32], mut h: usize, scale: Option<f32>) {
    let n = x.len();
    while h < n {
        debug_assert!(4 * h <= n, "stage parity broken: h={h}, n={n}");
        let last = 4 * h == n;
        let mut base = 0;
        while base < n {
            let (r0, r1, r2, r3) = windows4(x, base, h, h);
            bf4_dispatch(isa, r0, r1, r2, r3, last, scale);
            base += 4 * h;
        }
        h *= 4;
    }
}

/// First butterfly pass of a contiguous transform already resident in
/// `x`: radix-2 when the stage count is odd, radix-4 otherwise. Returns
/// the next stage h.
fn tile_first_pass(isa: Isa, x: &mut [f32], lg: usize, scale: Option<f32>) -> usize {
    if lg % 2 == 1 {
        let (scaled, s) = scale_flag(lg == 1, scale);
        match isa {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `Isa::Avx2` implies AVX2 was detected at runtime.
            Isa::Avx2 => unsafe { avx2::first2(x, scaled, s) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => neon::first2(x, scaled, s),
            Isa::Scalar => first2_scalar(x, scaled, s),
        }
        2
    } else {
        let (scaled, s) = scale_flag(lg == 2, scale);
        match isa {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `Isa::Avx2` implies AVX2 was detected at runtime.
            Isa::Avx2 => unsafe { avx2::first4(x, scaled, s) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => neon::first4(x, scaled, s),
            Isa::Scalar => first4_scalar(x, scaled, s),
        }
        4
    }
}

/// Scalar adjacent-pair radix-2 first pass (stage h = 1 in place).
fn first2_scalar(x: &mut [f32], scaled: bool, s: f32) {
    if scaled {
        for p in x.chunks_exact_mut(2) {
            let (a, b) = (p[0], p[1]);
            p[0] = (a + b) * s;
            p[1] = (a - b) * s;
        }
    } else {
        for p in x.chunks_exact_mut(2) {
            let (a, b) = (p[0], p[1]);
            p[0] = a + b;
            p[1] = a - b;
        }
    }
}

/// Scalar adjacent-quad fused radix-4 first pass (stages h = 1, 2).
fn first4_scalar(x: &mut [f32], scaled: bool, s: f32) {
    if scaled {
        for q in x.chunks_exact_mut(4) {
            let (a, b, c, d) = (q[0], q[1], q[2], q[3]);
            let (s0, d0, s1, d1) = (a + b, a - b, c + d, c - d);
            q[0] = (s0 + s1) * s;
            q[1] = (d0 + d1) * s;
            q[2] = (s0 - s1) * s;
            q[3] = (d0 - d1) * s;
        }
    } else {
        for q in x.chunks_exact_mut(4) {
            let (a, b, c, d) = (q[0], q[1], q[2], q[3]);
            let (s0, d0, s1, d1) = (a + b, a - b, c + d, c - d);
            q[0] = s0 + s1;
            q[1] = d0 + d1;
            q[2] = s0 - s1;
            q[3] = d0 - d1;
        }
    }
}

/// Full transform of one contiguous block (all stages h = 1..len/2).
fn tile_fwht(isa: Isa, x: &mut [f32], scale: Option<f32>) {
    let n = x.len();
    if n <= 1 {
        if let Some(s) = scale {
            // the scalar reference multiplies even at n = 1
            for v in x.iter_mut() {
                *v *= s;
            }
        }
        return;
    }
    let lg = n.trailing_zeros() as usize;
    let h0 = tile_first_pass(isa, x, lg, scale);
    tile_rest(isa, x, h0, scale);
}

/// First butterfly pass fused with the SRHT prologue: the pass loads
/// `w[i]·d[i]` (zero beyond `w`) instead of reading `x`, eliminating the
/// separate D·pad sweep. Same products, same adds — bit-identical to
/// prologue-then-butterfly.
fn tile_fwht_wd(isa: Isa, w: &[f32], d: &[f32], x: &mut [f32], scale: Option<f32>) {
    let n = x.len();
    debug_assert_eq!(d.len(), n);
    debug_assert!(w.len() <= n);
    if w.is_empty() {
        // tile entirely in the zero padding: every stage maps +0.0 to
        // +0.0 (and ·scale keeps +0.0), so the memset IS the transform
        x.fill(0.0);
        return;
    }
    if n == 1 {
        let v = w[0] * d[0];
        x[0] = match scale {
            Some(s) => v * s,
            None => v,
        };
        return;
    }
    let lg = n.trailing_zeros() as usize;
    let h0 = if w.len() == n {
        wd_first_pass_full(isa, w, d, x, lg, scale)
    } else {
        // boundary tile (runs at most once per transform) — stays scalar
        wd_first_pass_partial(w, d, x, lg, scale)
    };
    tile_rest(isa, x, h0, scale);
}

/// Fused-load first pass, tile fully inside the source vector:
/// branch-free zipped loads.
fn wd_first_pass_full(
    isa: Isa,
    w: &[f32],
    d: &[f32],
    x: &mut [f32],
    lg: usize,
    scale: Option<f32>,
) -> usize {
    if lg % 2 == 1 {
        let (scaled, s) = scale_flag(lg == 1, scale);
        match isa {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `Isa::Avx2` implies AVX2 was detected at runtime.
            Isa::Avx2 => unsafe { avx2::wd_first2(w, d, x, scaled, s) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => neon::wd_first2(w, d, x, scaled, s),
            Isa::Scalar => wd_first2_scalar(w, d, x, scaled, s),
        }
        2
    } else {
        let (scaled, s) = scale_flag(lg == 2, scale);
        match isa {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `Isa::Avx2` implies AVX2 was detected at runtime.
            Isa::Avx2 => unsafe { avx2::wd_first4(w, d, x, scaled, s) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => neon::wd_first4(w, d, x, scaled, s),
            Isa::Scalar => wd_first4_scalar(w, d, x, scaled, s),
        }
        4
    }
}

/// Scalar fused-load radix-2 first pass: branch-free zipped loads.
fn wd_first2_scalar(w: &[f32], d: &[f32], x: &mut [f32], scaled: bool, s: f32) {
    for ((p, ws), ds) in x.chunks_exact_mut(2).zip(w.chunks_exact(2)).zip(d.chunks_exact(2)) {
        let (a, b) = (ws[0] * ds[0], ws[1] * ds[1]);
        if scaled {
            p[0] = (a + b) * s;
            p[1] = (a - b) * s;
        } else {
            p[0] = a + b;
            p[1] = a - b;
        }
    }
}

/// Scalar fused-load radix-4 first pass.
fn wd_first4_scalar(w: &[f32], d: &[f32], x: &mut [f32], scaled: bool, s: f32) {
    for ((q, ws), ds) in x.chunks_exact_mut(4).zip(w.chunks_exact(4)).zip(d.chunks_exact(4)) {
        let (a, b, c, e) = (ws[0] * ds[0], ws[1] * ds[1], ws[2] * ds[2], ws[3] * ds[3]);
        let (s0, d0, s1, d1) = (a + b, a - b, c + e, c - e);
        if scaled {
            q[0] = (s0 + s1) * s;
            q[1] = (d0 + d1) * s;
            q[2] = (s0 - s1) * s;
            q[3] = (d0 - d1) * s;
        } else {
            q[0] = s0 + s1;
            q[1] = d0 + d1;
            q[2] = s0 - s1;
            q[3] = d0 - d1;
        }
    }
}

/// Fused-load first pass for the one tile straddling the n/n′ padding
/// boundary (runs at most once per transform — clarity over speed).
fn wd_first_pass_partial(
    w: &[f32],
    d: &[f32],
    x: &mut [f32],
    lg: usize,
    scale: Option<f32>,
) -> usize {
    let load = |i: usize| if i < w.len() { w[i] * d[i] } else { 0.0 };
    if lg % 2 == 1 {
        let last = lg == 1;
        for (p, pair) in x.chunks_exact_mut(2).enumerate() {
            let (a, b) = (load(2 * p), load(2 * p + 1));
            if let (true, Some(s)) = (last, scale) {
                pair[0] = (a + b) * s;
                pair[1] = (a - b) * s;
            } else {
                pair[0] = a + b;
                pair[1] = a - b;
            }
        }
        2
    } else {
        let last = lg == 2;
        for (qi, q) in x.chunks_exact_mut(4).enumerate() {
            let (a, b, c, e) = (load(4 * qi), load(4 * qi + 1), load(4 * qi + 2), load(4 * qi + 3));
            let (s0, d0, s1, d1) = (a + b, a - b, c + e, c - e);
            if let (true, Some(s)) = (last, scale) {
                q[0] = (s0 + s1) * s;
                q[1] = (d0 + d1) * s;
                q[2] = (s0 - s1) * s;
                q[3] = (d0 - d1) * s;
            } else {
                q[0] = s0 + s1;
                q[1] = d0 + d1;
                q[2] = s0 - s1;
                q[3] = d0 - d1;
            }
        }
        4
    }
}

// ---------------------------------------------------------------------
// cross phase: the R-point row transform (stages h = C, 2C, ..., n/2),
// strip-mined over columns so every strip is resident for all stages
// ---------------------------------------------------------------------

/// Row-transform stages over `x` viewed as (n/c) rows × c columns,
/// in-place via disjoint windows. Column strips are independent: row
/// stages only ever combine same-column elements, so running every
/// stage for one strip before touching the next preserves each
/// element's stage order exactly.
fn cross_pass(isa: Isa, x: &mut [f32], c: usize, strip: usize, scale: Option<f32>) {
    let n = x.len();
    let r = n / c;
    debug_assert!(r >= 2 && r * c == n && strip >= 1);
    let lg = r.trailing_zeros() as usize;
    let mut c0 = 0;
    while c0 < c {
        let w = strip.min(c - c0);
        let mut h = if lg % 2 == 1 {
            let last = lg == 1;
            let mut rbase = 0;
            while rbase < r {
                let (a, b) = windows2(x, rbase * c + c0, c, w);
                bf2_dispatch(isa, a, b, last, scale);
                rbase += 2;
            }
            2
        } else {
            1
        };
        while h < r {
            let last = 4 * h == r;
            // blocks of 4h rows; each block holds h independent quads
            // (rb+j, rb+j+h, rb+j+2h, rb+j+3h), j = 0..h
            let mut rb = 0;
            while rb < r {
                for j in 0..h {
                    let (r0, r1, r2, r3) = windows4(x, (rb + j) * c + c0, h * c, w);
                    bf4_dispatch(isa, r0, r1, r2, r3, last, scale);
                }
                rb += 4 * h;
            }
            h *= 4;
        }
        c0 += w;
    }
}

/// The same row-transform over an explicit row set (each row a disjoint
/// `&mut` window) — the shape the threaded column bands use, since one
/// band's rows cannot be expressed as a single contiguous slice.
fn cross_rows(isa: Isa, rows: &mut [&mut [f32]], strip: usize, scale: Option<f32>) {
    let r = rows.len();
    if r < 2 || rows[0].is_empty() {
        return;
    }
    let width = rows[0].len();
    let lg = r.trailing_zeros() as usize;
    let mut c0 = 0;
    while c0 < width {
        let w = strip.min(width - c0);
        let mut h = if lg % 2 == 1 {
            let last = lg == 1;
            let mut rbase = 0;
            while rbase < r {
                let (a, b) = rows2(rows, rbase, 1);
                bf2_dispatch(isa, &mut a[c0..c0 + w], &mut b[c0..c0 + w], last, scale);
                rbase += 2;
            }
            2
        } else {
            1
        };
        while h < r {
            let last = 4 * h == r;
            // blocks of 4h rows, h independent quads per block (see
            // `cross_pass`)
            let mut rb = 0;
            while rb < r {
                for j in 0..h {
                    let (r0, r1, r2, r3) = rows4(rows, rb + j, h);
                    bf4_dispatch(
                        isa,
                        &mut r0[c0..c0 + w],
                        &mut r1[c0..c0 + w],
                        &mut r2[c0..c0 + w],
                        &mut r3[c0..c0 + w],
                        last,
                        scale,
                    );
                }
                rb += 4 * h;
            }
            h *= 4;
        }
        c0 += w;
    }
}

/// Rows `i` and `i + h` as simultaneous `&mut` (outer split, safe).
#[inline(always)]
fn rows2<'a>(rows: &'a mut [&mut [f32]], i: usize, h: usize) -> (&'a mut [f32], &'a mut [f32]) {
    let seg = &mut rows[i..i + h + 1];
    let (a, b) = seg.split_at_mut(h);
    (&mut a[0][..], &mut b[0][..])
}

/// Rows `i + {0,1,2,3}·h` as simultaneous `&mut` (outer splits, safe).
#[inline(always)]
fn rows4<'a>(
    rows: &'a mut [&mut [f32]],
    i: usize,
    h: usize,
) -> (&'a mut [f32], &'a mut [f32], &'a mut [f32], &'a mut [f32]) {
    let seg = &mut rows[i..i + 3 * h + 1];
    let (a, seg) = seg.split_at_mut(h);
    let (b, seg) = seg.split_at_mut(h);
    let (c, d) = seg.split_at_mut(h);
    (&mut a[0][..], &mut b[0][..], &mut c[0][..], &mut d[0][..])
}

// ---------------------------------------------------------------------
// explicit SIMD lane kernels (DESIGN.md §14)
//
// Each function mirrors one scalar lane kernel exactly: the vector ops
// only widen the traversal across *independent* butterflies, so every
// lane computes the scalar kernel's op DAG with the scalar operand
// order (per-lane IEEE f32 add/sub/mul are exact positions in the DAG
// and Rust never FP-contracts, so results are bit-identical). Slice
// tails shorter than a vector delegate to the scalar kernels.
// ---------------------------------------------------------------------

/// AVX2 (8-lane f32) butterfly kernels. Every function is a safe
/// `#[target_feature]` fn: callers outside an AVX2 context must wrap
/// the call in `unsafe` and guarantee the CPU has AVX2 — which
/// [`Isa::Avx2`]'s construction (runtime detection) does.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Radix-2 pass over two equal-length disjoint windows.
    #[target_feature(enable = "avx2")]
    pub(super) fn bf2(a: &mut [f32], b: &mut [f32], scaled: bool, s: f32) {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut i = 0;
        while i + 8 <= n {
            // SAFETY: `i + 8 <= n` bounds every unaligned load/store in
            // both slices; lanes are independent butterflies, each
            // computing the scalar DAG ((x+y)[·s], (x−y)[·s]) with the
            // scalar operand order.
            unsafe {
                let x = _mm256_loadu_ps(a.as_ptr().add(i));
                let y = _mm256_loadu_ps(b.as_ptr().add(i));
                let mut u = _mm256_add_ps(x, y);
                let mut v = _mm256_sub_ps(x, y);
                if scaled {
                    let sv = _mm256_set1_ps(s);
                    u = _mm256_mul_ps(u, sv);
                    v = _mm256_mul_ps(v, sv);
                }
                _mm256_storeu_ps(a.as_mut_ptr().add(i), u);
                _mm256_storeu_ps(b.as_mut_ptr().add(i), v);
            }
            i += 8;
        }
        if scaled {
            super::bf2::<true>(&mut a[i..], &mut b[i..], s);
        } else {
            super::bf2::<false>(&mut a[i..], &mut b[i..], 1.0);
        }
    }

    /// Fused double radix-2 (= radix-4) pass over four disjoint windows.
    #[target_feature(enable = "avx2")]
    pub(super) fn bf4(
        r0: &mut [f32],
        r1: &mut [f32],
        r2: &mut [f32],
        r3: &mut [f32],
        scaled: bool,
        s: f32,
    ) {
        debug_assert!(r0.len() == r1.len() && r1.len() == r2.len() && r2.len() == r3.len());
        let n = r0.len();
        let mut i = 0;
        while i + 8 <= n {
            // SAFETY: `i + 8 <= n` bounds every unaligned load/store in
            // all four slices; per lane this is exactly the scalar bf4
            // DAG (s0,d0,s1,d1 then the four sums/differences, operand
            // order preserved).
            unsafe {
                let a = _mm256_loadu_ps(r0.as_ptr().add(i));
                let b = _mm256_loadu_ps(r1.as_ptr().add(i));
                let c = _mm256_loadu_ps(r2.as_ptr().add(i));
                let d = _mm256_loadu_ps(r3.as_ptr().add(i));
                let s0 = _mm256_add_ps(a, b);
                let d0 = _mm256_sub_ps(a, b);
                let s1 = _mm256_add_ps(c, d);
                let d1 = _mm256_sub_ps(c, d);
                let mut k0 = _mm256_add_ps(s0, s1);
                let mut k1 = _mm256_add_ps(d0, d1);
                let mut k2 = _mm256_sub_ps(s0, s1);
                let mut k3 = _mm256_sub_ps(d0, d1);
                if scaled {
                    let sv = _mm256_set1_ps(s);
                    k0 = _mm256_mul_ps(k0, sv);
                    k1 = _mm256_mul_ps(k1, sv);
                    k2 = _mm256_mul_ps(k2, sv);
                    k3 = _mm256_mul_ps(k3, sv);
                }
                _mm256_storeu_ps(r0.as_mut_ptr().add(i), k0);
                _mm256_storeu_ps(r1.as_mut_ptr().add(i), k1);
                _mm256_storeu_ps(r2.as_mut_ptr().add(i), k2);
                _mm256_storeu_ps(r3.as_mut_ptr().add(i), k3);
            }
            i += 8;
        }
        if scaled {
            super::bf4::<true>(&mut r0[i..], &mut r1[i..], &mut r2[i..], &mut r3[i..], s);
        } else {
            super::bf4::<false>(&mut r0[i..], &mut r1[i..], &mut r2[i..], &mut r3[i..], 1.0);
        }
    }

    /// In-register stage h = 1 over one 8-float vector holding four
    /// adjacent (a, b) butterflies: even lanes become a+b, odd lanes
    /// a−b (scalar operand order — the odd lane is `w − v`, i.e. a − b).
    #[inline]
    #[target_feature(enable = "avx2")]
    fn pairs_stage(v: __m256) -> __m256 {
        // swap adjacent pairs within each 128-bit half: (b0,a0,b1,a1|…)
        let w = _mm256_permute_ps::<0b10_11_00_01>(v);
        // even lanes ← v+w = a+b; odd lanes ← w−v = a−b
        _mm256_blend_ps::<0b1010_1010>(_mm256_add_ps(v, w), _mm256_sub_ps(w, v))
    }

    /// In-register stage h = 2 over one 8-float vector holding two
    /// adjacent (s0,d0,s1,d1) quads from [`pairs_stage`].
    #[inline]
    #[target_feature(enable = "avx2")]
    fn quads_stage(u: __m256) -> __m256 {
        // swap at distance 2 within each 128-bit half: (s1,d1,s0,d0|…)
        let w = _mm256_permute_ps::<0b01_00_11_10>(u);
        // lanes 0,1 ← u+w = (s0+s1, d0+d1); lanes 2,3 ← w−u = (s0−s1, d0−d1)
        _mm256_blend_ps::<0b1100_1100>(_mm256_add_ps(u, w), _mm256_sub_ps(w, u))
    }

    /// Contiguous radix-2 first pass (adjacent pairs, stage h = 1).
    #[target_feature(enable = "avx2")]
    pub(super) fn first2(x: &mut [f32], scaled: bool, s: f32) {
        let n = x.len();
        let mut i = 0;
        while i + 8 <= n {
            // SAFETY: `i + 8 <= n` bounds the unaligned load/store; the
            // in-register shuffle computes each adjacent pair's scalar
            // butterfly (even lane a+b, odd lane a−b) independently.
            unsafe {
                let mut u = pairs_stage(_mm256_loadu_ps(x.as_ptr().add(i)));
                if scaled {
                    u = _mm256_mul_ps(u, _mm256_set1_ps(s));
                }
                _mm256_storeu_ps(x.as_mut_ptr().add(i), u);
            }
            i += 8;
        }
        super::first2_scalar(&mut x[i..], scaled, s);
    }

    /// Contiguous fused radix-4 first pass (adjacent quads, h = 1, 2).
    #[target_feature(enable = "avx2")]
    pub(super) fn first4(x: &mut [f32], scaled: bool, s: f32) {
        let n = x.len();
        let mut i = 0;
        while i + 8 <= n {
            // SAFETY: `i + 8 <= n` bounds the unaligned load/store; the
            // two in-register stages compute each adjacent quad's scalar
            // radix-4 DAG with the scalar operand order.
            unsafe {
                let mut u = quads_stage(pairs_stage(_mm256_loadu_ps(x.as_ptr().add(i))));
                if scaled {
                    u = _mm256_mul_ps(u, _mm256_set1_ps(s));
                }
                _mm256_storeu_ps(x.as_mut_ptr().add(i), u);
            }
            i += 8;
        }
        super::first4_scalar(&mut x[i..], scaled, s);
    }

    /// Fused-load radix-2 first pass: butterflies over `w[i]·d[i]`.
    #[target_feature(enable = "avx2")]
    pub(super) fn wd_first2(w: &[f32], d: &[f32], x: &mut [f32], scaled: bool, s: f32) {
        debug_assert!(w.len() == x.len() && d.len() == x.len());
        let n = x.len();
        let mut i = 0;
        while i + 8 <= n {
            // SAFETY: `i + 8 <= n` bounds all three unaligned accesses
            // (`w`, `d` and `x` have equal length); each lane's product
            // w[i]·d[i] feeds the same butterfly DAG as the scalar pass.
            unsafe {
                let wv = _mm256_mul_ps(
                    _mm256_loadu_ps(w.as_ptr().add(i)),
                    _mm256_loadu_ps(d.as_ptr().add(i)),
                );
                let mut u = pairs_stage(wv);
                if scaled {
                    u = _mm256_mul_ps(u, _mm256_set1_ps(s));
                }
                _mm256_storeu_ps(x.as_mut_ptr().add(i), u);
            }
            i += 8;
        }
        super::wd_first2_scalar(&w[i..], &d[i..], &mut x[i..], scaled, s);
    }

    /// Fused-load radix-4 first pass: two stages over `w[i]·d[i]`.
    #[target_feature(enable = "avx2")]
    pub(super) fn wd_first4(w: &[f32], d: &[f32], x: &mut [f32], scaled: bool, s: f32) {
        debug_assert!(w.len() == x.len() && d.len() == x.len());
        let n = x.len();
        let mut i = 0;
        while i + 8 <= n {
            // SAFETY: `i + 8 <= n` bounds all three unaligned accesses
            // (`w`, `d` and `x` have equal length); per-quad DAG is the
            // scalar fused radix-4 pass over the products.
            unsafe {
                let wv = _mm256_mul_ps(
                    _mm256_loadu_ps(w.as_ptr().add(i)),
                    _mm256_loadu_ps(d.as_ptr().add(i)),
                );
                let mut u = quads_stage(pairs_stage(wv));
                if scaled {
                    u = _mm256_mul_ps(u, _mm256_set1_ps(s));
                }
                _mm256_storeu_ps(x.as_mut_ptr().add(i), u);
            }
            i += 8;
        }
        super::wd_first4_scalar(&w[i..], &d[i..], &mut x[i..], scaled, s);
    }
}

/// NEON (4-lane f32) butterfly kernels. NEON is in the aarch64 baseline
/// feature set, so these `#[target_feature]` fns are safe to call from
/// any aarch64 context — no runtime detection needed.
#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// Lane-select mask picking the odd lanes (1, 3) of a float32x4.
    #[inline]
    #[target_feature(enable = "neon")]
    fn odd_mask() -> uint32x4_t {
        // little-endian: low half of each u64 is the even lane
        vreinterpretq_u32_u64(vdupq_n_u64(0xFFFF_FFFF_0000_0000))
    }

    /// Radix-2 pass over two equal-length disjoint windows.
    #[target_feature(enable = "neon")]
    pub(super) fn bf2(a: &mut [f32], b: &mut [f32], scaled: bool, s: f32) {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut i = 0;
        while i + 4 <= n {
            // SAFETY: `i + 4 <= n` bounds every load/store in both
            // slices; lanes are independent butterflies computing the
            // scalar DAG with the scalar operand order.
            unsafe {
                let x = vld1q_f32(a.as_ptr().add(i));
                let y = vld1q_f32(b.as_ptr().add(i));
                let mut u = vaddq_f32(x, y);
                let mut v = vsubq_f32(x, y);
                if scaled {
                    u = vmulq_n_f32(u, s);
                    v = vmulq_n_f32(v, s);
                }
                vst1q_f32(a.as_mut_ptr().add(i), u);
                vst1q_f32(b.as_mut_ptr().add(i), v);
            }
            i += 4;
        }
        if scaled {
            super::bf2::<true>(&mut a[i..], &mut b[i..], s);
        } else {
            super::bf2::<false>(&mut a[i..], &mut b[i..], 1.0);
        }
    }

    /// Fused double radix-2 (= radix-4) pass over four disjoint windows.
    #[target_feature(enable = "neon")]
    pub(super) fn bf4(
        r0: &mut [f32],
        r1: &mut [f32],
        r2: &mut [f32],
        r3: &mut [f32],
        scaled: bool,
        s: f32,
    ) {
        debug_assert!(r0.len() == r1.len() && r1.len() == r2.len() && r2.len() == r3.len());
        let n = r0.len();
        let mut i = 0;
        while i + 4 <= n {
            // SAFETY: `i + 4 <= n` bounds every load/store in all four
            // slices; per lane this is exactly the scalar bf4 DAG.
            unsafe {
                let a = vld1q_f32(r0.as_ptr().add(i));
                let b = vld1q_f32(r1.as_ptr().add(i));
                let c = vld1q_f32(r2.as_ptr().add(i));
                let d = vld1q_f32(r3.as_ptr().add(i));
                let s0 = vaddq_f32(a, b);
                let d0 = vsubq_f32(a, b);
                let s1 = vaddq_f32(c, d);
                let d1 = vsubq_f32(c, d);
                let mut k0 = vaddq_f32(s0, s1);
                let mut k1 = vaddq_f32(d0, d1);
                let mut k2 = vsubq_f32(s0, s1);
                let mut k3 = vsubq_f32(d0, d1);
                if scaled {
                    k0 = vmulq_n_f32(k0, s);
                    k1 = vmulq_n_f32(k1, s);
                    k2 = vmulq_n_f32(k2, s);
                    k3 = vmulq_n_f32(k3, s);
                }
                vst1q_f32(r0.as_mut_ptr().add(i), k0);
                vst1q_f32(r1.as_mut_ptr().add(i), k1);
                vst1q_f32(r2.as_mut_ptr().add(i), k2);
                vst1q_f32(r3.as_mut_ptr().add(i), k3);
            }
            i += 4;
        }
        if scaled {
            super::bf4::<true>(&mut r0[i..], &mut r1[i..], &mut r2[i..], &mut r3[i..], s);
        } else {
            super::bf4::<false>(&mut r0[i..], &mut r1[i..], &mut r2[i..], &mut r3[i..], 1.0);
        }
    }

    /// In-register stage h = 1 over one 4-float vector holding two
    /// adjacent (a, b) butterflies (even lane a+b, odd lane a−b).
    #[inline]
    #[target_feature(enable = "neon")]
    fn pairs_stage(v: float32x4_t) -> float32x4_t {
        // swap adjacent pairs within each 64-bit half: (b0, a0, b1, a1)
        let w = vrev64q_f32(v);
        // odd lanes ← w−v = a−b; even lanes ← v+w = a+b
        vbslq_f32(odd_mask(), vsubq_f32(w, v), vaddq_f32(v, w))
    }

    /// In-register stage h = 2 over one (s0, d0, s1, d1) quad.
    #[inline]
    #[target_feature(enable = "neon")]
    fn quads_stage(u: float32x4_t) -> float32x4_t {
        // rotate by two lanes: (s1, d1, s0, d0)
        let w = vextq_f32::<2>(u, u);
        // high lanes ← w−u = (s0−s1, d0−d1); low ← u+w = (s0+s1, d0+d1)
        let high = vcombine_u32(vdup_n_u32(0), vdup_n_u32(0xFFFF_FFFF));
        vbslq_f32(high, vsubq_f32(w, u), vaddq_f32(u, w))
    }

    /// Contiguous radix-2 first pass (adjacent pairs, stage h = 1).
    #[target_feature(enable = "neon")]
    pub(super) fn first2(x: &mut [f32], scaled: bool, s: f32) {
        let n = x.len();
        let mut i = 0;
        while i + 4 <= n {
            // SAFETY: `i + 4 <= n` bounds the load/store; the shuffle
            // computes each adjacent pair's scalar butterfly.
            unsafe {
                let mut u = pairs_stage(vld1q_f32(x.as_ptr().add(i)));
                if scaled {
                    u = vmulq_n_f32(u, s);
                }
                vst1q_f32(x.as_mut_ptr().add(i), u);
            }
            i += 4;
        }
        super::first2_scalar(&mut x[i..], scaled, s);
    }

    /// Contiguous fused radix-4 first pass (adjacent quads, h = 1, 2).
    #[target_feature(enable = "neon")]
    pub(super) fn first4(x: &mut [f32], scaled: bool, s: f32) {
        let n = x.len();
        let mut i = 0;
        while i + 4 <= n {
            // SAFETY: `i + 4 <= n` bounds the load/store; the two
            // in-register stages are the scalar radix-4 quad DAG.
            unsafe {
                let mut u = quads_stage(pairs_stage(vld1q_f32(x.as_ptr().add(i))));
                if scaled {
                    u = vmulq_n_f32(u, s);
                }
                vst1q_f32(x.as_mut_ptr().add(i), u);
            }
            i += 4;
        }
        super::first4_scalar(&mut x[i..], scaled, s);
    }

    /// Fused-load radix-2 first pass: butterflies over `w[i]·d[i]`.
    #[target_feature(enable = "neon")]
    pub(super) fn wd_first2(w: &[f32], d: &[f32], x: &mut [f32], scaled: bool, s: f32) {
        debug_assert!(w.len() == x.len() && d.len() == x.len());
        let n = x.len();
        let mut i = 0;
        while i + 4 <= n {
            // SAFETY: `i + 4 <= n` bounds all three accesses (`w`, `d`
            // and `x` have equal length).
            unsafe {
                let wv = vmulq_f32(vld1q_f32(w.as_ptr().add(i)), vld1q_f32(d.as_ptr().add(i)));
                let mut u = pairs_stage(wv);
                if scaled {
                    u = vmulq_n_f32(u, s);
                }
                vst1q_f32(x.as_mut_ptr().add(i), u);
            }
            i += 4;
        }
        super::wd_first2_scalar(&w[i..], &d[i..], &mut x[i..], scaled, s);
    }

    /// Fused-load radix-4 first pass: two stages over `w[i]·d[i]`.
    #[target_feature(enable = "neon")]
    pub(super) fn wd_first4(w: &[f32], d: &[f32], x: &mut [f32], scaled: bool, s: f32) {
        debug_assert!(w.len() == x.len() && d.len() == x.len());
        let n = x.len();
        let mut i = 0;
        while i + 4 <= n {
            // SAFETY: `i + 4 <= n` bounds all three accesses (`w`, `d`
            // and `x` have equal length).
            unsafe {
                let wv = vmulq_f32(vld1q_f32(w.as_ptr().add(i)), vld1q_f32(d.as_ptr().add(i)));
                let mut u = quads_stage(pairs_stage(wv));
                if scaled {
                    u = vmulq_n_f32(u, s);
                }
                vst1q_f32(x.as_mut_ptr().add(i), u);
            }
            i += 4;
        }
        super::wd_first4_scalar(&w[i..], &d[i..], &mut x[i..], scaled, s);
    }
}

// ---------------------------------------------------------------------
// serial drivers
// ---------------------------------------------------------------------

fn assert_pow2(n: usize) {
    assert!(n.is_power_of_two(), "fwht needs power-of-two length, got {n}");
}

/// Blocked in-place transform with an explicit tile length (tests sweep
/// tiny tiles to exercise the blocking on small inputs; production
/// callers use [`TILE`] via the public wrappers).
pub fn fwht_with_tile(x: &mut [f32], tile: usize, normalized: bool) {
    assert_pow2(x.len());
    assert!(tile.is_power_of_two(), "tile must be a power of two, got {tile}");
    let scale = normalized.then(|| inv_sqrt_scale(x.len()));
    blocked_impl(x, Schedule { tile, strip: STRIP, isa: active_isa() }, scale);
}

fn blocked_impl(x: &mut [f32], sched: Schedule, scale: Option<f32>) {
    let n = x.len();
    if n <= sched.tile {
        tile_fwht(sched.isa, x, scale);
        return;
    }
    for t in x.chunks_exact_mut(sched.tile) {
        tile_fwht(sched.isa, t, None);
    }
    cross_pass(sched.isa, x, sched.tile, sched.strip, scale);
}

/// Unnormalized blocked FWHT — bit-identical to `fwht::scalar::fwht_inplace`.
pub fn fwht_blocked(x: &mut [f32]) {
    assert_pow2(x.len());
    blocked_impl(x, Schedule::for_len(x.len()), None);
}

/// Normalized blocked FWHT (`x ← (H/√n)·x`) with the 1/√n multiply fused
/// into each element's final butterfly write — bit-identical to
/// `fwht::scalar::fwht_normalized`.
pub fn fwht_blocked_normalized(x: &mut [f32]) {
    assert_pow2(x.len());
    blocked_impl(x, Schedule::for_len(x.len()), Some(inv_sqrt_scale(x.len())));
}

/// [`fwht_blocked_normalized`] pinned to an explicit dispatch level
/// instead of the process-wide [`active_isa`] — the hook the ISA-sweep
/// property tests and the `bench_fwht` simd-vs-scalar rows use. `isa`
/// must be executable on this machine (see [`Isa::available`]).
pub fn fwht_blocked_normalized_isa(x: &mut [f32], isa: Isa) {
    assert_pow2(x.len());
    let sched = Schedule { isa, ..Schedule::for_len(x.len()) };
    blocked_impl(x, sched, Some(inv_sqrt_scale(x.len())));
}

/// Fused SRHT rotate: `out ← (H/√n′)·(D ∘ pad(w))` with the D·pad
/// multiply folded into each tile's first butterfly pass (no separate
/// prologue sweep) and the normalization folded into the last.
/// `w.len() ≤ out.len() = dsign.len()`; lanes beyond `w` are the zero
/// padding.
pub fn fwht_rotate_normalized(w: &[f32], dsign: &[f32], out: &mut [f32]) {
    rotate_impl(w, dsign, out, Schedule::for_len(out.len()))
}

fn rotate_impl(w: &[f32], dsign: &[f32], out: &mut [f32], sched: Schedule) {
    let npad = out.len();
    assert_pow2(npad);
    assert_eq!(dsign.len(), npad, "dsign length must equal n'");
    assert!(w.len() <= npad, "source longer than padded buffer");
    let scale = Some(inv_sqrt_scale(npad));
    let tile = sched.tile;
    if npad <= tile {
        tile_fwht_wd(sched.isa, w, dsign, out, scale);
        return;
    }
    for (ti, t) in out.chunks_exact_mut(tile).enumerate() {
        let lo = (ti * tile).min(w.len());
        let hi = ((ti + 1) * tile).min(w.len());
        tile_fwht_wd(sched.isa, &w[lo..hi], &dsign[ti * tile..(ti + 1) * tile], t, None);
    }
    cross_pass(sched.isa, out, tile, sched.strip, scale);
}

// ---------------------------------------------------------------------
// batched + threaded drivers
// ---------------------------------------------------------------------

/// Normalized FWHT over B stacked vectors (row-major, each of length
/// `n`): one pass per vector, bit-identical to transforming each slice
/// with [`fwht_blocked_normalized`].
pub fn fwht_batch(xs: &mut [f32], n: usize) {
    assert!(n > 0 && xs.len() % n == 0, "batch len {} not a multiple of n={n}", xs.len());
    assert_pow2(n);
    let (sched, scale) = (Schedule::for_len(n), Some(inv_sqrt_scale(n)));
    for x in xs.chunks_exact_mut(n) {
        blocked_impl(x, sched, scale);
    }
}

/// [`fwht_batch`] with the independent vectors farmed to the scoped
/// worker pool — bit-identical for any thread count.
pub fn fwht_batch_threaded(xs: &mut [f32], n: usize, threads: usize) {
    assert!(n > 0 && xs.len() % n == 0, "batch len {} not a multiple of n={n}", xs.len());
    assert_pow2(n);
    if threads <= 1 || xs.len() == n {
        return fwht_batch(xs, n);
    }
    let (sched, scale) = (Schedule::for_len(n), Some(inv_sqrt_scale(n)));
    let rows: Vec<&mut [f32]> = xs.chunks_exact_mut(n).collect();
    par_map(rows, threads, |_, x| blocked_impl(x, sched, scale));
}

/// Unnormalized threaded transform of one large vector; see
/// [`fwht_threaded_normalized`].
pub fn fwht_threaded(x: &mut [f32], threads: usize) {
    assert_pow2(x.len());
    threaded_impl(x, threads, None);
}

/// Normalized threaded transform of one large vector: the independent
/// tiles go to the worker pool, then the cross phase is split into
/// disjoint column bands (row stages never mix columns) on the same
/// pool. Identical per-element operation DAG ⇒ bit-identical to the
/// serial kernel for any thread count.
pub fn fwht_threaded_normalized(x: &mut [f32], threads: usize) {
    assert_pow2(x.len());
    let scale = Some(inv_sqrt_scale(x.len()));
    threaded_impl(x, threads, scale);
}

fn threaded_impl(x: &mut [f32], threads: usize, scale: Option<f32>) {
    let n = x.len();
    let sched = Schedule::for_len(n);
    if threads <= 1 || n <= sched.tile {
        blocked_impl(x, sched, scale);
        return;
    }
    let tiles: Vec<&mut [f32]> = x.chunks_mut(sched.tile).collect();
    par_map(tiles, threads, |_, t| tile_fwht(sched.isa, t, None));
    let bands = build_bands(x, sched.tile, threads);
    par_map(bands, threads, |_, mut rows| cross_rows(sched.isa, &mut rows, sched.strip, scale));
}

/// Split the (n/c) × c matrix view of `x` into `nbands` disjoint column
/// bands, each a per-row set of `&mut` windows (safe `split_at_mut`
/// walk — no aliasing, no unsafe).
fn build_bands(x: &mut [f32], c: usize, nbands: usize) -> Vec<Vec<&mut [f32]>> {
    let r = x.len() / c;
    let nb = nbands.clamp(1, c);
    let (base, rem) = (c / nb, c % nb);
    let widths: Vec<usize> = (0..nb).map(|i| base + usize::from(i < rem)).collect();
    let mut bands: Vec<Vec<&mut [f32]>> = widths.iter().map(|_| Vec::with_capacity(r)).collect();
    for row in x.chunks_mut(c) {
        let mut rest = row;
        for (b, &wd) in widths.iter().enumerate() {
            let (head, tail) = rest.split_at_mut(wd);
            bands[b].push(head);
            rest = tail;
        }
    }
    bands
}

// ---------------------------------------------------------------------
// SketchPlan: aligned scratch + schedule, the per-thread kernel state
// ---------------------------------------------------------------------

/// One 64-byte-aligned chunk of scratch (a full cache line of f32).
#[repr(C, align(64))]
#[derive(Clone, Copy)]
struct Lane64([f32; 16]);

/// A 64-byte-aligned f32 buffer (size 64 = align 64 ⇒ no padding, so
/// the chunks are contiguous f32 lanes).
struct AlignedBuf {
    chunks: Vec<Lane64>,
    len: usize,
}

impl AlignedBuf {
    fn new(len: usize) -> AlignedBuf {
        AlignedBuf { chunks: vec![Lane64([0.0; 16]); len.div_ceil(16)], len }
    }

    fn as_mut_slice(&mut self) -> &mut [f32] {
        // SAFETY: `Lane64` is `repr(C, align(64))` over `[f32; 16]` —
        // size 64 equals the alignment, so there is no padding and the
        // Vec's storage is `chunks.len() * 16` contiguous, initialized
        // f32 lanes; `len <= chunks.len() * 16` by construction.
        unsafe { std::slice::from_raw_parts_mut(self.chunks.as_mut_ptr().cast::<f32>(), self.len) }
    }

    fn as_slice(&self) -> &[f32] {
        // SAFETY: as above, shared view.
        unsafe { std::slice::from_raw_parts(self.chunks.as_ptr().cast::<f32>(), self.len) }
    }
}

/// The precomputed stage schedule of one transform size: the
/// (tile, strip) factorization every kernel pass follows — `tile`
/// bounds the contiguous phase (stages h < tile run tile-local; the
/// cross phase has n′/tile rows), `strip` is the column group width of
/// the cross-phase passes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Schedule {
    /// contiguous tile length (stages h < tile run tile-local)
    pub tile: usize,
    /// columns per cross-phase strip
    pub strip: usize,
    /// butterfly lane-kernel dispatch level every pass runs at
    pub isa: Isa,
}

impl Schedule {
    /// Factorize a transform length into the blocked execution plan at
    /// the process-wide [`active_isa`] dispatch level.
    pub fn for_len(npad: usize) -> Schedule {
        Schedule { tile: npad.min(TILE), strip: STRIP, isa: active_isa() }
    }
}

/// Planned kernel state for one transform size n′: a 64-byte-aligned
/// n′-sized scratch plus the precomputed [`Schedule`]. Owned per thread
/// through [`with_plan`] — this replaces the old ad-hoc `FWHT_SCRATCH`
/// thread-local Vec, and additionally fuses the SRHT prologue/epilogue
/// into the butterfly passes (DESIGN.md §10).
pub struct SketchPlan {
    npad: usize,
    schedule: Schedule,
    scratch: AlignedBuf,
}

impl SketchPlan {
    /// Plan for n′-point transforms (n′ must be a power of two).
    pub fn new(npad: usize) -> SketchPlan {
        assert!(npad > 0);
        assert_pow2(npad);
        SketchPlan { npad, schedule: Schedule::for_len(npad), scratch: AlignedBuf::new(npad) }
    }

    /// [`Self::new`] pinned to an explicit dispatch level — the hook
    /// the ISA-sweep property tests use. `isa` must be executable on
    /// this machine (see [`Isa::available`]).
    pub fn with_isa(npad: usize, isa: Isa) -> SketchPlan {
        let mut plan = SketchPlan::new(npad);
        plan.schedule.isa = isa;
        plan
    }

    /// The transform length n′ this plan was built for.
    pub fn npad(&self) -> usize {
        self.npad
    }

    /// The precomputed tile/strip schedule driving every pass.
    pub fn schedule(&self) -> Schedule {
        self.schedule
    }

    /// scratch ← (H/√n′)·(D ∘ pad(w)), fully fused; returns the rotated
    /// view (valid until the next plan call).
    pub fn rotate_normalized(&mut self, w: &[f32], dsign: &[f32]) -> &[f32] {
        let sched = self.schedule;
        rotate_impl(w, dsign, self.scratch.as_mut_slice(), sched);
        self.scratch.as_slice()
    }

    /// scratch ← (H/√n′)·y for a full-length y (the de-rotation path).
    pub fn transform_normalized(&mut self, y: &[f32]) -> &[f32] {
        assert_eq!(y.len(), self.npad, "expected n'={} got {}", self.npad, y.len());
        let sched = self.schedule;
        let scale = Some(inv_sqrt_scale(self.npad));
        let x = self.scratch.as_mut_slice();
        x.copy_from_slice(y);
        blocked_impl(x, sched, scale);
        self.scratch.as_slice()
    }

    /// scratch ← (H/√n′)·(Sᵀ(scale·v)): zero, scatter the m sketch lanes
    /// to their sampled rows, transform (the adjoint's FWHT leg).
    pub fn adjoint_normalized(&mut self, sidx: &[u32], v: &[f32], scale: f32) -> &[f32] {
        assert_eq!(sidx.len(), v.len(), "sidx/v length mismatch");
        let sched = self.schedule;
        let nscale = Some(inv_sqrt_scale(self.npad));
        let x = self.scratch.as_mut_slice();
        x.fill(0.0);
        for (&i, &val) in sidx.iter().zip(v) {
            x[i as usize] = val * scale;
        }
        blocked_impl(x, sched, nscale);
        self.scratch.as_slice()
    }

    /// Threaded variant of [`Self::transform_normalized`] for the
    /// serial server context (bit-identical for any thread count).
    pub fn transform_normalized_threaded(&mut self, y: &[f32], threads: usize) -> &[f32] {
        assert_eq!(y.len(), self.npad, "expected n'={} got {}", self.npad, y.len());
        let scale = Some(inv_sqrt_scale(self.npad));
        let x = self.scratch.as_mut_slice();
        x.copy_from_slice(y);
        threaded_impl(x, threads, scale);
        self.scratch.as_slice()
    }

    /// Threaded variant of [`Self::adjoint_normalized`].
    pub fn adjoint_normalized_threaded(
        &mut self,
        sidx: &[u32],
        v: &[f32],
        scale: f32,
        threads: usize,
    ) -> &[f32] {
        assert_eq!(sidx.len(), v.len(), "sidx/v length mismatch");
        let nscale = Some(inv_sqrt_scale(self.npad));
        let x = self.scratch.as_mut_slice();
        x.fill(0.0);
        for (&i, &val) in sidx.iter().zip(v) {
            x[i as usize] = val * scale;
        }
        threaded_impl(x, threads, nscale);
        self.scratch.as_slice()
    }
}

thread_local! {
    // Per-thread plan cache, one plan per transform size seen on this
    // thread. A process touches a handful of sizes (one n′ per model
    // variant), and the data-parallel client phase gives every scoped
    // worker its own cache — same sharing story as the old FWHT_SCRATCH,
    // but with aligned scratch and the precomputed schedule attached.
    static PLAN_CACHE: RefCell<Vec<SketchPlan>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with this thread's cached [`SketchPlan`] for size `npad`
/// (created on first use).
pub fn with_plan<R>(npad: usize, f: impl FnOnce(&mut SketchPlan) -> R) -> R {
    PLAN_CACHE.with(|cell| {
        let mut plans = cell.borrow_mut();
        let idx = match plans.iter().position(|p| p.npad == npad) {
            Some(i) => i,
            None => {
                plans.push(SketchPlan::new(npad));
                plans.len() - 1
            }
        };
        f(&mut plans[idx])
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::fwht::scalar;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Bit-identity (not tolerance) against the scalar reference.
    fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len());
        for i in 0..got.len() {
            assert_eq!(
                got[i].to_bits(),
                want[i].to_bits(),
                "{what}: lane {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn blocked_matches_scalar_bitwise_all_small_sizes() {
        let mut rng = Rng::new(11);
        for lg in 0..=13 {
            let n = 1usize << lg;
            let x = randvec(&mut rng, n);
            let mut want = x.clone();
            scalar::fwht_inplace(&mut want);
            let mut got = x.clone();
            fwht_blocked(&mut got);
            assert_bits_eq(&got, &want, &format!("unnormalized n={n}"));

            let mut wantn = x.clone();
            scalar::fwht_normalized(&mut wantn);
            let mut gotn = x;
            fwht_blocked_normalized(&mut gotn);
            assert_bits_eq(&gotn, &wantn, &format!("normalized n={n}"));
        }
    }

    #[test]
    fn tile_override_bit_identity_property() {
        // tiny tiles force the cross phase (incl. n' smaller than one
        // production tile, and degenerate tile = 1)
        check("kernel_tile_override", 60, |rng| {
            let n = 1usize << rng.below(11);
            let tile = 1usize << rng.below(7);
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut want = x.clone();
            scalar::fwht_normalized(&mut want);
            let mut got = x;
            fwht_with_tile(&mut got, tile, true);
            for i in 0..n {
                if got[i].to_bits() != want[i].to_bits() {
                    return Err(format!("n={n} tile={tile} lane {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rotate_fused_matches_reference_pipeline() {
        check("kernel_rotate_fused", 40, |rng| {
            let npad = 1usize << (rng.below(11) + 1);
            let n = rng.below(npad) + 1;
            let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let d = rng.rademacher(npad);
            // reference: explicit prologue sweep, scalar FWHT, separate scale
            let mut want = vec![0.0f32; npad];
            for i in 0..n {
                want[i] = w[i] * d[i];
            }
            scalar::fwht_normalized(&mut want);
            // fused kernel, both via the free function and the plan;
            // the schedule (tile AND strip) is swept to exercise the
            // blocking on small inputs
            let mut got = vec![0.0f32; npad];
            // dirty the output to prove every lane is written
            got.iter_mut().for_each(|v| *v = f32::NAN);
            let isas = Isa::available();
            let sched = Schedule {
                tile: 1 << rng.below(7),
                strip: 1 << rng.below(5),
                isa: isas[rng.below(isas.len())],
            };
            rotate_impl(&w, &d, &mut got, sched);
            for i in 0..npad {
                if got[i].to_bits() != want[i].to_bits() {
                    return Err(format!("npad={npad} n={n} {sched:?} lane {i}"));
                }
            }
            let planned = with_plan(npad, |p| p.rotate_normalized(&w, &d).to_vec());
            for i in 0..npad {
                if planned[i].to_bits() != want[i].to_bits() {
                    return Err(format!("plan npad={npad} n={n} lane {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn threaded_bit_identical_for_any_thread_count() {
        let mut rng = Rng::new(23);
        // n > TILE so both the tile fan-out and the banded cross phase run
        let n = TILE * 8;
        let x = randvec(&mut rng, n);
        let mut want = x.clone();
        fwht_blocked_normalized(&mut want);
        for threads in [1usize, 2, 3, 4, 16] {
            let mut got = x.clone();
            fwht_threaded_normalized(&mut got, threads);
            assert_bits_eq(&got, &want, &format!("threads={threads}"));
            let mut gotu = x.clone();
            fwht_threaded(&mut gotu, threads);
            let mut wantu = x.clone();
            fwht_blocked(&mut wantu);
            assert_bits_eq(&gotu, &wantu, &format!("unnorm threads={threads}"));
        }
    }

    #[test]
    fn batch_matches_per_vector_loop() {
        let mut rng = Rng::new(31);
        for (b, n) in [(1usize, 64usize), (3, 256), (5, 1 << 13)] {
            let xs = randvec(&mut rng, b * n);
            let mut want = xs.clone();
            for x in want.chunks_exact_mut(n) {
                scalar::fwht_normalized(x);
            }
            let mut got = xs.clone();
            fwht_batch(&mut got, n);
            assert_bits_eq(&got, &want, &format!("batch B={b} n={n}"));
            for threads in [2usize, 7] {
                let mut gott = xs.clone();
                fwht_batch_threaded(&mut gott, n, threads);
                assert_bits_eq(&gott, &want, &format!("batch B={b} n={n} threads={threads}"));
            }
        }
    }

    #[test]
    fn plan_adjoint_and_transform_match_reference() {
        check("kernel_plan_paths", 30, |rng| {
            let npad = 1usize << (rng.below(9) + 1);
            let m = rng.below(npad) + 1;
            let mut idx: Vec<u32> = (0..npad as u32).collect();
            // distinct sample rows, like the operator's sidx
            for i in (1..idx.len()).rev() {
                let j = rng.below(i + 1);
                idx.swap(i, j);
            }
            idx.truncate(m);
            let v: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
            let scale = 1.37f32;
            let mut want = vec![0.0f32; npad];
            for (&i, &val) in idx.iter().zip(&v) {
                want[i as usize] = val * scale;
            }
            scalar::fwht_normalized(&mut want);
            let got = with_plan(npad, |p| p.adjoint_normalized(&idx, &v, scale).to_vec());
            for i in 0..npad {
                if got[i].to_bits() != want[i].to_bits() {
                    return Err(format!("adjoint npad={npad} m={m} lane {i}"));
                }
            }
            let gott =
                with_plan(npad, |p| p.adjoint_normalized_threaded(&idx, &v, scale, 4).to_vec());
            if gott != got {
                return Err("threaded adjoint differs".into());
            }
            let y: Vec<f32> = (0..npad).map(|_| rng.normal()).collect();
            let mut wanty = y.clone();
            scalar::fwht_normalized(&mut wanty);
            let goty = with_plan(npad, |p| p.transform_normalized(&y).to_vec());
            for i in 0..npad {
                if goty[i].to_bits() != wanty[i].to_bits() {
                    return Err(format!("transform npad={npad} lane {i}"));
                }
            }
            let gotyt = with_plan(npad, |p| p.transform_normalized_threaded(&y, 3).to_vec());
            if gotyt != goty {
                return Err("threaded transform differs".into());
            }
            Ok(())
        });
    }

    #[test]
    fn plan_scratch_is_aligned_and_reused_purely() {
        let mut plan = SketchPlan::new(1 << 10);
        let ptr = plan.scratch.as_mut_slice().as_ptr() as usize;
        assert_eq!(ptr % 64, 0, "scratch must be 64-byte aligned");
        let mut rng = Rng::new(3);
        let d: Vec<f32> = rng.rademacher(1 << 10);
        let a: Vec<f32> = (0..1000).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..1000).map(|_| rng.normal()).collect();
        let ra = plan.rotate_normalized(&a, &d).to_vec();
        let _ = plan.rotate_normalized(&b, &d); // dirty the scratch
        assert_eq!(plan.rotate_normalized(&a, &d), &ra[..], "plan reuse must be pure");
        assert_eq!(plan.schedule(), Schedule::for_len(1 << 10));
    }

    #[test]
    fn trivial_sizes_match_scalar() {
        for n in [1usize, 2, 4] {
            let x: Vec<f32> = (0..n).map(|i| i as f32 - 0.5).collect();
            let mut want = x.clone();
            scalar::fwht_normalized(&mut want);
            let mut got = x;
            fwht_blocked_normalized(&mut got);
            assert_bits_eq(&got, &want, &format!("trivial n={n}"));
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_pow2() {
        let mut x = vec![0.0f32; 24];
        fwht_blocked(&mut x);
    }

    #[test]
    fn every_available_isa_matches_scalar_oracle_bitwise() {
        // every dispatch level this machine can run, against the scalar
        // reference, at every size from n' = 1 (trivial) through odd/even
        // stage counts, SIMD-tail sizes, and a multi-tile 2^13 — both
        // normalized and not
        let mut rng = Rng::new(47);
        for &isa in &Isa::available() {
            for lg in 0..=13 {
                let n = 1usize << lg;
                let x = randvec(&mut rng, n);
                let mut want = x.clone();
                scalar::fwht_normalized(&mut want);
                let mut got = x.clone();
                fwht_blocked_normalized_isa(&mut got, isa);
                assert_bits_eq(&got, &want, &format!("isa={} normalized n={n}", isa.name()));

                let mut wantu = x.clone();
                scalar::fwht_inplace(&mut wantu);
                let mut gotu = x;
                blocked_impl(&mut gotu, Schedule { isa, ..Schedule::for_len(n) }, None);
                assert_bits_eq(&gotu, &wantu, &format!("isa={} unnorm n={n}", isa.name()));
            }
        }
    }

    #[test]
    fn isa_sweep_rotate_plan_property() {
        // the fused D·pad path (partial and full tiles) and the planned
        // adjoint/transform paths, at every executable dispatch level
        check("kernel_isa_sweep", 40, |rng| {
            let isas = Isa::available();
            let isa = isas[rng.below(isas.len())];
            let npad = 1usize << rng.below(14);
            let n = rng.below(npad) + 1;
            let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let d = rng.rademacher(npad);
            let mut want = vec![0.0f32; npad];
            for i in 0..n {
                want[i] = w[i] * d[i];
            }
            scalar::fwht_normalized(&mut want);
            let mut plan = SketchPlan::with_isa(npad, isa);
            let got = plan.rotate_normalized(&w, &d).to_vec();
            for i in 0..npad {
                if got[i].to_bits() != want[i].to_bits() {
                    return Err(format!("isa={} npad={npad} n={n} lane {i}", isa.name()));
                }
            }
            let y: Vec<f32> = (0..npad).map(|_| rng.normal()).collect();
            let mut wanty = y.clone();
            scalar::fwht_normalized(&mut wanty);
            let goty = plan.transform_normalized(&y).to_vec();
            for i in 0..npad {
                if goty[i].to_bits() != wanty[i].to_bits() {
                    return Err(format!("isa={} transform npad={npad} lane {i}", isa.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn isa_env_names_round_trip_and_reject_unknown() {
        for &isa in &Isa::available() {
            assert_eq!(Isa::from_env_name(isa.name()), Ok(isa));
            // parsing is trimmed and case-insensitive
            assert_eq!(Isa::from_env_name(&format!(" {} ", isa.name().to_uppercase())), Ok(isa));
        }
        assert!(Isa::from_env_name("sse9").is_err());
        assert!(Isa::from_env_name("").is_err());
        // the active level is always one this machine can execute
        assert!(Isa::available().contains(&active_isa()));
    }
}
