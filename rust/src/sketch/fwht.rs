//! Fast Walsh–Hadamard Transform — public entry points plus the scalar
//! reference kernel, the rust mirror of the L1 Pallas kernel
//! (`python/compile/kernels/fht.py`).
//!
//! Used on the request path by the *baselines* (OBCSAA/EDEN rotate update
//! vectors), by the server-side diagnostics, and by tests/benches that
//! cross-check the HLO artifacts bit-for-bit. [`fwht_inplace`] /
//! [`fwht_normalized`] execute on the cache-blocked, SIMD-friendly
//! kernel in [`super::kernel`] (DESIGN.md §10); the textbook butterfly
//! is retained in [`scalar`] as the bit-exactness oracle the kernel is
//! property-tested against — the blocked kernel only reorders traversal
//! across independent butterflies, so results are bit-identical. The
//! same oracle contract covers the explicit AVX2/NEON butterfly levels
//! (DESIGN.md §14): every [`super::kernel::Isa`] in
//! [`super::kernel::Isa::available`] is swept against [`scalar`] with
//! `to_bits()` equality, never a tolerance.

/// Unnormalized in-place FWHT (Sylvester/natural order).
///
/// `x.len()` must be a power of two. After this, `x = H_unnorm * x` where
/// `H_unnorm` has entries ±1. Runs on the blocked kernel; bit-identical
/// to [`scalar::fwht_inplace`].
pub fn fwht_inplace(x: &mut [f32]) {
    super::kernel::fwht_blocked(x);
}

/// Normalized in-place FWHT: `x <- (H/sqrt(n)) x`; involution (applying
/// twice returns the input) and isometry (preserves the l2 norm). Runs
/// on the blocked kernel with the 1/√n multiply fused into the final
/// butterfly stage; bit-identical to [`scalar::fwht_normalized`].
pub fn fwht_normalized(x: &mut [f32]) {
    super::kernel::fwht_blocked_normalized(x);
}

/// The textbook single-radix butterfly, retained verbatim as the
/// bit-exactness oracle for the blocked kernel (DESIGN.md §10). Every
/// restructured path in [`super::kernel`] is property-tested
/// bit-identical against these.
pub mod scalar {
    /// Reference unnormalized FWHT: one O(n)-strided pass per stage.
    pub fn fwht_inplace(x: &mut [f32]) {
        let n = x.len();
        assert!(n.is_power_of_two(), "fwht needs power-of-two length, got {n}");
        let mut h = 1;
        while h < n {
            let stride = h * 2;
            let mut base = 0;
            while base < n {
                for i in base..base + h {
                    let a = x[i];
                    let b = x[i + h];
                    x[i] = a + b;
                    x[i + h] = a - b;
                }
                base += stride;
            }
            h = stride;
        }
    }

    /// Reference normalized FWHT: full butterfly, then a separate 1/√n
    /// sweep (the multiply the blocked kernel fuses into its last stage).
    pub fn fwht_normalized(x: &mut [f32]) {
        let n = x.len();
        fwht_inplace(x);
        let scale = 1.0 / (n as f32).sqrt();
        for v in x.iter_mut() {
            *v *= scale;
        }
    }
}

/// Dense normalized Hadamard matrix row `r` dotted with `x` — O(n) oracle
/// used only by tests (entry H[r,c] = (-1)^{popcount(r & c)} / sqrt(n)).
pub fn hadamard_row_dot(r: usize, x: &[f32]) -> f64 {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    let mut acc = 0.0f64;
    for (c, &v) in x.iter().enumerate() {
        let sign = if ((r & c).count_ones() & 1) == 1 { -1.0 } else { 1.0 };
        acc += sign * v as f64;
    }
    acc / (n as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn matches_dense_definition() {
        let mut rng = Rng::new(1);
        for log2n in 0..=8 {
            let n = 1usize << log2n;
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut y = x.clone();
            fwht_normalized(&mut y);
            for r in 0..n {
                let want = hadamard_row_dot(r, &x);
                assert!(
                    (y[r] as f64 - want).abs() < 1e-3,
                    "n={n} row={r}: {} vs {want}",
                    y[r]
                );
            }
        }
    }

    #[test]
    fn blocked_entry_points_are_bit_identical_to_scalar() {
        check("fwht_entry_bit_identity", 60, |rng| {
            let n = 1usize << rng.below(14);
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut want = x.clone();
            scalar::fwht_inplace(&mut want);
            let mut got = x.clone();
            fwht_inplace(&mut got);
            for i in 0..n {
                if got[i].to_bits() != want[i].to_bits() {
                    return Err(format!("unnormalized n={n} lane {i}"));
                }
            }
            let mut wantn = x.clone();
            scalar::fwht_normalized(&mut wantn);
            let mut gotn = x;
            fwht_normalized(&mut gotn);
            for i in 0..n {
                if gotn[i].to_bits() != wantn[i].to_bits() {
                    return Err(format!("normalized n={n} lane {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn involution_property() {
        check("fwht_involution", 50, |rng| {
            let log2n = rng.below(12);
            let n = 1usize << log2n;
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut y = x.clone();
            fwht_normalized(&mut y);
            fwht_normalized(&mut y);
            for i in 0..n {
                if (y[i] - x[i]).abs() > 1e-3 * x[i].abs().max(1.0) {
                    return Err(format!("i={i}: {} vs {}", y[i], x[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn isometry_property() {
        check("fwht_isometry", 50, |rng| {
            let n = 1usize << (rng.below(10) + 1);
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let before = crate::util::stats::l2_norm(&x);
            let mut y = x;
            fwht_normalized(&mut y);
            let after = crate::util::stats::l2_norm(&y);
            if (before - after).abs() > 1e-2 * before.max(1.0) {
                return Err(format!("norm {before} -> {after}"));
            }
            Ok(())
        });
    }

    #[test]
    fn linearity_property() {
        check("fwht_linearity", 30, |rng| {
            let n = 1usize << (rng.below(8) + 1);
            let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut sum: Vec<f32> = a.iter().zip(&b).map(|(x, y)| 2.0 * x + 3.0 * y).collect();
            fwht_normalized(&mut sum);
            let mut ha = a;
            let mut hb = b;
            fwht_normalized(&mut ha);
            fwht_normalized(&mut hb);
            for i in 0..n {
                let want = 2.0 * ha[i] + 3.0 * hb[i];
                if (sum[i] - want).abs() > 1e-3 * want.abs().max(1.0) {
                    return Err(format!("i={i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn trivial_sizes() {
        let mut one = [5.0f32];
        fwht_normalized(&mut one);
        assert_eq!(one[0], 5.0);
        let mut two = [1.0f32, 2.0];
        fwht_normalized(&mut two);
        let s = 1.0 / 2.0f32.sqrt();
        assert!((two[0] - 3.0 * s).abs() < 1e-6);
        assert!((two[1] + 1.0 * s).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_pow2() {
        let mut x = vec![0.0f32; 12];
        fwht_inplace(&mut x);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn scalar_reference_rejects_non_pow2() {
        let mut x = vec![0.0f32; 12];
        scalar::fwht_inplace(&mut x);
    }
}
