//! The paper's sketching substrate, mirrored in rust.
//!
//! The HLO artifacts own the pFed1BS hot path; this module provides the
//! identical operator for baselines, server-side work, the dense-Gaussian
//! ablation (Appendix Fig. 3), bit-packing for the one-bit transport, and
//! the Lemma-1 majority vote. The FWHT itself runs on the planned,
//! cache-blocked kernel in [`kernel`] (DESIGN.md §10), bit-identical to
//! the retained scalar reference in [`fwht::scalar`].

pub mod bitpack;
pub mod fwht;
pub mod kernel;
pub mod srht;

pub use bitpack::{
    hamming_packed, majority_vote_uniform, majority_vote_weighted, pack_signs, packed_bytes,
    quantize_weight, unpack_signs, ScalarTally, SignVec, SignVecView, VoteAccumulator,
};
pub use fwht::{fwht_inplace, fwht_normalized};
pub use kernel::{
    active_isa, fwht_batch, fwht_batch_threaded, fwht_blocked_normalized_isa, fwht_threaded,
    fwht_threaded_normalized, with_plan, Isa, Schedule, SketchPlan,
};
pub use srht::{DenseGaussianOperator, Projection, SrhtOperator};
