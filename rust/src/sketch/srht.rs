//! The SRHT sketching operator Φ = √(n′/m)·S·H·D·P_pad (paper Eq. 16/18).
//!
//! This is the rust mirror of the L1 Pallas kernels: the *same* (D, S)
//! realization is shared with the HLO artifacts by passing `dsign`/`sidx`
//! as runtime inputs, so rust and XLA compute the identical operator —
//! `rust/tests/integration_runtime.rs` checks bit-for-bit agreement.
//!
//! On the pFed1BS hot path the sketch runs inside the HLO artifact; this
//! mirror serves the baselines (OBCSAA's compressed-sensing uplink, EDEN's
//! rotation), server-side reconstruction, and the dense-Gaussian ablation
//! of Appendix Fig. 3.

use std::cell::RefCell;

use crate::sketch::bitpack::SignVec;
use crate::sketch::fwht::fwht_normalized;
use crate::util::rng::Rng;

thread_local! {
    // Per-thread n'-sized FWHT workspace. forward/adjoint run on every
    // baseline client step and every dense-ablation regularizer step,
    // and the per-call `vec![0.0; npad]` was pure allocator traffic;
    // one thread-local buffer serves the data-parallel client phase
    // without sharing (each scoped worker gets its own).
    static FWHT_SCRATCH: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

fn with_scratch<R>(npad: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    FWHT_SCRATCH.with(|cell| {
        let mut buf = cell.borrow_mut();
        buf.clear();
        buf.resize(npad, 0.0);
        f(&mut buf)
    })
}

/// A concrete realization of the structured projection.
#[derive(Clone, Debug)]
pub struct SrhtOperator {
    /// original dimension n
    pub n: usize,
    /// padded power-of-two dimension n'
    pub npad: usize,
    /// sketch dimension m
    pub m: usize,
    /// diagonal Rademacher signs (length n')
    pub dsign: Vec<f32>,
    /// subsampled row indices (length m, distinct, < n')
    pub sidx: Vec<u32>,
    /// √(n′/m)
    pub scale: f32,
}

impl SrhtOperator {
    /// Build from a seed. The same seed on server and clients yields the
    /// same operator — the paper's "server broadcasts random seed I".
    pub fn from_seed(seed: u64, n: usize, m: usize) -> SrhtOperator {
        assert!(n > 0 && m > 0 && m <= n, "need 0 < m <= n (got n={n}, m={m})");
        let npad = n.next_power_of_two();
        let mut rng = Rng::new(seed ^ 0x5349_4754_4852_u64); // "SRHT"
        let dsign = rng.rademacher(npad);
        let sidx: Vec<u32> = rng
            .sample_without_replacement(npad, m)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        let scale = ((npad as f64 / m as f64).sqrt()) as f32;
        SrhtOperator { n, npad, m, dsign, sidx, scale }
    }

    /// Forward sketch z = Φw ∈ R^m (real-valued). Runs in the
    /// thread-local scratch buffer — no per-call n'-sized allocation.
    pub fn forward(&self, w: &[f32]) -> Vec<f32> {
        with_scratch(self.npad, |buf| {
            self.forward_padded_into(w, buf);
            self.subsample(buf)
        })
    }

    /// One-bit sketch z = sign(Φw) ∈ {−1,+1}^m, sign(0) := +1.
    pub fn sketch_sign(&self, w: &[f32]) -> Vec<f32> {
        self.forward(w)
            .into_iter()
            .map(|z| if z >= 0.0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// One-bit sketch packed straight from the rotated scratch buffer:
    /// the transport-ready form, with no f32 ±1 lane vector in between.
    pub fn sketch_sign_packed(&self, w: &[f32]) -> SignVec {
        with_scratch(self.npad, |buf| {
            self.forward_padded_into(w, buf);
            // same comparison as `sketch_sign`: sign of the *scaled*
            // coordinate (scale > 0, kept for exact f32 parity)
            SignVec::from_fn(self.m, |j| buf[self.sidx[j] as usize] * self.scale >= 0.0)
        })
    }

    /// Adjoint g = Φᵀv ∈ R^n. Uses the thread-local scratch for the
    /// n'-sized FWHT workspace; only the n-sized result is allocated.
    pub fn adjoint(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.m);
        with_scratch(self.npad, |buf| {
            for (&idx, &val) in self.sidx.iter().zip(v) {
                buf[idx as usize] = val * self.scale;
            }
            fwht_normalized(buf);
            buf.iter()
                .zip(&self.dsign)
                .take(self.n)
                .map(|(&b, &d)| b * d)
                .collect()
        })
    }

    /// H·D·pad(w) without subsampling — the full rotated vector. EDEN
    /// needs all n' rotated coordinates, not just the m sampled ones.
    pub fn rotate(&self, w: &[f32]) -> Vec<f32> {
        self.forward_padded(w)
    }

    /// Inverse of `rotate` (D·H·y, truncated) — exact because H and D are
    /// involutions.
    pub fn rotate_inverse(&self, y: &[f32]) -> Vec<f32> {
        assert_eq!(y.len(), self.npad);
        let mut buf = y.to_vec();
        fwht_normalized(&mut buf);
        for (b, &d) in buf.iter_mut().zip(&self.dsign) {
            *b *= d;
        }
        buf.truncate(self.n);
        buf
    }

    /// Allocating variant for callers that keep the full rotated vector
    /// (`rotate`). Hot paths go through `forward_padded_into` + scratch.
    fn forward_padded(&self, w: &[f32]) -> Vec<f32> {
        let mut buf = vec![0.0f32; self.npad];
        self.forward_padded_into(w, &mut buf);
        buf
    }

    /// H·D·pad(w) into a caller-provided zeroed buffer of length n'.
    fn forward_padded_into(&self, w: &[f32], buf: &mut [f32]) {
        assert_eq!(w.len(), self.n, "expected n={} got {}", self.n, w.len());
        debug_assert_eq!(buf.len(), self.npad);
        for ((b, &x), &d) in buf.iter_mut().zip(w).zip(&self.dsign) {
            *b = x * d;
        }
        fwht_normalized(buf);
    }

    fn subsample(&self, buf: &[f32]) -> Vec<f32> {
        self.sidx
            .iter()
            .map(|&i| buf[i as usize] * self.scale)
            .collect()
    }
}

/// Dense Gaussian projection baseline for Appendix Fig. 3: Φ_gauss with
/// i.i.d. N(0, 1/m) entries — the O(mn) apply (and O(mn) memory) that
/// the paper's FHT replaces. The matrix is materialized lazily on first
/// use (row-major, m×n f32 — ~4 GiB for mlp784; this testbed has 34 GiB),
/// using an Irwin–Hall(4) normal approximation so materialization is
/// generation-bandwidth- not transcendental-bound. The O(mn) apply cost
/// is exactly the point of the ablation: see `benches/bench_fwht.rs`.
#[derive(Clone, Debug)]
pub struct DenseGaussianOperator {
    pub n: usize,
    pub m: usize,
    seed: u64,
    // Arc<OnceLock>, not Rc<OnceCell>: clients sketch concurrently during
    // the parallel round phase, and first-touch materialization must be
    // race-free (OnceLock serializes the single initializer).
    rows: std::sync::Arc<std::sync::OnceLock<Vec<f32>>>,
}

impl DenseGaussianOperator {
    pub fn from_seed(seed: u64, n: usize, m: usize) -> Self {
        DenseGaussianOperator {
            n,
            m,
            seed,
            rows: std::sync::Arc::new(std::sync::OnceLock::new()),
        }
    }

    fn matrix(&self) -> &[f32] {
        self.rows.get_or_init(|| {
            let mut rng = Rng::new(self.seed ^ 0xDE45_E000);
            let inv = 1.0 / (self.m as f32).sqrt();
            let total = self.m * self.n;
            let mut g = Vec::with_capacity(total);
            // Irwin–Hall(4): (Σ₄ U(0,1) − 2)·√3 ≈ N(0,1); one u64 draw
            // per entry (four 16-bit uniforms) makes materializing the
            // ~10⁹-entry matrix generation-bandwidth-bound rather than
            // transcendental-bound. Documented deviation from exact
            // Gaussian: tails truncate at ±3.46σ — irrelevant for the
            // accuracy-parity ablation this operator exists for.
            const SQRT3: f32 = 1.732_050_8;
            const U16_INV: f32 = 1.0 / 65536.0;
            for _ in 0..total {
                let bits = rng.next_u64();
                let s = ((bits & 0xFFFF) as f32
                    + ((bits >> 16) & 0xFFFF) as f32
                    + ((bits >> 32) & 0xFFFF) as f32
                    + ((bits >> 48) & 0xFFFF) as f32)
                    * U16_INV;
                g.push((s - 2.0) * SQRT3 * inv);
            }
            g
        })
    }

    /// z = Gw — one dense matvec, O(mn).
    pub fn forward(&self, w: &[f32]) -> Vec<f32> {
        assert_eq!(w.len(), self.n);
        let mat = self.matrix();
        (0..self.m)
            .map(|r| {
                let row = &mat[r * self.n..(r + 1) * self.n];
                let mut acc = 0.0f32;
                for (a, b) in row.iter().zip(w) {
                    acc += a * b;
                }
                acc
            })
            .collect()
    }

    /// g = Gᵀv — O(mn).
    pub fn adjoint(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.m);
        let mat = self.matrix();
        let mut out = vec![0.0f32; self.n];
        for (r, &vr) in v.iter().enumerate() {
            if vr == 0.0 {
                continue;
            }
            let row = &mat[r * self.n..(r + 1) * self.n];
            for (o, &a) in out.iter_mut().zip(row) {
                *o += a * vr;
            }
        }
        out
    }

    pub fn sketch_sign(&self, w: &[f32]) -> Vec<f32> {
        self.forward(w)
            .into_iter()
            .map(|z| if z >= 0.0 { 1.0 } else { -1.0 })
            .collect()
    }

    pub fn sketch_sign_packed(&self, w: &[f32]) -> SignVec {
        SignVec::from_signs(&self.forward(w))
    }
}

/// Either projection, so algorithms can be generic over Appendix Fig. 3.
#[derive(Clone, Debug)]
pub enum Projection {
    Srht(SrhtOperator),
    Dense(DenseGaussianOperator),
}

impl Projection {
    pub fn m(&self) -> usize {
        match self {
            Projection::Srht(op) => op.m,
            Projection::Dense(op) => op.m,
        }
    }

    pub fn forward(&self, w: &[f32]) -> Vec<f32> {
        match self {
            Projection::Srht(op) => op.forward(w),
            Projection::Dense(op) => op.forward(w),
        }
    }

    pub fn adjoint(&self, v: &[f32]) -> Vec<f32> {
        match self {
            Projection::Srht(op) => op.adjoint(v),
            Projection::Dense(op) => op.adjoint(v),
        }
    }

    pub fn sketch_sign(&self, w: &[f32]) -> Vec<f32> {
        match self {
            Projection::Srht(op) => op.sketch_sign(w),
            Projection::Dense(op) => op.sketch_sign(w),
        }
    }

    /// The transport-ready packed one-bit sketch (same signs as
    /// `sketch_sign`, without materializing the f32 ±1 lanes for SRHT).
    pub fn sketch_sign_packed(&self, w: &[f32]) -> SignVec {
        match self {
            Projection::Srht(op) => op.sketch_sign_packed(w),
            Projection::Dense(op) => op.sketch_sign_packed(w),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::stats::dot;

    #[test]
    fn geometry() {
        let op = SrhtOperator::from_seed(7, 1000, 100);
        assert_eq!(op.npad, 1024);
        assert_eq!(op.dsign.len(), 1024);
        assert_eq!(op.sidx.len(), 100);
        let mut sorted = op.sidx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 100, "subsample indices must be distinct");
        assert!((op.scale - (1024.0f32 / 100.0).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn same_seed_same_operator() {
        let a = SrhtOperator::from_seed(42, 500, 50);
        let b = SrhtOperator::from_seed(42, 500, 50);
        assert_eq!(a.dsign, b.dsign);
        assert_eq!(a.sidx, b.sidx);
    }

    #[test]
    fn adjoint_identity_property() {
        // <Phi x, y> == <x, Phi^T y>
        check("srht_adjoint_identity", 40, |rng| {
            let n = rng.below(800) + 2;
            let m = rng.below(n.min(200)) + 1;
            let op = SrhtOperator::from_seed(rng.next_u64(), n, m);
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
            let lhs = dot(&op.forward(&x), &y);
            let rhs = dot(&x, &op.adjoint(&y));
            if (lhs - rhs).abs() > 1e-3 * lhs.abs().max(1.0) {
                return Err(format!("lhs {lhs} rhs {rhs}"));
            }
            Ok(())
        });
    }

    #[test]
    fn linearity_property() {
        check("srht_linearity", 30, |rng| {
            let n = rng.below(500) + 2;
            let m = (n / 10).max(1);
            let op = SrhtOperator::from_seed(rng.next_u64(), n, m);
            let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let combo: Vec<f32> = a.iter().zip(&b).map(|(x, y)| 2.0 * x - y).collect();
            let lhs = op.forward(&combo);
            let fa = op.forward(&a);
            let fb = op.forward(&b);
            for i in 0..m {
                let want = 2.0 * fa[i] - fb[i];
                if (lhs[i] - want).abs() > 1e-3 * want.abs().max(1.0) {
                    return Err(format!("i={i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn spectral_norm_bound_lemma2() {
        // ||Phi w|| <= sqrt(n'/m) ||w|| for all w; equality is attainable.
        check("srht_norm_bound", 30, |rng| {
            let n = rng.below(400) + 2;
            let m = (n / 5).max(1);
            let op = SrhtOperator::from_seed(rng.next_u64(), n, m);
            let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let zn = crate::util::stats::l2_norm(&op.forward(&w));
            let wn = crate::util::stats::l2_norm(&w);
            let bound = (op.npad as f64 / op.m as f64).sqrt() * wn;
            if zn > bound * (1.0 + 1e-4) {
                return Err(format!("||Phi w||={zn} > bound {bound}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rotate_inverse_round_trip() {
        let mut rng = Rng::new(3);
        let n = 300;
        let op = SrhtOperator::from_seed(5, n, 30);
        let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let back = op.rotate_inverse(&op.rotate(&w));
        for i in 0..n {
            assert!((back[i] - w[i]).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn sign_sketch_is_pm_one() {
        let mut rng = Rng::new(4);
        let op = SrhtOperator::from_seed(6, 128, 16);
        let w: Vec<f32> = (0..128).map(|_| rng.normal()).collect();
        assert!(op.sketch_sign(&w).iter().all(|&z| z == 1.0 || z == -1.0));
    }

    #[test]
    fn packed_sketch_matches_unpacked_for_both_projections() {
        check("sketch_sign_packed_parity", 30, |rng| {
            let n = rng.below(400) + 2;
            let m = (n / 4).max(1);
            let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let srht = SrhtOperator::from_seed(rng.next_u64(), n, m);
            if srht.sketch_sign_packed(&w).to_signs() != srht.sketch_sign(&w) {
                return Err("srht packed sketch disagrees".into());
            }
            let dense = DenseGaussianOperator::from_seed(rng.next_u64(), n.min(64), 8);
            let ws = &w[..n.min(64)];
            if dense.sketch_sign_packed(ws).to_signs() != dense.sketch_sign(ws) {
                return Err("dense packed sketch disagrees".into());
            }
            Ok(())
        });
    }

    #[test]
    fn scratch_reuse_is_pure() {
        // back-to-back forward/adjoint calls share the thread-local
        // scratch; results must be independent of call history
        let mut rng = Rng::new(21);
        let op = SrhtOperator::from_seed(22, 300, 40);
        let a: Vec<f32> = (0..300).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..300).map(|_| rng.normal()).collect();
        let fa = op.forward(&a);
        let _ = op.forward(&b); // dirty the scratch with other data
        assert_eq!(op.forward(&a), fa, "forward not pure under scratch reuse");
        let v: Vec<f32> = (0..40).map(|_| rng.normal()).collect();
        let ga = op.adjoint(&v);
        let _ = op.forward(&b);
        assert_eq!(op.adjoint(&v), ga, "adjoint not pure under scratch reuse");
    }

    #[test]
    fn dense_gaussian_adjoint_identity() {
        let mut rng = Rng::new(8);
        let (n, m) = (200, 20);
        let op = DenseGaussianOperator::from_seed(9, n, m);
        let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
        let lhs = dot(&op.forward(&x), &y);
        let rhs = dot(&x, &op.adjoint(&y));
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn dense_gaussian_norm_concentration() {
        // E||Gw||^2 = ||w||^2 with 1/m variance rows — loose 30% check.
        let mut rng = Rng::new(10);
        let (n, m) = (400, 200);
        let op = DenseGaussianOperator::from_seed(11, n, m);
        let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let zn = crate::util::stats::l2_norm(&op.forward(&w));
        let wn = crate::util::stats::l2_norm(&w);
        assert!((zn / wn - 1.0).abs() < 0.3, "ratio {}", zn / wn);
    }
}
