//! The SRHT sketching operator Φ = √(n′/m)·S·H·D·P_pad (paper Eq. 16/18).
//!
//! This is the rust mirror of the L1 Pallas kernels: the *same* (D, S)
//! realization is shared with the HLO artifacts by passing `dsign`/`sidx`
//! as runtime inputs, so rust and XLA compute the identical operator —
//! `rust/tests/integration_runtime.rs` checks bit-for-bit agreement.
//!
//! On the pFed1BS hot path the sketch runs inside the HLO artifact; this
//! mirror serves the baselines (OBCSAA's compressed-sensing uplink, EDEN's
//! rotation), server-side reconstruction, and the dense-Gaussian ablation
//! of Appendix Fig. 3.
//!
//! Every FWHT application routes through the planned blocked kernel
//! (`kernel::SketchPlan`, DESIGN.md §10): each thread's cached plan owns
//! the aligned n′ scratch, the D·pad prologue is fused into the first
//! butterfly pass, the 1/√n′ normalization into the last, and
//! `sketch_sign_packed` packs `SignVec` words straight off the rotated
//! scratch — no per-call n′ allocation and no intermediate ±1 lane
//! vector anywhere. The `*_threaded` variants run the same passes on the
//! scoped worker pool (bit-identical for any thread count); they exist
//! for the serial server context, not for the already-parallel client
//! phase.

use crate::coordinator::parallel::par_map;
use crate::sketch::bitpack::SignVec;
use crate::sketch::kernel::{fwht_rotate_normalized, with_plan, Isa};
use crate::util::rng::Rng;

/// A concrete realization of the structured projection.
#[derive(Clone, Debug)]
pub struct SrhtOperator {
    /// original dimension n
    pub n: usize,
    /// padded power-of-two dimension n'
    pub npad: usize,
    /// sketch dimension m
    pub m: usize,
    /// diagonal Rademacher signs (length n')
    pub dsign: Vec<f32>,
    /// subsampled row indices (length m, distinct, < n')
    pub sidx: Vec<u32>,
    /// √(n′/m)
    pub scale: f32,
}

impl SrhtOperator {
    /// Build from a seed. The same seed on server and clients yields the
    /// same operator — the paper's "server broadcasts random seed I".
    pub fn from_seed(seed: u64, n: usize, m: usize) -> SrhtOperator {
        assert!(n > 0 && m > 0 && m <= n, "need 0 < m <= n (got n={n}, m={m})");
        let npad = n.next_power_of_two();
        let mut rng = Rng::new(seed ^ 0x5349_4754_4852_u64); // "SRHT"
        let dsign = rng.rademacher(npad);
        let sidx: Vec<u32> = rng
            .sample_without_replacement(npad, m)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        let scale = ((npad as f64 / m as f64).sqrt()) as f32;
        SrhtOperator { n, npad, m, dsign, sidx, scale }
    }

    fn check_input(&self, w: &[f32]) {
        assert_eq!(w.len(), self.n, "expected n={} got {}", self.n, w.len());
    }

    /// Forward sketch z = Φw ∈ R^m (real-valued). Fully fused in the
    /// per-thread plan scratch — no per-call n'-sized allocation.
    pub fn forward(&self, w: &[f32]) -> Vec<f32> {
        self.check_input(w);
        with_plan(self.npad, |plan| self.subsample(plan.rotate_normalized(w, &self.dsign)))
    }

    /// One-bit sketch z = sign(Φw) ∈ {−1,+1}^m, sign(0) := +1.
    pub fn sketch_sign(&self, w: &[f32]) -> Vec<f32> {
        self.forward(w)
            .into_iter()
            .map(|z| if z >= 0.0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// One-bit sketch packed straight from the rotated plan scratch:
    /// the transport-ready form, with no f32 ±1 lane vector — or any
    /// intermediate m-vector — in between.
    pub fn sketch_sign_packed(&self, w: &[f32]) -> SignVec {
        self.check_input(w);
        with_plan(self.npad, |plan| {
            let isa = plan.schedule().isa;
            let buf = plan.rotate_normalized(w, &self.dsign);
            pack_signs_scaled(isa, buf, &self.sidx, self.scale, self.m)
        })
    }

    /// Adjoint g = Φᵀv ∈ R^n. The FWHT leg runs in the plan scratch;
    /// only the n-sized result is allocated.
    pub fn adjoint(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.m);
        with_plan(self.npad, |plan| {
            let buf = plan.adjoint_normalized(&self.sidx, v, self.scale);
            buf.iter()
                .zip(&self.dsign)
                .take(self.n)
                .map(|(&b, &d)| b * d)
                .collect()
        })
    }

    /// [`Self::adjoint`] with the transform farmed to `threads` scoped
    /// workers — bit-identical for any thread count. For the serial
    /// server context (reconstruction); client-phase callers are already
    /// data-parallel and should stay on [`Self::adjoint`].
    pub fn adjoint_threaded(&self, v: &[f32], threads: usize) -> Vec<f32> {
        assert_eq!(v.len(), self.m);
        with_plan(self.npad, |plan| {
            let buf = plan.adjoint_normalized_threaded(&self.sidx, v, self.scale, threads);
            buf.iter()
                .zip(&self.dsign)
                .take(self.n)
                .map(|(&b, &d)| b * d)
                .collect()
        })
    }

    /// H·D·pad(w) without subsampling — the full rotated vector. EDEN
    /// needs all n' rotated coordinates, not just the m sampled ones.
    /// The fused kernel writes straight into the returned vector (the
    /// one allocation is the result itself); callers that only need a
    /// borrowed view should use [`Self::rotate_with`].
    pub fn rotate(&self, w: &[f32]) -> Vec<f32> {
        self.check_input(w);
        let mut out = vec![0.0f32; self.npad];
        fwht_rotate_normalized(w, &self.dsign, &mut out);
        out
    }

    /// Run `f` over the rotated vector H·D·pad(w) borrowed from the plan
    /// scratch — zero allocation. `f` must not re-enter another sketch
    /// operation on the same thread (the plan is checked out for the
    /// duration of the call, like the old scratch borrow).
    pub fn rotate_with<R>(&self, w: &[f32], f: impl FnOnce(&[f32]) -> R) -> R {
        self.check_input(w);
        with_plan(self.npad, |plan| f(plan.rotate_normalized(w, &self.dsign)))
    }

    /// Inverse of `rotate` (D·H·y, truncated) — exact because H and D are
    /// involutions. Transforms in the plan scratch; only the n-sized
    /// result is allocated.
    pub fn rotate_inverse(&self, y: &[f32]) -> Vec<f32> {
        assert_eq!(y.len(), self.npad);
        with_plan(self.npad, |plan| {
            let buf = plan.transform_normalized(y);
            buf.iter()
                .zip(&self.dsign)
                .take(self.n)
                .map(|(&b, &d)| b * d)
                .collect()
        })
    }

    /// [`Self::rotate_inverse`] on the scoped worker pool — bit-identical
    /// for any thread count (serial server context only).
    pub fn rotate_inverse_threaded(&self, y: &[f32], threads: usize) -> Vec<f32> {
        assert_eq!(y.len(), self.npad);
        with_plan(self.npad, |plan| {
            let buf = plan.transform_normalized_threaded(y, threads);
            buf.iter()
                .zip(&self.dsign)
                .take(self.n)
                .map(|(&b, &d)| b * d)
                .collect()
        })
    }

    fn subsample(&self, buf: &[f32]) -> Vec<f32> {
        self.sidx
            .iter()
            .map(|&i| buf[i as usize] * self.scale)
            .collect()
    }
}

/// Subsample + scale + sign-pack straight off the rotated buffer, at the
/// schedule's dispatch level. The comparison is the same as
/// `sketch_sign`: sign of the *scaled* coordinate (scale > 0, kept for
/// exact f32 parity), bit set ⇔ sign is +1 (sign(0) := +1). Every level
/// is bit-identical — the AVX2 gather path and the gather-free NEON
/// path evaluate the identical per-lane `buf[idx]·scale >= 0.0`
/// predicate.
fn pack_signs_scaled(isa: Isa, buf: &[f32], sidx: &[u32], scale: f32, m: usize) -> SignVec {
    debug_assert_eq!(sidx.len(), m);
    #[cfg(target_arch = "x86_64")]
    if isa == Isa::Avx2 {
        // SAFETY: `Isa::Avx2` is only constructed after
        // `is_x86_feature_detected!("avx2")` returned true.
        return unsafe { pack_signs_avx2(buf, sidx, scale, m) };
    }
    #[cfg(target_arch = "aarch64")]
    if isa == Isa::Neon {
        // SAFETY: NEON is a baseline feature of the aarch64 target
        // (same justification as the kernel butterflies).
        return unsafe { pack_signs_neon(buf, sidx, scale, m) };
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    let _ = isa;
    SignVec::from_fn(m, |j| buf[sidx[j] as usize] * scale >= 0.0)
}

/// AVX2 gather + compare + movemask sign-pack: 8 sampled lanes per
/// iteration, writing whole 8-bit groups into the packed words (a group
/// never straddles a word since 64 % 8 == 0).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
fn pack_signs_avx2(buf: &[f32], sidx: &[u32], scale: f32, m: usize) -> SignVec {
    use std::arch::x86_64::*;
    let mut words = vec![0u64; m.div_ceil(64)];
    let mut j = 0;
    while j + 8 <= m {
        // SAFETY: `j + 8 <= m = sidx.len()` bounds the index load, and
        // every `sidx` entry is a row index < buf.len() (operator
        // invariant: distinct samples below n′), so the gather reads in
        // bounds. `_CMP_GE_OQ` is exactly Rust's `>= 0.0` (quiet
        // ordered: NaN → false, -0.0 >= 0.0 → true) and movemask bit i
        // is lane i's comparison mask MSB.
        unsafe {
            let idx = _mm256_loadu_si256(sidx.as_ptr().add(j).cast());
            let vals = _mm256_i32gather_ps::<4>(buf.as_ptr(), idx);
            let scaled = _mm256_mul_ps(vals, _mm256_set1_ps(scale));
            let ge = _mm256_cmp_ps::<_CMP_GE_OQ>(scaled, _mm256_setzero_ps());
            let bits = _mm256_movemask_ps(ge) as u32 as u64;
            words[j / 64] |= bits << (j % 64);
        }
        j += 8;
    }
    for k in j..m {
        if buf[sidx[k] as usize] * scale >= 0.0 {
            words[k / 64] |= 1u64 << (k % 64);
        }
    }
    SignVec::from_words(words, m)
}

/// Gather-free NEON sign-pack: 8 sampled lanes per iteration, writing
/// whole 8-bit groups into the packed words (64 % 8 == 0 — a group
/// never straddles a word). NEON has no gather unit, so the eight
/// `buf[sidx[j]]` loads land in a stack tile first; the scale-multiply,
/// compare, and movemask (two narrowing moves, a weighted AND, one
/// horizontal add) then run vectorized.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
fn pack_signs_neon(buf: &[f32], sidx: &[u32], scale: f32, m: usize) -> SignVec {
    use std::arch::aarch64::*;
    // lane i of the comparison mask contributes bit i of the group
    const WEIGHTS: [u16; 8] = [1, 2, 4, 8, 16, 32, 64, 128];
    let mut words = vec![0u64; m.div_ceil(64)];
    let mut j = 0;
    while j + 8 <= m {
        let mut tile = [0.0f32; 8];
        for (t, &i) in tile.iter_mut().zip(&sidx[j..j + 8]) {
            *t = buf[i as usize]; // bounds-checked: sidx entries < n′
        }
        // SAFETY: `tile` and `WEIGHTS` are 8-lane stack arrays, exactly
        // covering the 128-bit loads. `vcgeq_f32` is exactly Rust's
        // `>= 0.0` (NaN → false, -0.0 >= 0.0 → true); each true lane's
        // all-ones mask narrows to 0xFFFF, the AND keeps that lane's
        // bit weight, and the horizontal add (≤ 255, no u16 overflow)
        // yields the 8-bit movemask.
        unsafe {
            let s = vdupq_n_f32(scale);
            let z = vdupq_n_f32(0.0);
            let ge_lo = vcgeq_f32(vmulq_f32(vld1q_f32(tile.as_ptr()), s), z);
            let ge_hi = vcgeq_f32(vmulq_f32(vld1q_f32(tile.as_ptr().add(4)), s), z);
            let mask = vcombine_u16(vmovn_u32(ge_lo), vmovn_u32(ge_hi));
            let bits = vaddvq_u16(vandq_u16(mask, vld1q_u16(WEIGHTS.as_ptr()))) as u64;
            words[j / 64] |= bits << (j % 64);
        }
        j += 8;
    }
    for k in j..m {
        if buf[sidx[k] as usize] * scale >= 0.0 {
            words[k / 64] |= 1u64 << (k % 64);
        }
    }
    SignVec::from_words(words, m)
}

/// Dense Gaussian projection baseline for Appendix Fig. 3: Φ_gauss with
/// i.i.d. N(0, 1/m) entries — the O(mn) apply (and O(mn) memory) that
/// the paper's FHT replaces. The matrix is materialized lazily on first
/// use (row-major, m×n f32 — ~4 GiB for mlp784; this testbed has 34 GiB),
/// using an Irwin–Hall(4) normal approximation so materialization is
/// generation-bandwidth- not transcendental-bound. The O(mn) apply cost
/// is exactly the point of the ablation: see `benches/bench_fwht.rs`.
#[derive(Clone, Debug)]
pub struct DenseGaussianOperator {
    /// original dimension n
    pub n: usize,
    /// sketch dimension m
    pub m: usize,
    seed: u64,
    // Arc<OnceLock>, not Rc<OnceCell>: clients sketch concurrently during
    // the parallel round phase, and first-touch materialization must be
    // race-free (OnceLock serializes the single initializer).
    rows: std::sync::Arc<std::sync::OnceLock<Vec<f32>>>,
}

impl DenseGaussianOperator {
    /// Build from a seed (matrix materializes lazily on first use).
    pub fn from_seed(seed: u64, n: usize, m: usize) -> Self {
        DenseGaussianOperator {
            n,
            m,
            seed,
            rows: std::sync::Arc::new(std::sync::OnceLock::new()),
        }
    }

    fn matrix(&self) -> &[f32] {
        self.rows.get_or_init(|| {
            let mut rng = Rng::new(self.seed ^ 0xDE45_E000);
            let inv = 1.0 / (self.m as f32).sqrt();
            let total = self.m * self.n;
            let mut g = Vec::with_capacity(total);
            // Irwin–Hall(4): (Σ₄ U(0,1) − 2)·√3 ≈ N(0,1); one u64 draw
            // per entry (four 16-bit uniforms) makes materializing the
            // ~10⁹-entry matrix generation-bandwidth-bound rather than
            // transcendental-bound. Documented deviation from exact
            // Gaussian: tails truncate at ±3.46σ — irrelevant for the
            // accuracy-parity ablation this operator exists for.
            const SQRT3: f32 = 1.732_050_8;
            const U16_INV: f32 = 1.0 / 65536.0;
            for _ in 0..total {
                let bits = rng.next_u64();
                let s = ((bits & 0xFFFF) as f32
                    + ((bits >> 16) & 0xFFFF) as f32
                    + ((bits >> 32) & 0xFFFF) as f32
                    + ((bits >> 48) & 0xFFFF) as f32)
                    * U16_INV;
                g.push((s - 2.0) * SQRT3 * inv);
            }
            g
        })
    }

    /// z = Gw — one dense matvec, O(mn).
    pub fn forward(&self, w: &[f32]) -> Vec<f32> {
        assert_eq!(w.len(), self.n);
        let mat = self.matrix();
        (0..self.m)
            .map(|r| {
                let row = &mat[r * self.n..(r + 1) * self.n];
                let mut acc = 0.0f32;
                for (a, b) in row.iter().zip(w) {
                    acc += a * b;
                }
                acc
            })
            .collect()
    }

    /// g = Gᵀv — O(mn).
    pub fn adjoint(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.m);
        let mat = self.matrix();
        let mut out = vec![0.0f32; self.n];
        for (r, &vr) in v.iter().enumerate() {
            if vr == 0.0 {
                continue;
            }
            let row = &mat[r * self.n..(r + 1) * self.n];
            for (o, &a) in out.iter_mut().zip(row) {
                *o += a * vr;
            }
        }
        out
    }

    /// g = Gᵀv with the output split into disjoint column bands on the
    /// scoped worker pool. Each band accumulates its own coordinates
    /// over rows in the same ascending order as [`Self::adjoint`], so
    /// the per-element f32 sum association is unchanged — bit-identical
    /// for any thread count.
    pub fn adjoint_threaded(&self, v: &[f32], threads: usize) -> Vec<f32> {
        assert_eq!(v.len(), self.m);
        if threads <= 1 || self.n < 4096 {
            return self.adjoint(v);
        }
        let mat = self.matrix();
        let n = self.n;
        let mut out = vec![0.0f32; n];
        let chunk = n.div_ceil(threads);
        let bands: Vec<(usize, &mut [f32])> = out
            .chunks_mut(chunk)
            .enumerate()
            .map(|(i, band)| (i * chunk, band))
            .collect();
        par_map(bands, threads, |_, (off, band)| {
            for (r, &vr) in v.iter().enumerate() {
                if vr == 0.0 {
                    continue;
                }
                let row = &mat[r * n + off..r * n + off + band.len()];
                for (o, &a) in band.iter_mut().zip(row) {
                    *o += a * vr;
                }
            }
        });
        out
    }

    /// One-bit sketch sign(Gw) as ±1 lanes (sign(0) := +1).
    pub fn sketch_sign(&self, w: &[f32]) -> Vec<f32> {
        self.forward(w)
            .into_iter()
            .map(|z| if z >= 0.0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// One-bit sketch packed for transport.
    pub fn sketch_sign_packed(&self, w: &[f32]) -> SignVec {
        SignVec::from_signs(&self.forward(w))
    }
}

/// Either projection, so algorithms can be generic over Appendix Fig. 3.
#[derive(Clone, Debug)]
pub enum Projection {
    /// the paper's structured SRHT operator
    Srht(SrhtOperator),
    /// the dense Gaussian ablation operator
    Dense(DenseGaussianOperator),
}

impl Projection {
    /// Sketch dimension m.
    pub fn m(&self) -> usize {
        match self {
            Projection::Srht(op) => op.m,
            Projection::Dense(op) => op.m,
        }
    }

    /// Forward sketch z = Φw.
    pub fn forward(&self, w: &[f32]) -> Vec<f32> {
        match self {
            Projection::Srht(op) => op.forward(w),
            Projection::Dense(op) => op.forward(w),
        }
    }

    /// Adjoint g = Φᵀv.
    pub fn adjoint(&self, v: &[f32]) -> Vec<f32> {
        match self {
            Projection::Srht(op) => op.adjoint(v),
            Projection::Dense(op) => op.adjoint(v),
        }
    }

    /// Server-side reconstruction adjoint on the worker pool —
    /// bit-identical to [`Self::adjoint`] for any thread count.
    pub fn adjoint_threaded(&self, v: &[f32], threads: usize) -> Vec<f32> {
        match self {
            Projection::Srht(op) => op.adjoint_threaded(v, threads),
            Projection::Dense(op) => op.adjoint_threaded(v, threads),
        }
    }

    /// One-bit sketch sign(Φw) as ±1 lanes.
    pub fn sketch_sign(&self, w: &[f32]) -> Vec<f32> {
        match self {
            Projection::Srht(op) => op.sketch_sign(w),
            Projection::Dense(op) => op.sketch_sign(w),
        }
    }

    /// The transport-ready packed one-bit sketch (same signs as
    /// `sketch_sign`, without materializing the f32 ±1 lanes for SRHT).
    pub fn sketch_sign_packed(&self, w: &[f32]) -> SignVec {
        match self {
            Projection::Srht(op) => op.sketch_sign_packed(w),
            Projection::Dense(op) => op.sketch_sign_packed(w),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::fwht::scalar;
    use crate::util::proptest::check;
    use crate::util::stats::dot;

    #[test]
    fn geometry() {
        let op = SrhtOperator::from_seed(7, 1000, 100);
        assert_eq!(op.npad, 1024);
        assert_eq!(op.dsign.len(), 1024);
        assert_eq!(op.sidx.len(), 100);
        let mut sorted = op.sidx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 100, "subsample indices must be distinct");
        assert!((op.scale - (1024.0f32 / 100.0).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn same_seed_same_operator() {
        let a = SrhtOperator::from_seed(42, 500, 50);
        let b = SrhtOperator::from_seed(42, 500, 50);
        assert_eq!(a.dsign, b.dsign);
        assert_eq!(a.sidx, b.sidx);
    }

    /// The whole operator pipeline, spelled out against the scalar
    /// reference kernel: the planned/fused paths must match this
    /// BIT-FOR-BIT (the golden traces and the HLO cross-checks rest on
    /// it).
    fn reference_rotated(op: &SrhtOperator, w: &[f32]) -> Vec<f32> {
        let mut buf = vec![0.0f32; op.npad];
        for i in 0..op.n {
            buf[i] = w[i] * op.dsign[i];
        }
        scalar::fwht_normalized(&mut buf);
        buf
    }

    #[test]
    fn forward_and_adjoint_bit_identical_to_scalar_reference() {
        check("srht_bit_identity", 40, |rng| {
            let n = rng.below(3000) + 1;
            let m = rng.below(n) + 1;
            let op = SrhtOperator::from_seed(rng.next_u64(), n, m);
            let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let rot = reference_rotated(&op, &w);
            let want_fwd: Vec<f32> = op.sidx.iter().map(|&i| rot[i as usize] * op.scale).collect();
            let got_fwd = op.forward(&w);
            for j in 0..m {
                if got_fwd[j].to_bits() != want_fwd[j].to_bits() {
                    return Err(format!("forward n={n} m={m} lane {j}"));
                }
            }
            let v: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
            let mut buf = vec![0.0f32; op.npad];
            for (&i, &val) in op.sidx.iter().zip(&v) {
                buf[i as usize] = val * op.scale;
            }
            scalar::fwht_normalized(&mut buf);
            let want_adj: Vec<f32> = buf
                .iter()
                .zip(&op.dsign)
                .take(op.n)
                .map(|(&b, &d)| b * d)
                .collect();
            let got_adj = op.adjoint(&v);
            for j in 0..n {
                if got_adj[j].to_bits() != want_adj[j].to_bits() {
                    return Err(format!("adjoint n={n} m={m} lane {j}"));
                }
            }
            for threads in [2usize, 5] {
                if op.adjoint_threaded(&v, threads) != got_adj {
                    return Err(format!("adjoint_threaded diverges at threads={threads}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rotate_paths_bit_identical_and_zero_copy_view_matches() {
        check("srht_rotate_identity", 30, |rng| {
            let n = rng.below(5000) + 1;
            let op = SrhtOperator::from_seed(rng.next_u64(), n, (n / 10).max(1));
            let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let want = reference_rotated(&op, &w);
            let got = op.rotate(&w);
            for i in 0..op.npad {
                if got[i].to_bits() != want[i].to_bits() {
                    return Err(format!("rotate n={n} lane {i}"));
                }
            }
            let viewed = op.rotate_with(&w, |y| y.to_vec());
            if viewed != got {
                return Err("rotate_with view differs from rotate".into());
            }
            // inverse round trip must be bit-stable through the plan
            let back = op.rotate_inverse(&got);
            let mut refbuf = got.clone();
            scalar::fwht_normalized(&mut refbuf);
            let want_back: Vec<f32> = refbuf
                .iter()
                .zip(&op.dsign)
                .take(op.n)
                .map(|(&b, &d)| b * d)
                .collect();
            for i in 0..n {
                if back[i].to_bits() != want_back[i].to_bits() {
                    return Err(format!("rotate_inverse n={n} lane {i}"));
                }
            }
            if op.rotate_inverse_threaded(&got, 4) != back {
                return Err("rotate_inverse_threaded diverges".into());
            }
            Ok(())
        });
    }

    #[test]
    fn adjoint_identity_property() {
        // <Phi x, y> == <x, Phi^T y>
        check("srht_adjoint_identity", 40, |rng| {
            let n = rng.below(800) + 2;
            let m = rng.below(n.min(200)) + 1;
            let op = SrhtOperator::from_seed(rng.next_u64(), n, m);
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
            let lhs = dot(&op.forward(&x), &y);
            let rhs = dot(&x, &op.adjoint(&y));
            if (lhs - rhs).abs() > 1e-3 * lhs.abs().max(1.0) {
                return Err(format!("lhs {lhs} rhs {rhs}"));
            }
            Ok(())
        });
    }

    #[test]
    fn linearity_property() {
        check("srht_linearity", 30, |rng| {
            let n = rng.below(500) + 2;
            let m = (n / 10).max(1);
            let op = SrhtOperator::from_seed(rng.next_u64(), n, m);
            let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let combo: Vec<f32> = a.iter().zip(&b).map(|(x, y)| 2.0 * x - y).collect();
            let lhs = op.forward(&combo);
            let fa = op.forward(&a);
            let fb = op.forward(&b);
            for i in 0..m {
                let want = 2.0 * fa[i] - fb[i];
                if (lhs[i] - want).abs() > 1e-3 * want.abs().max(1.0) {
                    return Err(format!("i={i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn spectral_norm_bound_lemma2() {
        // ||Phi w|| <= sqrt(n'/m) ||w|| for all w; equality is attainable.
        check("srht_norm_bound", 30, |rng| {
            let n = rng.below(400) + 2;
            let m = (n / 5).max(1);
            let op = SrhtOperator::from_seed(rng.next_u64(), n, m);
            let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let zn = crate::util::stats::l2_norm(&op.forward(&w));
            let wn = crate::util::stats::l2_norm(&w);
            let bound = (op.npad as f64 / op.m as f64).sqrt() * wn;
            if zn > bound * (1.0 + 1e-4) {
                return Err(format!("||Phi w||={zn} > bound {bound}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rotate_inverse_round_trip() {
        let mut rng = crate::util::rng::Rng::new(3);
        let n = 300;
        let op = SrhtOperator::from_seed(5, n, 30);
        let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let back = op.rotate_inverse(&op.rotate(&w));
        for i in 0..n {
            assert!((back[i] - w[i]).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn sign_sketch_is_pm_one() {
        let mut rng = crate::util::rng::Rng::new(4);
        let op = SrhtOperator::from_seed(6, 128, 16);
        let w: Vec<f32> = (0..128).map(|_| rng.normal()).collect();
        assert!(op.sketch_sign(&w).iter().all(|&z| z == 1.0 || z == -1.0));
    }

    #[test]
    fn packed_sketch_matches_unpacked_for_both_projections() {
        check("sketch_sign_packed_parity", 30, |rng| {
            let n = rng.below(400) + 2;
            let m = (n / 4).max(1);
            let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let srht = SrhtOperator::from_seed(rng.next_u64(), n, m);
            if srht.sketch_sign_packed(&w).to_signs() != srht.sketch_sign(&w) {
                return Err("srht packed sketch disagrees".into());
            }
            let dense = DenseGaussianOperator::from_seed(rng.next_u64(), n.min(64), 8);
            let ws = &w[..n.min(64)];
            if dense.sketch_sign_packed(ws).to_signs() != dense.sketch_sign(ws) {
                return Err("dense packed sketch disagrees".into());
            }
            Ok(())
        });
    }

    #[test]
    fn packed_sketch_dirty_tail_parity_m_63_64_65() {
        // the fused subsample writes SignVec words directly; pin the
        // word-boundary geometries where a tail-masking bug would hide
        let mut rng = crate::util::rng::Rng::new(77);
        for m in [63usize, 64, 65] {
            let n = 200;
            let op = SrhtOperator::from_seed(1000 + m as u64, n, m);
            let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let packed = op.sketch_sign_packed(&w);
            assert_eq!(packed.m(), m);
            assert_eq!(packed.to_signs(), op.sketch_sign(&w), "m={m}");
            // canonical zero tail beyond m
            if m % 64 != 0 {
                let last = *packed.words().last().unwrap();
                assert_eq!(last >> (m % 64), 0, "dirty tail at m={m}");
            }
        }
    }

    #[test]
    fn packed_sign_isa_sweep_bit_identity() {
        // the gather/compare/movemask pack against the scalar from_fn
        // predicate at every executable dispatch level, across word
        // geometries (sub-word, exact word, word+1, tails < 8 lanes)
        // and the -0.0 / +0.0 sign(0) := +1 edge
        let mut rng = crate::util::rng::Rng::new(91);
        for &isa in &Isa::available() {
            for m in [1usize, 7, 8, 63, 64, 65, 200] {
                let npad = 256usize;
                let mut buf: Vec<f32> = (0..npad).map(|_| rng.normal()).collect();
                buf[0] = 0.0;
                buf[1] = -0.0;
                let mut idx: Vec<u32> = (0..npad as u32).collect();
                for i in (1..idx.len()).rev() {
                    let j = rng.below(i + 1);
                    idx.swap(i, j);
                }
                idx.truncate(m);
                let scale = 1.7f32;
                let want = SignVec::from_fn(m, |j| buf[idx[j] as usize] * scale >= 0.0);
                let got = pack_signs_scaled(isa, &buf, &idx, scale, m);
                assert_eq!(got.m(), m, "isa={} m={m}", isa.name());
                assert_eq!(got.words(), want.words(), "isa={} m={m}", isa.name());
            }
        }
    }

    #[test]
    fn plan_scratch_reuse_is_pure() {
        // back-to-back forward/adjoint calls share the per-thread plan
        // scratch; results must be independent of call history
        let mut rng = crate::util::rng::Rng::new(21);
        let op = SrhtOperator::from_seed(22, 300, 40);
        let a: Vec<f32> = (0..300).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..300).map(|_| rng.normal()).collect();
        let fa = op.forward(&a);
        let _ = op.forward(&b); // dirty the scratch with other data
        assert_eq!(op.forward(&a), fa, "forward not pure under plan reuse");
        let v: Vec<f32> = (0..40).map(|_| rng.normal()).collect();
        let ga = op.adjoint(&v);
        let _ = op.forward(&b);
        assert_eq!(op.adjoint(&v), ga, "adjoint not pure under plan reuse");
        let ra = op.rotate_inverse(&op.rotate(&a));
        let _ = op.forward(&b);
        assert_eq!(op.rotate_inverse(&op.rotate(&a)), ra, "rotate_inverse not pure");
    }

    #[test]
    fn dense_gaussian_adjoint_identity() {
        let mut rng = crate::util::rng::Rng::new(8);
        let (n, m) = (200, 20);
        let op = DenseGaussianOperator::from_seed(9, n, m);
        let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
        let lhs = dot(&op.forward(&x), &y);
        let rhs = dot(&x, &op.adjoint(&y));
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn dense_gaussian_threaded_adjoint_bit_identical() {
        let mut rng = crate::util::rng::Rng::new(12);
        let (n, m) = (5000, 64); // n >= the threading floor
        let op = DenseGaussianOperator::from_seed(13, n, m);
        let mut v: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
        v[3] = 0.0; // exercise the zero-row skip in both paths
        let serial = op.adjoint(&v);
        for threads in [2usize, 3, 8] {
            let par = op.adjoint_threaded(&v, threads);
            assert_eq!(par.len(), serial.len());
            for i in 0..n {
                assert_eq!(
                    par[i].to_bits(),
                    serial[i].to_bits(),
                    "threads={threads} lane {i}"
                );
            }
        }
    }

    #[test]
    fn dense_gaussian_norm_concentration() {
        // E||Gw||^2 = ||w||^2 with 1/m variance rows — loose 30% check.
        let mut rng = crate::util::rng::Rng::new(10);
        let (n, m) = (400, 200);
        let op = DenseGaussianOperator::from_seed(11, n, m);
        let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let zn = crate::util::stats::l2_norm(&op.forward(&w));
        let wn = crate::util::stats::l2_norm(&w);
        assert!((zn / wn - 1.0).abs() < 0.3, "ratio {}", zn / wn);
    }
}
