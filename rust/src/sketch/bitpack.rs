//! Packed one-bit sign vectors ([`SignVec`]), the server's weighted
//! majority vote (Lemma 1), and the streaming mergeable tally
//! ([`VoteAccumulator`]) the round engine folds uplinks into
//! (DESIGN.md §9).
//!
//! A sign vector z ∈ {−1,+1}^m is stored as ⌈m/64⌉ u64 words (bit set ⇔
//! +1, with the `sign(0) := +1` convention used everywhere in the
//! system) and stays packed end-to-end: algorithms build a `SignVec`
//! once at the compression boundary, the codec memcpys its words onto
//! the wire, the simulated network corrupts bits with masked XOR, and
//! the majority vote borrows client words directly. f32 ±1 lanes exist
//! only at the compute boundary (the HLO client step and server-side
//! reconstruction) — see DESIGN.md §8 for which layers own the
//! pack/unpack boundaries.
//!
//! Invariant: bits at positions ≥ m in the last word are always zero
//! ("canonical tail"), so derived equality and word-level popcounts are
//! semantic — every constructor masks the tail.

use std::borrow::Borrow;

/// A packed ±1 sign vector: ⌈m/64⌉ u64 words plus the logical length m.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SignVec {
    words: Vec<u64>,
    m: usize,
}

impl SignVec {
    /// Pack a ±1 (or arbitrary f32) vector; `sign(0) := +1`.
    pub fn from_signs(signs: &[f32]) -> SignVec {
        SignVec { words: pack_signs(signs), m: signs.len() }
    }

    /// Build bit-by-bit. `sign_is_plus(i)` is called exactly once per
    /// index, in ascending order 0..m — callers drive RNG streams
    /// through the closure and rely on that order for determinism.
    /// Each word is accumulated in a register and stored once (the
    /// fused SRHT subsample packs through this path every client round).
    pub fn from_fn(m: usize, mut sign_is_plus: impl FnMut(usize) -> bool) -> SignVec {
        let mut words = vec![0u64; m.div_ceil(64)];
        for (wi, word) in words.iter_mut().enumerate() {
            let bits = (m - wi * 64).min(64);
            let mut acc = 0u64;
            for b in 0..bits {
                if sign_is_plus(wi * 64 + b) {
                    acc |= 1u64 << b;
                }
            }
            *word = acc;
        }
        SignVec { words, m }
    }

    /// Adopt raw words (e.g. straight off the wire). The tail is masked
    /// to keep equality semantic even if the source carried garbage
    /// bits beyond m.
    pub fn from_words(mut words: Vec<u64>, m: usize) -> SignVec {
        assert_eq!(
            words.len(),
            m.div_ceil(64),
            "need {} words for m={m}, got {}",
            m.div_ceil(64),
            words.len()
        );
        mask_tail(&mut words, m);
        SignVec { words, m }
    }

    /// Logical length m (number of signs).
    pub fn m(&self) -> usize {
        self.m
    }

    /// Synonym for [`SignVec::m`].
    pub fn len(&self) -> usize {
        self.m
    }

    /// True for the zero-length sign vector.
    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    /// The packed words (tail bits beyond m are zero).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Exact payload bytes when serialized (whole words).
    pub fn byte_len(&self) -> usize {
        self.words.len() * 8
    }

    /// Bit i (true ⇔ +1).
    #[inline]
    pub fn bit(&self, i: usize) -> bool {
        debug_assert!(i < self.m);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    /// Sign i as ±1.0.
    #[inline]
    pub fn sign(&self, i: usize) -> f32 {
        if self.bit(i) {
            1.0
        } else {
            -1.0
        }
    }

    /// Unpack to ±1.0 f32 lanes (compute-boundary use only).
    pub fn to_signs(&self) -> Vec<f32> {
        unpack_signs(&self.words, self.m)
    }

    /// Iterate the signs as ±1.0 without materializing an f32 vector.
    pub fn iter_signs(&self) -> impl Iterator<Item = f32> + '_ {
        (0..self.m).map(move |i| self.sign(i))
    }

    /// Hamming distance to `other` (consensus-distance diagnostic).
    pub fn hamming(&self, other: &SignVec) -> usize {
        assert_eq!(self.m, other.m, "hamming over mismatched lengths");
        hamming_packed(&self.words, &other.words, self.m)
    }

    /// Flip the bits selected by `flip(i)` via per-word masked XOR.
    /// `flip` is called once per index in ascending order 0..m (so an
    /// RNG-driven closure consumes exactly the stream a ±1-lane walk
    /// would), and bits beyond m are never touched.
    pub fn flip_bits_where(&mut self, mut flip: impl FnMut(usize) -> bool) {
        let m = self.m;
        for (w, word) in self.words.iter_mut().enumerate() {
            let bits = (m - w * 64).min(64);
            let mut mask = 0u64;
            for b in 0..bits {
                if flip(w * 64 + b) {
                    mask |= 1u64 << b;
                }
            }
            *word ^= mask;
        }
    }
}

fn mask_tail(words: &mut [u64], m: usize) {
    let tail = m % 64;
    if tail != 0 {
        if let Some(last) = words.last_mut() {
            *last &= (1u64 << tail) - 1;
        }
    }
}

/// A borrowed packed sign vector over raw little-endian wire bytes —
/// the zero-copy counterpart of [`SignVec`] (DESIGN.md §14). The word
/// accessor reads the bytes in place with an unaligned load (wire
/// buffers carry no alignment guarantee: the packed words sit at byte
/// offset 5 of a `Signs` frame) and masks the final word's tail, so a
/// view over a dirty-tail frame observes exactly the canonical words
/// [`SignVec::from_words`] would have produced. The view borrows the
/// receive buffer; anything that must outlive the buffer goes through
/// [`SignVecView::to_owned`].
#[derive(Clone, Copy, Debug)]
pub struct SignVecView<'a> {
    bytes: &'a [u8],
    m: usize,
}

impl<'a> SignVecView<'a> {
    /// View `bytes` as ⌈m/64⌉ little-endian u64 words of packed signs.
    /// `bytes.len()` must be exactly [`packed_bytes`]`(m)`.
    pub fn new(bytes: &'a [u8], m: usize) -> SignVecView<'a> {
        assert_eq!(
            bytes.len(),
            packed_bytes(m),
            "need {} bytes for m={m}, got {}",
            packed_bytes(m),
            bytes.len()
        );
        SignVecView { bytes, m }
    }

    /// Logical length m (number of signs).
    pub fn m(&self) -> usize {
        self.m
    }

    /// Number of packed words, ⌈m/64⌉.
    pub fn words_len(&self) -> usize {
        self.m.div_ceil(64)
    }

    /// Word `i`, canonicalized: tail bits beyond m read as zero, exactly
    /// like the owned decode path.
    #[inline]
    pub fn word(&self, i: usize) -> u64 {
        let lo = i * 8;
        assert!(lo + 8 <= self.bytes.len(), "word {i} out of range");
        // SAFETY: the assert above bounds the 8-byte read inside the
        // borrowed buffer; `read_unaligned` requires no alignment and
        // every bit pattern is a valid u64.
        let raw = unsafe { self.bytes.as_ptr().add(lo).cast::<u64>().read_unaligned() };
        let w = u64::from_le(raw);
        let tail = self.m % 64;
        if tail != 0 && i == self.words_len() - 1 {
            w & ((1u64 << tail) - 1)
        } else {
            w
        }
    }

    /// Bit i (true ⇔ +1), identical to [`SignVec::bit`].
    #[inline]
    pub fn bit(&self, i: usize) -> bool {
        debug_assert!(i < self.m);
        self.word(i / 64) >> (i % 64) & 1 == 1
    }

    /// Sign i as ±1.0, identical to [`SignVec::sign`].
    #[inline]
    pub fn sign(&self, i: usize) -> f32 {
        if self.bit(i) {
            1.0
        } else {
            -1.0
        }
    }

    /// Materialize an owned canonical [`SignVec`] — bit-identical to
    /// the copying decode of the same bytes. (Takes `self` by value:
    /// the view is `Copy`.)
    pub fn to_owned(self) -> SignVec {
        SignVec::from_words((0..self.words_len()).map(|i| self.word(i)).collect(), self.m)
    }
}

/// Pack a ±1 f32 sign vector into u64 words (bit set ⇔ value >= 0).
pub fn pack_signs(signs: &[f32]) -> Vec<u64> {
    let words = signs.len().div_ceil(64);
    let mut out = vec![0u64; words];
    for (i, &s) in signs.iter().enumerate() {
        if s >= 0.0 {
            out[i / 64] |= 1u64 << (i % 64);
        }
    }
    out
}

/// Unpack to ±1 f32 of length `m`.
pub fn unpack_signs(words: &[u64], m: usize) -> Vec<f32> {
    assert!(words.len() * 64 >= m, "not enough words for m={m}");
    (0..m)
        .map(|i| {
            if words[i / 64] >> (i % 64) & 1 == 1 {
                1.0
            } else {
                -1.0
            }
        })
        .collect()
}

/// Exact number of payload bytes for an m-bit sign message.
pub fn packed_bytes(m: usize) -> usize {
    m.div_ceil(64) * 8
}

/// Fixed-point scale for aggregation weights: 2⁶⁴, exact in f64 (a power
/// of two). Weights enter every tally as integer counts of 2⁻⁶⁴ quanta.
const WEIGHT_SCALE: f64 = (1u128 << 64) as f64;

/// Quantize an aggregation weight to 64.64 fixed point (round to the
/// nearest 2⁻⁶⁴ quantum). Integer addition is associative and
/// commutative, so every tally built from quantized weights is
/// bit-identical for ANY absorb order, shard count, and merge order —
/// the invariant the streaming server path rests on (DESIGN.md §9).
/// Quantization error is ≤ 2⁻⁶⁵ per term; weights below ~5·10⁻²⁰
/// collapse to zero quanta and weights above ~10²⁰ saturate — both far
/// outside any federation this system models.
#[inline]
pub fn quantize_weight(w: f64) -> i128 {
    (w * WEIGHT_SCALE).round() as i128
}

/// Weighted majority vote v = sign(Σ pₖ zₖ) over packed sketches
/// (Lemma 1: the exact minimizer of the server objective, Eq. 13/14).
/// Ties (Σ = 0) break toward +1, matching `sign(0) = +1` everywhere
/// else. Generic over `Borrow<SignVec>` so callers can vote directly
/// over `&SignVec`s borrowed from delivered uplinks — no re-pack or
/// copy of the client words.
///
/// The per-bit sums are 64.64 fixed point ([`quantize_weight`]): exact
/// and order-invariant, so this batch form is the *reference* the
/// streaming [`VoteAccumulator`] is property-tested against — f32
/// accumulation could flip near-tie bits depending on client order,
/// which would make "bit-identical under any arrival order" unprovable.
pub fn majority_vote_weighted<S: Borrow<SignVec>>(
    sketches: &[S],
    weights: &[f32],
    m: usize,
) -> SignVec {
    assert_eq!(sketches.len(), weights.len());
    let mut acc = vec![0i128; m];
    for (z, &p) in sketches.iter().zip(weights) {
        let z = z.borrow();
        debug_assert_eq!(z.m(), m, "sketch length mismatch in vote");
        let q = quantize_weight(p as f64);
        for (i, a) in acc.iter_mut().enumerate() {
            let bit = z.words()[i / 64] >> (i % 64) & 1;
            *a += if bit == 1 { q } else { -q };
        }
    }
    SignVec::from_fn(m, |i| acc[i] >= 0)
}

/// Streaming, mergeable aggregation state — the O(m) heart of the server
/// (DESIGN.md §9). Holds one 64.64 fixed-point tally per bit; the cohort
/// itself is never stored:
///
/// * [`absorb`](VoteAccumulator::absorb) folds one delivered sketch with
///   its weight as the uplink arrives;
/// * [`merge`](VoteAccumulator::merge) folds a sibling shard (a
///   shard-parallel server folds per worker and merges, like the
///   `RoundBytes` ledger shards);
/// * [`finish`](VoteAccumulator::finish) signs the tally into the
///   consensus (Lemma 1), or
///   [`finish_sum`](VoteAccumulator::finish_sum) reads it back as the
///   real-valued estimate Σ wₖ zₖ for the linear one-bit estimators.
///
/// Because the tallies are integers, any absorb order, shard count, and
/// merge order yield bit-identical results, equal to the batch
/// [`majority_vote_weighted`] reference — property-tested below under
/// arbitrary permutations and shardings.
#[derive(Clone, Debug)]
pub struct VoteAccumulator {
    tally: Vec<i128>,
    m: usize,
    absorbed: usize,
}

impl VoteAccumulator {
    /// Empty tally over m bits.
    pub fn new(m: usize) -> VoteAccumulator {
        VoteAccumulator { tally: vec![0i128; m], m, absorbed: 0 }
    }

    /// Logical sketch length m.
    pub fn m(&self) -> usize {
        self.m
    }

    /// How many sketches this tally (including merged shards) has folded.
    pub fn absorbed(&self) -> usize {
        self.absorbed
    }

    /// The raw 64.64 fixed-point tally quanta, one per bit — what an
    /// edge aggregator ships to the root in its merge frame
    /// (`Payload::TallyFrame`, DESIGN.md §11). Integers, so the wire
    /// round trip is exact.
    pub fn quanta(&self) -> &[i128] {
        &self.tally
    }

    /// Rebuild a tally from wire quanta (the root's side of the merge
    /// frame). `merge`-ing the result is bit-identical to having
    /// absorbed the shard's sketches locally.
    pub fn from_quanta(quanta: Vec<i128>, absorbed: usize) -> VoteAccumulator {
        VoteAccumulator { m: quanta.len(), tally: quanta, absorbed }
    }

    /// Fold one sketch: `tally[i] += ±quantize(weight)`. `weight` is the
    /// vote weight pₖ, or pₖ·cₖ for the scaled linear estimators. O(m);
    /// the sketch is only read and can be dropped immediately after.
    pub fn absorb(&mut self, z: &SignVec, weight: f64) {
        assert_eq!(z.m(), self.m, "sketch length mismatch in absorb");
        self.absorb_words(|w| z.words()[w], weight);
    }

    /// Fold one sketch straight off a borrowed wire view — the zero-copy
    /// hot path. `tally[i]` receives exactly the same ±q term as
    /// [`absorb`](Self::absorb) over the materialized view, so the two
    /// paths are bit-identical by construction.
    pub fn absorb_view(&mut self, z: &SignVecView<'_>, weight: f64) {
        assert_eq!(z.m(), self.m, "sketch length mismatch in absorb");
        self.absorb_words(|w| z.word(w), weight);
    }

    /// The single absorb loop both entry points share: one word fetch
    /// per 64 tallies, each tally taking `+q` on a set bit and `-q`
    /// otherwise (independent per element, so the word-outer walk is
    /// bit-identical to a flat index walk).
    fn absorb_words(&mut self, word: impl Fn(usize) -> u64, weight: f64) {
        let q = quantize_weight(weight);
        for (wi, chunk) in self.tally.chunks_mut(64).enumerate() {
            let w = word(wi);
            for (b, a) in chunk.iter_mut().enumerate() {
                *a += if w >> b & 1 == 1 { q } else { -q };
            }
        }
        self.absorbed += 1;
    }

    /// Fold a sibling shard. Integer sums commute and associate, so the
    /// merged tally is bit-identical to absorbing every sketch into one
    /// accumulator, in any order.
    pub fn merge(&mut self, other: VoteAccumulator) {
        assert_eq!(other.m, self.m, "merging accumulators of different m");
        for (a, b) in self.tally.iter_mut().zip(other.tally) {
            *a += b;
        }
        self.absorbed += other.absorbed;
    }

    /// Fold a sibling shard read lazily off the wire: `quantum(i)` is
    /// called once per bit, in ascending order, and must return the
    /// shard's i-th tally quanta. Bit-identical to
    /// `merge(from_quanta(...))` without materializing the i128 vector.
    /// The caller must have verified the shard carries exactly m quanta.
    pub fn merge_quanta(&mut self, absorbed: usize, quantum: impl Fn(usize) -> i128) {
        for (i, a) in self.tally.iter_mut().enumerate() {
            *a += quantum(i);
        }
        self.absorbed += absorbed;
    }

    /// Sign the tally into the packed consensus (ties → +1, the global
    /// `sign(0) := +1` convention). Callers decide what an empty tally
    /// means: with zero sketches absorbed this is all-+1, which a server
    /// normally wants to discard rather than adopt.
    pub fn finish(&self) -> SignVec {
        SignVec::from_fn(self.m, |i| self.tally[i] >= 0)
    }

    /// Read the tally back as real values — the linear-estimator close,
    /// Σₖ wₖ zₖ as f32 lanes at the compute boundary (zSignFed, FedBAT,
    /// EDEN, OBCSAA reconstruction).
    pub fn finish_sum(&self) -> Vec<f32> {
        self.tally
            .iter()
            .map(|&t| (t as f64 / WEIGHT_SCALE) as f32)
            .collect()
    }
}

/// Exact scalar companion to [`VoteAccumulator`]: an order-invariant
/// weighted sum of scalars in the same 64.64 fixed point (OBDA's step
/// scale Σ pₖ·|Δ|ₖ, OBCSAA's norm estimate). Mergeable like the vector
/// tally, for the same reason.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalarTally {
    quanta: i128,
}

impl ScalarTally {
    /// Empty (zero) tally.
    pub fn new() -> ScalarTally {
        ScalarTally::default()
    }

    /// Add one term (computed in f64, quantized once).
    pub fn add(&mut self, v: f64) {
        self.quanta += quantize_weight(v);
    }

    /// The raw fixed-point quanta (for the edge→root merge frame).
    pub fn quanta(&self) -> i128 {
        self.quanta
    }

    /// Rebuild from wire quanta (exact inverse of [`ScalarTally::quanta`]).
    pub fn from_quanta(quanta: i128) -> ScalarTally {
        ScalarTally { quanta }
    }

    /// Fold a sibling shard (exact).
    pub fn merge(&mut self, other: ScalarTally) {
        self.quanta += other.quanta;
    }

    /// The accumulated sum as a real value.
    pub fn value(&self) -> f64 {
        self.quanta as f64 / WEIGHT_SCALE
    }
}

/// Identity-bucketed robust tally: G independent [`VoteAccumulator`]
/// partials, client `k` always folding into group `k mod G` (DESIGN.md
/// §16). Because the bucket is a pure function of the client identity —
/// never of arrival order, shard, or thread — every group tally inherits
/// the 64.64 fixed-point exactness of its `VoteAccumulator`, so a
/// grouped tally is bit-identical under any absorb order, shard count,
/// and merge order, exactly like the plain vote.
///
/// Two robust closes read the same state:
///
/// * [`finish_trimmed`](GroupedTally::finish_trimmed) — per-coordinate
///   trimmed sum over the *active* (absorbed > 0) group tallies. With
///   `G = K` fleet clients each active group holds exactly one client's
///   ±q contribution, making this the coordinate-wise trimmed mean over
///   clients; `trim_frac = 0` sums every group and is bit-for-bit the
///   plain [`VoteAccumulator::finish`] (inactive groups contribute
///   exact zeros).
/// * [`finish_median`](GroupedTally::finish_median) — per-coordinate
///   median of the active group tallies (median-of-means over the i128
///   quanta; an even count signs the exact sum of the two middle
///   values). `G = 1` reduces to the plain vote verbatim.
#[derive(Clone, Debug)]
pub struct GroupedTally {
    groups: Vec<VoteAccumulator>,
}

impl GroupedTally {
    /// Empty grouped tally: `groups` ≥ 1 partials over m bits each.
    pub fn new(m: usize, groups: usize) -> GroupedTally {
        assert!(groups >= 1, "a grouped tally needs at least one group");
        GroupedTally { groups: (0..groups).map(|_| VoteAccumulator::new(m)).collect() }
    }

    /// Logical sketch length m.
    pub fn m(&self) -> usize {
        self.groups[0].m()
    }

    /// Number of group partials G.
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// Sketches folded across all groups (including merged shards).
    pub fn absorbed(&self) -> usize {
        self.groups.iter().map(|g| g.absorbed()).sum()
    }

    /// The group partials, in group order (what a merge frame ships).
    pub fn groups(&self) -> &[VoteAccumulator] {
        &self.groups
    }

    /// The bucket client `k` folds into: `k mod G`. Identity-keyed so
    /// the assignment is invariant under arrival order and sharding.
    pub fn group_of(&self, client: usize) -> usize {
        client % self.groups.len()
    }

    /// Fold client `k`'s sketch into its identity bucket.
    pub fn absorb(&mut self, client: usize, z: &SignVec, weight: f64) {
        let g = self.group_of(client);
        self.groups[g].absorb(z, weight);
    }

    /// Zero-copy twin of [`absorb`](Self::absorb) over a borrowed wire
    /// view — bit-identical by the same argument as
    /// [`VoteAccumulator::absorb_view`].
    pub fn absorb_view(&mut self, client: usize, z: &SignVecView<'_>, weight: f64) {
        let g = self.group_of(client);
        self.groups[g].absorb_view(z, weight);
    }

    /// Fold a sibling shard group-by-group (exact: each group pair is a
    /// plain integer tally merge).
    pub fn merge(&mut self, other: GroupedTally) {
        assert_eq!(
            other.group_count(),
            self.group_count(),
            "merging grouped tallies with different group counts"
        );
        for (a, b) in self.groups.iter_mut().zip(other.groups) {
            a.merge(b);
        }
    }

    /// Fold one group of a sibling shard read lazily off the wire —
    /// the grouped counterpart of [`VoteAccumulator::merge_quanta`].
    pub fn merge_group_quanta(
        &mut self,
        group: usize,
        absorbed: usize,
        quantum: impl Fn(usize) -> i128,
    ) {
        self.groups[group].merge_quanta(absorbed, quantum);
    }

    /// The ungrouped tally this state refines: the exact per-bit sum
    /// over all groups (equals the plain [`VoteAccumulator`] the same
    /// absorbs would have built).
    pub fn total_quanta(&self) -> Vec<i128> {
        let m = self.m();
        let mut total = vec![0i128; m];
        for g in &self.groups {
            for (t, &q) in total.iter_mut().zip(g.quanta()) {
                *t += q;
            }
        }
        total
    }

    /// Coordinate-wise trimmed vote: per bit, sort the active groups'
    /// quanta, drop `⌊trim_frac · active⌋` from each end (clamped so at
    /// least one value survives), sign the exact sum of the rest (ties
    /// → +1). `trim_frac = 0` is bit-for-bit the plain vote. Zero active
    /// groups finish all-+1 like an empty [`VoteAccumulator`]; callers
    /// gate on [`absorbed`](Self::absorbed) instead of adopting that.
    pub fn finish_trimmed(&self, trim_frac: f64) -> SignVec {
        let active: Vec<&VoteAccumulator> =
            self.groups.iter().filter(|g| g.absorbed() > 0).collect();
        let m = self.m();
        if active.is_empty() {
            return SignVec::from_fn(m, |_| true);
        }
        let mut t = (trim_frac * active.len() as f64).floor() as usize;
        if 2 * t >= active.len() {
            t = (active.len() - 1) / 2;
        }
        let mut vals = vec![0i128; active.len()];
        SignVec::from_fn(m, |i| {
            for (v, g) in vals.iter_mut().zip(&active) {
                *v = g.quanta()[i];
            }
            vals.sort_unstable();
            vals[t..vals.len() - t].iter().sum::<i128>() >= 0
        })
    }

    /// Coordinate-wise median-of-means vote: per bit, the sign of the
    /// median of the active groups' quanta (an even count signs the
    /// exact i128 sum of the two middle values; ties → +1). One group
    /// reduces to the plain vote verbatim.
    pub fn finish_median(&self) -> SignVec {
        let active: Vec<&VoteAccumulator> =
            self.groups.iter().filter(|g| g.absorbed() > 0).collect();
        let m = self.m();
        if active.is_empty() {
            return SignVec::from_fn(m, |_| true);
        }
        let mut vals = vec![0i128; active.len()];
        SignVec::from_fn(m, |i| {
            for (v, g) in vals.iter_mut().zip(&active) {
                *v = g.quanta()[i];
            }
            vals.sort_unstable();
            let n = vals.len();
            if n % 2 == 1 {
                vals[n / 2] >= 0
            } else {
                vals[n / 2 - 1] + vals[n / 2] >= 0
            }
        })
    }
}

/// Uniform-weight majority vote on packed words via per-bit counters —
/// the optimized path: one popcount-style pass, no f32 accumulator array
/// walk per client bit. For K clients bit i wins (+1) iff
/// #,{k: bit set} * 2 >= K (ties toward +1).
pub fn majority_vote_uniform<S: Borrow<SignVec>>(sketches: &[S], m: usize) -> SignVec {
    let k = sketches.len();
    assert!(k > 0);
    let words = m.div_ceil(64);
    let mut out = vec![0u64; words];
    // Column-major counting with a u16 counter per bit, processed one
    // 64-bit lane at a time to stay cache-friendly.
    let mut counts = vec![0u16; 64];
    for w in 0..words {
        counts.iter_mut().for_each(|c| *c = 0);
        for z in sketches {
            let word = z.borrow().words()[w];
            // unrolled bit-scatter: only set bits touch the counter
            let mut rem = word;
            while rem != 0 {
                let b = rem.trailing_zeros() as usize;
                counts[b] += 1;
                rem &= rem - 1;
            }
        }
        let mut res = 0u64;
        for (b, &c) in counts.iter().enumerate() {
            if 2 * c as usize >= k {
                res |= 1u64 << b;
            }
        }
        out[w] = res;
    }
    // mask tail bits beyond m so the canonical-tail invariant holds
    // (padding-bit ties toward +1 are irrelevant; keep them zero)
    mask_tail(&mut out, m);
    SignVec { words: out, m }
}

/// Hamming distance between two packed sign vectors (first m bits).
/// Word-level primitive: garbage bits beyond m are masked out, so
/// callers may pass raw (non-canonical) word buffers.
pub fn hamming_packed(a: &[u64], b: &[u64], m: usize) -> usize {
    let words = m.div_ceil(64);
    let mut dist = 0usize;
    for w in 0..words {
        let mut x = a[w] ^ b[w];
        if w == words - 1 && m % 64 != 0 {
            x &= (1u64 << (m % 64)) - 1;
        }
        dist += x.count_ones() as usize;
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn rand_signs(rng: &mut crate::util::rng::Rng, m: usize) -> Vec<f32> {
        (0..m)
            .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
            .collect()
    }

    #[test]
    fn pack_round_trip_property() {
        check("bitpack_round_trip", 50, |rng| {
            let m = rng.below(500) + 1;
            let signs = rand_signs(rng, m);
            let packed = SignVec::from_signs(&signs);
            if packed.words().len() != m.div_ceil(64) {
                return Err("wrong word count".into());
            }
            if packed.m() != m || packed.byte_len() != packed_bytes(m) {
                return Err("wrong geometry".into());
            }
            if packed.to_signs() != signs {
                return Err("round trip mismatch".into());
            }
            if packed.iter_signs().collect::<Vec<f32>>() != signs {
                return Err("iter_signs mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn from_fn_matches_from_signs() {
        check("signvec_from_fn", 50, |rng| {
            let m = rng.below(400) + 1;
            let signs = rand_signs(rng, m);
            let a = SignVec::from_signs(&signs);
            let mut order = Vec::new();
            let b = SignVec::from_fn(m, |i| {
                order.push(i);
                signs[i] >= 0.0
            });
            if a != b {
                return Err("from_fn disagrees with from_signs".into());
            }
            if order != (0..m).collect::<Vec<usize>>() {
                return Err("from_fn did not call in ascending index order".into());
            }
            Ok(())
        });
    }

    #[test]
    fn from_words_masks_garbage_tail() {
        // a wire frame may carry arbitrary bits beyond m; adopting the
        // words must canonicalize so equality stays semantic
        let dirty = vec![u64::MAX];
        let sv = SignVec::from_words(dirty, 3);
        assert_eq!(sv.words(), &[0b111u64]);
        assert_eq!(sv, SignVec::from_signs(&[1.0, 1.0, 1.0]));
        // exact multiples of 64 have no tail to mask
        let full = SignVec::from_words(vec![u64::MAX], 64);
        assert_eq!(full.words(), &[u64::MAX]);
    }

    #[test]
    fn view_matches_owned_on_dirty_and_unaligned_buffers() {
        check("signvec_view_identity", 60, |rng| {
            let m = rng.below(400) + 1;
            let words: Vec<u64> = (0..m.div_ceil(64)).map(|_| rng.next_u64()).collect();
            let owned = SignVec::from_words(words.clone(), m);
            // serialize the *unmasked* words after a random 0..8-byte
            // prefix, so view reads hit every alignment class and the
            // tail bytes carry garbage the view must mask
            let off = rng.below(8);
            let mut bytes = vec![0xA5u8; off];
            for w in &words {
                bytes.extend_from_slice(&w.to_le_bytes());
            }
            let view = SignVecView::new(&bytes[off..], m);
            if view.m() != m || view.words_len() != owned.words().len() {
                return Err("view geometry mismatch".into());
            }
            if view.to_owned() != owned {
                return Err("to_owned disagrees with from_words".into());
            }
            for _ in 0..16 {
                let i = rng.below(m);
                if view.bit(i) != owned.bit(i) || view.sign(i) != owned.sign(i) {
                    return Err(format!("bit/sign mismatch at {i}"));
                }
            }
            // absorb_view must be bit-identical to absorb on the owned vec
            let weight = rng.f32() as f64 + 0.1;
            let mut a = VoteAccumulator::new(m);
            let mut b = VoteAccumulator::new(m);
            a.absorb(&owned, weight);
            b.absorb_view(&view, weight);
            if a.quanta() != b.quanta() || a.absorbed() != b.absorbed() {
                return Err("absorb_view tally mismatch".into());
            }
            if a.finish() != b.finish() {
                return Err("absorb_view finish mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn merge_quanta_matches_merge_from_quanta() {
        check("merge_quanta_identity", 40, |rng| {
            let m = rng.below(300) + 1;
            let mut base = VoteAccumulator::new(m);
            base.absorb(&SignVec::from_signs(&rand_signs(rng, m)), 0.7);
            let shard: Vec<i128> = (0..m).map(|_| rng.next_u64() as i128 - (1 << 62)).collect();
            let mut a = base.clone();
            let mut b = base;
            a.merge(VoteAccumulator::from_quanta(shard.clone(), 3));
            b.merge_quanta(3, |i| shard[i]);
            if a.quanta() != b.quanta() || a.absorbed() != b.absorbed() {
                return Err("merge_quanta disagrees with merge".into());
            }
            Ok(())
        });
    }

    #[test]
    fn packed_bytes_exact() {
        assert_eq!(packed_bytes(1), 8);
        assert_eq!(packed_bytes(64), 8);
        assert_eq!(packed_bytes(65), 16);
        assert_eq!(packed_bytes(15901), 15901usize.div_ceil(64) * 8);
    }

    #[test]
    fn zero_is_packed_as_plus_one() {
        let packed = SignVec::from_signs(&[0.0, -1.0, 1.0]);
        assert_eq!(packed.to_signs(), vec![1.0, -1.0, 1.0]);
        assert!(packed.bit(0), "sign(0) := +1");
    }

    #[test]
    fn flip_bits_where_is_exact_and_tail_safe() {
        check("signvec_flip_mask", 40, |rng| {
            let m = rng.below(300) + 1;
            let signs = rand_signs(rng, m);
            let mut sv = SignVec::from_signs(&signs);
            let flips: Vec<bool> = (0..m).map(|_| rng.f32() < 0.3).collect();
            sv.flip_bits_where(|i| flips[i]);
            // reference: flip the f32 lanes
            let want: Vec<f32> = signs
                .iter()
                .zip(&flips)
                .map(|(&s, &f)| if f { -s } else { s })
                .collect();
            if sv.to_signs() != want {
                return Err("flip pattern mismatch".into());
            }
            // canonical tail must survive arbitrary flips
            let tail = m % 64;
            if tail != 0 && sv.words().last().unwrap() >> tail != 0 {
                return Err("flip touched tail bits beyond m".into());
            }
            Ok(())
        });
    }

    #[test]
    fn weighted_vote_matches_unpacked_reference() {
        check("majority_vote_weighted_ref", 40, |rng| {
            let k = rng.below(8) + 1;
            let m = rng.below(300) + 1;
            let sketches: Vec<Vec<f32>> = (0..k).map(|_| rand_signs(rng, m)).collect();
            let mut weights: Vec<f32> = (0..k).map(|_| rng.f32() + 0.01).collect();
            let total: f32 = weights.iter().sum();
            weights.iter_mut().for_each(|w| *w /= total);

            // reference: accumulate in f64 then sign
            let mut acc = vec![0.0f64; m];
            for (z, &p) in sketches.iter().zip(&weights) {
                for i in 0..m {
                    acc[i] += p as f64 * z[i] as f64;
                }
            }
            let want: Vec<f32> = acc.iter().map(|&a| if a >= 0.0 { 1.0 } else { -1.0 }).collect();

            let packed: Vec<SignVec> = sketches.iter().map(|z| SignVec::from_signs(z)).collect();
            let got = majority_vote_weighted(&packed, &weights, m).to_signs();
            // f32-vs-f64 accumulation can disagree only at near-exact ties
            let mismatches = got
                .iter()
                .zip(&want)
                .enumerate()
                .filter(|(i, (g, w))| g != w && acc[*i].abs() > 1e-5)
                .count();
            if mismatches > 0 {
                return Err(format!("{mismatches} non-tie mismatches"));
            }
            Ok(())
        });
    }

    #[test]
    fn uniform_vote_matches_weighted_with_equal_weights() {
        check("majority_vote_uniform_eq", 40, |rng| {
            // odd K only: exact ties are resolved identically but f32
            // accumulation of ±1/K may land on either side of 0.0
            let k = 2 * rng.below(5) + 1;
            let m = rng.below(500) + 1;
            let packed: Vec<SignVec> = (0..k)
                .map(|_| SignVec::from_signs(&rand_signs(rng, m)))
                .collect();
            let w = vec![1.0f32 / k as f32; k];
            let a = majority_vote_uniform(&packed, m);
            let b = majority_vote_weighted(&packed, &w, m);
            if a != b {
                return Err("uniform != weighted".into());
            }
            Ok(())
        });
    }

    #[test]
    fn votes_accept_borrowed_sketches() {
        // the server path: vote directly over &SignVec borrowed from
        // delivered uplinks, no copy or re-pack
        let owned: Vec<SignVec> = vec![
            SignVec::from_signs(&[1.0, -1.0, 1.0]),
            SignVec::from_signs(&[1.0, 1.0, -1.0]),
            SignVec::from_signs(&[1.0, -1.0, -1.0]),
        ];
        let borrowed: Vec<&SignVec> = owned.iter().collect();
        let w = vec![1.0f32 / 3.0; 3];
        assert_eq!(
            majority_vote_weighted(&borrowed, &w, 3),
            majority_vote_weighted(&owned, &w, 3)
        );
        assert_eq!(
            majority_vote_uniform(&borrowed, 3).to_signs(),
            vec![1.0, -1.0, -1.0]
        );
    }

    #[test]
    fn vote_is_lemma1_optimal_brute_force() {
        // check v* minimizes sum_k p_k g(v, z_k) over all v in {±1}^m
        check("vote_lemma1_optimal", 20, |rng| {
            let k = rng.below(5) + 1;
            let m = rng.below(6) + 1;
            let sketches: Vec<Vec<f32>> = (0..k).map(|_| rand_signs(rng, m)).collect();
            let weights = vec![1.0f32 / k as f32; k];
            let packed: Vec<SignVec> = sketches.iter().map(|z| SignVec::from_signs(z)).collect();
            let vstar = majority_vote_weighted(&packed, &weights, m).to_signs();

            let g = |v: &[f32]| -> f64 {
                // one-sided l1: sum_k p_k || [v ⊙ z_k]_- ||_1   (Eq. 2)
                sketches
                    .iter()
                    .zip(&weights)
                    .map(|(z, &p)| {
                        p as f64
                            * v.iter()
                                .zip(z)
                                .map(|(&vi, &zi)| (vi * zi).min(0.0).abs() as f64)
                                .sum::<f64>()
                    })
                    .sum()
            };
            let star = g(&vstar);
            for c in 0..(1usize << m) {
                let cand: Vec<f32> = (0..m)
                    .map(|b| if c >> b & 1 == 1 { 1.0 } else { -1.0 })
                    .collect();
                if g(&cand) < star - 1e-9 {
                    return Err(format!("candidate {cand:?} beats vote {vstar:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn streaming_accumulator_matches_batch_vote_any_order_and_sharding() {
        // THE streaming-aggregation theorem: absorbing in an arbitrary
        // permutation, split across an arbitrary number of shards merged
        // in arbitrary order, is bit-identical to the batch reference —
        // including sketches adopted from dirty (garbage-tail) words.
        check("vote_accumulator_bit_identity", 60, |rng| {
            let k = rng.below(12) + 1;
            let m = rng.below(400) + 1;
            let words = m.div_ceil(64);
            let sketches: Vec<SignVec> = (0..k)
                .map(|_| {
                    // half the cohort arrives as raw wire words with
                    // garbage beyond m (from_words canonicalizes)
                    if rng.f32() < 0.5 {
                        SignVec::from_words((0..words).map(|_| rng.next_u64()).collect(), m)
                    } else {
                        SignVec::from_signs(&rand_signs(rng, m))
                    }
                })
                .collect();
            let mut weights: Vec<f32> = (0..k).map(|_| rng.f32() + 0.01).collect();
            let total: f32 = weights.iter().sum();
            weights.iter_mut().for_each(|w| *w /= total);
            let batch = majority_vote_weighted(&sketches, &weights, m);

            // arbitrary arrival order into one accumulator
            let mut order: Vec<usize> = (0..k).collect();
            rng.shuffle(&mut order);
            let mut acc = VoteAccumulator::new(m);
            for &i in &order {
                acc.absorb(&sketches[i], weights[i] as f64);
            }
            if acc.finish() != batch {
                return Err("permuted streaming vote != batch vote".into());
            }
            if acc.absorbed() != k {
                return Err("absorbed count wrong".into());
            }

            // arbitrary sharding, shards merged in shuffled order
            let shards = rng.below(5) + 1;
            let mut parts: Vec<VoteAccumulator> =
                (0..shards).map(|_| VoteAccumulator::new(m)).collect();
            for &i in &order {
                parts[rng.below(shards)].absorb(&sketches[i], weights[i] as f64);
            }
            rng.shuffle(&mut parts);
            let mut merged = parts.pop().unwrap();
            for p in parts {
                merged.merge(p);
            }
            if merged.finish() != batch {
                return Err(format!("{shards}-shard merged vote != batch vote"));
            }
            if merged.absorbed() != k {
                return Err("merged absorbed count wrong".into());
            }
            Ok(())
        });
    }

    #[test]
    fn streaming_accumulator_with_equal_weights_matches_uniform_vote() {
        // exact for ANY k (even ones included): the tally is (2c−k)·q,
        // whose sign is the uniform popcount rule 2c ≥ k, ties → +1
        check("vote_accumulator_vs_uniform", 40, |rng| {
            let k = rng.below(10) + 1;
            let m = rng.below(400) + 1;
            let sketches: Vec<SignVec> = (0..k)
                .map(|_| SignVec::from_signs(&rand_signs(rng, m)))
                .collect();
            let mut acc = VoteAccumulator::new(m);
            for z in &sketches {
                acc.absorb(z, 1.0 / k as f64);
            }
            if acc.finish() != majority_vote_uniform(&sketches, m) {
                return Err(format!("accumulator != uniform vote (k={k})"));
            }
            Ok(())
        });
    }

    #[test]
    fn finish_sum_matches_linear_estimator_reference() {
        // the linear-estimator close: Σ wₖ zₖ read back as f32 lanes,
        // within the 64.64 quantization error of an f64 reference
        check("finish_sum_reference", 40, |rng| {
            let k = rng.below(8) + 1;
            let m = rng.below(200) + 1;
            let sketches: Vec<Vec<f32>> = (0..k).map(|_| rand_signs(rng, m)).collect();
            let weights: Vec<f64> = (0..k).map(|_| rng.f64() * 2.0 + 1e-6).collect();
            let mut acc = VoteAccumulator::new(m);
            for (z, &w) in sketches.iter().zip(&weights) {
                acc.absorb(&SignVec::from_signs(z), w);
            }
            let got = acc.finish_sum();
            for i in 0..m {
                let want: f64 = sketches
                    .iter()
                    .zip(&weights)
                    .map(|(z, &w)| w * z[i] as f64)
                    .sum();
                if (got[i] as f64 - want).abs() > 1e-6 * (1.0 + want.abs()) {
                    return Err(format!("bit {i}: {} vs {want}", got[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scalar_tally_is_exact_and_order_invariant() {
        let terms = [0.5f64, 0.125, 0.25, 0.0625];
        let mut fwd = ScalarTally::new();
        terms.iter().for_each(|&v| fwd.add(v));
        let mut rev = ScalarTally::new();
        terms.iter().rev().for_each(|&v| rev.add(v));
        assert_eq!(fwd.value(), rev.value());
        assert_eq!(fwd.value(), 0.9375);
        // shard merge
        let mut a = ScalarTally::new();
        a.add(0.5);
        let mut b = ScalarTally::new();
        b.add(0.4375);
        a.merge(b);
        assert_eq!(a.value(), 0.9375);
    }

    #[test]
    fn empty_accumulator_finishes_all_plus_one() {
        // documented edge: zero sketches → all ties → all +1; servers
        // gate on absorbed() == 0 instead of adopting this
        let acc = VoteAccumulator::new(70);
        assert_eq!(acc.absorbed(), 0);
        assert_eq!(acc.finish(), SignVec::from_signs(&[1.0f32; 70]));
        assert_eq!(acc.finish_sum(), vec![0.0f32; 70]);
    }

    #[test]
    fn hamming_matches_unpacked_reference_with_dirty_tails() {
        // the word-level primitive must count only the first m bits even
        // when the tail words carry garbage
        check("hamming_packed_ref", 50, |rng| {
            let m = rng.below(300) + 1;
            let words = m.div_ceil(64);
            let a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            // reference: compare the unpacked f32 lanes over exactly m bits
            let ua = unpack_signs(&a, m);
            let ub = unpack_signs(&b, m);
            let want = ua.iter().zip(&ub).filter(|(x, y)| x != y).count();
            if hamming_packed(&a, &b, m) != want {
                return Err(format!("hamming_packed != {want} (m={m})"));
            }
            // the canonicalizing SignVec path must agree
            let sa = SignVec::from_words(a, m);
            let sb = SignVec::from_words(b, m);
            if sa.hamming(&sb) != want {
                return Err("SignVec::hamming disagrees".into());
            }
            Ok(())
        });
    }

    #[test]
    fn hamming_distance() {
        let a = SignVec::from_signs(&[1.0, 1.0, -1.0, 1.0]);
        let b = SignVec::from_signs(&[1.0, -1.0, -1.0, -1.0]);
        assert_eq!(a.hamming(&b), 2);
        assert_eq!(a.hamming(&a), 0);
    }

    #[test]
    fn single_client_vote_is_identity() {
        let z = SignVec::from_signs(&[1.0, -1.0, 1.0, -1.0, -1.0]);
        let v = majority_vote_uniform(&[z.clone()], 5);
        assert_eq!(v, z);
    }

    /// Brute-force oracle for the robust closes: per coordinate, the
    /// signed contributions of the active groups as exact i128 quanta.
    fn grouped_reference(
        sketches: &[SignVec],
        weights: &[f64],
        m: usize,
        groups: usize,
    ) -> Vec<Vec<i128>> {
        // per-group per-bit quanta, identity-bucketed like GroupedTally
        let mut per_group = vec![vec![0i128; m]; groups];
        for (k, (z, &w)) in sketches.iter().zip(weights).enumerate() {
            let q = quantize_weight(w);
            for i in 0..m {
                per_group[k % groups][i] += if z.bit(i) { q } else { -q };
            }
        }
        per_group
    }

    #[test]
    fn grouped_tally_trim_zero_and_one_group_reduce_to_vote() {
        check("grouped_reduces_to_vote", 40, |rng| {
            let m = rng.below(200) + 1;
            let k = rng.below(12) + 1;
            let groups = rng.below(6) + 1;
            let sketches: Vec<SignVec> = (0..k)
                .map(|_| {
                    SignVec::from_words(
                        (0..m.div_ceil(64)).map(|_| rng.next_u64()).collect(),
                        m,
                    )
                })
                .collect();
            let weights: Vec<f64> =
                (0..k).map(|_| rng.f64() + 0.01).collect();

            let mut vote = VoteAccumulator::new(m);
            let mut grouped = GroupedTally::new(m, groups);
            let mut one_group = GroupedTally::new(m, 1);
            for (c, (z, &w)) in sketches.iter().zip(&weights).enumerate() {
                vote.absorb(z, w);
                grouped.absorb(c, z, w);
                one_group.absorb(c, z, w);
            }
            // trim=0 sums every active group; inactive groups hold exact
            // zeros, so the total IS the plain vote tally bit for bit
            if grouped.total_quanta() != vote.quanta() {
                return Err(format!("total_quanta != vote quanta (m={m} k={k} g={groups})"));
            }
            if grouped.finish_trimmed(0.0) != vote.finish() {
                return Err(format!("trim=0 finish != vote finish (m={m} k={k} g={groups})"));
            }
            // one group: the median of a single value is that value
            if one_group.finish_median() != vote.finish() {
                return Err(format!("groups=1 median != vote finish (m={m} k={k})"));
            }
            Ok(())
        });
    }

    #[test]
    fn grouped_tally_matches_brute_force_references() {
        check("grouped_vs_reference", 40, |rng| {
            let m = rng.below(150) + 1;
            let k = rng.below(15) + 1;
            let groups = rng.below(8) + 1;
            let trim_frac = rng.f64() * 0.49;
            let sketches: Vec<SignVec> = (0..k)
                .map(|_| {
                    SignVec::from_words(
                        (0..m.div_ceil(64)).map(|_| rng.next_u64()).collect(),
                        m,
                    )
                })
                .collect();
            let weights: Vec<f64> =
                (0..k).map(|_| rng.f64() + 0.01).collect();

            let mut tally = GroupedTally::new(m, groups);
            for (c, (z, &w)) in sketches.iter().zip(&weights).enumerate() {
                tally.absorb(c, z, w);
            }

            let per_group = grouped_reference(&sketches, &weights, m, groups);
            // a group is active iff some client index maps to it
            let active: Vec<usize> = (0..groups)
                .filter(|&g| (0..k).any(|c| c % groups == g))
                .collect();

            let mut t = (trim_frac * active.len() as f64).floor() as usize;
            if 2 * t >= active.len() {
                t = (active.len() - 1) / 2;
            }
            let want_trim = SignVec::from_fn(m, |i| {
                let mut vals: Vec<i128> =
                    active.iter().map(|&g| per_group[g][i]).collect();
                vals.sort_unstable();
                vals[t..vals.len() - t].iter().sum::<i128>() >= 0
            });
            if tally.finish_trimmed(trim_frac) != want_trim {
                return Err(format!(
                    "trimmed finish != reference (m={m} k={k} g={groups} trim={trim_frac})"
                ));
            }

            let want_med = SignVec::from_fn(m, |i| {
                let mut vals: Vec<i128> =
                    active.iter().map(|&g| per_group[g][i]).collect();
                vals.sort_unstable();
                let n = vals.len();
                if n % 2 == 1 {
                    vals[n / 2] >= 0
                } else {
                    vals[n / 2 - 1] + vals[n / 2] >= 0
                }
            });
            if tally.finish_median() != want_med {
                return Err(format!(
                    "median finish != reference (m={m} k={k} g={groups})"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn grouped_tally_is_order_shard_and_merge_invariant() {
        // the grouped analogue of the streaming-accumulator oracle test:
        // any absorb order, any shard split, any merge order → the same
        // bits, because buckets are identity-keyed and quanta are i128
        check("grouped_order_shard_invariance", 30, |rng| {
            let m = rng.below(200) + 1;
            let k = rng.below(14) + 2;
            let groups = rng.below(5) + 1;
            let trim_frac = rng.f64() * 0.49;
            let sketches: Vec<SignVec> = (0..k)
                .map(|_| {
                    SignVec::from_words(
                        (0..m.div_ceil(64)).map(|_| rng.next_u64()).collect(),
                        m,
                    )
                })
                .collect();
            let weights: Vec<f64> =
                (0..k).map(|_| rng.f64() + 0.01).collect();

            // reference: absorb in client order into one tally
            let mut reference = GroupedTally::new(m, groups);
            for (c, (z, &w)) in sketches.iter().zip(&weights).enumerate() {
                reference.absorb(c, z, w);
            }

            // permuted absorb order across 1..5 shards, merged shuffled
            let mut order: Vec<usize> = (0..k).collect();
            for i in (1..k).rev() {
                order.swap(i, rng.below(i + 1));
            }
            let shards = rng.below(5) + 1;
            let mut parts: Vec<GroupedTally> =
                (0..shards).map(|_| GroupedTally::new(m, groups)).collect();
            for (pos, &c) in order.iter().enumerate() {
                parts[pos % shards].absorb(c, &sketches[c], weights[c]);
            }
            for i in (1..parts.len()).rev() {
                parts.swap(i, rng.below(i + 1));
            }
            let mut merged = parts.remove(0);
            for p in parts {
                merged.merge(p);
            }

            if merged.total_quanta() != reference.total_quanta() {
                return Err("sharded total_quanta diverged".into());
            }
            for (a, b) in merged.groups().iter().zip(reference.groups()) {
                if a.quanta() != b.quanta() || a.absorbed() != b.absorbed() {
                    return Err("per-group quanta diverged under sharding".into());
                }
            }
            if merged.finish_trimmed(trim_frac)
                != reference.finish_trimmed(trim_frac)
            {
                return Err("trimmed finish diverged under sharding".into());
            }
            if merged.finish_median() != reference.finish_median() {
                return Err("median finish diverged under sharding".into());
            }
            Ok(())
        });
    }

    #[test]
    fn grouped_tally_trim_drops_outlier_groups() {
        // 5 clients in 5 groups vote +1 with weight 1 on every bit; one
        // adversary votes -1 with weight 100. trim_frac=0.25 trims one
        // value from each end, dropping the adversary's group entirely.
        let m = 67;
        let honest = SignVec::from_fn(m, |_| true);
        let hostile = SignVec::from_fn(m, |_| false);
        let mut tally = GroupedTally::new(m, 5);
        for c in 0..4 {
            tally.absorb(c, &honest, 1.0);
        }
        tally.absorb(4, &hostile, 100.0);
        // untrimmed: the heavy adversary wins every coordinate
        assert_eq!(tally.finish_trimmed(0.0), hostile);
        // trimmed: the adversary (sole minimum) is dropped, honest wins
        assert_eq!(tally.finish_trimmed(0.25), honest);
        // median of [−100, 1, 1, 1, 1] sorted quanta is +1: honest wins
        assert_eq!(tally.finish_median(), honest);
    }

    #[test]
    fn grouped_tally_empty_and_clamped_trim_edges() {
        // zero absorbs → all-+1, mirroring the empty VoteAccumulator
        let empty = GroupedTally::new(33, 4);
        assert_eq!(empty.absorbed(), 0);
        assert_eq!(empty.finish_trimmed(0.3), SignVec::from_fn(33, |_| true));
        assert_eq!(empty.finish_median(), SignVec::from_fn(33, |_| true));
        // a trim that would drop every active group clamps so at least
        // one value survives: with 2 active groups and trim 0.49 → t=0
        let z = SignVec::from_fn(10, |_| false);
        let mut two = GroupedTally::new(10, 8);
        two.absorb(0, &z, 1.0);
        two.absorb(1, &z, 1.0);
        assert_eq!(two.absorbed(), 2);
        assert_eq!(two.finish_trimmed(0.49), z);
    }
}
