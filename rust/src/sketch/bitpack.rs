//! One-bit packing and the server's weighted majority vote (Lemma 1).
//!
//! Sign vectors in {−1,+1}^m are transported as ⌈m/64⌉ u64 words (bit 1 ⇔
//! +1). The server aggregation v = sign(Σ pₖ zₖ) runs either on unpacked
//! f32 accumulators (general weights) or fully packed via popcount when
//! weights are uniform — the packed path is the optimized hot loop used
//! by `benches/bench_aggregate.rs`.

/// Pack a ±1 f32 sign vector into u64 words (bit set ⇔ value >= 0).
pub fn pack_signs(signs: &[f32]) -> Vec<u64> {
    let words = signs.len().div_ceil(64);
    let mut out = vec![0u64; words];
    for (i, &s) in signs.iter().enumerate() {
        if s >= 0.0 {
            out[i / 64] |= 1u64 << (i % 64);
        }
    }
    out
}

/// Unpack to ±1 f32 of length `m`.
pub fn unpack_signs(words: &[u64], m: usize) -> Vec<f32> {
    assert!(words.len() * 64 >= m, "not enough words for m={m}");
    (0..m)
        .map(|i| {
            if words[i / 64] >> (i % 64) & 1 == 1 {
                1.0
            } else {
                -1.0
            }
        })
        .collect()
}

/// Exact number of payload bytes for an m-bit sign message.
pub fn packed_bytes(m: usize) -> usize {
    m.div_ceil(64) * 8
}

/// Weighted majority vote v = sign(Σ pₖ zₖ) over packed sketches
/// (Lemma 1: the exact minimizer of the server objective, Eq. 13/14).
/// Ties (Σ = 0) break toward +1, matching `sign(0) = +1` everywhere else.
pub fn majority_vote_weighted(sketches: &[Vec<u64>], weights: &[f32], m: usize) -> Vec<u64> {
    assert_eq!(sketches.len(), weights.len());
    let words = m.div_ceil(64);
    let mut acc = vec![0.0f32; m];
    for (z, &p) in sketches.iter().zip(weights) {
        debug_assert!(z.len() >= words);
        for (i, a) in acc.iter_mut().enumerate() {
            let bit = z[i / 64] >> (i % 64) & 1;
            *a += if bit == 1 { p } else { -p };
        }
    }
    let mut out = vec![0u64; words];
    for (i, &a) in acc.iter().enumerate() {
        if a >= 0.0 {
            out[i / 64] |= 1u64 << (i % 64);
        }
    }
    out
}

/// Uniform-weight majority vote on packed words via per-bit counters —
/// the optimized path: one popcount-style pass, no f32 accumulator array
/// walk per client bit. For K clients bit i wins (+1) iff
/// #,{k: bit set} * 2 >= K (ties toward +1).
pub fn majority_vote_uniform(sketches: &[Vec<u64>], m: usize) -> Vec<u64> {
    let k = sketches.len();
    assert!(k > 0);
    let words = m.div_ceil(64);
    let mut out = vec![0u64; words];
    // Column-major counting with a u16 counter per bit, processed one
    // 64-bit lane at a time to stay cache-friendly.
    let mut counts = vec![0u16; 64];
    for w in 0..words {
        counts.iter_mut().for_each(|c| *c = 0);
        for z in sketches {
            let word = z[w];
            // unrolled bit-scatter: only set bits touch the counter
            let mut rem = word;
            while rem != 0 {
                let b = rem.trailing_zeros() as usize;
                counts[b] += 1;
                rem &= rem - 1;
            }
        }
        let mut res = 0u64;
        for (b, &c) in counts.iter().enumerate() {
            if 2 * c as usize >= k {
                res |= 1u64 << b;
            }
        }
        out[w] = res;
    }
    // mask tail bits beyond m so equality checks are well-defined
    let tail = m % 64;
    if tail != 0 {
        let mask = (1u64 << tail) - 1;
        *out.last_mut().unwrap() &= mask;
        // ties toward +1 for padding bits are irrelevant; keep them zero
    }
    out
}

/// Hamming distance between two packed sign vectors (first m bits).
pub fn hamming_packed(a: &[u64], b: &[u64], m: usize) -> usize {
    let words = m.div_ceil(64);
    let mut dist = 0usize;
    for w in 0..words {
        let mut x = a[w] ^ b[w];
        if w == words - 1 && m % 64 != 0 {
            x &= (1u64 << (m % 64)) - 1;
        }
        dist += x.count_ones() as usize;
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn pack_round_trip_property() {
        check("bitpack_round_trip", 50, |rng| {
            let m = rng.below(500) + 1;
            let signs: Vec<f32> = (0..m)
                .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
                .collect();
            let packed = pack_signs(&signs);
            if packed.len() != m.div_ceil(64) {
                return Err("wrong word count".into());
            }
            let back = unpack_signs(&packed, m);
            if back != signs {
                return Err("round trip mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn packed_bytes_exact() {
        assert_eq!(packed_bytes(1), 8);
        assert_eq!(packed_bytes(64), 8);
        assert_eq!(packed_bytes(65), 16);
        assert_eq!(packed_bytes(15901), 15901usize.div_ceil(64) * 8);
    }

    #[test]
    fn zero_is_packed_as_plus_one() {
        let packed = pack_signs(&[0.0, -1.0, 1.0]);
        let back = unpack_signs(&packed, 3);
        assert_eq!(back, vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn weighted_vote_matches_unpacked_reference() {
        check("majority_vote_weighted_ref", 40, |rng| {
            let k = rng.below(8) + 1;
            let m = rng.below(300) + 1;
            let sketches: Vec<Vec<f32>> = (0..k)
                .map(|_| {
                    (0..m)
                        .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
                        .collect()
                })
                .collect();
            let mut weights: Vec<f32> = (0..k).map(|_| rng.f32() + 0.01).collect();
            let total: f32 = weights.iter().sum();
            weights.iter_mut().for_each(|w| *w /= total);

            // reference: accumulate in f64 then sign
            let mut acc = vec![0.0f64; m];
            for (z, &p) in sketches.iter().zip(&weights) {
                for i in 0..m {
                    acc[i] += p as f64 * z[i] as f64;
                }
            }
            let want: Vec<f32> = acc.iter().map(|&a| if a >= 0.0 { 1.0 } else { -1.0 }).collect();

            let packed: Vec<Vec<u64>> = sketches.iter().map(|z| pack_signs(z)).collect();
            let got = unpack_signs(&majority_vote_weighted(&packed, &weights, m), m);
            // f32-vs-f64 accumulation can disagree only at near-exact ties
            let mismatches = got
                .iter()
                .zip(&want)
                .enumerate()
                .filter(|(i, (g, w))| g != w && acc[*i].abs() > 1e-5)
                .count();
            if mismatches > 0 {
                return Err(format!("{mismatches} non-tie mismatches"));
            }
            Ok(())
        });
    }

    #[test]
    fn uniform_vote_matches_weighted_with_equal_weights() {
        check("majority_vote_uniform_eq", 40, |rng| {
            // odd K only: exact ties are resolved identically but f32
            // accumulation of ±1/K may land on either side of 0.0
            let k = 2 * rng.below(5) + 1;
            let m = rng.below(500) + 1;
            let packed: Vec<Vec<u64>> = (0..k)
                .map(|_| {
                    let signs: Vec<f32> = (0..m)
                        .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
                        .collect();
                    pack_signs(&signs)
                })
                .collect();
            let w = vec![1.0f32 / k as f32; k];
            let a = majority_vote_uniform(&packed, m);
            let b = majority_vote_weighted(&packed, &w, m);
            if unpack_signs(&a, m) != unpack_signs(&b, m) {
                return Err("uniform != weighted".into());
            }
            Ok(())
        });
    }

    #[test]
    fn vote_is_lemma1_optimal_brute_force() {
        // check v* minimizes sum_k p_k g(v, z_k) over all v in {±1}^m
        check("vote_lemma1_optimal", 20, |rng| {
            let k = rng.below(5) + 1;
            let m = rng.below(6) + 1;
            let sketches: Vec<Vec<f32>> = (0..k)
                .map(|_| {
                    (0..m)
                        .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
                        .collect()
                })
                .collect();
            let weights = vec![1.0f32 / k as f32; k];
            let packed: Vec<Vec<u64>> = sketches.iter().map(|z| pack_signs(z)).collect();
            let vstar = unpack_signs(&majority_vote_weighted(&packed, &weights, m), m);

            let g = |v: &[f32]| -> f64 {
                // one-sided l1: sum_k p_k || [v ⊙ z_k]_- ||_1   (Eq. 2)
                sketches
                    .iter()
                    .zip(&weights)
                    .map(|(z, &p)| {
                        p as f64
                            * v.iter()
                                .zip(z)
                                .map(|(&vi, &zi)| (vi * zi).min(0.0).abs() as f64)
                                .sum::<f64>()
                    })
                    .sum()
            };
            let star = g(&vstar);
            for c in 0..(1usize << m) {
                let cand: Vec<f32> = (0..m)
                    .map(|b| if c >> b & 1 == 1 { 1.0 } else { -1.0 })
                    .collect();
                if g(&cand) < star - 1e-9 {
                    return Err(format!("candidate {cand:?} beats vote {vstar:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn hamming_distance() {
        let a = pack_signs(&[1.0, 1.0, -1.0, 1.0]);
        let b = pack_signs(&[1.0, -1.0, -1.0, -1.0]);
        assert_eq!(hamming_packed(&a, &b, 4), 2);
        assert_eq!(hamming_packed(&a, &a, 4), 0);
    }

    #[test]
    fn single_client_vote_is_identity() {
        let z = pack_signs(&[1.0, -1.0, 1.0, -1.0, -1.0]);
        let v = majority_vote_uniform(&[z.clone()], 5);
        assert_eq!(unpack_signs(&v, 5), unpack_signs(&z, 5));
    }
}
