//! Training checkpoints: persist and resume federated state.
//!
//! A deployment-grade coordinator must survive restarts: checkpoints
//! capture the round counter, the consensus vector v, every client's
//! personalized model, and the RNG-relevant seed, in a self-describing
//! little-endian binary format (no serde in the offline mirror).
//!
//! Layout v2 (all little-endian):
//!   magic  b"PF1B"            4 B
//!   version u32               4 B      (2; v1 files remain readable)
//!   round   u64               8 B
//!   seed    u64               8 B
//!   edges   u32               4 B      (topology-era metadata, v2+:
//!                                       edge count at save time, 0 =
//!                                       flat; informational — the
//!                                       client→edge assignment is
//!                                       derived, never persisted, so
//!                                       resume is topology-free)
//!   m       u32               4 B      (consensus length; 0 = none)
//!   v       f32 × m
//!   k       u32               4 B      (number of client models)
//!   n       u32               4 B      (params per model; uniform)
//!   w_k     f32 × n, k times
//!   crc     u32               4 B      (FNV-1a over all preceding bytes)
//!
//! Version 1 is the same layout without the `edges` field; `decode`
//! reads v1–v3.
//!
//! Version 3 (error feedback — DESIGN.md §16) appends after the models,
//! before the CRC:
//!   r       u32               4 B      (residual vector count)
//!   rn u32, e f32 × rn        r times  (per-residual length + lanes;
//!                                       rn is 0 for a client that has
//!                                       not uplinked yet, m otherwise)
//!
//! `encode` writes the residual section — and stamps version 3 — ONLY
//! when `residuals` is non-empty: a run without error feedback saves
//! bytes identical to the v2 encoder's, so old tooling keeps reading
//! today's checkpoints.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"PF1B";
const VERSION: u32 = 3;
/// What `encode` stamps when there is no residual section — the exact
/// pre-error-feedback format.
const VERSION_V2: u32 = 2;

/// Federated training state snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// rounds completed when this snapshot was taken
    pub round: u64,
    /// the run's seed (RNG streams re-derive from it)
    pub seed: u64,
    /// topology-era metadata (v2): edge aggregator count at save time,
    /// 0 = flat. Informational only — edge assignment is derived
    /// (`k mod E`), so restoring never needs it.
    pub edges: u32,
    /// consensus vector v (empty when the algorithm has none)
    pub consensus: Vec<f32>,
    /// per-client personalized models (global algorithms store one)
    pub models: Vec<Vec<f32>>,
    /// per-client error-feedback residuals (v3 — DESIGN.md §16); empty
    /// when error feedback is off, and the file is then byte-identical
    /// to the v2 layout
    pub residuals: Vec<Vec<f32>>,
}

impl Checkpoint {
    /// Write atomically (temp file + rename) to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let bytes = self.encode()?;
        // atomic-ish: write to temp then rename
        let tmp = path.with_extension("tmp");
        std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?
            .write_all(&bytes)?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Read and decode a checkpoint file (v1, v2, or v3).
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?
            .read_to_end(&mut bytes)?;
        Self::decode(&bytes)
    }

    /// Serialize to wire bytes (CRC included): the exact v2 layout when
    /// `residuals` is empty, v3 with the residual section otherwise.
    pub fn encode(&self) -> Result<Vec<u8>> {
        let n = self.models.first().map(|m| m.len()).unwrap_or(0);
        if self.models.iter().any(|m| m.len() != n) {
            bail!("all client models must have equal length");
        }
        let version = if self.residuals.is_empty() { VERSION_V2 } else { VERSION };
        let mut out = Vec::with_capacity(
            40 + 4 * self.consensus.len() + self.models.len() * 4 * n,
        );
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&version.to_le_bytes());
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.edges.to_le_bytes());
        out.extend_from_slice(&(self.consensus.len() as u32).to_le_bytes());
        for x in &self.consensus {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out.extend_from_slice(&(self.models.len() as u32).to_le_bytes());
        out.extend_from_slice(&(n as u32).to_le_bytes());
        for m in &self.models {
            for x in m {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        if version >= 3 {
            out.extend_from_slice(&(self.residuals.len() as u32).to_le_bytes());
            for e in &self.residuals {
                out.extend_from_slice(&(e.len() as u32).to_le_bytes());
                for x in e {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        let crc = fnv1a(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        Ok(out)
    }

    /// Parse v1–v3 wire bytes (CRC-checked). v1 files predate the
    /// topology metadata and load with `edges = 0`; v1/v2 files predate
    /// error feedback and load with empty `residuals`.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < 36 {
            bail!("checkpoint too short ({} bytes)", bytes.len());
        }
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
        let want = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        if fnv1a(body) != want {
            bail!("checkpoint CRC mismatch — file corrupt or truncated");
        }
        let mut cur = Cursor { b: body, pos: 0 };
        if cur.take(4)? != MAGIC {
            bail!("bad checkpoint magic");
        }
        let version = cur.u32()?;
        if !(1..=VERSION).contains(&version) {
            bail!("unsupported checkpoint version {version}");
        }
        let round = cur.u64()?;
        let seed = cur.u64()?;
        // the v2 topology-era metadata slot; absent in v1 files
        let edges = if version >= 2 { cur.u32()? } else { 0 };
        let m = cur.u32()? as usize;
        let consensus = cur.f32s(m)?;
        let k = cur.u32()? as usize;
        let n = cur.u32()? as usize;
        let mut models = Vec::with_capacity(k);
        for _ in 0..k {
            models.push(cur.f32s(n)?);
        }
        // the v3 error-feedback residual section; absent in v1/v2 files
        let mut residuals = Vec::new();
        if version >= 3 {
            let r = cur.u32()? as usize;
            residuals.reserve(r);
            for _ in 0..r {
                let rn = cur.u32()? as usize;
                residuals.push(cur.f32s(rn)?);
            }
        }
        if cur.pos != body.len() {
            bail!("trailing bytes in checkpoint");
        }
        Ok(Checkpoint { round, seed, edges, consensus, models, residuals })
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("checkpoint truncated at offset {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h = 0x811c_9dc5u32;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn sample() -> Checkpoint {
        Checkpoint {
            round: 42,
            seed: 17,
            edges: 4,
            consensus: vec![1.0, -1.0, 1.0],
            models: vec![vec![0.1, 0.2], vec![-0.3, 0.4]],
            residuals: Vec::new(),
        }
    }

    #[test]
    fn encode_decode_round_trip() {
        let c = sample();
        assert_eq!(Checkpoint::decode(&c.encode().unwrap()).unwrap(), c);
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("pfed1bs_ckpt_test");
        let path = dir.join("state.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), c);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = sample().encode().unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(Checkpoint::decode(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let bytes = sample().encode().unwrap();
        assert!(Checkpoint::decode(&bytes[..bytes.len() - 5]).is_err());
        assert!(Checkpoint::decode(&[]).is_err());
    }

    #[test]
    fn bad_magic_and_version() {
        let mut bytes = sample().encode().unwrap();
        bytes[0] = b'X';
        // fix CRC so the magic check (not the CRC) fires
        let n = bytes.len();
        let crc = super::fnv1a(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
        assert!(Checkpoint::decode(&bytes).unwrap_err().to_string().contains("magic"));
    }

    #[test]
    fn uneven_models_rejected() {
        let c = Checkpoint {
            round: 0,
            seed: 0,
            edges: 0,
            consensus: vec![],
            models: vec![vec![1.0], vec![1.0, 2.0]],
            residuals: Vec::new(),
        };
        assert!(c.encode().is_err());
    }

    #[test]
    fn empty_state_round_trips() {
        let c = Checkpoint {
            round: 0,
            seed: 0,
            edges: 0,
            consensus: vec![],
            models: vec![],
            residuals: vec![],
        };
        assert_eq!(Checkpoint::decode(&c.encode().unwrap()).unwrap(), c);
    }

    /// A v1 file, byte-for-byte as the pre-topology encoder wrote it
    /// (no `edges` field). The v2 reader must load it with `edges = 0`.
    /// The fixture is constructed by hand here — NOT by the encoder
    /// under test, which only writes v2.
    #[test]
    fn v1_fixture_loads_with_zero_edges() {
        let mut v1 = Vec::new();
        v1.extend_from_slice(b"PF1B");
        v1.extend_from_slice(&1u32.to_le_bytes()); // version 1
        v1.extend_from_slice(&7u64.to_le_bytes()); // round
        v1.extend_from_slice(&17u64.to_le_bytes()); // seed
        v1.extend_from_slice(&2u32.to_le_bytes()); // m
        v1.extend_from_slice(&1.0f32.to_le_bytes());
        v1.extend_from_slice(&(-1.0f32).to_le_bytes());
        v1.extend_from_slice(&1u32.to_le_bytes()); // k
        v1.extend_from_slice(&3u32.to_le_bytes()); // n
        for x in [0.5f32, -0.25, 2.0] {
            v1.extend_from_slice(&x.to_le_bytes());
        }
        let crc = super::fnv1a(&v1);
        v1.extend_from_slice(&crc.to_le_bytes());

        let got = Checkpoint::decode(&v1).expect("v1 files must stay readable");
        assert_eq!(
            got,
            Checkpoint {
                round: 7,
                seed: 17,
                edges: 0,
                consensus: vec![1.0, -1.0],
                models: vec![vec![0.5, -0.25, 2.0]],
                residuals: vec![],
            }
        );
        // and the v1 CRC/truncation protections still apply
        let mut corrupt = v1.clone();
        corrupt[10] ^= 0xFF;
        assert!(Checkpoint::decode(&corrupt).is_err());
        assert!(Checkpoint::decode(&v1[..v1.len() - 3]).is_err());
        // a future version must be rejected, not misparsed
        let mut v9 = v1.clone();
        v9[4..8].copy_from_slice(&9u32.to_le_bytes());
        let n = v9.len();
        let crc = super::fnv1a(&v9[..n - 4]);
        v9[n - 4..].copy_from_slice(&crc.to_le_bytes());
        let err = Checkpoint::decode(&v9).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn v2_round_trips_topology_metadata() {
        let c = sample();
        assert_eq!(c.edges, 4);
        let back = Checkpoint::decode(&c.encode().unwrap()).unwrap();
        assert_eq!(back.edges, 4);
        // flat runs record 0 edges
        let flat = Checkpoint { edges: 0, ..sample() };
        assert_eq!(Checkpoint::decode(&flat.encode().unwrap()).unwrap().edges, 0);
    }

    /// A v2 file, byte-for-byte as the pre-error-feedback encoder wrote
    /// it (no residual section). Built by hand — NOT by the encoder
    /// under test — and it must load with empty residuals; the same CRC
    /// and truncation protections the v1 fixture test pins apply.
    #[test]
    fn v2_fixture_loads_with_empty_residuals() {
        let mut v2 = Vec::new();
        v2.extend_from_slice(b"PF1B");
        v2.extend_from_slice(&2u32.to_le_bytes()); // version 2
        v2.extend_from_slice(&9u64.to_le_bytes()); // round
        v2.extend_from_slice(&23u64.to_le_bytes()); // seed
        v2.extend_from_slice(&4u32.to_le_bytes()); // edges
        v2.extend_from_slice(&2u32.to_le_bytes()); // m
        v2.extend_from_slice(&1.0f32.to_le_bytes());
        v2.extend_from_slice(&(-1.0f32).to_le_bytes());
        v2.extend_from_slice(&1u32.to_le_bytes()); // k
        v2.extend_from_slice(&2u32.to_le_bytes()); // n
        for x in [0.75f32, -1.5] {
            v2.extend_from_slice(&x.to_le_bytes());
        }
        let crc = super::fnv1a(&v2);
        v2.extend_from_slice(&crc.to_le_bytes());

        let want = Checkpoint {
            round: 9,
            seed: 23,
            edges: 4,
            consensus: vec![1.0, -1.0],
            models: vec![vec![0.75, -1.5]],
            residuals: vec![],
        };
        let got = Checkpoint::decode(&v2).expect("v2 files must stay readable");
        assert_eq!(got, want);
        // and the encoder still writes EXACTLY these bytes for a
        // residual-free state — old tooling keeps reading new files
        assert_eq!(want.encode().unwrap(), v2);
        // v2 CRC/truncation protections are unchanged
        let mut corrupt = v2.clone();
        corrupt[14] ^= 0xFF;
        assert!(Checkpoint::decode(&corrupt).is_err());
        assert!(Checkpoint::decode(&v2[..v2.len() - 2]).is_err());
    }

    #[test]
    fn v3_residuals_round_trip_and_stay_crc_protected() {
        let c = Checkpoint {
            residuals: vec![vec![0.5, -0.5, 0.125], vec![], vec![1.0, 2.0, -3.0]],
            ..sample()
        };
        let bytes = c.encode().unwrap();
        // version word stamps 3 only because residuals are present
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), 3);
        assert_eq!(Checkpoint::decode(&bytes).unwrap(), c);
        // a flipped byte INSIDE the residual section trips the CRC
        let mut corrupt = bytes.clone();
        let off = bytes.len() - 8; // inside the last residual's lanes
        corrupt[off] ^= 0xFF;
        assert!(Checkpoint::decode(&corrupt).is_err());
        // truncating the residual section is caught too
        assert!(Checkpoint::decode(&bytes[..bytes.len() - 6]).is_err());
    }

    #[test]
    fn prop_arbitrary_states_round_trip() {
        check("checkpoint_round_trip", 30, |rng| {
            let m = rng.below(100);
            let k = rng.below(5);
            let n = rng.below(200);
            let c = Checkpoint {
                round: rng.next_u64(),
                seed: rng.next_u64(),
                edges: rng.below(17) as u32,
                consensus: (0..m)
                    .map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 })
                    .collect(),
                models: (0..k)
                    .map(|_| (0..n).map(|_| rng.normal()).collect())
                    .collect(),
                residuals: if rng.f32() < 0.5 {
                    Vec::new()
                } else {
                    (0..k).map(|_| (0..m).map(|_| rng.normal()).collect()).collect()
                },
            };
            let back = Checkpoint::decode(&c.encode().map_err(|e| e.to_string())?)
                .map_err(|e| e.to_string())?;
            if back != c {
                return Err("round trip mismatch".into());
            }
            Ok(())
        });
    }
}
