//! Training history: per-round records and CSV emission for the figure
//! regenerators (Figs. 3/4, Appendix Figs. 1–3 plot these files).

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::comm::RoundBytes;

/// One communication round's record.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    /// round index t
    pub round: usize,
    /// mean task loss over local steps this round (Fig. 4)
    pub train_loss: f64,
    /// personalized test accuracy, when evaluated this round (Fig. 3)
    pub test_acc: Option<f64>,
    /// personalized test loss, when evaluated this round
    pub test_loss: Option<f64>,
    /// the round's measured wire traffic, both tiers (DESIGN.md §5, §11)
    pub bytes: RoundBytes,
    /// wall-clock duration of the whole round, ms
    pub duration_ms: f64,
    /// mean ‖∇F̃_k‖² diagnostic (Theorem 1), when requested
    pub grad_norm: Option<f64>,
    /// Hamming distance between this round's consensus v^{t+1} and the
    /// previous round's, computed on the packed words (`hamming_packed`
    /// popcount — DESIGN.md §8). `None` for algorithms without a
    /// consensus and for the first consensus-bearing round.
    pub consensus_flips: Option<usize>,
    /// uplinks accepted into the round's aggregation (= S in the default
    /// barrier rounds; fewer under dropouts/deadlines — DESIGN.md §9)
    pub delivered: usize,
    /// uplinks sent (and metered) but cut by the deadline / target count
    pub stragglers_cut: usize,
    /// server aggregate-phase wall time: streaming absorbs + shard
    /// merges + finish, ms
    pub aggregate_ms: f64,
    /// edge aggregators in the topology (0 = flat — DESIGN.md §11)
    pub edges: usize,
    /// whether the round closed at quorum before the full cohort landed
    /// (always `false` for barrier rounds — DESIGN.md §13)
    pub quorum_closed: bool,
    /// uplinks that missed the close but were buffered into round t+1's
    /// aggregator instead of cut (`max-staleness > 0` only)
    pub buffered_late: usize,
    /// fraction of this round's normalization mass contributed by
    /// carried-in stale uplinks (0.0 for barrier rounds)
    pub stale_weight: f64,
    /// computing clients whose uplink was Byzantine-corrupted this round
    /// (0 under `attack = none` — DESIGN.md §16)
    pub adversaries: usize,
}

/// Full run history + summary.
#[derive(Clone, Debug, Default)]
pub struct History {
    /// one record per completed round, in round order
    pub records: Vec<RoundRecord>,
}

impl History {
    /// Append one round's record.
    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    /// Final accuracy: the last evaluated round.
    pub fn final_accuracy(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.test_acc)
    }

    /// Test loss of the last evaluated round.
    pub fn final_test_loss(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.test_loss)
    }

    /// Best accuracy across evaluations.
    pub fn best_accuracy(&self) -> Option<f64> {
        self.records
            .iter()
            .filter_map(|r| r.test_acc)
            .fold(None, |acc, x| Some(acc.map_or(x, |a: f64| a.max(x))))
    }

    /// Mean per-round communication (MB) — the Table 2 cost metric.
    pub fn mean_round_mb(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.bytes.total_mb()).sum::<f64>()
            / self.records.len() as f64
    }

    /// Total communication (MB) across all completed rounds.
    pub fn total_mb(&self) -> f64 {
        self.records.iter().map(|r| r.bytes.total_mb()).sum()
    }

    /// Rounds to first reach `target` accuracy (communication-efficiency
    /// crossover metric).
    pub fn rounds_to_accuracy(&self, target: f64) -> Option<usize> {
        self.records
            .iter()
            .find(|r| r.test_acc.map(|a| a >= target).unwrap_or(false))
            .map(|r| r.round)
    }

    /// Write `round,train_loss,test_acc,test_loss,uplink_bytes,
    /// downlink_bytes,duration_ms,grad_norm,consensus_flips,delivered,
    /// stragglers_cut,aggregate_ms,edges,edge_merges,edge_bytes_up,
    /// edge_bytes_down,quorum_closed,buffered_late,stale_weight,
    /// adversaries` CSV (the edge columns are all zero under the
    /// default `flat` topology — DESIGN.md §11 — the quorum columns are
    /// `0,0,0.000000` for barrier rounds — DESIGN.md §13 — and
    /// `adversaries` is 0 for honest fleets — DESIGN.md §16).
    pub fn write_csv(&self, path: impl AsRef<Path>, header_comment: &str) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        if !header_comment.is_empty() {
            writeln!(f, "# {header_comment}")?;
        }
        writeln!(
            f,
            "round,train_loss,test_acc,test_loss,uplink_bytes,downlink_bytes,duration_ms,grad_norm,consensus_flips,delivered,stragglers_cut,aggregate_ms,edges,edge_merges,edge_bytes_up,edge_bytes_down,quorum_closed,buffered_late,stale_weight,adversaries"
        )?;
        for r in &self.records {
            writeln!(
                f,
                "{},{:.6},{},{},{},{},{:.3},{},{},{},{},{:.4},{},{},{},{},{},{},{:.6},{}",
                r.round,
                r.train_loss,
                fmt_opt(r.test_acc),
                fmt_opt(r.test_loss),
                r.bytes.uplink,
                r.bytes.downlink,
                r.duration_ms,
                fmt_opt(r.grad_norm),
                r.consensus_flips
                    .map(|x| x.to_string())
                    .unwrap_or_default(),
                r.delivered,
                r.stragglers_cut,
                r.aggregate_ms,
                r.edges,
                r.bytes.edge_up_msgs,
                r.bytes.edge_up,
                r.bytes.edge_down,
                r.quorum_closed as u8,
                r.buffered_late,
                r.stale_weight,
                r.adversaries,
            )?;
        }
        Ok(())
    }
}

fn fmt_opt(v: Option<f64>) -> String {
    v.map(|x| format!("{x:.6}")).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: Option<f64>) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0 / (round + 1) as f64,
            test_acc: acc,
            test_loss: acc.map(|a| 1.0 - a),
            bytes: RoundBytes {
                uplink: 100,
                downlink: 50,
                uplink_msgs: 2,
                downlink_msgs: 1,
                edge_up: 64,
                edge_down: 32,
                edge_up_msgs: 4,
                edge_down_msgs: 4,
            },
            duration_ms: 5.0,
            grad_norm: None,
            consensus_flips: if round > 0 { Some(round * 3) } else { None },
            delivered: 2,
            stragglers_cut: round % 2,
            aggregate_ms: 0.25,
            edges: 4,
            quorum_closed: round % 2 == 1,
            buffered_late: round % 2,
            stale_weight: 0.0,
            adversaries: round % 3,
        }
    }

    #[test]
    fn summaries() {
        let mut h = History::default();
        h.push(rec(0, None));
        h.push(rec(1, Some(0.5)));
        h.push(rec(2, Some(0.8)));
        h.push(rec(3, None));
        assert_eq!(h.final_accuracy(), Some(0.8));
        assert_eq!(h.best_accuracy(), Some(0.8));
        assert_eq!(h.rounds_to_accuracy(0.6), Some(2));
        assert_eq!(h.rounds_to_accuracy(0.9), None);
        assert!(h.mean_round_mb() > 0.0);
        // 100 + 50 client-tier + 64 + 32 edge-tier bytes per record
        assert!((h.total_mb() - 4.0 * 246.0 / (1024.0 * 1024.0)).abs() < 1e-9);
    }

    #[test]
    fn csv_round_trip_shape() {
        let mut h = History::default();
        h.push(rec(0, Some(0.25)));
        let dir = std::env::temp_dir().join("pfed1bs_test_metrics");
        let path = dir.join("hist.csv");
        h.write_csv(&path, "unit test").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].starts_with("# unit test"));
        assert!(lines[1].starts_with("round,train_loss"));
        assert!(lines[1].ends_with(
            "edge_bytes_up,edge_bytes_down,quorum_closed,buffered_late,stale_weight,adversaries"
        ));
        assert_eq!(lines.len(), 3);
        assert!(lines[2].starts_with("0,"));
        // round 0: quorum_closed false, buffered_late 0, stale_weight 0,
        // adversaries 0
        assert!(
            lines[2].ends_with(",2,0,0.2500,4,4,64,32,0,0,0.000000,0"),
            "{}",
            lines[2]
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_history() {
        let h = History::default();
        assert_eq!(h.final_accuracy(), None);
        assert_eq!(h.mean_round_mb(), 0.0);
    }
}
