//! Event-driven round planning: over-selection, per-link dropout and
//! latency draws, deadline cuts, and delivered-set weight
//! renormalization (DESIGN.md §9).
//!
//! A round is planned *before* any client computes: the cohort is
//! sampled, each selected client's channel draws its fate (dropout) and
//! uplink service time from its own lifecycle stream, and the arrival
//! schedule is fixed — simulated time, so the plan depends only on
//! `(config, seed, t)`, never on wall-clock or thread scheduling. The
//! coordinator then executes the plan: compute runs data-parallel while
//! the engine folds each delivered uplink into the round's streaming
//! aggregator in arrival order.
//!
//! Acceptance rule (the over-selection protocol of production FL
//! systems): arrivals are processed in simulated-time order (ties broken
//! by selection index) and accepted until `participating` uplinks are in
//! or the deadline passes; everything later is a straggler — its bytes
//! were spent on the link, its payload never enters server state.
//!
//! Under an `edge:E` topology with `edge_dropout_prob > 0`, a whole edge
//! aggregator can additionally miss the round (DESIGN.md §11): every
//! arrival it had accepted is demoted to a cut straggler — uplink bytes
//! stay metered (they reached the edge), payloads never reach the root —
//! and the delivered-set weights renormalize over the surviving edges,
//! composing with §9's delivered-set renormalization.
//!
//! Quorum close + staleness buffering (DESIGN.md §13): with `quorum < S`
//! the round closes as soon as `quorum` uplinks are in, and with
//! `max_staleness > 0` an arrival that misses the close by at most
//! `max_staleness` rounds is **buffered** — flagged for the coordinator
//! to stash into the next round's aggregator at staleness-decayed mass
//! `p_k · staleness_decay^age` — instead of being cut. The
//! renormalization then spans delivered + carried-in mass (the
//! `carry_mass` argument of [`plan_round_buffered`]), so delivered and
//! carried weights together form one probability vector. At the default
//! knobs every branch degenerates: `carry_mass = 0` skips the add,
//! no arrival is ever buffered, and the plan is bit-identical to the
//! barrier engine.

use crate::comm::{Payload, Transport};
use crate::config::{Attack, RunConfig, Topology};
use crate::sketch::bitpack::SignVec;
use crate::util::rng::{splitmix64, Rng};

/// One scheduled uplink arrival.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    /// index into the round's compute set (selection order)
    pub task: usize,
    /// client id
    pub client: usize,
    /// simulated arrival time, ms after round start
    pub at_ms: f64,
    /// delivered (absorbed into the aggregator) vs cut as a straggler
    pub accepted: bool,
    /// late but within `max_staleness` of the close: the coordinator
    /// buffers this uplink into round t+1's aggregator instead of
    /// cutting it (DESIGN.md §13). Mutually exclusive with `accepted`.
    pub buffered: bool,
    /// rounds late relative to the close (1 = within one deadline
    /// window after it); 0 for accepted and cut arrivals
    pub staleness: usize,
    /// delivered-set weight p_k (renormalized over what arrived in
    /// time plus any carried-in staleness mass); 0.0 for cut and
    /// buffered arrivals — a buffered uplink's weight materializes next
    /// round, decayed and renormalized there
    pub weight: f32,
    /// this round's Byzantine adversary (DESIGN.md §16): the client
    /// computes honestly but its uplink payload is corrupted by the
    /// configured [`Attack`] at the wire boundary. Drawn statelessly
    /// per `(seed, t, k)`; always false under `attack = none`.
    pub adversarial: bool,
}

/// A fully planned round: who was selected, who computes, and in what
/// order their uplinks reach the server.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    /// round index t
    pub t: usize,
    /// the over-selected cohort S̃^t, in selection order
    pub selected: Vec<usize>,
    /// clients that actually run the client phase (selection order):
    /// `selected` minus dropouts
    pub computing: Vec<usize>,
    /// arrival schedule over `computing`, sorted by (at_ms, task)
    pub arrivals: Vec<Arrival>,
    /// accepted arrivals (≤ participating)
    pub delivered: usize,
    /// computed-and-uploaded but cut by the deadline / target count (or
    /// stranded on a failed edge — DESIGN.md §11)
    pub stragglers_cut: usize,
    /// selected but unreachable this round
    pub dropped: usize,
    /// edge aggregators that missed this round's deadline (empty under
    /// `flat` or when `edge_dropout_prob = 0`), ascending edge ids
    pub failed_edges: Vec<usize>,
    /// the quorum — not the deadline or the target count — closed this
    /// round with in-time uplinks still outstanding (DESIGN.md §13)
    pub quorum_closed: bool,
    /// late arrivals buffered into round t+1 instead of cut
    pub buffered_late: usize,
    /// the mass the delivered-set weights were normalized by: delivered
    /// p_k plus carried-in staleness mass. 0.0 when nothing was
    /// delivered or the degenerate-mass guard fired (in which case the
    /// coordinator absorbs nothing, carry included).
    pub norm_total: f32,
    /// computing clients marked adversarial this round (DESIGN.md §16);
    /// 0 under `attack = none`
    pub adversaries: usize,
}

impl RoundPlan {
    /// The degenerate plan the pre-engine API exposes: every listed
    /// client computes and delivers instantly, with caller-supplied
    /// weights (benches and budget-loop examples drive rounds this way).
    pub fn full_delivery(t: usize, selected: Vec<usize>, weights: Vec<f32>) -> RoundPlan {
        assert_eq!(selected.len(), weights.len());
        let arrivals = selected
            .iter()
            .zip(&weights)
            .enumerate()
            .map(|(i, (&k, &w))| Arrival {
                task: i,
                client: k,
                at_ms: 0.0,
                accepted: true,
                buffered: false,
                staleness: 0,
                weight: w,
                adversarial: false,
            })
            .collect();
        RoundPlan {
            t,
            computing: selected.clone(),
            selected,
            arrivals,
            delivered: weights.len(),
            stragglers_cut: 0,
            dropped: 0,
            failed_edges: Vec::new(),
            quorum_closed: false,
            buffered_late: 0,
            // caller-supplied weights arrive pre-normalized
            norm_total: 1.0,
            adversaries: 0,
        }
    }
}

/// The per-(seed, round, edge) outage draw: a stateless SplitMix64
/// stream, so enabling edge outages consumes nothing from the
/// coordinator RNG or any client channel — plans with
/// `edge_dropout_prob = 0` are byte-identical to flat planning.
fn edge_outage_draw(seed: u64, t: usize, edge: usize) -> f64 {
    let mut s = seed
        ^ 0x4544_4745_u64 // "EDGE"
        ^ (t as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93)
        ^ (edge as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let _ = splitmix64(&mut s); // whiten once before drawing
    (splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// The per-(seed, wave, client) churn draw (DESIGN.md §13): a stateless
/// SplitMix64 stream like [`edge_outage_draw`], so enabling churn
/// consumes nothing from any client channel or the coordinator RNG —
/// `churn_prob = 0` planning stays byte-identical. One draw covers a
/// whole availability wave (`churn_period` rounds): a departed client is
/// gone for every round of its wave and redrawn — it may rejoin — for
/// the next.
fn churn_wave_draw(seed: u64, wave: usize, client: usize) -> f64 {
    let mut s = seed
        ^ 0x4348_5552_u64 // "CHUR"
        ^ (wave as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93)
        ^ (client as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let _ = splitmix64(&mut s); // whiten once before drawing
    (splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// The per-(seed, round, client) adversary draw (DESIGN.md §16): a
/// stateless SplitMix64 stream like [`churn_wave_draw`], so arming an
/// attack consumes nothing from any client channel or the coordinator
/// RNG — `attack = none` planning stays byte-identical. Redrawn every
/// round: a client hostile in round t may be honest in t+1 (mobile
/// Byzantine model).
fn adversary_draw(seed: u64, t: usize, client: usize) -> f64 {
    let mut s = seed
        ^ 0x4154_434B_u64 // "ATCK"
        ^ (t as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93)
        ^ (client as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let _ = splitmix64(&mut s); // whiten once before drawing
    (splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// The shared malicious sketch colluders submit (DESIGN.md §16): one
/// sign vector per `(seed, t)`, derived statelessly so every colluder —
/// on any shard, any thread, any transport — lands on the same bits
/// without coordinating through an RNG.
fn collusion_sketch(seed: u64, t: usize, m: usize) -> SignVec {
    let mut s = seed
        ^ 0x434F_4C4C_u64 // "COLL"
        ^ (t as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93);
    let _ = splitmix64(&mut s); // whiten once before drawing
    SignVec::from_words((0..m.div_ceil(64)).map(|_| splitmix64(&mut s)).collect(), m)
}

/// Corrupt an adversarial client's uplink payload in place (DESIGN.md
/// §16). Called by the coordinator AFTER honest local compute and
/// BEFORE the payload is metered onto the wire, so the attack costs the
/// adversary nothing extra and the wire ledger bills the corrupted
/// bytes. Deterministic per `(seed, t)`: the same hostile fleet replays
/// bit-for-bit across shards, threads, and transports.
///
/// - `SignFlip`: every uplink sign negated (Dense lanes negated) — the
///   strongest direction-reversal a one-bit channel admits.
/// - `Scale { gamma }`: Dense lanes and `ScaledSigns` scales multiply
///   by `gamma`; plain one-bit `Signs` carry no magnitude, so only the
///   sign of `gamma` acts (negative flips, positive is a no-op — the
///   documented degenerate case).
/// - `Collude`: the payload's signs are replaced by the round's shared
///   [`collusion_sketch`], concentrating the colluders' mass on one
///   adversarial direction instead of cancelling.
pub fn corrupt_payload(payload: &mut Payload, attack: &Attack, seed: u64, t: usize) {
    match *attack {
        Attack::None => {}
        Attack::SignFlip { .. } => match payload {
            Payload::Signs(z) => z.flip_bits_where(|_| true),
            Payload::ScaledSigns { signs, .. } => signs.flip_bits_where(|_| true),
            Payload::Dense(v) => v.iter_mut().for_each(|x| *x = -*x),
            Payload::TallyFrame(_) => {}
        },
        Attack::Scale { gamma, .. } => match payload {
            Payload::Dense(v) => {
                v.iter_mut().for_each(|x| *x = (*x as f64 * gamma) as f32)
            }
            Payload::ScaledSigns { scale, .. } => *scale = (*scale as f64 * gamma) as f32,
            Payload::Signs(z) => {
                if gamma < 0.0 {
                    z.flip_bits_where(|_| true);
                }
            }
            Payload::TallyFrame(_) => {}
        },
        Attack::Collude { .. } => match payload {
            Payload::Signs(z) => *z = collusion_sketch(seed, t, z.len()),
            Payload::ScaledSigns { signs, .. } => {
                *signs = collusion_sketch(seed, t, signs.len())
            }
            Payload::Dense(v) => {
                let sketch = collusion_sketch(seed, t, v.len());
                for (i, x) in v.iter_mut().enumerate() {
                    *x = sketch.sign(i);
                }
            }
            Payload::TallyFrame(_) => {}
        },
    }
}

/// How many rounds stale a post-close arrival is: 1 if it lands within
/// one deadline window after the close, 2 within the next, and so on.
/// With no deadline configured there is no window length, so every late
/// arrival counts one round stale (it is absorbed at the next open
/// regardless).
fn staleness_age(at_ms: f64, close_ms: f64, deadline_ms: f64) -> usize {
    if deadline_ms > 0.0 && close_ms.is_finite() {
        1 + ((at_ms - close_ms) / deadline_ms).floor().max(0.0) as usize
    } else {
        1
    }
}

/// Plan round `t`: sample the (over-)selected cohort from `rng`, draw
/// each client's fate from its own channel, schedule arrivals, apply the
/// target-count/deadline acceptance rule, and renormalize `client_weights`
/// (the full fleet's p_k) over the delivered set.
///
/// With every scenario knob at its default this reduces exactly to the
/// barrier round: cohort = S, nobody drops, everyone arrives at t=0 in
/// selection order, all are accepted, and the weights equal the
/// selection-order renormalization — byte-for-byte the pre-engine
/// behavior (no lifecycle draw is even consumed).
///
/// Generic over the [`Transport`]: the lifecycle streams are keyed by
/// `(seed, k)` on every transport, so the same plan comes out whether
/// the bytes will ride the simulation or a socket.
pub fn plan_round<N: Transport>(
    t: usize,
    cfg: &RunConfig,
    client_weights: &[f32],
    net: &mut N,
    rng: &mut Rng,
) -> RoundPlan {
    plan_round_buffered(t, cfg, client_weights, 0.0, net, rng)
}

/// [`plan_round`] with carried-in staleness mass (DESIGN.md §13): the
/// coordinator passes the Σ of raw staleness-decayed weights it buffered
/// from round t−1, and the delivered-set renormalization spans delivered
/// + carried mass so both together form one probability vector. The plan
/// reports the divisor back as `norm_total` (the coordinator divides
/// each carried raw weight by it). `carry_mass = 0.0` is exactly
/// [`plan_round`] — the add is skipped, not folded, so the default
/// arithmetic stays bit-identical.
pub fn plan_round_buffered<N: Transport>(
    t: usize,
    cfg: &RunConfig,
    client_weights: &[f32],
    carry_mass: f32,
    net: &mut N,
    rng: &mut Rng,
) -> RoundPlan {
    let cohort = (cfg.participating + cfg.over_select).min(cfg.clients);
    let selected = rng.sample_without_replacement(cfg.clients, cohort);

    // lifecycle draws in selection order, each from the client's OWN
    // lifecycle stream — the plan is invariant to how it is executed
    let mut computing = Vec::with_capacity(selected.len());
    let mut arrivals: Vec<Arrival> = Vec::with_capacity(selected.len());
    let mut dropped = 0usize;
    for &k in &selected {
        // churn wave (DESIGN.md §13): a departed client is unreachable
        // for its whole wave, exactly like a dropout — drawn statelessly
        // so the client's channel consumes no extra draw
        if cfg.churn_prob > 0.0
            && churn_wave_draw(cfg.seed, t / cfg.churn_period, k) < cfg.churn_prob
        {
            dropped += 1;
            continue;
        }
        if net.draw_dropout(k, cfg.dropout_prob) {
            dropped += 1;
            continue;
        }
        let at_ms = net.draw_latency(k, &cfg.latency);
        // Byzantine marking (DESIGN.md §16): stateless per-(seed, t, k)
        // draw, so `attack = none` plans stay byte-identical and no
        // channel or coordinator draw is ever consumed by the check
        let adversarial = cfg.attack.is_active()
            && adversary_draw(cfg.seed, t, k) < cfg.attack.fraction();
        arrivals.push(Arrival {
            task: computing.len(),
            client: k,
            at_ms,
            accepted: false,
            buffered: false,
            staleness: 0,
            weight: 0.0,
            adversarial,
        });
        computing.push(k);
    }

    // event order: simulated time, ties broken by selection index so the
    // zero-latency default is exactly selection order
    arrivals.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms).then(a.task.cmp(&b.task)));

    // accept until the quorum (default: the full target S) or the
    // deadline, whichever first
    let quorum = cfg.effective_quorum();
    let mut delivered = 0usize;
    let mut quorum_closed = false;
    for a in arrivals.iter_mut() {
        let in_time = cfg.deadline_ms <= 0.0 || a.at_ms <= cfg.deadline_ms;
        if delivered < quorum && in_time {
            a.accepted = true;
            delivered += 1;
        } else if in_time && cfg.quorum_active() {
            // an in-time uplink the filled quorum refused: the quorum —
            // not the deadline — closed this round early
            quorum_closed = true;
        }
    }
    // when the round closed: the quorum-filling arrival if the count
    // rule fired, else the deadline, else never (everything accepted)
    let close_ms = if delivered == quorum {
        arrivals.iter().filter(|a| a.accepted).map(|a| a.at_ms).fold(0.0, f64::max)
    } else if cfg.deadline_ms > 0.0 {
        cfg.deadline_ms
    } else {
        f64::INFINITY
    };

    // edge-lifecycle cut (DESIGN.md §11): a failed edge strands every
    // arrival it had accepted — demote them to stragglers BEFORE the
    // weight renormalization, so p_k renormalizes over what actually
    // reaches the root, exactly like deadline-cut stragglers
    let mut failed_edges = Vec::new();
    if let Topology::Edge { edges } = cfg.topology {
        if cfg.edge_dropout_prob > 0.0 {
            failed_edges = (0..edges)
                .filter(|&e| edge_outage_draw(cfg.seed, t, e) < cfg.edge_dropout_prob)
                .collect();
            for a in arrivals.iter_mut() {
                if a.accepted && failed_edges.contains(&cfg.topology.edge_of(a.client)) {
                    a.accepted = false;
                    delivered -= 1;
                }
            }
        }
    }

    // staleness buffering (DESIGN.md §13): an arrival that missed the
    // close by at most `max_staleness` rounds is flagged for the
    // coordinator to buffer into round t+1 instead of being cut.
    // Arrivals stranded on a failed edge stay cut — the edge lost them.
    let mut buffered_late = 0usize;
    if cfg.max_staleness > 0 {
        for a in arrivals.iter_mut() {
            if a.accepted || failed_edges.contains(&cfg.topology.edge_of(a.client)) {
                continue;
            }
            let age = staleness_age(a.at_ms, close_ms, cfg.deadline_ms);
            if age <= cfg.max_staleness {
                a.buffered = true;
                a.staleness = age;
                buffered_late += 1;
            }
        }
    }

    // renormalize p_k over the delivered set plus carried-in staleness
    // mass (Σ delivered weights + Σ carried weights = 1 whenever
    // anything was delivered or carried), accumulated in arrival order
    let delivered_mass: f32 = arrivals
        .iter()
        .filter(|a| a.accepted)
        .map(|a| client_weights[a.client])
        .sum();
    let total =
        if carry_mass > 0.0 { delivered_mass + carry_mass } else { delivered_mass };
    let norm_total = if total.is_finite() && total >= f32::MIN_POSITIVE {
        for a in arrivals.iter_mut() {
            if a.accepted {
                a.weight = client_weights[a.client] / total;
            }
        }
        total
    } else {
        // zero/denormal/NaN delivered mass cannot be renormalized:
        // dividing would hand every weight (and, through
        // quantize_weight, the exact tally) NaN or inf. Treat the round
        // as all-dropped — nothing is accepted, the coordinator absorbs
        // neither uplinks nor carry, server state stays untouched.
        for a in arrivals.iter_mut() {
            a.accepted = false;
            a.weight = 0.0;
        }
        delivered = 0;
        0.0
    };

    let stragglers_cut = arrivals.len() - delivered - buffered_late;
    let adversaries = arrivals.iter().filter(|a| a.adversarial).count();
    RoundPlan {
        t,
        selected,
        computing,
        arrivals,
        delivered,
        stragglers_cut,
        dropped,
        failed_edges,
        quorum_closed,
        buffered_late,
        norm_total,
        adversaries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{LatencyModel, SimNetwork};
    use crate::config::RunConfig;
    use crate::data::DatasetName;

    fn fleet_weights(k: usize) -> Vec<f32> {
        // unequal but normalized, like data-derived p_k
        let raw: Vec<f32> = (0..k).map(|i| 1.0 + (i % 5) as f32).collect();
        let total: f32 = raw.iter().sum();
        raw.into_iter().map(|w| w / total).collect()
    }

    #[test]
    fn default_plan_is_the_barrier_round_in_selection_order() {
        let cfg = RunConfig::preset(DatasetName::Mnist); // all knobs default
        let weights = fleet_weights(cfg.clients);
        let mut net = SimNetwork::new(cfg.seed);
        let mut rng = Rng::new(99);
        // the reference: what the pre-engine coordinator computed
        let mut ref_rng = Rng::new(99);
        let ref_selected =
            ref_rng.sample_without_replacement(cfg.clients, cfg.participating);
        let raw: Vec<f32> = ref_selected.iter().map(|&k| weights[k]).collect();
        let total: f32 = raw.iter().sum();
        let ref_weights: Vec<f32> = raw.iter().map(|&p| p / total).collect();

        let plan = plan_round(0, &cfg, &weights, &mut net, &mut rng);
        assert_eq!(plan.selected, ref_selected);
        assert_eq!(plan.computing, ref_selected);
        assert_eq!((plan.delivered, plan.stragglers_cut, plan.dropped), (20, 0, 0));
        for (i, a) in plan.arrivals.iter().enumerate() {
            assert_eq!(a.task, i, "zero latency must keep selection order");
            assert!(a.accepted);
            assert_eq!(a.at_ms, 0.0);
            assert_eq!(a.weight, ref_weights[i], "weight arithmetic must match");
        }
    }

    #[test]
    fn scenario_plan_is_deterministic_and_renormalizes_over_delivered() {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.participating = 10;
        cfg.over_select = 6;
        cfg.dropout_prob = 0.25;
        cfg.deadline_ms = 12.0;
        cfg.latency = LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 30.0 };
        cfg.validate().unwrap();
        let weights = fleet_weights(cfg.clients);

        let build = || {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(7);
            (0..4)
                .map(|t| plan_round(t, &cfg, &weights, &mut net, &mut rng))
                .collect::<Vec<_>>()
        };
        let plans = build();
        let replay = build();
        for (t, (p, q)) in plans.iter().zip(&replay).enumerate() {
            // fully deterministic in (cfg, seeds, t)
            assert_eq!(p.selected, q.selected, "round {t}");
            assert_eq!(p.delivered, q.delivered, "round {t}");
            let pw: Vec<f32> = p.arrivals.iter().map(|a| a.weight).collect();
            let qw: Vec<f32> = q.arrivals.iter().map(|a| a.weight).collect();
            assert_eq!(pw, qw, "round {t}");

            // structural invariants
            assert_eq!(p.selected.len(), 16);
            assert_eq!(p.computing.len() + p.dropped, p.selected.len());
            assert_eq!(p.arrivals.len(), p.computing.len());
            assert_eq!(
                p.arrivals.iter().filter(|a| a.accepted).count(),
                p.delivered
            );
            assert_eq!(p.stragglers_cut + p.delivered, p.computing.len());
            assert!(p.delivered <= cfg.participating);
            for a in &p.arrivals {
                assert!(
                    !a.accepted || a.at_ms <= cfg.deadline_ms,
                    "accepted an arrival past the deadline"
                );
            }
            for w in p.arrivals.windows(2) {
                assert!(
                    w[0].at_ms <= w[1].at_ms,
                    "arrivals must be in simulated-time order"
                );
            }
            // the delivered-set weights renormalize to exactly one
            if p.delivered > 0 {
                let sum: f32 = p
                    .arrivals
                    .iter()
                    .filter(|a| a.accepted)
                    .map(|a| a.weight)
                    .sum();
                assert!((sum - 1.0).abs() < 1e-4, "round {t}: Σp = {sum}");
            }
            for a in p.arrivals.iter().filter(|a| !a.accepted) {
                assert_eq!(a.weight, 0.0, "cut arrivals carry no weight");
            }
        }
        // the scenario actually exercises cuts/dropouts somewhere in 4
        // rounds (deterministic, so this is a stable property of seed 7)
        let total_cut: usize = plans.iter().map(|p| p.stragglers_cut).sum();
        let total_dropped: usize = plans.iter().map(|p| p.dropped).sum();
        assert!(total_cut + total_dropped > 0, "scenario produced no lifecycle events");
    }

    #[test]
    fn over_selection_closes_at_the_target_count() {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.participating = 5;
        cfg.over_select = 10;
        cfg.latency = LatencyModel::Uniform { lo_ms: 0.0, hi_ms: 10.0 };
        cfg.validate().unwrap();
        let weights = fleet_weights(cfg.clients);
        let mut net = SimNetwork::new(3);
        let mut rng = Rng::new(3);
        let plan = plan_round(0, &cfg, &weights, &mut net, &mut rng);
        assert_eq!(plan.selected.len(), 15);
        assert_eq!(plan.delivered, 5, "round must close at S deliveries");
        assert_eq!(plan.stragglers_cut, 10);
        // the accepted five are exactly the five earliest arrivals
        let cutoff = plan.arrivals[4].at_ms;
        for a in &plan.arrivals {
            assert_eq!(a.accepted, a.at_ms <= cutoff);
        }
    }

    #[test]
    fn edge_topology_without_outages_plans_exactly_like_flat() {
        use crate::config::Topology;
        // the edge tier reroutes aggregation, not planning: with
        // edge_dropout_prob = 0 the plan must be identical to flat —
        // no draw is consumed anywhere
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.participating = 10;
        cfg.over_select = 4;
        cfg.dropout_prob = 0.2;
        cfg.latency = LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 20.0 };
        let weights = fleet_weights(cfg.clients);
        let flat_plan = {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(5);
            plan_round(1, &cfg, &weights, &mut net, &mut rng)
        };
        cfg.topology = Topology::Edge { edges: 4 };
        cfg.validate().unwrap();
        let edge_plan = {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(5);
            plan_round(1, &cfg, &weights, &mut net, &mut rng)
        };
        assert_eq!(flat_plan.selected, edge_plan.selected);
        assert_eq!(flat_plan.delivered, edge_plan.delivered);
        assert!(edge_plan.failed_edges.is_empty());
        let fw: Vec<f32> = flat_plan.arrivals.iter().map(|a| a.weight).collect();
        let ew: Vec<f32> = edge_plan.arrivals.iter().map(|a| a.weight).collect();
        assert_eq!(fw, ew, "edge topology must not move a single weight bit");
    }

    #[test]
    fn failed_edges_strand_their_arrivals_and_weights_renormalize() {
        use crate::config::Topology;
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.topology = Topology::Edge { edges: 4 };
        cfg.edge_dropout_prob = 0.4;
        cfg.validate().unwrap();
        let weights = fleet_weights(cfg.clients);

        let build = || {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(11);
            (0..8)
                .map(|t| plan_round(t, &cfg, &weights, &mut net, &mut rng))
                .collect::<Vec<_>>()
        };
        let plans = build();
        // deterministic: outage draws are stateless in (seed, t, edge)
        for (p, q) in plans.iter().zip(&build()) {
            assert_eq!(p.failed_edges, q.failed_edges);
            assert_eq!(p.delivered, q.delivered);
        }
        let mut saw_failure = false;
        for p in &plans {
            for a in &p.arrivals {
                let failed = p.failed_edges.contains(&cfg.topology.edge_of(a.client));
                if failed {
                    saw_failure = true;
                    assert!(!a.accepted, "arrival survived its failed edge");
                    assert_eq!(a.weight, 0.0);
                }
            }
            assert_eq!(
                p.delivered + p.stragglers_cut,
                p.computing.len(),
                "stranded arrivals must count as cut stragglers"
            );
            if p.delivered > 0 {
                let sum: f32 =
                    p.arrivals.iter().filter(|a| a.accepted).map(|a| a.weight).sum();
                assert!(
                    (sum - 1.0).abs() < 1e-4,
                    "weights must renormalize over surviving edges: Σp = {sum}"
                );
            }
        }
        assert!(saw_failure, "0.4 outage probability produced no failure in 8 rounds");
    }

    #[test]
    fn full_delivery_plan_mirrors_its_inputs() {
        let plan = RoundPlan::full_delivery(3, vec![4, 9, 2], vec![0.5, 0.3, 0.2]);
        assert_eq!(plan.t, 3);
        assert_eq!(plan.computing, vec![4, 9, 2]);
        assert_eq!((plan.delivered, plan.stragglers_cut, plan.dropped), (3, 0, 0));
        assert_eq!(plan.arrivals[1].client, 9);
        assert_eq!(plan.arrivals[1].weight, 0.3);
        assert!(plan.arrivals.iter().all(|a| a.accepted));
        assert!(!plan.quorum_closed);
        assert_eq!(plan.buffered_late, 0);
        assert_eq!(plan.norm_total, 1.0);
    }

    #[test]
    fn regression_zero_delivered_weight_is_treated_as_all_dropped() {
        // the old renormalization divided by the delivered-set mass
        // unconditionally: an all-zero (or denormal-sum) weight vector
        // produced NaN/inf weights that poisoned the tally. The guard
        // must demote the round to all-dropped instead.
        let cfg = RunConfig::preset(DatasetName::Mnist);
        for weights in [
            vec![0.0f32; cfg.clients],
            // subnormal per-client mass whose sum underflows the guard
            vec![f32::from_bits(1); cfg.clients],
        ] {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(13);
            let plan = plan_round(0, &cfg, &weights, &mut net, &mut rng);
            assert_eq!(plan.delivered, 0, "degenerate mass must deliver nothing");
            assert_eq!(plan.norm_total, 0.0);
            assert_eq!(plan.stragglers_cut, plan.computing.len());
            for a in &plan.arrivals {
                assert!(!a.accepted);
                assert!(a.weight == 0.0 && a.weight.is_sign_positive(), "no NaN/inf leaks");
            }
        }
        // sanity: a healthy fleet is untouched by the guard
        let weights = fleet_weights(cfg.clients);
        let mut net = SimNetwork::new(cfg.seed);
        let mut rng = Rng::new(13);
        let plan = plan_round(0, &cfg, &weights, &mut net, &mut rng);
        assert_eq!(plan.delivered, cfg.participating);
    }

    #[test]
    fn quorum_closes_early_and_staleness_buffers_the_tail() {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.participating = 10;
        cfg.quorum = 6;
        cfg.max_staleness = 2;
        cfg.latency = LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 30.0 };
        cfg.validate().unwrap();
        let weights = fleet_weights(cfg.clients);
        let mut net = SimNetwork::new(cfg.seed);
        let mut rng = Rng::new(21);
        let plan = plan_round(0, &cfg, &weights, &mut net, &mut rng);
        // no dropout, no deadline: all 10 compute, the quorum takes the
        // 6 earliest, and the 4-strong tail is buffered (age 1 — no
        // deadline window), not cut
        assert_eq!(plan.computing.len(), 10);
        assert_eq!(plan.delivered, 6);
        assert!(plan.quorum_closed, "4 in-time uplinks were refused by the filled quorum");
        assert_eq!(plan.buffered_late, 4);
        assert_eq!(plan.stragglers_cut, 0);
        for a in &plan.arrivals {
            assert!(a.accepted != a.buffered, "every arrival is exactly one of the two");
            if a.buffered {
                assert_eq!(a.staleness, 1);
                assert_eq!(a.weight, 0.0, "buffered mass materializes next round");
            }
        }
        // with no carry, the delivered weights alone renormalize to 1
        let sum: f32 = plan.arrivals.iter().filter(|a| a.accepted).map(|a| a.weight).sum();
        assert!((sum - 1.0).abs() < 1e-4, "Σp = {sum}");
        assert!(plan.norm_total > 0.0);

        // max_staleness = 0 cuts the same tail outright
        cfg.max_staleness = 0;
        let mut net = SimNetwork::new(cfg.seed);
        let mut rng = Rng::new(21);
        let cut_plan = plan_round(0, &cfg, &weights, &mut net, &mut rng);
        assert_eq!(cut_plan.delivered, 6);
        assert_eq!(cut_plan.buffered_late, 0);
        assert_eq!(cut_plan.stragglers_cut, 4);
    }

    #[test]
    fn carry_mass_joins_the_renormalization() {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.participating = 8;
        cfg.quorum = 8;
        cfg.validate().unwrap();
        let weights = fleet_weights(cfg.clients);
        let base = {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(9);
            plan_round(0, &cfg, &weights, &mut net, &mut rng)
        };
        let delivered_mass = base.norm_total;
        let carry = 0.5 * delivered_mass;
        let plan = {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(9);
            plan_round_buffered(0, &cfg, &weights, carry, &mut net, &mut rng)
        };
        assert_eq!(plan.selected, base.selected, "carry mass must not move the plan");
        assert_eq!(plan.norm_total, delivered_mass + carry);
        // delivered weights now sum to delivered/(delivered+carry) = 2/3
        let sum: f32 = plan.arrivals.iter().filter(|a| a.accepted).map(|a| a.weight).sum();
        assert!((sum - 2.0 / 3.0).abs() < 1e-4, "Σp = {sum}");
    }

    #[test]
    fn deadline_staleness_ages_count_whole_windows() {
        // close at the deadline (12 ms): an arrival 0.5 windows late is
        // age 1, 1.5 windows late is age 2, beyond max_staleness is cut
        assert_eq!(staleness_age(13.0, 12.0, 12.0), 1);
        assert_eq!(staleness_age(23.9, 12.0, 12.0), 1);
        assert_eq!(staleness_age(24.1, 12.0, 12.0), 2);
        assert_eq!(staleness_age(60.0, 12.0, 12.0), 5);
        // no deadline: every late arrival is one round stale
        assert_eq!(staleness_age(1e9, 3.0, 0.0), 1);

        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.participating = 10;
        cfg.deadline_ms = 12.0;
        cfg.max_staleness = 1;
        cfg.latency = LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 40.0 };
        cfg.validate().unwrap();
        let weights = fleet_weights(cfg.clients);
        let mut net = SimNetwork::new(cfg.seed);
        let mut rng = Rng::new(17);
        let plan = plan_round(0, &cfg, &weights, &mut net, &mut rng);
        for a in &plan.arrivals {
            if a.buffered {
                assert!(a.at_ms > 12.0 && a.at_ms <= 24.0, "age-1 window only");
            } else if !a.accepted {
                assert!(a.at_ms > 24.0, "older than max_staleness must be cut");
            }
        }
        assert_eq!(
            plan.delivered + plan.buffered_late + plan.stragglers_cut,
            plan.computing.len()
        );
    }

    #[test]
    fn churn_waves_are_deterministic_and_hold_for_the_whole_period() {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.churn_prob = 0.4;
        cfg.churn_period = 4;
        cfg.validate().unwrap();
        let weights = fleet_weights(cfg.clients);
        let build = || {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(29);
            (0..8).map(|t| plan_round(t, &cfg, &weights, &mut net, &mut rng)).collect::<Vec<_>>()
        };
        let plans = build();
        for (p, q) in plans.iter().zip(&build()) {
            assert_eq!(p.computing, q.computing, "churn draws must be stateless");
        }
        // within one wave, a client's availability cannot change: if it
        // was churned out of one round of the wave and selected again in
        // another, it must be out there too
        for wave in [0usize, 1] {
            let rounds = &plans[wave * 4..(wave + 1) * 4];
            let mut out: Vec<usize> = Vec::new();
            for p in rounds {
                for &k in &p.selected {
                    if !p.computing.contains(&k) {
                        out.push(k);
                    }
                }
            }
            for p in rounds {
                for k in &out {
                    assert!(
                        !p.computing.contains(k),
                        "client {k} flip-flopped within wave {wave}"
                    );
                }
            }
        }
        let total_dropped: usize = plans.iter().map(|p| p.dropped).sum();
        assert!(total_dropped > 0, "0.4 churn produced no departure in 8 rounds");
    }

    #[test]
    fn arming_an_attack_changes_only_the_marks_and_consumes_no_draws() {
        let honest = RunConfig::preset(DatasetName::Mnist);
        let mut hostile = honest.clone();
        hostile.attack = Attack::SignFlip { frac: 0.5 };
        hostile.validate().unwrap();
        let weights = fleet_weights(honest.clients);

        let run = |cfg: &RunConfig| {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(7);
            let plans: Vec<RoundPlan> =
                (0..5).map(|t| plan_round(t, cfg, &weights, &mut net, &mut rng)).collect();
            // the sentinel draw proves the planner consumed exactly the
            // same RNG stream whether or not the attack was armed
            (plans, rng.next_u64())
        };
        let (clean, clean_sentinel) = run(&honest);
        let (marked, marked_sentinel) = run(&hostile);
        assert_eq!(clean_sentinel, marked_sentinel, "attack marking consumed RNG draws");

        let mut total_marked = 0usize;
        for (p, q) in clean.iter().zip(&marked) {
            // everything except the Byzantine marks is bit-identical
            assert_eq!(p.selected, q.selected);
            assert_eq!(p.computing, q.computing);
            assert_eq!(p.delivered, q.delivered);
            assert_eq!(p.norm_total.to_bits(), q.norm_total.to_bits());
            for (a, b) in p.arrivals.iter().zip(&q.arrivals) {
                assert_eq!(a.client, b.client);
                assert_eq!(a.at_ms.to_bits(), b.at_ms.to_bits());
                assert_eq!(a.weight.to_bits(), b.weight.to_bits());
                assert!(!a.adversarial, "attack=none must never mark an arrival");
            }
            assert_eq!(p.adversaries, 0);
            assert_eq!(q.adversaries, q.arrivals.iter().filter(|a| a.adversarial).count());
            total_marked += q.adversaries;
        }
        assert!(total_marked > 0, "frac=0.5 marked nobody across 5 rounds");

        // the marks themselves are a pure function of (seed, t, k)
        let (again, _) = run(&hostile);
        for (p, q) in marked.iter().zip(&again) {
            let pm: Vec<bool> = p.arrivals.iter().map(|a| a.adversarial).collect();
            let qm: Vec<bool> = q.arrivals.iter().map(|a| a.adversarial).collect();
            assert_eq!(pm, qm, "adversary marks must replay bit-for-bit");
        }
    }

    #[test]
    fn corrupt_payload_covers_every_attack_and_payload_shape() {
        let mut rng = Rng::new(41);
        let m = 130; // straddles a word boundary with a ragged tail
        let z = SignVec::from_fn(m, |_| rng.next_u64() & 1 == 1);
        let dense: Vec<f32> = (0..m).map(|i| (i as f32 - 60.0) * 0.25).collect();

        // none: byte-identical no-op on every shape
        let mut p = Payload::Signs(z.clone());
        corrupt_payload(&mut p, &Attack::None, 1, 2);
        assert_eq!(p, Payload::Signs(z.clone()));

        // signflip: every sign negated, dense lanes negated
        let mut p = Payload::Signs(z.clone());
        corrupt_payload(&mut p, &Attack::SignFlip { frac: 0.3 }, 1, 2);
        match &p {
            Payload::Signs(f) => assert_eq!(f.hamming(&z), m, "signflip missed a bit"),
            _ => unreachable!(),
        }
        let mut p = Payload::ScaledSigns { signs: z.clone(), scale: 0.75 };
        corrupt_payload(&mut p, &Attack::SignFlip { frac: 0.3 }, 1, 2);
        match &p {
            Payload::ScaledSigns { signs, scale } => {
                assert_eq!(signs.hamming(&z), m);
                assert_eq!(scale.to_bits(), 0.75f32.to_bits(), "signflip touched the scale");
            }
            _ => unreachable!(),
        }
        let mut p = Payload::Dense(dense.clone());
        corrupt_payload(&mut p, &Attack::SignFlip { frac: 0.3 }, 1, 2);
        match &p {
            Payload::Dense(v) => {
                for (a, b) in v.iter().zip(&dense) {
                    assert_eq!(a.to_bits(), (-b).to_bits());
                }
            }
            _ => unreachable!(),
        }

        // scale: γ multiplies magnitudes; γ < 0 flips a one-bit uplink
        let gamma = Attack::Scale { frac: 0.3, gamma: -3.0 };
        let mut p = Payload::ScaledSigns { signs: z.clone(), scale: 0.5 };
        corrupt_payload(&mut p, &gamma, 1, 2);
        match &p {
            Payload::ScaledSigns { signs, scale } => {
                assert_eq!(signs, &z, "scale must not touch packed signs");
                assert_eq!(*scale, -1.5);
            }
            _ => unreachable!(),
        }
        let mut p = Payload::Signs(z.clone());
        corrupt_payload(&mut p, &gamma, 1, 2);
        match &p {
            Payload::Signs(f) => assert_eq!(f.hamming(&z), m, "negative γ must flip signs"),
            _ => unreachable!(),
        }
        let mut p = Payload::Signs(z.clone());
        corrupt_payload(&mut p, &Attack::Scale { frac: 0.3, gamma: 3.0 }, 1, 2);
        assert_eq!(p, Payload::Signs(z.clone()), "positive γ is absorbed by sign()");

        // collude: every colluder lands on the SAME sketch per (seed, t)
        let mut a = Payload::Signs(z.clone());
        let mut b = Payload::Signs(SignVec::from_fn(m, |i| i % 3 == 0));
        corrupt_payload(&mut a, &Attack::Collude { frac: 0.3 }, 9, 4);
        corrupt_payload(&mut b, &Attack::Collude { frac: 0.3 }, 9, 4);
        assert_eq!(a, b, "colluders diverged within one round");
        let mut c = Payload::Signs(z.clone());
        corrupt_payload(&mut c, &Attack::Collude { frac: 0.3 }, 9, 5);
        assert_ne!(a, c, "collusion sketch failed to rotate across rounds");
        let mut d = Payload::Dense(dense.clone());
        corrupt_payload(&mut d, &Attack::Collude { frac: 0.3 }, 9, 4);
        match (&a, &d) {
            (Payload::Signs(sig), Payload::Dense(v)) => {
                for (i, x) in v.iter().enumerate() {
                    assert_eq!(x.to_bits(), sig.sign(i).to_bits());
                }
            }
            _ => unreachable!(),
        }
    }
}
