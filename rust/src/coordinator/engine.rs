//! Event-driven round planning: over-selection, per-link dropout and
//! latency draws, deadline cuts, and delivered-set weight
//! renormalization (DESIGN.md §9).
//!
//! A round is planned *before* any client computes: the cohort is
//! sampled, each selected client's channel draws its fate (dropout) and
//! uplink service time from its own lifecycle stream, and the arrival
//! schedule is fixed — simulated time, so the plan depends only on
//! `(config, seed, t)`, never on wall-clock or thread scheduling. The
//! coordinator then executes the plan: compute runs data-parallel while
//! the engine folds each delivered uplink into the round's streaming
//! aggregator in arrival order.
//!
//! Acceptance rule (the over-selection protocol of production FL
//! systems): arrivals are processed in simulated-time order (ties broken
//! by selection index) and accepted until `participating` uplinks are in
//! or the deadline passes; everything later is a straggler — its bytes
//! were spent on the link, its payload never enters server state.
//!
//! Under an `edge:E` topology with `edge_dropout_prob > 0`, a whole edge
//! aggregator can additionally miss the round (DESIGN.md §11): every
//! arrival it had accepted is demoted to a cut straggler — uplink bytes
//! stay metered (they reached the edge), payloads never reach the root —
//! and the delivered-set weights renormalize over the surviving edges,
//! composing with §9's delivered-set renormalization.

use crate::comm::Transport;
use crate::config::{RunConfig, Topology};
use crate::util::rng::{splitmix64, Rng};

/// One scheduled uplink arrival.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    /// index into the round's compute set (selection order)
    pub task: usize,
    /// client id
    pub client: usize,
    /// simulated arrival time, ms after round start
    pub at_ms: f64,
    /// delivered (absorbed into the aggregator) vs cut as a straggler
    pub accepted: bool,
    /// delivered-set weight p_k (renormalized over what arrived in
    /// time); 0.0 for cut arrivals
    pub weight: f32,
}

/// A fully planned round: who was selected, who computes, and in what
/// order their uplinks reach the server.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    /// round index t
    pub t: usize,
    /// the over-selected cohort S̃^t, in selection order
    pub selected: Vec<usize>,
    /// clients that actually run the client phase (selection order):
    /// `selected` minus dropouts
    pub computing: Vec<usize>,
    /// arrival schedule over `computing`, sorted by (at_ms, task)
    pub arrivals: Vec<Arrival>,
    /// accepted arrivals (≤ participating)
    pub delivered: usize,
    /// computed-and-uploaded but cut by the deadline / target count (or
    /// stranded on a failed edge — DESIGN.md §11)
    pub stragglers_cut: usize,
    /// selected but unreachable this round
    pub dropped: usize,
    /// edge aggregators that missed this round's deadline (empty under
    /// `flat` or when `edge_dropout_prob = 0`), ascending edge ids
    pub failed_edges: Vec<usize>,
}

impl RoundPlan {
    /// The degenerate plan the pre-engine API exposes: every listed
    /// client computes and delivers instantly, with caller-supplied
    /// weights (benches and budget-loop examples drive rounds this way).
    pub fn full_delivery(t: usize, selected: Vec<usize>, weights: Vec<f32>) -> RoundPlan {
        assert_eq!(selected.len(), weights.len());
        let arrivals = selected
            .iter()
            .zip(&weights)
            .enumerate()
            .map(|(i, (&k, &w))| Arrival {
                task: i,
                client: k,
                at_ms: 0.0,
                accepted: true,
                weight: w,
            })
            .collect();
        RoundPlan {
            t,
            computing: selected.clone(),
            selected,
            arrivals,
            delivered: weights.len(),
            stragglers_cut: 0,
            dropped: 0,
            failed_edges: Vec::new(),
        }
    }
}

/// The per-(seed, round, edge) outage draw: a stateless SplitMix64
/// stream, so enabling edge outages consumes nothing from the
/// coordinator RNG or any client channel — plans with
/// `edge_dropout_prob = 0` are byte-identical to flat planning.
fn edge_outage_draw(seed: u64, t: usize, edge: usize) -> f64 {
    let mut s = seed
        ^ 0x4544_4745_u64 // "EDGE"
        ^ (t as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93)
        ^ (edge as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let _ = splitmix64(&mut s); // whiten once before drawing
    (splitmix64(&mut s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Plan round `t`: sample the (over-)selected cohort from `rng`, draw
/// each client's fate from its own channel, schedule arrivals, apply the
/// target-count/deadline acceptance rule, and renormalize `client_weights`
/// (the full fleet's p_k) over the delivered set.
///
/// With every scenario knob at its default this reduces exactly to the
/// barrier round: cohort = S, nobody drops, everyone arrives at t=0 in
/// selection order, all are accepted, and the weights equal the
/// selection-order renormalization — byte-for-byte the pre-engine
/// behavior (no lifecycle draw is even consumed).
///
/// Generic over the [`Transport`]: the lifecycle streams are keyed by
/// `(seed, k)` on every transport, so the same plan comes out whether
/// the bytes will ride the simulation or a socket.
pub fn plan_round<N: Transport>(
    t: usize,
    cfg: &RunConfig,
    client_weights: &[f32],
    net: &mut N,
    rng: &mut Rng,
) -> RoundPlan {
    let cohort = (cfg.participating + cfg.over_select).min(cfg.clients);
    let selected = rng.sample_without_replacement(cfg.clients, cohort);

    // lifecycle draws in selection order, each from the client's OWN
    // lifecycle stream — the plan is invariant to how it is executed
    let mut computing = Vec::with_capacity(selected.len());
    let mut arrivals: Vec<Arrival> = Vec::with_capacity(selected.len());
    let mut dropped = 0usize;
    for &k in &selected {
        if net.draw_dropout(k, cfg.dropout_prob) {
            dropped += 1;
            continue;
        }
        let at_ms = net.draw_latency(k, &cfg.latency);
        arrivals.push(Arrival {
            task: computing.len(),
            client: k,
            at_ms,
            accepted: false,
            weight: 0.0,
        });
        computing.push(k);
    }

    // event order: simulated time, ties broken by selection index so the
    // zero-latency default is exactly selection order
    arrivals.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms).then(a.task.cmp(&b.task)));

    // accept until the target count or the deadline, whichever first
    let mut delivered = 0usize;
    for a in arrivals.iter_mut() {
        let in_time = cfg.deadline_ms <= 0.0 || a.at_ms <= cfg.deadline_ms;
        if delivered < cfg.participating && in_time {
            a.accepted = true;
            delivered += 1;
        }
    }

    // edge-lifecycle cut (DESIGN.md §11): a failed edge strands every
    // arrival it had accepted — demote them to stragglers BEFORE the
    // weight renormalization, so p_k renormalizes over what actually
    // reaches the root, exactly like deadline-cut stragglers
    let mut failed_edges = Vec::new();
    if let Topology::Edge { edges } = cfg.topology {
        if cfg.edge_dropout_prob > 0.0 {
            failed_edges = (0..edges)
                .filter(|&e| edge_outage_draw(cfg.seed, t, e) < cfg.edge_dropout_prob)
                .collect();
            for a in arrivals.iter_mut() {
                if a.accepted && failed_edges.contains(&cfg.topology.edge_of(a.client)) {
                    a.accepted = false;
                    delivered -= 1;
                }
            }
        }
    }

    // renormalize p_k over the delivered set (Σ weights = 1 whenever
    // anything was delivered), accumulated in arrival order
    let total: f32 = arrivals
        .iter()
        .filter(|a| a.accepted)
        .map(|a| client_weights[a.client])
        .sum();
    for a in arrivals.iter_mut() {
        if a.accepted {
            a.weight = client_weights[a.client] / total;
        }
    }

    let stragglers_cut = arrivals.len() - delivered;
    RoundPlan {
        t,
        selected,
        computing,
        arrivals,
        delivered,
        stragglers_cut,
        dropped,
        failed_edges,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{LatencyModel, SimNetwork};
    use crate::config::RunConfig;
    use crate::data::DatasetName;

    fn fleet_weights(k: usize) -> Vec<f32> {
        // unequal but normalized, like data-derived p_k
        let raw: Vec<f32> = (0..k).map(|i| 1.0 + (i % 5) as f32).collect();
        let total: f32 = raw.iter().sum();
        raw.into_iter().map(|w| w / total).collect()
    }

    #[test]
    fn default_plan_is_the_barrier_round_in_selection_order() {
        let cfg = RunConfig::preset(DatasetName::Mnist); // all knobs default
        let weights = fleet_weights(cfg.clients);
        let mut net = SimNetwork::new(cfg.seed);
        let mut rng = Rng::new(99);
        // the reference: what the pre-engine coordinator computed
        let mut ref_rng = Rng::new(99);
        let ref_selected =
            ref_rng.sample_without_replacement(cfg.clients, cfg.participating);
        let raw: Vec<f32> = ref_selected.iter().map(|&k| weights[k]).collect();
        let total: f32 = raw.iter().sum();
        let ref_weights: Vec<f32> = raw.iter().map(|&p| p / total).collect();

        let plan = plan_round(0, &cfg, &weights, &mut net, &mut rng);
        assert_eq!(plan.selected, ref_selected);
        assert_eq!(plan.computing, ref_selected);
        assert_eq!((plan.delivered, plan.stragglers_cut, plan.dropped), (20, 0, 0));
        for (i, a) in plan.arrivals.iter().enumerate() {
            assert_eq!(a.task, i, "zero latency must keep selection order");
            assert!(a.accepted);
            assert_eq!(a.at_ms, 0.0);
            assert_eq!(a.weight, ref_weights[i], "weight arithmetic must match");
        }
    }

    #[test]
    fn scenario_plan_is_deterministic_and_renormalizes_over_delivered() {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.participating = 10;
        cfg.over_select = 6;
        cfg.dropout_prob = 0.25;
        cfg.deadline_ms = 12.0;
        cfg.latency = LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 30.0 };
        cfg.validate().unwrap();
        let weights = fleet_weights(cfg.clients);

        let build = || {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(7);
            (0..4)
                .map(|t| plan_round(t, &cfg, &weights, &mut net, &mut rng))
                .collect::<Vec<_>>()
        };
        let plans = build();
        let replay = build();
        for (t, (p, q)) in plans.iter().zip(&replay).enumerate() {
            // fully deterministic in (cfg, seeds, t)
            assert_eq!(p.selected, q.selected, "round {t}");
            assert_eq!(p.delivered, q.delivered, "round {t}");
            let pw: Vec<f32> = p.arrivals.iter().map(|a| a.weight).collect();
            let qw: Vec<f32> = q.arrivals.iter().map(|a| a.weight).collect();
            assert_eq!(pw, qw, "round {t}");

            // structural invariants
            assert_eq!(p.selected.len(), 16);
            assert_eq!(p.computing.len() + p.dropped, p.selected.len());
            assert_eq!(p.arrivals.len(), p.computing.len());
            assert_eq!(
                p.arrivals.iter().filter(|a| a.accepted).count(),
                p.delivered
            );
            assert_eq!(p.stragglers_cut + p.delivered, p.computing.len());
            assert!(p.delivered <= cfg.participating);
            for a in &p.arrivals {
                assert!(
                    !a.accepted || a.at_ms <= cfg.deadline_ms,
                    "accepted an arrival past the deadline"
                );
            }
            for w in p.arrivals.windows(2) {
                assert!(
                    w[0].at_ms <= w[1].at_ms,
                    "arrivals must be in simulated-time order"
                );
            }
            // the delivered-set weights renormalize to exactly one
            if p.delivered > 0 {
                let sum: f32 = p
                    .arrivals
                    .iter()
                    .filter(|a| a.accepted)
                    .map(|a| a.weight)
                    .sum();
                assert!((sum - 1.0).abs() < 1e-4, "round {t}: Σp = {sum}");
            }
            for a in p.arrivals.iter().filter(|a| !a.accepted) {
                assert_eq!(a.weight, 0.0, "cut arrivals carry no weight");
            }
        }
        // the scenario actually exercises cuts/dropouts somewhere in 4
        // rounds (deterministic, so this is a stable property of seed 7)
        let total_cut: usize = plans.iter().map(|p| p.stragglers_cut).sum();
        let total_dropped: usize = plans.iter().map(|p| p.dropped).sum();
        assert!(total_cut + total_dropped > 0, "scenario produced no lifecycle events");
    }

    #[test]
    fn over_selection_closes_at_the_target_count() {
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.participating = 5;
        cfg.over_select = 10;
        cfg.latency = LatencyModel::Uniform { lo_ms: 0.0, hi_ms: 10.0 };
        cfg.validate().unwrap();
        let weights = fleet_weights(cfg.clients);
        let mut net = SimNetwork::new(3);
        let mut rng = Rng::new(3);
        let plan = plan_round(0, &cfg, &weights, &mut net, &mut rng);
        assert_eq!(plan.selected.len(), 15);
        assert_eq!(plan.delivered, 5, "round must close at S deliveries");
        assert_eq!(plan.stragglers_cut, 10);
        // the accepted five are exactly the five earliest arrivals
        let cutoff = plan.arrivals[4].at_ms;
        for a in &plan.arrivals {
            assert_eq!(a.accepted, a.at_ms <= cutoff);
        }
    }

    #[test]
    fn edge_topology_without_outages_plans_exactly_like_flat() {
        use crate::config::Topology;
        // the edge tier reroutes aggregation, not planning: with
        // edge_dropout_prob = 0 the plan must be identical to flat —
        // no draw is consumed anywhere
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.participating = 10;
        cfg.over_select = 4;
        cfg.dropout_prob = 0.2;
        cfg.latency = LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 20.0 };
        let weights = fleet_weights(cfg.clients);
        let flat_plan = {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(5);
            plan_round(1, &cfg, &weights, &mut net, &mut rng)
        };
        cfg.topology = Topology::Edge { edges: 4 };
        cfg.validate().unwrap();
        let edge_plan = {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(5);
            plan_round(1, &cfg, &weights, &mut net, &mut rng)
        };
        assert_eq!(flat_plan.selected, edge_plan.selected);
        assert_eq!(flat_plan.delivered, edge_plan.delivered);
        assert!(edge_plan.failed_edges.is_empty());
        let fw: Vec<f32> = flat_plan.arrivals.iter().map(|a| a.weight).collect();
        let ew: Vec<f32> = edge_plan.arrivals.iter().map(|a| a.weight).collect();
        assert_eq!(fw, ew, "edge topology must not move a single weight bit");
    }

    #[test]
    fn failed_edges_strand_their_arrivals_and_weights_renormalize() {
        use crate::config::Topology;
        let mut cfg = RunConfig::preset(DatasetName::Mnist);
        cfg.topology = Topology::Edge { edges: 4 };
        cfg.edge_dropout_prob = 0.4;
        cfg.validate().unwrap();
        let weights = fleet_weights(cfg.clients);

        let build = || {
            let mut net = SimNetwork::new(cfg.seed);
            let mut rng = Rng::new(11);
            (0..8)
                .map(|t| plan_round(t, &cfg, &weights, &mut net, &mut rng))
                .collect::<Vec<_>>()
        };
        let plans = build();
        // deterministic: outage draws are stateless in (seed, t, edge)
        for (p, q) in plans.iter().zip(&build()) {
            assert_eq!(p.failed_edges, q.failed_edges);
            assert_eq!(p.delivered, q.delivered);
        }
        let mut saw_failure = false;
        for p in &plans {
            for a in &p.arrivals {
                let failed = p.failed_edges.contains(&cfg.topology.edge_of(a.client));
                if failed {
                    saw_failure = true;
                    assert!(!a.accepted, "arrival survived its failed edge");
                    assert_eq!(a.weight, 0.0);
                }
            }
            assert_eq!(
                p.delivered + p.stragglers_cut,
                p.computing.len(),
                "stranded arrivals must count as cut stragglers"
            );
            if p.delivered > 0 {
                let sum: f32 =
                    p.arrivals.iter().filter(|a| a.accepted).map(|a| a.weight).sum();
                assert!(
                    (sum - 1.0).abs() < 1e-4,
                    "weights must renormalize over surviving edges: Σp = {sum}"
                );
            }
        }
        assert!(saw_failure, "0.4 outage probability produced no failure in 8 rounds");
    }

    #[test]
    fn full_delivery_plan_mirrors_its_inputs() {
        let plan = RoundPlan::full_delivery(3, vec![4, 9, 2], vec![0.5, 0.3, 0.2]);
        assert_eq!(plan.t, 3);
        assert_eq!(plan.computing, vec![4, 9, 2]);
        assert_eq!((plan.delivered, plan.stragglers_cut, plan.dropped), (3, 0, 0));
        assert_eq!(plan.arrivals[1].client, 9);
        assert_eq!(plan.arrivals[1].weight, 0.3);
        assert!(plan.arrivals.iter().all(|a| a.accepted));
    }
}
