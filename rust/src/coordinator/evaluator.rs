//! Personalized evaluation: every client's model on its own held-out
//! test distribution, aggregated across clients — the paper's Top-1
//! metric ("aggregated across all clients' personalized models").
//!
//! Padding rows in the final partial batch carry label −1 and are masked
//! *inside* the eval HLO artifact (see `model.eval_batch`), so the
//! accumulated (correct, loss_sum) statistics here are exact.

use anyhow::Result;

use crate::algorithms::Algorithm;
use crate::data::{EvalBatches, FederatedData};
use crate::runtime::ModelRuntime;

/// Accuracy + mean loss over all clients.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    /// Top-1 accuracy over every evaluated sample
    pub accuracy: f64,
    /// mean task loss over every evaluated sample
    pub mean_loss: f64,
    /// how many (unpadded) samples went into the aggregate
    pub samples: usize,
}

/// Evaluate `alg`'s per-client models over every client's test shard.
pub fn evaluate(
    model: &ModelRuntime,
    data: &FederatedData,
    alg: &dyn Algorithm,
) -> Result<EvalResult> {
    let mut correct = 0.0f64;
    let mut loss_sum = 0.0f64;
    let mut total = 0usize;
    for (k, client) in data.clients.iter().enumerate() {
        let w = alg.model_for(k);
        let mut batches = EvalBatches::new(client, model.geom.eval_batch);
        while let Some((x, y, valid)) = batches.next_batch() {
            let (c, l) = model.eval_batch(w, &x, &y)?;
            correct += c as f64;
            loss_sum += l as f64;
            total += valid;
        }
    }
    Ok(EvalResult {
        accuracy: correct / total.max(1) as f64,
        mean_loss: loss_sum / total.max(1) as f64,
        samples: total,
    })
}

/// Per-client accuracies (heterogeneity diagnostics + fairness spread).
pub fn evaluate_per_client(
    model: &ModelRuntime,
    data: &FederatedData,
    alg: &dyn Algorithm,
) -> Result<Vec<EvalResult>> {
    let mut out = Vec::with_capacity(data.num_clients());
    for (k, client) in data.clients.iter().enumerate() {
        let w = alg.model_for(k);
        let mut correct = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut total = 0usize;
        let mut batches = EvalBatches::new(client, model.geom.eval_batch);
        while let Some((x, y, valid)) = batches.next_batch() {
            let (c, l) = model.eval_batch(w, &x, &y)?;
            correct += c as f64;
            loss_sum += l as f64;
            total += valid;
        }
        out.push(EvalResult {
            accuracy: correct / total.max(1) as f64,
            mean_loss: loss_sum / total.max(1) as f64,
            samples: total,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    // evaluate() needs a live PJRT runtime; covered end-to-end by
    // rust/tests/integration_training.rs. The padding mask itself is
    // unit-tested in python/tests/test_model.py::test_eval_batch_masks_padding.
}
