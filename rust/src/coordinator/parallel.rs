//! Deterministic data-parallel execution of the client phase.
//!
//! rayon is unavailable in the offline mirror (DESIGN.md §2), so this is
//! a minimal scoped-thread work-stealing map: a shared atomic cursor
//! hands out item indices, each result lands in its own slot, and the
//! output order is the input order. Because every item is a pure
//! function of its pre-forked inputs (per-client RNG streams are forked
//! by the coordinator in selection order *before* the parallel section),
//! the results are bit-identical for any thread count — `threads == 1`
//! runs inline without spawning.
//!
//! [`par_map_consume`] is the streaming sibling the event-driven round
//! engine drives: same worker pool, but results are handed to a
//! caller-thread consumer one at a time in a caller-chosen order
//! (simulated arrival order) instead of being collected into a `Vec`.

use std::convert::Infallible;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Resolve the client-phase worker count: a positive config value wins,
/// then the `PFED1BS_CLIENT_THREADS` environment variable, then the
/// machine's available parallelism.
pub fn thread_count(cfg_threads: usize) -> usize {
    if cfg_threads > 0 {
        return cfg_threads;
    }
    if let Some(n) = std::env::var("PFED1BS_CLIENT_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
    {
        return n;
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

/// Map `f` over `items` on up to `threads` workers; `out[i] = f(i,
/// items[i])` with output order independent of scheduling.
///
/// Fully safe: `F: Sync` makes the compiler check every capture. A
/// caller holding a reference that is thread-safe in practice but not
/// statically `Sync` (the coordinator's PJRT model handle) wraps that
/// one field in its own documented `unsafe impl Sync` newtype rather
/// than suppressing checking for the whole environment.
///
/// Thin wrapper over [`par_map_consume`] (identity consumption order,
/// results collected into a `Vec`) so there is exactly one worker-pool
/// implementation to keep correct.
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let order: Vec<usize> = (0..n).collect();
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    par_map_consume(items, threads, &order, f, |i, r| -> Result<(), Infallible> {
        out[i] = Some(r);
        Ok(())
    })
    .expect("infallible");
    out.into_iter()
        .map(|slot| slot.expect("worker died before filling slot"))
        .collect()
}

/// One result slot plus its readiness signal ([`par_map_consume`]).
type Slot<R> = (Mutex<Option<std::thread::Result<R>>>, Condvar);

/// Streaming variant of [`par_map`] for the event-driven round engine
/// (DESIGN.md §9): workers compute `f` over the items while the CALLER's
/// thread consumes each result in `order` (a permutation of `0..n` —
/// the round's simulated-arrival order), one at a time, as soon as it is
/// ready. Results are handed over slot-by-slot and never materialized as
/// a `Vec`; with `threads <= 1` the items are computed lazily in
/// consumption order, so nothing is ever buffered at all. Workers pull
/// work in `order` too, so under homogeneous task costs the compute
/// lead over the consumer stays around the worker count.
///
/// `consume` runs only on the caller's thread, so it may hold `&mut`
/// state (the network, the round aggregator) that the workers never
/// see. An `Err` from `consume` stops consumption and is returned after
/// the workers drain; a panic inside `f` is re-raised on the caller's
/// thread when its slot is reached.
pub fn par_map_consume<T, R, F, C, E>(
    items: Vec<T>,
    threads: usize,
    order: &[usize],
    f: F,
    mut consume: C,
) -> Result<(), E>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
    C: FnMut(usize, R) -> Result<(), E>,
{
    let n = items.len();
    assert_eq!(order.len(), n, "consume order must cover every item exactly once");
    // validate the permutation up front, on the caller's thread: a
    // duplicated index discovered by a worker would panic outside the
    // slot protocol and leave the consumer blocked forever
    let mut seen = vec![false; n];
    for &i in order {
        assert!(
            i < n && !std::mem::replace(&mut seen[i], true),
            "consume order must be a permutation of 0..{n}"
        );
    }
    if threads <= 1 || n <= 1 {
        let mut items: Vec<Option<T>> = items.into_iter().map(Some).collect();
        for &i in order {
            let item = items[i].take().expect("index repeated in consume order");
            consume(i, f(i, item))?;
        }
        return Ok(());
    }
    let queue: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let slots: Vec<Slot<R>> = (0..n).map(|_| (Mutex::new(None), Condvar::new())).collect();
    let cursor = AtomicUsize::new(0);
    let (f_ref, queue_ref, slots_ref, cursor_ref) = (&f, &queue, &slots, &cursor);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(n) {
            scope.spawn(move || loop {
                let c = cursor_ref.fetch_add(1, Ordering::Relaxed);
                if c >= order.len() {
                    break;
                }
                let i = order[c];
                let item = queue_ref[i].lock().unwrap().take().expect("item taken twice");
                // catch panics so a dead worker can't leave the consumer
                // blocked on an empty slot; the consumer re-raises.
                // AssertUnwindSafe: on Err the payload is immediately
                // re-thrown, no captured state is observed afterwards.
                let result = catch_unwind(AssertUnwindSafe(|| f_ref(i, item)));
                let (lock, ready) = &slots_ref[i];
                *lock.lock().unwrap() = Some(result);
                ready.notify_all();
            });
        }
        // the caller's thread is the consumer: walk the arrival order,
        // blocking on each slot until its worker delivers
        for &i in order {
            let (lock, ready) = &slots_ref[i];
            let mut slot = lock.lock().unwrap();
            while slot.is_none() {
                slot = ready.wait(slot).unwrap();
            }
            let result = slot.take().expect("slot emptied twice");
            drop(slot);
            match result {
                Ok(r) => consume(i, r)?,
                Err(panic) => resume_unwind(panic),
            }
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_matches_serial_for_any_thread_count() {
        let items: Vec<u64> = (0..57).collect();
        let serial: Vec<u64> = par_map(items.clone(), 1, |i, x| x * 3 + i as u64);
        for threads in [2, 4, 16] {
            let parallel: Vec<u64> = par_map(items.clone(), threads, |i, x| x * 3 + i as u64);
            assert_eq!(parallel, serial, "threads={threads}");
        }
    }

    #[test]
    fn more_workers_than_items_is_fine() {
        let out: Vec<usize> = par_map(vec![7usize, 8], 32, |_, x| x + 1);
        assert_eq!(out, vec![8, 9]);
    }

    #[test]
    fn empty_input_yields_empty_output() {
        let out: Vec<u32> = par_map(Vec::<u32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn thread_count_prefers_config() {
        assert_eq!(thread_count(3), 3);
        assert!(thread_count(0) >= 1);
    }

    #[test]
    fn consume_follows_the_given_order_for_any_thread_count() {
        let items: Vec<u64> = (0..37).collect();
        // a scrambled but fixed "arrival order"
        let order: Vec<usize> = (0..37).map(|i| (i * 11) % 37).collect();
        for threads in [1usize, 2, 8] {
            let mut seen: Vec<(usize, u64)> = Vec::new();
            par_map_consume(
                items.clone(),
                threads,
                &order,
                |i, x| x * 2 + i as u64,
                |i, r| -> Result<(), ()> {
                    seen.push((i, r));
                    Ok(())
                },
            )
            .unwrap();
            let want: Vec<(usize, u64)> =
                order.iter().map(|&i| (i, items[i] * 2 + i as u64)).collect();
            assert_eq!(seen, want, "threads={threads}");
        }
    }

    #[test]
    fn consumer_error_short_circuits_but_workers_drain() {
        let order: Vec<usize> = (0..20).collect();
        for threads in [1usize, 4] {
            let mut consumed = 0;
            let out = par_map_consume(
                (0..20u32).collect::<Vec<_>>(),
                threads,
                &order,
                |_, x| x,
                |_, r| {
                    consumed += 1;
                    if r == 5 {
                        Err("stop at five")
                    } else {
                        Ok(())
                    }
                },
            );
            assert_eq!(out, Err("stop at five"));
            assert_eq!(consumed, 6, "threads={threads}");
        }
    }

    #[test]
    #[should_panic(expected = "worker boom")]
    fn worker_panic_reraises_on_the_consumer_thread() {
        let order: Vec<usize> = (0..8).collect();
        let _ = par_map_consume(
            (0..8u32).collect::<Vec<_>>(),
            4,
            &order,
            |i, x| {
                if i == 3 {
                    panic!("worker boom");
                }
                x
            },
            |_, _| -> Result<(), ()> { Ok(()) },
        );
    }

    #[test]
    fn empty_and_single_item_inputs() {
        par_map_consume(Vec::<u8>::new(), 4, &[], |_, x| x, |_, _| -> Result<(), ()> {
            panic!("nothing to consume")
        })
        .unwrap();
        let mut got = None;
        par_map_consume(vec![41u8], 4, &[0], |_, x| x + 1, |_, r| -> Result<(), ()> {
            got = Some(r);
            Ok(())
        })
        .unwrap();
        assert_eq!(got, Some(42));
    }
}
