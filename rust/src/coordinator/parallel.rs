//! Deterministic data-parallel execution of the client phase.
//!
//! rayon is unavailable in the offline mirror (DESIGN.md §2), so this is
//! a minimal scoped-thread work-stealing map: a shared atomic cursor
//! hands out item indices, each result lands in its own slot, and the
//! output order is the input order. Because every item is a pure
//! function of its pre-forked inputs (per-client RNG streams are forked
//! by the coordinator in selection order *before* the parallel section),
//! the results are bit-identical for any thread count — `threads == 1`
//! runs inline without spawning.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve the client-phase worker count: a positive config value wins,
/// then the `PFED1BS_CLIENT_THREADS` environment variable, then the
/// machine's available parallelism.
pub fn thread_count(cfg_threads: usize) -> usize {
    if cfg_threads > 0 {
        return cfg_threads;
    }
    if let Some(n) = std::env::var("PFED1BS_CLIENT_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
    {
        return n;
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

/// Map `f` over `items` on up to `threads` workers; `out[i] = f(i,
/// items[i])` with output order independent of scheduling.
///
/// Fully safe: `F: Sync` makes the compiler check every capture. A
/// caller holding a reference that is thread-safe in practice but not
/// statically `Sync` (the coordinator's PJRT model handle) wraps that
/// one field in its own documented `unsafe impl Sync` newtype rather
/// than suppressing checking for the whole environment.
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let queue: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    let (f_ref, queue_ref, slots_ref, cursor_ref) = (&f, &queue, &slots, &cursor);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(n) {
            scope.spawn(move || loop {
                let i = cursor_ref.fetch_add(1, Ordering::Relaxed);
                if i >= queue_ref.len() {
                    break;
                }
                let item = queue_ref[i].lock().unwrap().take().expect("item taken twice");
                let result = f_ref(i, item);
                *slots_ref[i].lock().unwrap() = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("worker died before filling slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_matches_serial_for_any_thread_count() {
        let items: Vec<u64> = (0..57).collect();
        let serial: Vec<u64> = par_map(items.clone(), 1, |i, x| x * 3 + i as u64);
        for threads in [2, 4, 16] {
            let parallel: Vec<u64> = par_map(items.clone(), threads, |i, x| x * 3 + i as u64);
            assert_eq!(parallel, serial, "threads={threads}");
        }
    }

    #[test]
    fn more_workers_than_items_is_fine() {
        let out: Vec<usize> = par_map(vec![7usize, 8], 32, |_, x| x + 1);
        assert_eq!(out, vec![8, 9]);
    }

    #[test]
    fn empty_input_yields_empty_output() {
        let out: Vec<u32> = par_map(Vec::<u32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn thread_count_prefers_config() {
        assert_eq!(thread_count(3), 3);
        assert!(thread_count(0) >= 1);
    }
}
