//! The federated round loop — Algorithm 1's outer `for t = 0..T` — and
//! the owner of the transport: an event-driven round engine
//! (DESIGN.md §3, §9).
//!
//! Owns everything mutable (network, RNG, algorithm state). Each round
//! is *planned* first ([`engine::plan_round`]): the (over-)selected
//! cohort S̃^t is sampled uniformly without replacement (the setting of
//! Lemma 6 / Theorem 1), every selected client's channel draws its fate
//! (dropout) and uplink latency, the deadline/target-count rule fixes
//! the delivered set, and p_k renormalizes over what will actually
//! arrive. The plan is pure simulated time — a function of
//! `(config, seed, t)` only. Execution then streams:
//!
//! 1. `server_broadcast` → one metered, independently-noisy delivery per
//!    selected client through that client's channel (dropouts included:
//!    the server does not yet know they are gone);
//! 2. `client_round` for every reachable participant, data-parallel
//!    over scoped threads (bit-identical to serial for any thread
//!    count: each client gets an RNG stream forked in selection order
//!    beforehand);
//! 3. each uplink is transported through its sender's channel and —
//!    if it made the deadline/target — absorbed into its edge's
//!    streaming [`RoundAggregator`] shard *in arrival order*, on this
//!    thread, the payload dropped immediately (the cohort is never
//!    stored). Under the default `flat` topology there is exactly one
//!    shard; under `edge:E` each of the E edge aggregators owns an O(m)
//!    shard and ships one compact merge frame to the root, which merges
//!    the shards in canonical edge order — bit-identical to the flat
//!    server for the exact tally kinds (DESIGN.md §11);
//! 4. `finish_aggregate` folds the closed (merged) aggregator into
//!    server state — under quorum mode (DESIGN.md §13) stale uplinks
//!    carried over from the previous round's close absorb at the root
//!    first, at their staleness-decayed share of the same
//!    renormalization mass;
//! 5. optional `server_notify` broadcast to the reachable participants.
//!
//! Algorithms never see the network or the topology; the hierarchical
//! edge tier slots in behind steps 1/3/5 exactly the way §3 promised a
//! sharded-server transport would. The coordinator itself is generic
//! over [`Transport`] — `SimNetwork` is the default type parameter, and
//! [`Coordinator::with_transport`] drops a socket-backed
//! [`StreamTransport`](crate::comm::StreamTransport) behind the same
//! internals (DESIGN.md §12).
//!
//! [`RoundAggregator`]: crate::algorithms::RoundAggregator

pub mod checkpoint;
pub mod engine;
pub mod evaluator;
pub mod metrics;
pub mod parallel;

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::algorithms::{
    Algorithm, BatchCtx, BatchTask, CarriedUplink, ClientCtx, ClientOutput, InitCtx,
    RoundAggregator, RoundOutcome, ServerCtx,
};
use crate::comm::{Downlink, SimNetwork, Transport};
use crate::config::{ProjectionKind, RunConfig, Topology};
use crate::data::{generate, FederatedData};
use crate::runtime::ModelRuntime;
use crate::sketch::{DenseGaussianOperator, Projection, SignVec, SrhtOperator};
use crate::util::rng::Rng;

pub use checkpoint::Checkpoint;
pub use engine::{plan_round, plan_round_buffered, Arrival, RoundPlan};
pub use evaluator::{evaluate, evaluate_per_client, EvalResult};
pub use metrics::{History, RoundRecord};

/// Result of a full training run.
pub struct RunResult {
    /// every round's record (losses, bytes, lifecycle counters)
    pub history: History,
    /// personalized test accuracy at the last evaluated round
    pub final_accuracy: f64,
    /// test loss at the last evaluated round
    pub final_loss: f64,
    /// mean per-round communication in MB (the Table 2 cost metric)
    pub mean_round_mb: f64,
    /// which algorithm produced this run
    pub algorithm: String,
}

/// One client's pre-forked inputs for the parallel phase.
struct ClientTask {
    k: usize,
    rng: Rng,
    downlink: Option<Downlink>,
}

/// Scopes the one thread-safety assertion the type system cannot see:
/// the `xla` PJRT wrapper types hold raw FFI pointers, which suppresses
/// the auto traits, but PJRT clients and loaded executables are
/// documented thread-safe for concurrent `Execute` calls — and the
/// client phase only ever calls `&self` execution methods on the
/// runtime. Everything else captured by the parallel closure is checked
/// by the compiler (`par_map_consume` requires `F: Sync`).
struct SyncRuntime<'a>(&'a ModelRuntime);
// SAFETY: see the struct docs — shared-reference use of the PJRT
// execution methods is concurrency-safe per the PJRT API contract.
unsafe impl Sync for SyncRuntime<'_> {}

/// Drives one (algorithm × dataset × seed) training run. Generic over
/// the [`Transport`] carrying its bytes; defaults to the in-process
/// [`SimNetwork`], so every existing call site and golden trace is
/// unchanged.
pub struct Coordinator<'a, N: Transport = SimNetwork> {
    /// the run's full configuration
    pub cfg: RunConfig,
    /// the generated federated dataset (per-client shards + weights)
    pub data: FederatedData,
    /// compiled model runtime shared across runs of a sweep
    pub model: &'a ModelRuntime,
    /// the transport carrying this run's bytes (channels/sockets, byte
    /// metering, lifecycle streams)
    pub net: N,
    /// rust-side mirror of Φ for baselines and server-side work
    pub projection: Projection,
    /// when set, save a checkpoint to `.0` every `.1` rounds
    pub checkpoint: Option<(String, usize)>,
    rng: Rng,
    /// root-resident stale uplinks buffered past the previous round's
    /// quorum close, awaiting absorption into the next round at their
    /// staleness-decayed weights (DESIGN.md §13). Empty for barrier
    /// rounds — the default knobs never populate it.
    carry: Vec<CarriedUplink>,
}

impl<'a> Coordinator<'a, SimNetwork> {
    /// Build coordinator state for `cfg` against an already-loaded model
    /// runtime (model runtimes are expensive to compile, so experiment
    /// sweeps share them across runs), on the default simulated network.
    pub fn new(cfg: RunConfig, model: &'a ModelRuntime) -> Coordinator<'a> {
        let net = SimNetwork::new(cfg.seed);
        Coordinator::with_transport(cfg, model, net)
    }

    /// The shared SRHT realization for this run's seed (what the HLO
    /// artifacts must be fed). Panics if configured for dense projection.
    pub fn srht_operator(cfg: &RunConfig, n: usize, m: usize) -> SrhtOperator {
        SrhtOperator::from_seed(cfg.seed, n, m)
    }
}

impl<'a, N: Transport> Coordinator<'a, N> {
    /// As [`Coordinator::new`], but over a caller-supplied transport —
    /// how a socket-backed [`StreamTransport`](crate::comm::StreamTransport)
    /// slots in behind the unchanged round loop (DESIGN.md §12). The
    /// dataset, projection, and RNG derivations are identical for every
    /// transport, so two runs differing only in `net` are comparable
    /// bit for bit.
    pub fn with_transport(cfg: RunConfig, model: &'a ModelRuntime, net: N) -> Coordinator<'a, N> {
        let spec = cfg.dataset.spec();
        let data = generate(&spec, cfg.clients, &cfg.make_partition(), cfg.seed);
        let projection = match cfg.projection {
            ProjectionKind::Fht => Projection::Srht(SrhtOperator::from_seed(
                cfg.seed,
                model.geom.n,
                model.geom.m,
            )),
            ProjectionKind::DenseGaussian => Projection::Dense(DenseGaussianOperator::from_seed(
                cfg.seed,
                model.geom.n,
                model.geom.m,
            )),
        };
        let rng = Rng::new(cfg.seed ^ 0x434F_4F52); // "COOR"
        Coordinator { cfg, data, model, net, projection, checkpoint: None, rng, carry: Vec::new() }
    }

    /// One-time algorithm setup against this coordinator's geometry.
    pub fn init_algorithm(&self, alg: &mut dyn Algorithm) -> Result<()> {
        alg.init(&InitCtx {
            model: self.model,
            data: &self.data,
            cfg: &self.cfg,
            projection: &self.projection,
        })
    }

    /// Drive one fully-delivered protocol round `t` over `selected` with
    /// caller-supplied weights — no over-selection, latency, dropout, or
    /// deadline modeling (does not close the ledger round — callers pair
    /// this with `net.end_round()`). Benches and budget-loop examples
    /// drive rounds through this; the training loop plans scenario
    /// rounds via [`engine::plan_round`] and [`Coordinator::run_round_plan`].
    pub fn run_round(
        &mut self,
        alg: &mut dyn Algorithm,
        t: usize,
        selected: &[usize],
        weights: &[f32],
    ) -> Result<RoundOutcome> {
        anyhow::ensure!(
            selected.len() == weights.len(),
            "round {t}: {} participants but {} weights",
            selected.len(),
            weights.len()
        );
        let plan = RoundPlan::full_delivery(t, selected.to_vec(), weights.to_vec());
        self.run_round_plan(alg, &plan).map(|(outcome, _)| outcome)
    }

    /// Execute a planned round: broadcast, data-parallel client phase,
    /// streaming arrival-order aggregation, finish, notify. Returns the
    /// round outcome and the aggregate-phase wall time in ms (absorbs +
    /// finish — the server-side cost the metrics CSV reports).
    pub fn run_round_plan(
        &mut self,
        alg: &mut dyn Algorithm,
        plan: &RoundPlan,
    ) -> Result<(RoundOutcome, f64)> {
        let t = plan.t;
        anyhow::ensure!(
            !plan.selected.is_empty(),
            "round {t}: empty participant set (validate the config before running)"
        );
        // stale uplinks buffered past the previous round's close join
        // this round at the root (DESIGN.md §13); taken now so the
        // borrow checker sees `self.carry` free for the re-stash below
        let carried = std::mem::take(&mut self.carry);

        // phase 1: broadcast — one independent delivery per selected
        // client, dropouts included (the server cannot know yet); only
        // reachable clients become compute tasks. Forks happen in
        // selection order, before the parallel section: determinism for
        // any thread count.
        let topo = self.cfg.topology;
        let broadcast = alg.server_broadcast(t);
        // hierarchical fan-out (DESIGN.md §11): the root ships one copy
        // to every edge with at least one selected client (the root
        // sampled the cohort, so it knows the derived assignment; it
        // cannot yet know about dropouts), then each edge fans out to
        // its clients through the per-client channels below.
        if let Some(d) = &broadcast {
            for e in active_edges(topo, &plan.selected) {
                self.net.edge_downlink(e, &d.payload)?;
            }
        }
        let mut tasks: Vec<ClientTask> = Vec::with_capacity(plan.computing.len());
        let mut next_computing = plan.computing.iter().peekable();
        for &k in &plan.selected {
            let delivered = match &broadcast {
                Some(d) => Some(Downlink::new(d.round, self.net.downlink_to(k, &d.payload)?)),
                None => None,
            };
            if next_computing.peek() == Some(&&k) {
                next_computing.next();
                let rng = self.rng.fork(client_stream_tag(t, k));
                tasks.push(ClientTask { k, rng, downlink: delivered });
            }
        }

        // phases 2+3: data-parallel client rounds, consumed on THIS
        // thread in simulated-arrival order — each uplink is transported
        // and folded into its edge's streaming aggregator shard the
        // moment it arrives, then dropped. Under `flat` there is exactly
        // one shard and this is byte-for-byte the single-server absorb
        // loop; under `edge:E` each shard receives its own clients'
        // uplinks in arrival order (the global arrival walk restricted
        // to one edge IS that edge's arrival order). The closure is
        // `Sync`-checked by `par_map_consume`; only the PJRT handle
        // needs the scoped `SyncRuntime` assertion.
        let threads = parallel::thread_count(self.cfg.client_threads);
        let model = SyncRuntime(self.model);
        let data = &self.data;
        let cfg = &self.cfg;
        let projection = &self.projection;
        let alg_shared: &dyn Algorithm = alg;
        let mut shards: Vec<RoundAggregator> =
            (0..topo.shards()).map(|_| alg_shared.begin_aggregate(t)).collect();
        let order: Vec<usize> = plan.arrivals.iter().map(|a| a.task).collect();
        let net = &mut self.net;
        let mut agg_time = Duration::ZERO;
        let mut arrivals = plan.arrivals.iter();
        // the arrival-order absorb body, shared verbatim by the
        // per-client and device-batched paths below
        let mut consume = |task_idx: usize, result: Result<ClientOutput>| -> Result<()> {
            let arrival = arrivals.next().expect("one arrival per consumed task");
            debug_assert_eq!(arrival.task, task_idx);
            let mut out = result.with_context(|| format!("client phase of round {t}"))?;
            // Byzantine corruption (DESIGN.md §16) lands AFTER honest
            // local compute and BEFORE the wire: the adversary's
            // personalized state evolves normally, but the bytes it
            // ships — and the wire ledger bills — are the corrupted ones
            if arrival.adversarial {
                if let Some(up) = out.uplink.as_mut() {
                    engine::corrupt_payload(&mut up.payload, &cfg.attack, cfg.seed, t);
                }
            }
            // the uplink is transported (metered, noise-corrupted)
            // whether or not the deadline cuts it: the bytes were
            // spent on the link either way
            if let Some(up) = out.uplink.as_mut() {
                up.payload = net.uplink_from(out.client, &up.payload)?;
            }
            let started = Instant::now();
            let shard = &mut shards[topo.edge_of(out.client)];
            if arrival.accepted {
                shard
                    .absorb(out, arrival.weight)
                    .with_context(|| format!("absorbing round-{t} uplink"))?;
            } else if arrival.buffered {
                // missed the quorum close but within max-staleness:
                // the write-back lands now, the payload is buffered
                // for round t+1 at its decayed raw mass
                // p_k · staleness_decay^age (DESIGN.md §13)
                let raw = data.weights[out.client]
                    * (cfg.staleness_decay as f32).powi(arrival.staleness as i32);
                shard.buffer_late(out, raw, arrival.staleness);
            } else {
                // straggler (or stranded on a failed edge): payload
                // discarded, local state kept
                shard.absorb_cut(out);
            }
            agg_time += started.elapsed();
            Ok(())
        };
        // Device-batched grouping (DESIGN.md §15): when the loaded
        // runtime carries cohort-batched executables AND the algorithm
        // can pack a group, consecutive groups of ≤ B tasks (selection
        // order) each run as one stacked dispatch chain; group outputs
        // concatenate back to per-task order and the IDENTICAL consume
        // body replays in simulated-arrival order. `device_batch() == 1`
        // (the default load) never enters this branch, so the per-client
        // path below remains byte-for-byte today's code.
        let device_batch =
            if alg_shared.supports_batched_rounds() { self.model.device_batch() } else { 1 };
        if device_batch > 1 {
            let n_tasks = tasks.len();
            let mut groups: Vec<Vec<ClientTask>> = Vec::with_capacity(n_tasks.div_ceil(device_batch));
            let mut tasks = tasks;
            while !tasks.is_empty() {
                let tail = tasks.split_off(device_batch.min(tasks.len()));
                groups.push(std::mem::replace(&mut tasks, tail));
            }
            let results = parallel::par_map(groups, threads, |_, group: Vec<ClientTask>| {
                let batch: Vec<BatchTask> = group
                    .into_iter()
                    .map(|ClientTask { k, rng, downlink }| BatchTask { k, rng, downlink })
                    .collect();
                let ctx = BatchCtx { model: model.0, data, cfg, projection };
                alg_shared.client_round_batched(t, batch, &ctx)
            });
            let mut slots: Vec<Option<ClientOutput>> = Vec::with_capacity(n_tasks);
            for res in results {
                let outs =
                    res.with_context(|| format!("batched client phase of round {t}"))?;
                slots.extend(outs.into_iter().map(Some));
            }
            anyhow::ensure!(
                slots.len() == n_tasks,
                "batched client phase returned {} outputs for {n_tasks} tasks",
                slots.len()
            );
            for &i in &order {
                let out = slots[i].take().expect("arrival order is a permutation");
                consume(i, Ok(out))?;
            }
        } else {
            parallel::par_map_consume(
                tasks,
                threads,
                &order,
                |_, task: ClientTask| {
                    let ClientTask { k, rng, downlink } = task;
                    let mut ctx = ClientCtx { model: model.0, data, cfg, projection, rng };
                    alg_shared.client_round(t, k, downlink.as_ref(), &mut ctx)
                },
                consume,
            )?;
        }

        // edge → root: every live edge that had compute work ships its
        // O(m) merge frame (metered on the edge tier); a failed edge
        // missed the round and ships nothing. The root then merges ALL
        // shards in canonical edge order — bit-identical to the flat
        // absorb loop for the exact tally kinds (DESIGN.md §11); failed
        // edges contribute only their clients' personalized write-backs,
        // which are simulation bookkeeping and never crossed the wire.
        for e in active_edges(topo, &plan.computing) {
            if !plan.failed_edges.contains(&e) {
                if let Some(frame) = shards[e].merge_payload() {
                    self.net.edge_uplink(e, &frame)?;
                }
            }
        }
        let started = Instant::now();
        let mut shards = shards.into_iter();
        let mut agg = shards.next().expect("topology has at least one shard");
        for shard in shards {
            agg.merge(shard)
                .with_context(|| format!("merging round-{t} edge shards"))?;
        }
        // carried-in stale uplinks absorb at the ROOT: the carry buffer
        // was drained from the previous round's merged aggregator, so
        // it never re-crosses the edge tier and edge failures cannot
        // touch it (DESIGN.md §13). Each absorbs at raw/norm_total —
        // the same mass the engine's renormalization spanned. When the
        // all-dropped guard zeroed norm_total, the carry drops with the
        // round (server state untouched). The default knobs leave
        // `carried` empty, so this loop is bit-free for barrier rounds.
        for c in carried {
            if plan.norm_total > 0.0 {
                agg.absorb(c.out, c.raw_weight / plan.norm_total)
                    .with_context(|| format!("absorbing round-{t} carried-in uplink"))?;
            }
        }
        // stash this round's buffered lates (edge carries concatenated
        // in canonical merge order) for round t+1
        self.carry = agg.take_carry();
        agg_time += started.elapsed();

        // phase 4: fold the closed aggregator into server state
        let started = Instant::now();
        let outcome = alg.finish_aggregate(
            t,
            agg,
            &ServerCtx { cfg: &self.cfg, projection: &self.projection },
        )?;
        agg_time += started.elapsed();

        // phase 5: optional end-of-round broadcast to every reachable
        // participant (metered per recipient; the simulated stateless
        // clients discard it — dropouts are unreachable and skipped).
        // Under `edge:E` the note first hops root → edge for every edge
        // with reachable clients, like the pre-round broadcast.
        if let Some(note) = alg.server_notify(t) {
            for e in active_edges(topo, &plan.computing) {
                self.net.edge_downlink(e, &note.payload)?;
            }
            for &k in &plan.computing {
                self.net.downlink_to(k, &note.payload)?;
            }
        }
        Ok((outcome, agg_time.as_secs_f64() * 1e3))
    }

    /// Run the full T-round training loop.
    pub fn run(&mut self, alg: &mut dyn Algorithm) -> Result<RunResult> {
        self.run_with_diagnostics(alg, false)
    }

    /// As `run`, optionally recording the Theorem-1 gradient-norm
    /// diagnostic each eval round (extra forward/backward cost).
    pub fn run_with_diagnostics(
        &mut self,
        alg: &mut dyn Algorithm,
        grad_diag: bool,
    ) -> Result<RunResult> {
        // catch degenerate configs (participating = 0, …) here with a
        // clear error instead of a NaN/panic deep in the round loop
        self.cfg.validate().context("invalid run configuration")?;
        self.init_algorithm(alg)?;

        let mut history = History::default();
        // previous round's packed consensus, for the Hamming-flip
        // diagnostic (popcount over the packed words — no unpack)
        let mut prev_consensus: Option<SignVec> = None;
        for t in 0..self.cfg.rounds {
            let started = Instant::now();
            // raw mass of the stale uplinks about to join this round —
            // the engine folds it into the renormalization so delivered
            // + carried weights share one normalizer (DESIGN.md §13);
            // 0.0 (empty carry) makes this call exactly `plan_round`
            let carry_mass: f32 = self.carry.iter().map(|c| c.raw_weight).sum();
            let plan = engine::plan_round_buffered(
                t,
                &self.cfg,
                &self.data.weights,
                carry_mass,
                &mut self.net,
                &mut self.rng,
            );
            let stale_weight = if plan.norm_total > 0.0 {
                (carry_mass / plan.norm_total) as f64
            } else {
                0.0
            };
            let (outcome, aggregate_ms) = self.run_round_plan(alg, &plan)?;
            let bytes = self.net.end_round();

            let consensus_flips = alg.consensus_packed().and_then(|cur| {
                let flips = prev_consensus.as_ref().map(|prev| prev.hamming(cur));
                prev_consensus = Some(cur.clone());
                flips
            });

            let is_eval_round =
                t % self.cfg.eval_every == 0 || t + 1 == self.cfg.rounds;
            let (test_acc, test_loss) = if is_eval_round {
                let ev = evaluate(self.model, &self.data, alg)?;
                (Some(ev.accuracy), Some(ev.mean_loss))
            } else {
                (None, None)
            };

            let grad_norm = if grad_diag && is_eval_round {
                // over the DELIVERED set, like every other round metric:
                // dropouts did no local work and cut stragglers never
                // entered server state this round
                let delivered: Vec<usize> = plan
                    .arrivals
                    .iter()
                    .filter(|a| a.accepted)
                    .map(|a| a.client)
                    .collect();
                Some(self.gradient_diagnostic(alg, &delivered)?)
            } else {
                None
            };

            history.push(RoundRecord {
                round: t,
                train_loss: outcome.train_loss,
                test_acc,
                test_loss,
                bytes,
                duration_ms: started.elapsed().as_secs_f64() * 1e3,
                grad_norm,
                consensus_flips,
                delivered: plan.delivered,
                stragglers_cut: plan.stragglers_cut,
                aggregate_ms,
                edges: self.cfg.topology.edges(),
                quorum_closed: plan.quorum_closed,
                buffered_late: plan.buffered_late,
                stale_weight,
                adversaries: plan.adversaries,
            });
            if let Some((path, every)) = &self.checkpoint {
                if (t + 1) % every == 0 || t + 1 == self.cfg.rounds {
                    let (models, consensus) = alg.snapshot();
                    if !models.is_empty() {
                        Checkpoint {
                            round: t as u64 + 1,
                            seed: self.cfg.seed,
                            edges: self.cfg.topology.edges() as u32,
                            consensus,
                            models,
                            residuals: alg.snapshot_aux(),
                        }
                        .save(path)?;
                        crate::debug!("checkpoint saved to {path} at round {t}");
                    }
                }
            }
            crate::info!(
                "[{}] round {t}/{}: train_loss={:.4}{} bytes={}{}",
                alg.name(),
                self.cfg.rounds,
                outcome.train_loss,
                test_acc
                    .map(|a| format!(" acc={:.4}", a))
                    .unwrap_or_default(),
                bytes.total(),
                if self.cfg.has_scenario() {
                    format!(
                        " delivered={}/{} cut={} dropped={}{}{}",
                        plan.delivered,
                        plan.selected.len(),
                        plan.stragglers_cut,
                        plan.dropped,
                        if plan.buffered_late > 0 {
                            format!(" buffered={}", plan.buffered_late)
                        } else {
                            String::new()
                        },
                        if plan.failed_edges.is_empty() {
                            String::new()
                        } else {
                            format!(" edges_failed={:?}", plan.failed_edges)
                        }
                    )
                } else {
                    String::new()
                },
            );
        }

        Ok(RunResult {
            final_accuracy: history.final_accuracy().unwrap_or(0.0),
            final_loss: history.final_test_loss().unwrap_or(f64::NAN),
            mean_round_mb: history.mean_round_mb(),
            algorithm: alg.name().to_string(),
            history,
        })
    }

    /// Σ_k p_k ‖∇F̃_k(w_k; v)‖² over the sampled clients on one fresh
    /// batch each — the Theorem-1 stationarity measure.
    fn gradient_diagnostic(
        &mut self,
        alg: &dyn Algorithm,
        selected: &[usize],
    ) -> Result<f64> {
        let Some(v) = alg.consensus() else {
            return Ok(f64::NAN); // only meaningful for pFed1BS
        };
        let v = v.to_vec();
        let mut acc = 0.0f64;
        let mut wsum = 0.0f64;
        for &k in selected {
            let client = &self.data.clients[k];
            let mut batches = crate::data::BatchIter::new(
                client,
                self.model.geom.train_batch,
                self.rng.fork(k as u64 ^ 0xD1A6),
            );
            let (x, y) = batches.next_batch();
            let gn = self.model.grad_norm(
                alg.model_for(k),
                x,
                y,
                &v,
                self.cfg.lambda,
                self.cfg.mu,
                self.cfg.gamma,
            )?;
            let p = self.data.weights[k] as f64;
            acc += p * gn as f64;
            wsum += p;
        }
        Ok(acc / wsum.max(1e-12))
    }
}

/// Stream tag for client `k`'s round-`t` RNG fork.
fn client_stream_tag(t: usize, k: usize) -> u64 {
    crate::algorithms::common::hash3(k as u64, t as u64, 0x434C_4953) // "CLIS"
}

/// The edge ids (ascending) that have at least one client in `clients`
/// under `topo`'s derived assignment — which edges the root fans out to
/// or expects merge frames from. Empty under `flat` (no edge tier).
fn active_edges(topo: Topology, clients: &[usize]) -> Vec<usize> {
    let Topology::Edge { edges } = topo else {
        return Vec::new();
    };
    let mut active = vec![false; edges];
    for &k in clients {
        active[topo.edge_of(k)] = true;
    }
    (0..edges).filter(|&e| active[e]).collect()
}
