//! The federated round loop — Algorithm 1's outer `for t = 0..T`.
//!
//! Owns everything mutable (network, RNG, algorithm state), samples the
//! participant set S^t uniformly without replacement (the setting of
//! Lemma 6 / Theorem 1), normalizes the aggregation weights p_k over the
//! subset, dispatches the round to the algorithm, and records metrics.

pub mod checkpoint;
pub mod evaluator;
pub mod metrics;

use std::time::Instant;

use anyhow::Result;

use crate::algorithms::{Algorithm, Ctx};
use crate::comm::SimNetwork;
use crate::config::{ProjectionKind, RunConfig};
use crate::data::{generate, FederatedData};
use crate::runtime::ModelRuntime;
use crate::sketch::{DenseGaussianOperator, Projection, SrhtOperator};
use crate::util::rng::Rng;

pub use checkpoint::Checkpoint;
pub use evaluator::{evaluate, evaluate_per_client, EvalResult};
pub use metrics::{History, RoundRecord};

/// Result of a full training run.
pub struct RunResult {
    pub history: History,
    pub final_accuracy: f64,
    pub final_loss: f64,
    pub mean_round_mb: f64,
    pub algorithm: String,
}

/// Drives one (algorithm × dataset × seed) training run.
pub struct Coordinator<'a> {
    pub cfg: RunConfig,
    pub data: FederatedData,
    pub model: &'a ModelRuntime,
    pub net: SimNetwork,
    pub projection: Projection,
    /// when set, save a checkpoint to `.0` every `.1` rounds
    pub checkpoint: Option<(String, usize)>,
    rng: Rng,
}

impl<'a> Coordinator<'a> {
    /// Build coordinator state for `cfg` against an already-loaded model
    /// runtime (model runtimes are expensive to compile, so experiment
    /// sweeps share them across runs).
    pub fn new(cfg: RunConfig, model: &'a ModelRuntime) -> Coordinator<'a> {
        let spec = cfg.dataset.spec();
        let data = generate(&spec, cfg.clients, &cfg.make_partition(), cfg.seed);
        let projection = match cfg.projection {
            ProjectionKind::Fht => Projection::Srht(SrhtOperator::from_seed(
                cfg.seed,
                model.geom.n,
                model.geom.m,
            )),
            ProjectionKind::DenseGaussian => Projection::Dense(DenseGaussianOperator::from_seed(
                cfg.seed,
                model.geom.n,
                model.geom.m,
            )),
        };
        let net = SimNetwork::new(cfg.seed);
        let rng = Rng::new(cfg.seed ^ 0x434F_4F52); // "COOR"
        Coordinator { cfg, data, model, net, projection, checkpoint: None, rng }
    }

    /// The shared SRHT realization for this run's seed (what the HLO
    /// artifacts must be fed). Panics if configured for dense projection.
    pub fn srht_operator(cfg: &RunConfig, n: usize, m: usize) -> SrhtOperator {
        SrhtOperator::from_seed(cfg.seed, n, m)
    }

    /// Sample S^t uniformly without replacement and normalize p_k over it.
    fn sample_round(&mut self) -> (Vec<usize>, Vec<f32>) {
        let selected = self
            .rng
            .sample_without_replacement(self.cfg.clients, self.cfg.participating);
        let raw: Vec<f32> = selected.iter().map(|&k| self.data.weights[k]).collect();
        let total: f32 = raw.iter().sum();
        let weights = raw.iter().map(|&p| p / total).collect();
        (selected, weights)
    }

    /// Run the full T-round training loop.
    pub fn run(&mut self, alg: &mut dyn Algorithm) -> Result<RunResult> {
        self.run_with_diagnostics(alg, false)
    }

    /// As `run`, optionally recording the Theorem-1 gradient-norm
    /// diagnostic each eval round (extra forward/backward cost).
    pub fn run_with_diagnostics(
        &mut self,
        alg: &mut dyn Algorithm,
        grad_diag: bool,
    ) -> Result<RunResult> {
        {
            let mut ctx = Ctx {
                model: self.model,
                data: &self.data,
                cfg: &self.cfg,
                net: &mut self.net,
                rng: &mut self.rng,
                projection: &self.projection,
            };
            alg.init(&mut ctx)?;
        }

        let mut history = History::default();
        for t in 0..self.cfg.rounds {
            let started = Instant::now();
            let (selected, weights) = self.sample_round();
            let outcome = {
                let mut ctx = Ctx {
                    model: self.model,
                    data: &self.data,
                    cfg: &self.cfg,
                    net: &mut self.net,
                    rng: &mut self.rng,
                    projection: &self.projection,
                };
                alg.round(t, &selected, &weights, &mut ctx)?
            };
            let bytes = self.net.end_round();

            let is_eval_round =
                t % self.cfg.eval_every == 0 || t + 1 == self.cfg.rounds;
            let (test_acc, test_loss) = if is_eval_round {
                let ev = evaluate(self.model, &self.data, alg)?;
                (Some(ev.accuracy), Some(ev.mean_loss))
            } else {
                (None, None)
            };

            let grad_norm = if grad_diag && is_eval_round {
                Some(self.gradient_diagnostic(alg, &selected)?)
            } else {
                None
            };

            history.push(RoundRecord {
                round: t,
                train_loss: outcome.train_loss,
                test_acc,
                test_loss,
                bytes,
                duration_ms: started.elapsed().as_secs_f64() * 1e3,
                grad_norm,
            });
            if let Some((path, every)) = &self.checkpoint {
                if (t + 1) % every == 0 || t + 1 == self.cfg.rounds {
                    let (models, consensus) = alg.snapshot();
                    if !models.is_empty() {
                        Checkpoint {
                            round: t as u64 + 1,
                            seed: self.cfg.seed,
                            consensus,
                            models,
                        }
                        .save(path)?;
                        crate::debug!("checkpoint saved to {path} at round {t}");
                    }
                }
            }
            crate::info!(
                "[{}] round {t}/{}: train_loss={:.4}{} bytes={}",
                alg.name(),
                self.cfg.rounds,
                outcome.train_loss,
                test_acc
                    .map(|a| format!(" acc={:.4}", a))
                    .unwrap_or_default(),
                bytes.total(),
            );
        }

        Ok(RunResult {
            final_accuracy: history.final_accuracy().unwrap_or(0.0),
            final_loss: history.final_test_loss().unwrap_or(f64::NAN),
            mean_round_mb: history.mean_round_mb(),
            algorithm: alg.name().to_string(),
            history,
        })
    }

    /// Σ_k p_k ‖∇F̃_k(w_k; v)‖² over the sampled clients on one fresh
    /// batch each — the Theorem-1 stationarity measure.
    fn gradient_diagnostic(
        &mut self,
        alg: &dyn Algorithm,
        selected: &[usize],
    ) -> Result<f64> {
        let Some(v) = alg.consensus() else {
            return Ok(f64::NAN); // only meaningful for pFed1BS
        };
        let v = v.to_vec();
        let mut acc = 0.0f64;
        let mut wsum = 0.0f64;
        for &k in selected {
            let client = &self.data.clients[k];
            let mut batches = crate::data::BatchIter::new(
                client,
                self.model.geom.train_batch,
                self.rng.fork(k as u64 ^ 0xD1A6),
            );
            let (x, y) = batches.next_batch();
            let gn = self.model.grad_norm(
                alg.model_for(k),
                x,
                y,
                &v,
                self.cfg.lambda,
                self.cfg.mu,
                self.cfg.gamma,
            )?;
            let p = self.data.weights[k] as f64;
            acc += p * gn as f64;
            wsum += p;
        }
        Ok(acc / wsum.max(1e-12))
    }
}
