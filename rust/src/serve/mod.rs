//! Multi-process transport roles (DESIGN.md §12): the root server
//! (`pfed1bs serve`), edge aggregator (`pfed1bs edge`), multiplexed mock
//! client fleet (`pfed1bs client-fleet`), and load generator
//! (`pfed1bs loadgen`) — one machine running a real client→edge→root
//! round over TCP or Unix-domain sockets.
//!
//! The protocol is the paper's, with deterministic *mock* clients in
//! place of the PJRT compute stack (no artifacts needed, so CI can smoke
//! the wire path anywhere): every process derives the same client
//! selections and sketches from the seed the root's WELCOME announces,
//! and each round's sketches are keyed on the *received* consensus — so
//! the final consensus is a checksum of every byte of every round, and
//! any corruption anywhere in the chain diverges it. The root's
//! `--check-consensus` recomputes the run in-process
//! ([`reference_consensus`]) and fails unless the socket run matches bit
//! for bit; that is the CI smoke job's assertion.
//!
//! Aggregation is the real thing: the root (and each edge) folds
//! uplinks into the exact 64.64 fixed-point [`VoteAccumulator`], edges
//! ship the same `Payload::TallyFrame` merge frames the in-process
//! hierarchy uses, and order-invariance makes absorb-on-arrival over
//! real sockets bit-identical to any serial schedule.
//!
//! With `--quorum` below the cohort the root runs the asynchronous
//! quorum protocol of DESIGN.md §13: each round closes after a
//! deterministic selection-order quorum, and the remaining
//! designated-late uplinks join the *next* round's tally at weight
//! `--staleness-decay` — checked bit for bit against
//! [`reference_consensus_quorum`].

use std::collections::{HashMap, HashSet};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::algorithms::common::hash3;
use crate::comm::codec::{frame_bytes, Payload, PayloadView, TallyFrame};
use crate::comm::transport::frame::{
    decode_body_borrowed, kind_name, Frame, FrameView, Hello, PeerRole, Welcome, KIND_BYE,
};
use crate::comm::transport::stream::{connect, FramedConn, Listener, Tuning};
use crate::config::{Endpoint, ServeConfig, ServeRole};
use crate::sketch::{packed_bytes, SignVec, VoteAccumulator};
use crate::util::rng::Rng;
use crate::util::stats::percentile_nearest_rank;

/// Sentinel reader index for an edge's upstream (root-facing) link.
const ROOT: usize = usize::MAX;

/// The deterministic per-round cohort every process derives from the
/// announced seed: round `t`'s selection is the `t`-th draw of a
/// persistent seed-keyed stream (fresh uniform sample each round).
pub fn mock_selections(
    seed: u64,
    clients: usize,
    participating: usize,
    rounds: usize,
) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed ^ 0x5345_5256); // "SERV"
    (0..rounds)
        .map(|_| rng.sample_without_replacement(clients, participating))
        .collect()
}

/// Client `k`'s round-`t` mock sketch. Keyed on the *received* consensus
/// words (hash-folded into the stream seed), so the sketch — and with it
/// every later round — diverges if any downlink bit was corrupted
/// anywhere on the wire: the final consensus is an end-to-end checksum.
pub fn mock_sketch(seed: u64, m: usize, client: u32, round: u32, consensus: &SignVec) -> SignVec {
    let mut h = seed ^ 0x4D4F_434B; // "MOCK"
    for w in consensus.words() {
        h = hash3(h, *w, 0x5348_4153); // "SHAS"
    }
    let mut rng = Rng::new(hash3(client as u64, round as u64, h));
    let words = (0..packed_bytes(m) / 8).map(|_| rng.next_u64()).collect();
    SignVec::from_words(words, m)
}

/// The in-process replay of a full mock run: what the socket run's final
/// consensus must equal bit for bit (the `--check-consensus` oracle and
/// the CI smoke assertion). Uniform weight 1.0 per delivered sketch,
/// ties toward +1 — the same [`VoteAccumulator`] the real server uses.
pub fn reference_consensus(
    seed: u64,
    m: usize,
    clients: usize,
    participating: usize,
    rounds: usize,
) -> SignVec {
    reference_consensus_quorum(seed, m, clients, participating, rounds, 0, 0.5)
}

/// As [`reference_consensus`], but replaying the quorum protocol
/// (DESIGN.md §13): each round absorbs the *previous* round's
/// designated-late sketches at weight `decay` — keyed on the consensus
/// that round broadcast, exactly as the wire clients computed them —
/// then the first `quorum` selected clients at weight 1.0. The final
/// round's lates are drained and discarded on the wire, so they never
/// enter any tally here either. `quorum = 0` (or `= participating`)
/// leaves no lates and reduces to the barrier replay verbatim.
pub fn reference_consensus_quorum(
    seed: u64,
    m: usize,
    clients: usize,
    participating: usize,
    rounds: usize,
    quorum: usize,
    decay: f32,
) -> SignVec {
    let q = if quorum == 0 { participating } else { quorum.min(participating) };
    let selections = mock_selections(seed, clients, participating, rounds);
    let mut consensus = SignVec::from_fn(m, |_| true);
    // the previous round's designated-late sketches, already keyed on
    // the consensus that was live when they were computed
    let mut pending: Vec<SignVec> = Vec::new();
    for (t, sel) in selections.iter().enumerate() {
        let mut acc = VoteAccumulator::new(m);
        for z in pending.drain(..) {
            acc.absorb(&z, decay);
        }
        for &k in &sel[..q] {
            acc.absorb(&mock_sketch(seed, m, k as u32, t as u32, &consensus), 1.0);
        }
        if t + 1 < rounds {
            pending = sel[q..]
                .iter()
                .map(|&k| mock_sketch(seed, m, k as u32, t as u32, &consensus))
                .collect();
        }
        consensus = acc.finish();
    }
    consensus
}

/// Run the role `cfg` describes (the CLI entry point).
pub fn run(cfg: &ServeConfig) -> Result<()> {
    crate::info!("{}", cfg.summary());
    match cfg.role {
        ServeRole::Root => run_root(cfg),
        ServeRole::Edge => run_edge(cfg),
        ServeRole::Fleet => run_fleet(cfg),
        ServeRole::Loadgen => run_loadgen(cfg).map(|_| ()),
    }
}

/// One accepted downstream peer: the write half of its connection plus
/// what its HELLO declared (reader threads own cloned read halves).
struct Peer {
    conn: FramedConn,
    role: PeerRole,
    want_ack: bool,
}

/// Resolve a HELLO's claimed client range against the fleet size
/// (`hi == 0` means "through the whole fleet").
fn resolve_range(hello: &Hello, fleet: usize) -> Result<(usize, usize)> {
    let lo = hello.lo as usize;
    let hi = if hello.hi == 0 { fleet } else { hello.hi as usize };
    ensure!(
        lo < hi && hi <= fleet,
        "peer claims clients {lo}..{hi} of a {fleet}-client fleet"
    );
    Ok((lo, hi))
}

/// Accept downstream peers until every client in `lo..hi` has exactly
/// one owner; overlapping or out-of-range claims are protocol errors.
/// Returns the peers and the owner index of each client (offset by `lo`).
fn accept_peers(
    listener: &Listener,
    tuning: &Tuning,
    welcome: &Welcome,
    lo: usize,
    hi: usize,
    timeout: Duration,
) -> Result<(Vec<Peer>, Vec<usize>)> {
    let fleet = welcome.clients as usize;
    let mut peers: Vec<Peer> = Vec::new();
    let mut owners: Vec<Option<usize>> = vec![None; hi - lo];
    while owners.iter().any(Option::is_none) {
        let mut conn = listener
            .accept_deadline(tuning, timeout)
            .with_context(|| format!("waiting for peers covering clients {lo}..{hi}"))?;
        let hello = conn.handshake_server(welcome)?;
        let (plo, phi) = resolve_range(&hello, fleet)?;
        ensure!(
            plo >= lo && phi <= hi,
            "peer range {plo}..{phi} outside this listener's {lo}..{hi}"
        );
        for k in plo..phi {
            ensure!(owners[k - lo].is_none(), "client {k} claimed by two peers");
            owners[k - lo] = Some(peers.len());
        }
        crate::info!(
            "peer {} connected: {:?} covering clients {plo}..{phi}",
            peers.len(),
            hello.role
        );
        peers.push(Peer { conn, role: hello.role, want_ack: hello.want_ack });
    }
    Ok((peers, owners.into_iter().map(|o| o.expect("coverage loop")).collect()))
}

/// Park a cloned read half in a thread that forwards every raw frame
/// body to `tx` tagged with `idx`. Bodies stay undecoded: receivers
/// parse them in place with [`decode_body_borrowed`] and relays can
/// forward the exact bytes without a re-encode. Exits on connection
/// error or after forwarding BYE (the kind byte is `body[0]`).
fn spawn_reader(
    conn: &FramedConn,
    idx: usize,
    tx: mpsc::Sender<(usize, Vec<u8>)>,
) -> Result<thread::JoinHandle<()>> {
    let mut r = conn.split_reader()?;
    thread::Builder::new()
        .name(format!("pfed1bs-reader-{idx}"))
        .spawn(move || loop {
            match r.recv_body() {
                Ok(body) => {
                    let bye = body.first() == Some(&KIND_BYE);
                    if tx.send((idx, body)).is_err() || bye {
                        break;
                    }
                }
                Err(_) => break, // peer closed or timed out; main decides
            }
        })
        .context("spawning reader thread")
}

/// What a finished root run measured (the serve JSON report).
pub struct RootReport {
    /// the final consensus after the last round
    pub consensus: SignVec,
    /// total sketches absorbed across all rounds (direct + via edges)
    pub absorbed: usize,
    /// client-tier downlink bytes (codec frames, per delivered copy)
    pub downlink_bytes: u64,
    /// client-tier uplink bytes absorbed directly at the root
    pub uplink_bytes: u64,
    /// edge-tier merge-frame bytes
    pub tally_bytes: u64,
    /// wall time from first broadcast to last absorb
    pub elapsed_s: f64,
    /// completed rounds per wall-clock second
    pub rounds_per_sec: f64,
}

impl RootReport {
    /// One-line machine-readable summary (the serve stdout contract).
    pub fn to_json(&self, cfg: &ServeConfig) -> String {
        let ones: u32 = self.consensus.words().iter().map(|w| w.count_ones()).sum();
        format!(
            "{{\"suite\":\"serve\",\"clients\":{},\"participating\":{},\"quorum\":{},\"rounds\":{},\"m\":{},\
             \"absorbed\":{},\"downlink_bytes\":{},\"uplink_bytes\":{},\"tally_bytes\":{},\
             \"consensus_ones\":{ones},\"elapsed_s\":{:.3},\"rounds_per_sec\":{:.3}}}",
            cfg.clients,
            cfg.participating,
            cfg.effective_quorum(),
            cfg.rounds,
            cfg.m,
            self.absorbed,
            self.downlink_bytes,
            self.uplink_bytes,
            self.tally_bytes,
            self.elapsed_s,
            self.rounds_per_sec,
        )
    }
}

/// `pfed1bs serve`: bind the configured endpoint, drive the run, print
/// the JSON report.
pub fn run_root(cfg: &ServeConfig) -> Result<()> {
    let ep = cfg.listen.as_ref().expect("validated: root listens");
    let listener = Listener::bind(ep)?;
    let report = run_root_on(&listener, cfg)?;
    println!("{}", report.to_json(cfg));
    Ok(())
}

/// Root body over an already-bound listener (tests bind `tcp:…:0` and
/// pass the resolved listener in). Accepts peers until the whole fleet
/// `0..K` is owned, then runs `T` rounds: broadcast the consensus to the
/// selected cohort, absorb exactly `S` sketches (direct uplinks and/or
/// edge merge frames), sign the tally, repeat; finally BYE every peer.
/// With `check_consensus`, fails unless the result equals
/// [`reference_consensus`] bit for bit.
///
/// With `--quorum` below the cohort (DESIGN.md §13) the round closes
/// after the first `quorum` clients *in selection order* plus the
/// previous round's designated lates: the remaining `S − quorum`
/// clients of each round are designated late, their uplinks are
/// stashed when they arrive early and awaited at the next round's
/// close, absorbed at weight `staleness_decay`. Selection-order
/// designation keeps the protocol deterministic — both sides and the
/// [`reference_consensus_quorum`] oracle agree on who is late without
/// any wall-clock race deciding membership — while the root genuinely
/// never waits on a designated-late socket to close a round. Quorum
/// mode requires direct clients (an edge answers for its whole range
/// with one indivisible merge frame).
pub fn run_root_on(listener: &Listener, cfg: &ServeConfig) -> Result<RootReport> {
    let tuning = cfg.tuning();
    let timeout = Duration::from_millis(cfg.timeout_ms);
    let welcome = Welcome {
        m: cfg.m as u32,
        seed: cfg.seed,
        rounds: cfg.rounds as u32,
        participating: cfg.participating as u32,
        clients: cfg.clients as u32,
    };
    let (mut peers, owners) = accept_peers(listener, &tuning, &welcome, 0, cfg.clients, timeout)?;
    let (tx, rx) = mpsc::channel();
    let readers: Vec<_> = peers
        .iter()
        .enumerate()
        .map(|(i, p)| spawn_reader(&p.conn, i, tx.clone()))
        .collect::<Result<_>>()?;
    drop(tx);

    let m = cfg.m;
    let quorum = cfg.effective_quorum();
    let decay = cfg.staleness_decay as f32;
    if cfg.quorum_active() {
        ensure!(
            peers.iter().all(|p| p.role != PeerRole::Edge),
            "quorum mode requires direct clients: an edge answers for its whole \
             range with one indivisible merge frame the root cannot close early"
        );
    }
    let selections = mock_selections(cfg.seed, cfg.clients, cfg.participating, cfg.rounds);
    let mut consensus = SignVec::from_fn(m, |_| true);
    let (mut downlink_bytes, mut uplink_bytes, mut tally_bytes) = (0u64, 0u64, 0u64);
    let mut absorbed_total = 0usize;
    // quorum mode: designated-late sketches that arrived before their
    // absorbing round opened, and the late clients still in flight from
    // the previous round (both empty in barrier mode)
    let mut stash: HashMap<u32, SignVec> = HashMap::new();
    let mut late_wait: HashSet<u32> = HashSet::new();
    let started = Instant::now();
    for (t, sel) in selections.iter().enumerate() {
        let t32 = t as u32;
        let payload = Payload::Signs(consensus.clone());
        // who closes this round: the first `quorum` direct clients in
        // selection order uplink themselves; an edge answers for ALL
        // its selected clients with one merge frame. Designated lates
        // (`sel[quorum..]`) still get the broadcast — they compute and
        // send, the round just does not wait for them.
        let mut want_up: HashSet<u32> = HashSet::new();
        let mut want_tally: HashSet<usize> = HashSet::new();
        let late_set: HashSet<u32> = sel[quorum..].iter().map(|&k| k as u32).collect();
        for (i, &k) in sel.iter().enumerate() {
            let pi = owners[k];
            if peers[pi].role == PeerRole::Edge {
                want_tally.insert(pi);
            } else if i < quorum {
                want_up.insert(k as u32);
            }
            peers[pi]
                .conn
                .send(&Frame::Downlink { round: t32, client: k as u32, payload: payload.clone() })?;
            downlink_bytes += frame_bytes(&payload) as u64;
        }
        let mut acc = VoteAccumulator::new(m);
        // last round's early-arrived lates absorb first (order is
        // irrelevant: the 64.64 tally is exactly order-invariant)
        let mut lates_absorbed = 0usize;
        for (_, z) in stash.drain() {
            acc.absorb(&z, decay);
            lates_absorbed += 1;
        }
        while !want_up.is_empty() || !want_tally.is_empty() || !late_wait.is_empty() {
            let (pi, body) = rx
                .recv_timeout(timeout)
                .with_context(|| format!("round {t}: waiting for uplinks"))?;
            // parse in place: uplink sketches absorb straight out of the
            // receive buffer, only a stashed late is ever materialized
            match decode_body_borrowed(&body)? {
                FrameView::Uplink { round, client, payload } => {
                    // payload bytes on the wire = body minus the
                    // kind/round/peer header (equals frame_bytes)
                    uplink_bytes += (body.len() - 9) as u64;
                    let PayloadView::Signs(z) = payload else {
                        bail!("round {t}: uplink from client {client} was not a packed sketch")
                    };
                    ensure!(z.m() == m, "round {t}: sketch m={} (want {m})", z.m());
                    if round == t32 && want_up.remove(&client) {
                        acc.absorb_view(&z, 1.0);
                    } else if round == t32
                        && late_set.contains(&client)
                        && !stash.contains_key(&client)
                    {
                        // this round's designated late arrived before
                        // close: hold it (owned) for round t+1's tally
                        stash.insert(client, z.to_owned());
                    } else if round + 1 == t32 && late_wait.remove(&client) {
                        // last round's late landing now, one round stale
                        acc.absorb_view(&z, decay);
                        lates_absorbed += 1;
                    } else {
                        bail!(
                            "round {t}: unexpected round-{round} uplink from client {client}"
                        );
                    }
                    if peers[pi].want_ack {
                        peers[pi].conn.send(&Frame::Ack { round, client })?;
                    }
                }
                FrameView::Tally { round, edge, payload: tf } => {
                    ensure!(round == t32, "round {t}: got a round-{round} merge frame");
                    tally_bytes += (body.len() - 9) as u64;
                    // the mock root speaks the plain vote only: a tag-5
                    // grouped frame (robust tallies — DESIGN.md §16) is
                    // a protocol error here, not silently mis-merged
                    ensure!(
                        tf.group_count() == 0,
                        "round {t}: edge {edge} sent a grouped tally frame \
                         (robust kinds are not part of the serve protocol)"
                    );
                    ensure!(
                        tf.quanta_len() == m,
                        "round {t}: edge {edge} tally over {} bits (want {m})",
                        tf.quanta_len()
                    );
                    ensure!(want_tally.remove(&pi), "duplicate merge frame from peer {pi}");
                    acc.merge_quanta(tf.absorbed as usize, |i| tf.quantum(i));
                }
                FrameView::Bye => bail!("peer {pi} left mid-round"),
                f => bail!("round {t}: unexpected {} from peer {pi}", kind_name(f.kind())),
            }
        }
        ensure!(
            acc.absorbed() == sel.len() - late_set.len() + lates_absorbed,
            "round {t}: absorbed {} of {} sketches",
            acc.absorbed(),
            sel.len() - late_set.len() + lates_absorbed
        );
        // who we still owe a wait next round: this round's lates that
        // have not already been stashed
        late_wait = late_set.iter().copied().filter(|k| !stash.contains_key(k)).collect();
        absorbed_total += acc.absorbed();
        consensus = acc.finish();
    }
    // the final round's designated lates are still in flight (every
    // fleet client answers every downlink it received): receive, meter,
    // and discard them so the byte ledger is complete and no peer is
    // mid-send when the BYE lands. They influence no tally — the run is
    // over (the oracle drops them the same way).
    while !late_wait.is_empty() {
        let (pi, body) = rx
            .recv_timeout(timeout)
            .context("draining the final round's designated-late uplinks")?;
        match decode_body_borrowed(&body)? {
            FrameView::Uplink { round, client, .. } if late_wait.remove(&client) => {
                uplink_bytes += (body.len() - 9) as u64;
                if peers[pi].want_ack {
                    peers[pi].conn.send(&Frame::Ack { round, client })?;
                }
            }
            FrameView::Bye => bail!("peer {pi} left before the final lates drained"),
            f => bail!("drain: unexpected {} from peer {pi}", kind_name(f.kind())),
        }
    }
    let elapsed_s = started.elapsed().as_secs_f64();

    for p in peers.iter_mut() {
        let _ = p.conn.send(&Frame::Bye);
    }
    for p in &peers {
        let _ = p.conn.shutdown();
    }
    drop(rx);
    for h in readers {
        let _ = h.join();
    }

    if cfg.check_consensus {
        let want = reference_consensus_quorum(
            cfg.seed,
            m,
            cfg.clients,
            cfg.participating,
            cfg.rounds,
            cfg.quorum,
            decay,
        );
        ensure!(
            consensus == want,
            "socket-run consensus diverged from the in-process reference"
        );
        crate::info!("consensus matches the in-process reference bit for bit");
    }
    Ok(RootReport {
        consensus,
        absorbed: absorbed_total,
        downlink_bytes,
        uplink_bytes,
        tally_bytes,
        elapsed_s,
        rounds_per_sec: if elapsed_s > 0.0 { cfg.rounds as f64 / elapsed_s } else { 0.0 },
    })
}

/// One open round at an edge: the running tally and how many of this
/// edge's uplinks are still outstanding.
struct EdgeShard {
    acc: VoteAccumulator,
    pending: usize,
}

/// `pfed1bs edge`: bind the fleet-side endpoint, then run the edge body.
pub fn run_edge(cfg: &ServeConfig) -> Result<()> {
    let ep = cfg.listen.as_ref().expect("validated: edge listens");
    let listener = Listener::bind(ep)?;
    run_edge_on(&listener, cfg)
}

/// Edge body over an already-bound fleet-side listener: connect upstream
/// (HELLO role=edge announcing its client range), forward the root's
/// WELCOME to its own fleet peers, then per round forward downlinks
/// down and absorb uplinks into the round's [`VoteAccumulator`] shard —
/// shipping exactly one `TallyFrame` merge frame upstream once every
/// selected client in its range has answered. Exits when the root says
/// BYE (forwarded to the fleet peers).
pub fn run_edge_on(listener: &Listener, cfg: &ServeConfig) -> Result<()> {
    let tuning = cfg.tuning();
    let timeout = Duration::from_millis(cfg.timeout_ms);
    let mut up = connect(
        cfg.connect.as_ref().expect("validated: edge connects"),
        &tuning,
        timeout.max(Duration::from_secs(10)),
    )?;
    let welcome = up.handshake_client(&Hello {
        role: PeerRole::Edge,
        lo: cfg.lo,
        hi: cfg.hi,
        m: 0,
        want_ack: false,
    })?;
    let m = welcome.m as usize;
    let clients = welcome.clients as usize;
    let lo = cfg.lo as usize;
    let hi = if cfg.hi == 0 { clients } else { cfg.hi as usize };
    ensure!(lo < hi && hi <= clients, "edge range {lo}..{hi} vs {clients} clients");

    let (mut peers, owners) = accept_peers(listener, &tuning, &welcome, lo, hi, timeout)?;
    let (tx, rx) = mpsc::channel();
    let mut readers: Vec<_> = peers
        .iter()
        .enumerate()
        .map(|(i, p)| spawn_reader(&p.conn, i, tx.clone()))
        .collect::<Result<_>>()?;
    readers.push(spawn_reader(&up, ROOT, tx.clone())?);
    drop(tx);

    // how many uplinks each round owes this edge — derived from the
    // shared selection stream, so the edge knows when its shard closes
    let selections = mock_selections(
        welcome.seed,
        clients,
        welcome.participating as usize,
        welcome.rounds as usize,
    );
    let expected: Vec<usize> = selections
        .iter()
        .map(|sel| sel.iter().filter(|&&k| k >= lo && k < hi).count())
        .collect();

    let mut shards: HashMap<u32, EdgeShard> = HashMap::new();
    loop {
        let (pi, body) = rx
            .recv_timeout(timeout)
            .context("edge: waiting for traffic")?;
        if pi == ROOT {
            match decode_body_borrowed(&body)? {
                FrameView::Downlink { round, client, .. } => {
                    let k = client as usize;
                    ensure!(k >= lo && k < hi, "root routed client {k} to edge {lo}..{hi}");
                    // relay the exact received bytes: the client gets the
                    // downlink byte-identical to what the root sent, with
                    // no decode→re-encode of the payload in between
                    peers[owners[k - lo]].conn.send_body(&body)?;
                    // first downlink of a round opens its shard
                    shards.entry(round).or_insert_with(|| EdgeShard {
                        acc: VoteAccumulator::new(m),
                        pending: expected.get(round as usize).copied().unwrap_or(0),
                    });
                }
                FrameView::Bye => {
                    for p in peers.iter_mut() {
                        let _ = p.conn.send(&Frame::Bye);
                    }
                    break;
                }
                f => bail!("edge: unexpected {} from the root", kind_name(f.kind())),
            }
        } else {
            match decode_body_borrowed(&body)? {
                FrameView::Uplink { round, client, payload } => {
                    let PayloadView::Signs(z) = payload else {
                        bail!("edge: uplink from client {client} was not a packed sketch")
                    };
                    ensure!(z.m() == m, "edge: sketch m={} (want {m})", z.m());
                    let sh = shards
                        .get_mut(&round)
                        .with_context(|| format!("edge: uplink for unopened round {round}"))?;
                    ensure!(
                        sh.pending > 0,
                        "edge: more round-{round} uplinks than clients selected in {lo}..{hi}"
                    );
                    sh.acc.absorb_view(&z, 1.0);
                    sh.pending -= 1;
                    if peers[pi].want_ack {
                        peers[pi].conn.send(&Frame::Ack { round, client })?;
                    }
                    if sh.pending == 0 {
                        let sh = shards.remove(&round).expect("just updated");
                        up.send(&Frame::Tally {
                            round,
                            edge: cfg.edge_id,
                            payload: Payload::TallyFrame(TallyFrame {
                                absorbed: sh.acc.absorbed() as u32,
                                loss_sum: 0.0,
                                scalar: 0,
                                quanta: sh.acc.quanta().to_vec(),
                                groups: Vec::new(),
                            }),
                        })?;
                    }
                }
                FrameView::Bye => bail!("edge: fleet peer {pi} left before the run ended"),
                f => bail!("edge: unexpected {} from fleet peer {pi}", kind_name(f.kind())),
            }
        }
    }
    for p in &peers {
        let _ = p.conn.shutdown();
    }
    let _ = up.shutdown();
    drop(rx);
    for h in readers {
        let _ = h.join();
    }
    Ok(())
}

/// What one fleet connection saw over its whole life.
struct ConnStats {
    uplinks: u64,
    latencies_ms: Vec<f64>,
    rounds: u32,
}

/// Drive one connection's worth of mock clients (`lo..hi`): answer every
/// downlink with the deterministic [`mock_sketch`] of the *received*
/// consensus, optionally timing uplink→ACK (the uplink-to-absorb probe),
/// until the server says BYE.
fn fleet_connection(
    ep: &Endpoint,
    tuning: &Tuning,
    role: PeerRole,
    lo: u32,
    hi: u32,
    want_ack: bool,
) -> Result<ConnStats> {
    let mut conn = connect(ep, tuning, Duration::from_secs(10))?;
    let welcome = conn.handshake_client(&Hello { role, lo, hi, m: 0, want_ack })?;
    let m = welcome.m as usize;
    let mut inflight: HashMap<(u32, u32), Instant> = HashMap::new();
    let mut stats = ConnStats { uplinks: 0, latencies_ms: Vec::new(), rounds: welcome.rounds };
    loop {
        match conn.recv().context("fleet: waiting for the next downlink")? {
            Frame::Downlink { round, client, payload } => {
                ensure!(
                    client >= lo && client < hi,
                    "fleet {lo}..{hi}: got a downlink for client {client}"
                );
                let Payload::Signs(received) = payload else {
                    bail!("fleet: downlink was not a packed consensus")
                };
                ensure!(received.m() == m, "fleet: consensus m={} (want {m})", received.m());
                let sketch = mock_sketch(welcome.seed, m, client, round, &received);
                if want_ack {
                    inflight.insert((round, client), Instant::now());
                }
                conn.send(&Frame::Uplink { round, client, payload: Payload::Signs(sketch) })?;
                stats.uplinks += 1;
            }
            Frame::Ack { round, client } => {
                if let Some(t0) = inflight.remove(&(round, client)) {
                    stats.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                }
            }
            Frame::Bye => break,
            f => bail!("fleet: unexpected {} frame", kind_name(f.kind())),
        }
    }
    let _ = conn.shutdown();
    Ok(stats)
}

/// Split the configured client range over `conns` connections, drive
/// them on parallel threads, and return every connection's stats plus
/// the wall time.
fn drive_fleet(cfg: &ServeConfig, role: PeerRole) -> Result<(Vec<ConnStats>, f64)> {
    let ep = cfg.connect.clone().expect("validated: fleet connects");
    let tuning = cfg.tuning();
    let lo = cfg.lo;
    let hi = if cfg.hi == 0 { cfg.clients as u32 } else { cfg.hi };
    let chunk = (hi - lo).div_ceil(cfg.conns as u32);
    let want_ack = cfg.want_ack;
    let started = Instant::now();
    let mut handles = Vec::new();
    for c in 0..cfg.conns as u32 {
        let clo = lo + c * chunk;
        let chi = (clo + chunk).min(hi);
        if clo >= chi {
            break;
        }
        let ep = ep.clone();
        let tuning = tuning.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("pfed1bs-fleet-{c}"))
                .spawn(move || fleet_connection(&ep, &tuning, role, clo, chi, want_ack))
                .context("spawning fleet connection thread")?,
        );
    }
    let mut stats = Vec::new();
    for h in handles {
        stats.push(h.join().map_err(|_| anyhow::anyhow!("fleet thread panicked"))??);
    }
    Ok((stats, started.elapsed().as_secs_f64()))
}

/// `pfed1bs client-fleet`: simulate `lo..hi` mock clients over `conns`
/// connections against a live root or edge; exits on the server's BYE.
pub fn run_fleet(cfg: &ServeConfig) -> Result<()> {
    let (stats, elapsed) = drive_fleet(cfg, PeerRole::Fleet)?;
    let uplinks: u64 = stats.iter().map(|s| s.uplinks).sum();
    println!(
        "{{\"suite\":\"client-fleet\",\"conns\":{},\"uplinks\":{uplinks},\"elapsed_s\":{elapsed:.3}}}",
        stats.len()
    );
    Ok(())
}

/// What a loadgen run measured (emitted as `BENCH_loadgen.json`).
pub struct LoadgenReport {
    /// mock clients simulated
    pub clients: usize,
    /// connections they multiplexed over
    pub conns: usize,
    /// protocol rounds the root announced
    pub rounds: u32,
    /// total uplinks sent
    pub uplinks: u64,
    /// wall time of the whole run
    pub elapsed_s: f64,
    /// completed rounds per wall-clock second
    pub rounds_per_sec: f64,
    /// median uplink→ACK (absorb) latency, milliseconds
    pub p50_uplink_to_absorb_ms: f64,
    /// 99th-percentile uplink→ACK latency, milliseconds
    pub p99_uplink_to_absorb_ms: f64,
}

impl LoadgenReport {
    /// One-line machine-readable form (the `BENCH_<name>.json` convention).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"suite\":\"loadgen\",\"clients\":{},\"conns\":{},\"rounds\":{},\"uplinks\":{},\
             \"elapsed_s\":{:.3},\"rounds_per_sec\":{:.3},\
             \"p50_uplink_to_absorb_ms\":{:.3},\"p99_uplink_to_absorb_ms\":{:.3}}}",
            self.clients,
            self.conns,
            self.rounds,
            self.uplinks,
            self.elapsed_s,
            self.rounds_per_sec,
            self.p50_uplink_to_absorb_ms,
            self.p99_uplink_to_absorb_ms,
        )
    }
}

/// `pfed1bs loadgen`: drive a large mock fleet (ACKs on) against a live
/// root, then report rounds/sec and p50/p99 uplink-to-absorb latency —
/// printed to stdout and written to `BENCH_loadgen.json`.
pub fn run_loadgen(cfg: &ServeConfig) -> Result<LoadgenReport> {
    let (stats, elapsed_s) = drive_fleet(cfg, PeerRole::Loadgen)?;
    let conns = stats.len();
    let rounds = stats.iter().map(|s| s.rounds).max().unwrap_or(0);
    let uplinks: u64 = stats.iter().map(|s| s.uplinks).sum();
    let lat: Vec<f64> = stats.into_iter().flat_map(|s| s.latencies_ms).collect();
    let hi = if cfg.hi == 0 { cfg.clients as u32 } else { cfg.hi };
    let report = LoadgenReport {
        clients: (hi - cfg.lo) as usize,
        conns,
        rounds,
        uplinks,
        elapsed_s,
        rounds_per_sec: if elapsed_s > 0.0 { rounds as f64 / elapsed_s } else { 0.0 },
        // nearest-rank, not interpolation: a short run collects < 100
        // ACKs, where interpolated p99 aliases toward the interior
        // instead of reporting the worst observed tail (DESIGN.md §12)
        p50_uplink_to_absorb_ms: percentile_nearest_rank(&lat, 50.0),
        p99_uplink_to_absorb_ms: percentile_nearest_rank(&lat, 99.0),
    };
    std::fs::write("BENCH_loadgen.json", report.to_json() + "\n")
        .context("writing BENCH_loadgen.json")?;
    println!("{}", report.to_json());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_sketches_are_deterministic_and_fully_keyed() {
        let c = SignVec::from_fn(96, |i| i % 2 == 0);
        let a = mock_sketch(7, 96, 3, 1, &c);
        assert_eq!(a, mock_sketch(7, 96, 3, 1, &c));
        assert_eq!(a.m(), 96);
        assert_ne!(a, mock_sketch(7, 96, 4, 1, &c), "client key");
        assert_ne!(a, mock_sketch(7, 96, 3, 2, &c), "round key");
        assert_ne!(a, mock_sketch(8, 96, 3, 1, &c), "seed key");
        let c2 = SignVec::from_fn(96, |i| i % 3 == 0);
        assert_ne!(
            a,
            mock_sketch(7, 96, 3, 1, &c2),
            "sketches must chain on the received consensus"
        );
    }

    #[test]
    fn mock_selections_are_deterministic_uniform_draws() {
        let s = mock_selections(17, 64, 16, 3);
        assert_eq!(s, mock_selections(17, 64, 16, 3));
        assert_eq!(s.len(), 3);
        for sel in &s {
            assert_eq!(sel.len(), 16);
            assert!(sel.iter().all(|&k| k < 64));
            let mut d = sel.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 16, "cohort must be without replacement");
        }
        assert_ne!(s[0], s[1], "each round draws a fresh cohort");
    }

    #[test]
    fn reference_consensus_is_deterministic_and_seed_keyed() {
        let a = reference_consensus(17, 130, 64, 16, 3);
        assert_eq!(a, reference_consensus(17, 130, 64, 16, 3));
        assert_eq!(a.m(), 130);
        assert_ne!(a, reference_consensus(18, 130, 64, 16, 3));
        // one round over one client is that client's own sketch, signed
        let one = reference_consensus(5, 64, 1, 1, 1);
        let z = mock_sketch(5, 64, 0, 0, &SignVec::from_fn(64, |_| true));
        assert_eq!(one, z, "a single vote with weight 1 is the sketch itself");
    }

    #[test]
    fn quorum_reference_reduces_to_the_barrier_replay_at_defaults() {
        let barrier = reference_consensus(17, 130, 64, 16, 3);
        // both sentinel spellings of "whole cohort" are the barrier run,
        // whatever the (then-unused) decay says
        assert_eq!(barrier, reference_consensus_quorum(17, 130, 64, 16, 3, 0, 0.5));
        assert_eq!(barrier, reference_consensus_quorum(17, 130, 64, 16, 3, 16, 0.25));
        // a real quorum reshapes every tally: lates join one round stale
        let q = reference_consensus_quorum(17, 130, 64, 16, 3, 12, 0.5);
        assert_eq!(q, reference_consensus_quorum(17, 130, 64, 16, 3, 12, 0.5));
        assert_ne!(q, barrier);
        assert_ne!(q, reference_consensus_quorum(17, 130, 64, 16, 3, 12, 0.25), "decay keys");
    }

    #[test]
    fn quorum_reference_drops_the_final_rounds_lates() {
        // one round: only sel[..q] can ever vote — the designated lates
        // of the last round are drained and discarded, not absorbed
        let sel = &mock_selections(17, 64, 16, 1)[0];
        let init = SignVec::from_fn(130, |_| true);
        let mut acc = VoteAccumulator::new(130);
        for &k in &sel[..12] {
            acc.absorb(&mock_sketch(17, 130, k as u32, 0, &init), 1.0);
        }
        assert_eq!(acc.finish(), reference_consensus_quorum(17, 130, 64, 16, 1, 12, 0.5));
    }

    #[test]
    fn range_resolution_enforces_bounds() {
        let hello = |lo, hi| Hello { role: PeerRole::Fleet, lo, hi, m: 0, want_ack: false };
        assert_eq!(resolve_range(&hello(0, 0), 64).unwrap(), (0, 64));
        assert_eq!(resolve_range(&hello(8, 16), 64).unwrap(), (8, 16));
        assert!(resolve_range(&hello(8, 8), 64).is_err());
        assert!(resolve_range(&hello(0, 65), 64).is_err());
        assert!(resolve_range(&hello(64, 0), 64).is_err());
    }
}
