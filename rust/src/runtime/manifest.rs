//! Parser for `artifacts/manifest.txt` (written by `python -m compile.aot`).
//!
//! Line-oriented `key=value` records — the contract between the build-time
//! python layer and the rust runtime. The manifest carries the geometry
//! (n, n', m, batch sizes) the coordinator needs *before* loading any HLO.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One artifact record.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactInfo {
    /// artifact kind (`client_step`, `sketch`, `eval`, …)
    pub artifact: String,
    /// model variant (`mlp784`, `mlp3072`, …)
    pub variant: String,
    /// HLO text file name, relative to the manifest directory
    pub file: String,
    /// parameter count n
    pub n: usize,
    /// n padded to the next power of two
    pub npad: usize,
    /// sketch dimension m
    pub m: usize,
    /// input feature dimension
    pub input_dim: usize,
    /// number of classes
    pub classes: usize,
    /// training batch rows
    pub train_batch: usize,
    /// evaluation batch rows
    pub eval_batch: usize,
    /// cohort batch width B for `*_batched` artifacts; `None` for the
    /// per-client artifacts (legacy rows carry no `batch=` key)
    pub batch: Option<usize>,
    /// content hash of the HLO file (build provenance)
    pub sha256: String,
}

/// Parsed manifest. Per-client records are indexed by (artifact, variant);
/// cohort-batched records (those carrying `batch=B`) live in a separate
/// index keyed (artifact, variant, B) so one variant can ship several
/// batch widths.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// the artifacts directory the file paths resolve against
    pub dir: PathBuf,
    entries: HashMap<(String, String), ArtifactInfo>,
    batched: HashMap<(String, String, usize), ArtifactInfo>,
}

impl Manifest {
    /// Read and parse `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (whitespace-separated `key=value` records,
    /// one artifact per line).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut entries = HashMap::new();
        let mut batched = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut kv: HashMap<&str, &str> = HashMap::new();
            for tok in line.split_whitespace() {
                let Some((k, v)) = tok.split_once('=') else {
                    bail!("manifest line {}: bad token `{tok}`", lineno + 1);
                };
                kv.insert(k, v);
            }
            let get = |k: &str| -> Result<&str> {
                kv.get(k)
                    .copied()
                    .with_context(|| format!("manifest line {}: missing `{k}`", lineno + 1))
            };
            let num = |k: &str| -> Result<usize> {
                get(k)?
                    .parse()
                    .with_context(|| format!("manifest line {}: bad number for `{k}`", lineno + 1))
            };
            // `batch=` marks a cohort-batched record. Parse through i64 so
            // zero/negative widths get a geometry error, not a bare
            // integer-parse failure.
            let batch = match kv.get("batch").copied() {
                None => None,
                Some(raw) => {
                    let b: i64 = raw.parse().with_context(|| {
                        format!("manifest line {}: bad number for `batch`", lineno + 1)
                    })?;
                    if b < 1 {
                        bail!(
                            "manifest line {}: batch={b} — cohort batch width must be a positive integer",
                            lineno + 1
                        );
                    }
                    Some(b as usize)
                }
            };
            let info = ArtifactInfo {
                artifact: get("artifact")?.to_string(),
                variant: get("variant")?.to_string(),
                file: get("file")?.to_string(),
                n: num("n")?,
                npad: num("npad")?,
                m: num("m")?,
                input_dim: num("input_dim")?,
                classes: num("classes")?,
                train_batch: num("train_batch")?,
                eval_batch: num("eval_batch")?,
                batch,
                sha256: get("sha256")?.to_string(),
            };
            match info.batch {
                None => {
                    let key = (info.artifact.clone(), info.variant.clone());
                    if entries.insert(key, info).is_some() {
                        bail!("manifest line {}: duplicate record", lineno + 1);
                    }
                }
                Some(b) => {
                    let key = (info.artifact.clone(), info.variant.clone(), b);
                    if batched.insert(key, info).is_some() {
                        bail!("manifest line {}: duplicate batched record", lineno + 1);
                    }
                }
            }
        }
        Ok(Manifest { dir, entries, batched })
    }

    /// Look up a record by (artifact kind, variant).
    pub fn get(&self, artifact: &str, variant: &str) -> Result<&ArtifactInfo> {
        self.entries
            .get(&(artifact.to_string(), variant.to_string()))
            .with_context(|| {
                format!("artifact `{artifact}` for variant `{variant}` not in manifest")
            })
    }

    /// Look up a cohort-batched record by (artifact kind, variant, batch width).
    pub fn get_batched(&self, artifact: &str, variant: &str, batch: usize) -> Result<&ArtifactInfo> {
        self.batched
            .get(&(artifact.to_string(), variant.to_string(), batch))
            .with_context(|| {
                format!(
                    "batched artifact `{artifact}` (B={batch}) for variant `{variant}` not in manifest"
                )
            })
    }

    /// Cohort batch widths available for a variant, sorted ascending.
    ///
    /// A width counts only when the full batched family
    /// (`client_step_batched`, `client_step_batched_w`, `sketch_batched`)
    /// is present — the runtime needs all three to run a batched round.
    pub fn batch_sizes(&self, variant: &str) -> Vec<usize> {
        let mut bs: Vec<usize> = self
            .batched
            .keys()
            .filter(|(a, v, b)| {
                a == "client_step_batched"
                    && v == variant
                    && self.get_batched("client_step_batched_w", variant, *b).is_ok()
                    && self.get_batched("sketch_batched", variant, *b).is_ok()
            })
            .map(|(_, _, b)| *b)
            .collect();
        bs.sort_unstable();
        bs
    }

    /// Every distinct model variant, sorted.
    pub fn variants(&self) -> Vec<String> {
        let mut vs: Vec<String> = self
            .entries
            .keys()
            .map(|(_, v)| v.clone())
            .collect();
        vs.sort();
        vs.dedup();
        vs
    }

    /// Absolute path of a record's HLO file.
    pub fn path_for(&self, info: &ArtifactInfo) -> PathBuf {
        self.dir.join(&info.file)
    }

    /// Number of artifact records (per-client + batched).
    pub fn len(&self) -> usize {
        self.entries.len() + self.batched.len()
    }

    /// True when the manifest has no records.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty() && self.batched.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# pfed1bs artifact manifest v1
artifact=client_step variant=mlp784 file=client_step_mlp784.hlo.txt n=159010 npad=262144 m=15901 input_dim=784 classes=10 train_batch=32 eval_batch=256 sha256=abc
artifact=eval variant=mlp784 file=eval_mlp784.hlo.txt n=159010 npad=262144 m=15901 input_dim=784 classes=10 train_batch=32 eval_batch=256 sha256=def
";

    #[test]
    fn parses_records() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.len(), 2);
        let cs = m.get("client_step", "mlp784").unwrap();
        assert_eq!(cs.n, 159010);
        assert_eq!(cs.npad, 262144);
        assert_eq!(cs.m, 15901);
        assert_eq!(m.variants(), vec!["mlp784".to_string()]);
        assert!(m.path_for(cs).ends_with("client_step_mlp784.hlo.txt"));
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.get("sketch", "mlp784").is_err());
        assert!(m.get("client_step", "bogus").is_err());
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Manifest::parse("garbage line", PathBuf::new()).is_err());
        assert!(Manifest::parse("artifact=a", PathBuf::new()).is_err()); // missing fields
        let dup = format!("{SAMPLE}\nartifact=eval variant=mlp784 file=f n=1 npad=1 m=1 input_dim=1 classes=1 train_batch=1 eval_batch=1 sha256=x");
        assert!(Manifest::parse(&dup, PathBuf::new()).is_err());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let m = Manifest::parse("# only comments\n\n", PathBuf::new()).unwrap();
        assert!(m.is_empty());
    }

    fn batched_row(artifact: &str, batch: &str) -> String {
        format!(
            "artifact={artifact} variant=mlp784 file={artifact}_b{batch}_mlp784.hlo.txt \
             n=159010 npad=262144 m=15901 input_dim=784 classes=10 train_batch=32 \
             eval_batch=256 batch={batch} sha256=abc"
        )
    }

    fn batched_family(batch: &str) -> String {
        [
            batched_row("client_step_batched", batch),
            batched_row("client_step_batched_w", batch),
            batched_row("sketch_batched", batch),
        ]
        .join("\n")
    }

    #[test]
    fn batched_records_indexed_separately() {
        let text = format!("{SAMPLE}{}\n{}\n", batched_family("4"), batched_family("8"));
        let m = Manifest::parse(&text, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.len(), 8);
        // per-client index is untouched by batched rows
        assert!(m.get("client_step_batched", "mlp784").is_err());
        assert_eq!(m.get("client_step", "mlp784").unwrap().batch, None);
        let b4 = m.get_batched("client_step_batched", "mlp784", 4).unwrap();
        assert_eq!(b4.batch, Some(4));
        assert_eq!(b4.n, 159010);
        assert!(m.get_batched("client_step_batched", "mlp784", 16).is_err());
        assert_eq!(m.batch_sizes("mlp784"), vec![4, 8]);
        assert!(m.batch_sizes("bogus").is_empty());
    }

    #[test]
    fn incomplete_batched_family_not_advertised() {
        // only two of the three artifacts at B=4 -> width must not be offered
        let text = format!(
            "{SAMPLE}{}\n{}\n",
            batched_row("client_step_batched", "4"),
            batched_row("client_step_batched_w", "4"),
        );
        let m = Manifest::parse(&text, PathBuf::from("/tmp")).unwrap();
        assert!(m.batch_sizes("mlp784").is_empty());
    }

    #[test]
    fn bad_batch_values_rejected_with_clear_error() {
        for bad in ["0", "-3"] {
            let text = format!("{SAMPLE}{}\n", batched_row("client_step_batched", bad));
            let err = Manifest::parse(&text, PathBuf::new()).unwrap_err().to_string();
            assert!(
                err.contains("batch width must be a positive integer"),
                "batch={bad}: unexpected error `{err}`"
            );
        }
        let text = format!("{SAMPLE}{}\n", batched_row("client_step_batched", "wide"));
        let err = Manifest::parse(&text, PathBuf::new()).unwrap_err().to_string();
        assert!(err.contains("bad number for `batch`"), "unexpected error `{err}`");
    }

    #[test]
    fn duplicate_batched_record_rejected() {
        let dup = format!(
            "{SAMPLE}{}\n{}\n",
            batched_row("sketch_batched", "8"),
            batched_row("sketch_batched", "8"),
        );
        let err = Manifest::parse(&dup, PathBuf::new()).unwrap_err().to_string();
        assert!(err.contains("duplicate batched record"), "unexpected error `{err}`");
    }
}
