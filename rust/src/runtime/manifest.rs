//! Parser for `artifacts/manifest.txt` (written by `python -m compile.aot`).
//!
//! Line-oriented `key=value` records — the contract between the build-time
//! python layer and the rust runtime. The manifest carries the geometry
//! (n, n', m, batch sizes) the coordinator needs *before* loading any HLO.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One artifact record.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactInfo {
    /// artifact kind (`client_step`, `sketch`, `eval`, …)
    pub artifact: String,
    /// model variant (`mlp784`, `mlp3072`, …)
    pub variant: String,
    /// HLO text file name, relative to the manifest directory
    pub file: String,
    /// parameter count n
    pub n: usize,
    /// n padded to the next power of two
    pub npad: usize,
    /// sketch dimension m
    pub m: usize,
    /// input feature dimension
    pub input_dim: usize,
    /// number of classes
    pub classes: usize,
    /// training batch rows
    pub train_batch: usize,
    /// evaluation batch rows
    pub eval_batch: usize,
    /// content hash of the HLO file (build provenance)
    pub sha256: String,
}

/// Parsed manifest, indexed by (artifact, variant).
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// the artifacts directory the file paths resolve against
    pub dir: PathBuf,
    entries: HashMap<(String, String), ArtifactInfo>,
}

impl Manifest {
    /// Read and parse `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (whitespace-separated `key=value` records,
    /// one artifact per line).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut entries = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut kv: HashMap<&str, &str> = HashMap::new();
            for tok in line.split_whitespace() {
                let Some((k, v)) = tok.split_once('=') else {
                    bail!("manifest line {}: bad token `{tok}`", lineno + 1);
                };
                kv.insert(k, v);
            }
            let get = |k: &str| -> Result<&str> {
                kv.get(k)
                    .copied()
                    .with_context(|| format!("manifest line {}: missing `{k}`", lineno + 1))
            };
            let num = |k: &str| -> Result<usize> {
                get(k)?
                    .parse()
                    .with_context(|| format!("manifest line {}: bad number for `{k}`", lineno + 1))
            };
            let info = ArtifactInfo {
                artifact: get("artifact")?.to_string(),
                variant: get("variant")?.to_string(),
                file: get("file")?.to_string(),
                n: num("n")?,
                npad: num("npad")?,
                m: num("m")?,
                input_dim: num("input_dim")?,
                classes: num("classes")?,
                train_batch: num("train_batch")?,
                eval_batch: num("eval_batch")?,
                sha256: get("sha256")?.to_string(),
            };
            let key = (info.artifact.clone(), info.variant.clone());
            if entries.insert(key, info).is_some() {
                bail!("manifest line {}: duplicate record", lineno + 1);
            }
        }
        Ok(Manifest { dir, entries })
    }

    /// Look up a record by (artifact kind, variant).
    pub fn get(&self, artifact: &str, variant: &str) -> Result<&ArtifactInfo> {
        self.entries
            .get(&(artifact.to_string(), variant.to_string()))
            .with_context(|| {
                format!("artifact `{artifact}` for variant `{variant}` not in manifest")
            })
    }

    /// Every distinct model variant, sorted.
    pub fn variants(&self) -> Vec<String> {
        let mut vs: Vec<String> = self
            .entries
            .keys()
            .map(|(_, v)| v.clone())
            .collect();
        vs.sort();
        vs.dedup();
        vs
    }

    /// Absolute path of a record's HLO file.
    pub fn path_for(&self, info: &ArtifactInfo) -> PathBuf {
        self.dir.join(&info.file)
    }

    /// Number of artifact records.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the manifest has no records.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# pfed1bs artifact manifest v1
artifact=client_step variant=mlp784 file=client_step_mlp784.hlo.txt n=159010 npad=262144 m=15901 input_dim=784 classes=10 train_batch=32 eval_batch=256 sha256=abc
artifact=eval variant=mlp784 file=eval_mlp784.hlo.txt n=159010 npad=262144 m=15901 input_dim=784 classes=10 train_batch=32 eval_batch=256 sha256=def
";

    #[test]
    fn parses_records() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.len(), 2);
        let cs = m.get("client_step", "mlp784").unwrap();
        assert_eq!(cs.n, 159010);
        assert_eq!(cs.npad, 262144);
        assert_eq!(cs.m, 15901);
        assert_eq!(m.variants(), vec!["mlp784".to_string()]);
        assert!(m.path_for(cs).ends_with("client_step_mlp784.hlo.txt"));
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.get("sketch", "mlp784").is_err());
        assert!(m.get("client_step", "bogus").is_err());
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Manifest::parse("garbage line", PathBuf::new()).is_err());
        assert!(Manifest::parse("artifact=a", PathBuf::new()).is_err()); // missing fields
        let dup = format!("{SAMPLE}\nartifact=eval variant=mlp784 file=f n=1 npad=1 m=1 input_dim=1 classes=1 train_batch=1 eval_batch=1 sha256=x");
        assert!(Manifest::parse(&dup, PathBuf::new()).is_err());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let m = Manifest::parse("# only comments\n\n", PathBuf::new()).unwrap();
        assert!(m.is_empty());
    }
}
