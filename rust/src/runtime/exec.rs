//! PJRT execution runtime: load HLO-text artifacts, compile once, execute
//! on the request path.
//!
//! Interchange contract (see /opt/xla-example/README.md and aot.py):
//! HLO *text* → `HloModuleProto::from_text_file` → `XlaComputation` →
//! `client.compile`. Every artifact returns a tuple (lowered with
//! `return_tuple=True`), so outputs always `to_tuple()`.
//!
//! Two-level design:
//! * [`ModelExecutables`] — the five compiled executables of one model
//!   variant. Compilation costs seconds; experiment sweeps share these
//!   across runs through an `Arc`.
//! * [`ModelRuntime`] — executables + the run's SRHT operator realization
//!   (dsign: n′ f32, sidx: m i32) uploaded to device ONCE and reused by
//!   every `client_step`/`sketch` via `execute_b`; re-uploading dsign per
//!   step would copy 1–4 MiB per local step (EXPERIMENTS.md §Perf).

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::manifest::{ArtifactInfo, Manifest};
use crate::sketch::SrhtOperator;

/// Geometry of one model variant, read from the manifest.
#[derive(Clone, Copy, Debug)]
pub struct Geometry {
    /// parameter count n
    pub n: usize,
    /// n padded to the next power of two (the FWHT length n′)
    pub npad: usize,
    /// sketch dimension m
    pub m: usize,
    /// input feature dimension d
    pub input_dim: usize,
    /// number of classes
    pub classes: usize,
    /// training batch rows the HLO artifact was lowered with
    pub train_batch: usize,
    /// evaluation batch rows the HLO artifact was lowered with
    pub eval_batch: usize,
}

impl Geometry {
    fn from_info(info: &ArtifactInfo) -> Geometry {
        Geometry {
            n: info.n,
            npad: info.npad,
            m: info.m,
            input_dim: info.input_dim,
            classes: info.classes,
            train_batch: info.train_batch,
            eval_batch: info.eval_batch,
        }
    }
}

/// Shared PJRT client + manifest.
pub struct Runtime {
    /// the CPU PJRT client every executable compiles against
    pub client: PjRtClient,
    /// parsed `artifacts/manifest.txt`
    pub manifest: Manifest,
}

impl Runtime {
    /// Create a CPU PJRT client and read the artifact manifest.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Runtime { client, manifest })
    }

    fn compile(&self, info: &ArtifactInfo) -> Result<PjRtLoadedExecutable> {
        // Both the parse and the PJRT compile error are wrapped with the
        // manifest record identity — a bad (batched) artifact must be
        // diagnosable from the error alone, not just a file path.
        let record = || match info.batch {
            Some(b) => format!(
                "artifact `{}` variant `{}` batch={b} ({})",
                info.artifact, info.variant, info.file
            ),
            None => format!("artifact `{}` variant `{}` ({})", info.artifact, info.variant, info.file),
        };
        let path = self.manifest.path_for(info);
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))
        .with_context(|| format!("loading {}", record()))?;
        let comp = XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("PJRT compile: {e:?}"))
            .with_context(|| format!("compiling {}", record()))
    }

    /// Compile all executables of a model variant (expensive; share the
    /// result across runs via the returned Arc).
    pub fn load_variant(&self, variant: &str) -> Result<Arc<ModelExecutables>> {
        self.load_variant_batched(variant, 1)
    }

    /// Compile a variant's executables plus, when `device_batch > 1`, the
    /// cohort-batched family at the LARGEST manifest width ≤ `device_batch`
    /// (short cohort tails are padded at execute time, so one width serves
    /// every group size up to B). When the manifest carries no usable
    /// width the per-client executables load alone and the runtime
    /// degrades to per-client dispatch.
    pub fn load_variant_batched(
        &self,
        variant: &str,
        device_batch: usize,
    ) -> Result<Arc<ModelExecutables>> {
        let info = self.manifest.get("client_step", variant)?;
        let geom = Geometry::from_info(info);
        let batched = if device_batch > 1 {
            match self
                .manifest
                .batch_sizes(variant)
                .into_iter()
                .rev()
                .find(|&b| b <= device_batch)
            {
                Some(b) => Some(self.load_batched_family(variant, b, &geom)?),
                None => None,
            }
        } else {
            None
        };
        Ok(Arc::new(ModelExecutables {
            client: self.client.clone(),
            geom,
            variant: variant.to_string(),
            client_step: self.compile(info)?,
            client_step_w: self.compile(self.manifest.get("client_step_w", variant)?)?,
            sgd_step: self.compile(self.manifest.get("sgd_step", variant)?)?,
            sgd_step_w: self.compile(self.manifest.get("sgd_step_w", variant)?)?,
            sketch: self.compile(self.manifest.get("sketch", variant)?)?,
            eval: self.compile(self.manifest.get("eval", variant)?)?,
            grad_norm: self.compile(self.manifest.get("grad_norm", variant)?)?,
            batched,
        }))
    }

    fn load_batched_family(
        &self,
        variant: &str,
        batch: usize,
        geom: &Geometry,
    ) -> Result<BatchedExecutables> {
        let info = self.manifest.get_batched("client_step_batched", variant, batch)?;
        if info.n != geom.n || info.npad != geom.npad || info.m != geom.m {
            bail!(
                "batched artifact geometry (n={}, n'={}, m={}) does not match variant `{variant}` (n={}, n'={}, m={})",
                info.n, info.npad, info.m, geom.n, geom.npad, geom.m
            );
        }
        Ok(BatchedExecutables {
            batch,
            client_step_batched: self.compile(info)?,
            client_step_batched_w: self
                .compile(self.manifest.get_batched("client_step_batched_w", variant, batch)?)?,
            sketch_batched: self
                .compile(self.manifest.get_batched("sketch_batched", variant, batch)?)?,
        })
    }

    /// Convenience: compile a variant and bind an operator in one call.
    pub fn model(&self, variant: &str, operator: &SrhtOperator) -> Result<ModelRuntime> {
        ModelRuntime::bind(self.load_variant(variant)?, operator)
    }

    /// Convenience: compile a variant (with the batched family when
    /// available at ≤ `device_batch`) and bind an operator in one call.
    pub fn model_with_batch(
        &self,
        variant: &str,
        operator: &SrhtOperator,
        device_batch: usize,
    ) -> Result<ModelRuntime> {
        ModelRuntime::bind(self.load_variant_batched(variant, device_batch)?, operator)
    }
}

/// The cohort-batched executable family of one variant at one width B.
///
/// One dispatch of `client_step_batched_w` advances B clients one local
/// step; the stacked `[B, n]` weight buffer stays device-resident across
/// the whole local round exactly like the per-client `client_step_w` loop
/// (DESIGN.md §15).
pub struct BatchedExecutables {
    /// the lowered cohort width B
    pub batch: usize,
    client_step_batched: PjRtLoadedExecutable,
    /// single-output variant: stacked w' as a non-tuple root
    client_step_batched_w: PjRtLoadedExecutable,
    sketch_batched: PjRtLoadedExecutable,
}

/// The five compiled executables of one model variant.
pub struct ModelExecutables {
    client: PjRtClient,
    /// the variant's geometry (n, n′, m, batch shapes)
    pub geom: Geometry,
    /// variant name (`mlp784`, `mlp3072`, …)
    pub variant: String,
    client_step: PjRtLoadedExecutable,
    /// single-output variant: w' as a non-tuple root (device-resident loop)
    client_step_w: PjRtLoadedExecutable,
    sgd_step: PjRtLoadedExecutable,
    sgd_step_w: PjRtLoadedExecutable,
    sketch: PjRtLoadedExecutable,
    eval: PjRtLoadedExecutable,
    grad_norm: PjRtLoadedExecutable,
    /// cohort-batched family; `None` when loaded at `device_batch=1` or
    /// when the manifest ships no usable width
    batched: Option<BatchedExecutables>,
}

/// Executables + the bound SRHT operator realization (device-resident).
pub struct ModelRuntime {
    exes: Arc<ModelExecutables>,
    /// the variant's geometry (n, n′, m, batch shapes)
    pub geom: Geometry,
    /// variant name (`mlp784`, `mlp3072`, …)
    pub variant: String,
    dsign_buf: PjRtBuffer,
    sidx_buf: PjRtBuffer,
}

impl ModelRuntime {
    /// Bind an operator realization to compiled executables (cheap: two
    /// host→device uploads).
    pub fn bind(exes: Arc<ModelExecutables>, operator: &SrhtOperator) -> Result<ModelRuntime> {
        let geom = exes.geom;
        if operator.npad != geom.npad || operator.m != geom.m || operator.n != geom.n {
            bail!(
                "operator geometry (n={}, n'={}, m={}) does not match artifact (n={}, n'={}, m={})",
                operator.n, operator.npad, operator.m, geom.n, geom.npad, geom.m
            );
        }
        let dsign_buf = exes
            .client
            .buffer_from_host_buffer(&operator.dsign, &[geom.npad], None)
            .map_err(|e| anyhow!("uploading dsign: {e:?}"))?;
        let sidx_i32: Vec<i32> = operator.sidx.iter().map(|&i| i as i32).collect();
        let sidx_buf = exes
            .client
            .buffer_from_host_buffer(&sidx_i32, &[geom.m], None)
            .map_err(|e| anyhow!("uploading sidx: {e:?}"))?;
        Ok(ModelRuntime {
            variant: exes.variant.clone(),
            geom,
            exes,
            dsign_buf,
            sidx_buf,
        })
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.exes
            .client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("host->device f32 {dims:?}: {e:?}"))
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.exes
            .client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("host->device i32 {dims:?}: {e:?}"))
    }

    fn scalar(&self, x: f32) -> Result<PjRtBuffer> {
        self.exes
            .client
            .buffer_from_host_buffer(&[x], &[], None)
            .map_err(|e| anyhow!("host->device scalar: {e:?}"))
    }

    fn run(&self, exe: &PjRtLoadedExecutable, args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let out = exe.execute_b(args).map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("device->host: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    fn vec_f32(lit: &Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("literal->vec: {e:?}"))
    }

    fn scalar_f32(lit: &Literal) -> Result<f32> {
        lit.get_first_element::<f32>()
            .map_err(|e| anyhow!("literal->scalar: {e:?}"))
    }

    /// One pFed1BS local step (Algorithm 1 line 16). Returns (w', loss).
    #[allow(clippy::too_many_arguments)]
    pub fn client_step(
        &self,
        w: &[f32],
        x: &[f32],
        y: &[i32],
        v: &[f32],
        eta: f32,
        lambda: f32,
        mu: f32,
        gamma: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let g = &self.geom;
        debug_assert_eq!(w.len(), g.n);
        debug_assert_eq!(x.len(), g.train_batch * g.input_dim);
        debug_assert_eq!(y.len(), g.train_batch);
        debug_assert_eq!(v.len(), g.m);
        let wb = self.buf_f32(w, &[g.n])?;
        let xb = self.buf_f32(x, &[g.train_batch, g.input_dim])?;
        let yb = self.buf_i32(y, &[g.train_batch])?;
        let vb = self.buf_f32(v, &[g.m])?;
        let args = [
            &wb,
            &xb,
            &yb,
            &vb,
            &self.dsign_buf,
            &self.sidx_buf,
            &self.scalar(eta)?,
            &self.scalar(lambda)?,
            &self.scalar(mu)?,
            &self.scalar(gamma)?,
        ];
        let out = self.run(&self.exes.client_step, &args)?;
        if out.len() != 2 {
            bail!("client_step returned {} outputs, want 2", out.len());
        }
        Ok((Self::vec_f32(&out[0])?, Self::scalar_f32(&out[1])?))
    }

    /// Plain local SGD step (baselines). Returns (w', loss).
    pub fn sgd_step(
        &self,
        w: &[f32],
        x: &[f32],
        y: &[i32],
        eta: f32,
        mu: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let g = &self.geom;
        let wb = self.buf_f32(w, &[g.n])?;
        let xb = self.buf_f32(x, &[g.train_batch, g.input_dim])?;
        let yb = self.buf_i32(y, &[g.train_batch])?;
        let args = [&wb, &xb, &yb, &self.scalar(eta)?, &self.scalar(mu)?];
        let out = self.run(&self.exes.sgd_step, &args)?;
        if out.len() != 2 {
            bail!("sgd_step returned {} outputs, want 2", out.len());
        }
        Ok((Self::vec_f32(&out[0])?, Self::scalar_f32(&out[1])?))
    }

    /// R pFed1BS local steps with w DEVICE-RESIDENT throughout: step r's
    /// output buffer (non-tuple root) feeds step r+1's input directly,
    /// eliminating 2·n f32 host transfers per step (§Perf: measured
    /// before/after in EXPERIMENTS.md). The first step runs through the
    /// tuple-rooted `client_step` to obtain the round's train loss.
    ///
    /// `next_batch` is called R times and must yield (x, y) of the
    /// artifact's train-batch shape.
    #[allow(clippy::too_many_arguments)]
    pub fn client_round(
        &self,
        w: &[f32],
        mut next_batch: impl FnMut() -> (Vec<f32>, Vec<i32>),
        r_steps: usize,
        v: &[f32],
        eta: f32,
        lambda: f32,
        mu: f32,
        gamma: f32,
    ) -> Result<(Vec<f32>, f32)> {
        assert!(r_steps >= 1);
        let g = &self.geom;
        let vb = self.buf_f32(v, &[g.m])?;
        let scalars = [
            self.scalar(eta)?,
            self.scalar(lambda)?,
            self.scalar(mu)?,
            self.scalar(gamma)?,
        ];
        // step 0: tuple-rooted artifact → loss; w' comes back to host once
        let (x0, y0) = next_batch();
        let (w_host, loss) = self.client_step(w, &x0, &y0, v, eta, lambda, mu, gamma)?;
        let mut w_dev = self.buf_f32(&w_host, &[g.n])?;
        // steps 1..R: non-tuple artifact, output buffer loops back
        for _ in 1..r_steps {
            let (x, y) = next_batch();
            let xb = self.buf_f32(&x, &[g.train_batch, g.input_dim])?;
            let yb = self.buf_i32(&y, &[g.train_batch])?;
            let args = [
                &w_dev,
                &xb,
                &yb,
                &vb,
                &self.dsign_buf,
                &self.sidx_buf,
                &scalars[0],
                &scalars[1],
                &scalars[2],
                &scalars[3],
            ];
            let mut out = self
                .exes
                .client_step_w
                .execute_b(&args)
                .map_err(|e| anyhow!("client_step_w execute: {e:?}"))?;
            w_dev = out
                .get_mut(0)
                .and_then(|v| {
                    if v.is_empty() {
                        None
                    } else {
                        Some(v.remove(0))
                    }
                })
                .ok_or_else(|| anyhow!("client_step_w returned no buffer"))?;
        }
        let lit = w_dev
            .to_literal_sync()
            .map_err(|e| anyhow!("device->host: {e:?}"))?;
        Ok((Self::vec_f32(&lit)?, loss))
    }

    /// Cohort batch width B of the loaded batched executables, or 1 when
    /// only the per-client family is loaded.
    pub fn device_batch(&self) -> usize {
        self.exes.batched.as_ref().map_or(1, |b| b.batch)
    }

    /// Stack L ≤ B per-lane vectors into one `[B, per]` row-major buffer,
    /// padding lanes L..B by replicating the last real lane. Padded lanes
    /// are pure dispatch ballast: their outputs are never read back, and
    /// replicating a real lane keeps every value finite so no NaN/Inf can
    /// leak out of a lane (vmap lanes are data-independent — DESIGN.md §15).
    fn stack_padded(lanes: &[&[f32]], b: usize, per: usize) -> Vec<f32> {
        debug_assert!(!lanes.is_empty() && lanes.len() <= b);
        let mut out = Vec::with_capacity(b * per);
        for lane in lanes {
            debug_assert_eq!(lane.len(), per);
            out.extend_from_slice(lane);
        }
        let last = lanes[lanes.len() - 1];
        for _ in lanes.len()..b {
            out.extend_from_slice(last);
        }
        out
    }

    /// R pFed1BS local steps for up to B clients with ONE device dispatch
    /// per step instead of B (`local_round_batched` of DESIGN.md §15).
    ///
    /// Lane layout: `ws[lane]` / `vs[lane]` are client `lane`'s weights and
    /// personal sketch; `next_batch(lane)` is called once per (step, lane)
    /// in step-major, lane-ascending order and must yield that lane's next
    /// train tile — each lane therefore consumes exactly the batch
    /// sequence it would in the per-client path. Short cohorts (L < B) are
    /// padded by replicating the last real lane; padded outputs are
    /// discarded. The stacked `[B, n]` weight buffer is device-resident
    /// across steps 1..R exactly like the per-client `client_round`.
    ///
    /// Returns one `(w', loss)` per REAL lane, in lane order — bit-identical
    /// to L separate `client_round` calls (property-tested).
    #[allow(clippy::too_many_arguments)]
    pub fn client_round_batched(
        &self,
        ws: &[&[f32]],
        vs: &[&[f32]],
        mut next_batch: impl FnMut(usize) -> (Vec<f32>, Vec<i32>),
        r_steps: usize,
        eta: f32,
        lambda: f32,
        mu: f32,
        gamma: f32,
    ) -> Result<Vec<(Vec<f32>, f32)>> {
        assert!(r_steps >= 1);
        let g = self.geom;
        let bex = self
            .exes
            .batched
            .as_ref()
            .ok_or_else(|| anyhow!("no batched executables loaded for `{}`", self.variant))?;
        let b = bex.batch;
        let l = ws.len();
        if l == 0 || l > b {
            bail!("client_round_batched: {l} lanes for batch width {b}");
        }
        if vs.len() != l {
            bail!("client_round_batched: {} v lanes for {l} w lanes", vs.len());
        }
        // One (step, lane) tile gather → stacked [B, tb, d] / [B, tb] literals.
        let tile = g.train_batch * g.input_dim;
        let gather_step =
            |next_batch: &mut dyn FnMut(usize) -> (Vec<f32>, Vec<i32>)| -> (Vec<f32>, Vec<i32>) {
                let mut xs = Vec::with_capacity(b * tile);
                let mut ys = Vec::with_capacity(b * g.train_batch);
                for lane in 0..l {
                    let (x, y) = next_batch(lane);
                    debug_assert_eq!(x.len(), tile);
                    debug_assert_eq!(y.len(), g.train_batch);
                    xs.extend_from_slice(&x);
                    ys.extend_from_slice(&y);
                }
                for _ in l..b {
                    // replicate the last real lane's tile (see stack_padded)
                    let (xl, yl) = (xs[(l - 1) * tile..l * tile].to_vec(), ys[(l - 1) * g.train_batch..l * g.train_batch].to_vec());
                    xs.extend_from_slice(&xl);
                    ys.extend_from_slice(&yl);
                }
                (xs, ys)
            };
        let vb = self.buf_f32(&Self::stack_padded(vs, b, g.m), &[b, g.m])?;
        let scalars = [
            self.scalar(eta)?,
            self.scalar(lambda)?,
            self.scalar(mu)?,
            self.scalar(gamma)?,
        ];
        // step 0: tuple-rooted artifact → per-lane losses; stacked w' comes
        // back to host once, mirroring the per-client path's step 0.
        let w0 = Self::stack_padded(ws, b, g.n);
        let wb = self.buf_f32(&w0, &[b, g.n])?;
        let (x0, y0) = gather_step(&mut next_batch);
        let x0b = self.buf_f32(&x0, &[b, g.train_batch, g.input_dim])?;
        let y0b = self.buf_i32(&y0, &[b, g.train_batch])?;
        let args = [
            &wb,
            &x0b,
            &y0b,
            &vb,
            &self.dsign_buf,
            &self.sidx_buf,
            &scalars[0],
            &scalars[1],
            &scalars[2],
            &scalars[3],
        ];
        let out = self.run(&bex.client_step_batched, &args)?;
        if out.len() != 2 {
            bail!("client_step_batched returned {} outputs, want 2", out.len());
        }
        let w_host = Self::vec_f32(&out[0])?;
        let losses = Self::vec_f32(&out[1])?;
        if w_host.len() != b * g.n || losses.len() != b {
            bail!(
                "client_step_batched output shape mismatch: {} weights / {} losses for B={b}",
                w_host.len(),
                losses.len()
            );
        }
        let mut w_dev = self.buf_f32(&w_host, &[b, g.n])?;
        // steps 1..R: non-tuple artifact, stacked output buffer loops back
        for _ in 1..r_steps {
            let (x, y) = gather_step(&mut next_batch);
            let xb = self.buf_f32(&x, &[b, g.train_batch, g.input_dim])?;
            let yb = self.buf_i32(&y, &[b, g.train_batch])?;
            let args = [
                &w_dev,
                &xb,
                &yb,
                &vb,
                &self.dsign_buf,
                &self.sidx_buf,
                &scalars[0],
                &scalars[1],
                &scalars[2],
                &scalars[3],
            ];
            let mut out = bex
                .client_step_batched_w
                .execute_b(&args)
                .map_err(|e| anyhow!("client_step_batched_w execute: {e:?}"))?;
            w_dev = out
                .get_mut(0)
                .and_then(|v| {
                    if v.is_empty() {
                        None
                    } else {
                        Some(v.remove(0))
                    }
                })
                .ok_or_else(|| anyhow!("client_step_batched_w returned no buffer"))?;
        }
        let lit = w_dev
            .to_literal_sync()
            .map_err(|e| anyhow!("device->host: {e:?}"))?;
        let stacked = Self::vec_f32(&lit)?;
        if stacked.len() != b * g.n {
            bail!("stacked w' length {} != B·n", stacked.len());
        }
        Ok((0..l)
            .map(|lane| (stacked[lane * g.n..(lane + 1) * g.n].to_vec(), losses[lane]))
            .collect())
    }

    /// Packed one-bit sketches for up to B clients in one dispatch —
    /// the batched form of [`Self::sketch_sign_packed`]. Lane order and
    /// padding semantics match [`Self::client_round_batched`].
    pub fn sketch_sign_batched_packed(
        &self,
        ws: &[&[f32]],
    ) -> Result<Vec<crate::sketch::bitpack::SignVec>> {
        let g = self.geom;
        let bex = self
            .exes
            .batched
            .as_ref()
            .ok_or_else(|| anyhow!("no batched executables loaded for `{}`", self.variant))?;
        let b = bex.batch;
        let l = ws.len();
        if l == 0 || l > b {
            bail!("sketch_sign_batched_packed: {l} lanes for batch width {b}");
        }
        let wb = self.buf_f32(&Self::stack_padded(ws, b, g.n), &[b, g.n])?;
        let out = self.run(&bex.sketch_batched, &[&wb, &self.dsign_buf, &self.sidx_buf])?;
        let z = Self::vec_f32(&out[0])?;
        if z.len() != b * g.m {
            bail!("sketch_batched output length {} != B·m", z.len());
        }
        Ok((0..l)
            .map(|lane| crate::sketch::bitpack::SignVec::from_signs(&z[lane * g.m..(lane + 1) * g.m]))
            .collect())
    }

    /// R plain SGD steps with device-resident w (baselines' ClientUpdate;
    /// same optimization as `client_round`).
    pub fn sgd_round(
        &self,
        w: &[f32],
        mut next_batch: impl FnMut() -> (Vec<f32>, Vec<i32>),
        r_steps: usize,
        eta: f32,
        mu: f32,
    ) -> Result<(Vec<f32>, f32)> {
        assert!(r_steps >= 1);
        let g = &self.geom;
        let scalars = [self.scalar(eta)?, self.scalar(mu)?];
        let (x0, y0) = next_batch();
        let (w_host, loss) = self.sgd_step(w, &x0, &y0, eta, mu)?;
        let mut w_dev = self.buf_f32(&w_host, &[g.n])?;
        for _ in 1..r_steps {
            let (x, y) = next_batch();
            let xb = self.buf_f32(&x, &[g.train_batch, g.input_dim])?;
            let yb = self.buf_i32(&y, &[g.train_batch])?;
            let args = [&w_dev, &xb, &yb, &scalars[0], &scalars[1]];
            let mut out = self
                .exes
                .sgd_step_w
                .execute_b(&args)
                .map_err(|e| anyhow!("sgd_step_w execute: {e:?}"))?;
            w_dev = out
                .get_mut(0)
                .and_then(|v| {
                    if v.is_empty() {
                        None
                    } else {
                        Some(v.remove(0))
                    }
                })
                .ok_or_else(|| anyhow!("sgd_step_w returned no buffer"))?;
        }
        let lit = w_dev
            .to_literal_sync()
            .map_err(|e| anyhow!("device->host: {e:?}"))?;
        Ok((Self::vec_f32(&lit)?, loss))
    }

    /// z = sign(Φw) ∈ {−1,+1}^m (Algorithm 1 line 18).
    pub fn sketch_sign(&self, w: &[f32]) -> Result<Vec<f32>> {
        let wb = self.buf_f32(w, &[self.geom.n])?;
        let out = self.run(&self.exes.sketch, &[&wb, &self.dsign_buf, &self.sidx_buf])?;
        Self::vec_f32(&out[0])
    }

    /// z = sign(Φw) packed to u64 words — the transport-ready form and
    /// the single pack at the compute/transport boundary (DESIGN.md §8).
    /// The HLO artifact emits f32 ±1 lanes in a PJRT literal; the
    /// literal→host copy is the one m-vector this path materializes
    /// (the `xla` crate exposes no borrowed literal view), and the
    /// words are packed straight from it — mirroring the rust-side
    /// `SrhtOperator::sketch_sign_packed`, which packs directly off the
    /// kernel plan's rotated scratch (DESIGN.md §10).
    pub fn sketch_sign_packed(&self, w: &[f32]) -> Result<crate::sketch::bitpack::SignVec> {
        Ok(crate::sketch::bitpack::SignVec::from_signs(&self.sketch_sign(w)?))
    }

    /// (#correct, loss_sum) over one eval batch (padding labels < 0 are
    /// masked inside the artifact).
    pub fn eval_batch(&self, w: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let g = &self.geom;
        let wb = self.buf_f32(w, &[g.n])?;
        let xb = self.buf_f32(x, &[g.eval_batch, g.input_dim])?;
        let yb = self.buf_i32(y, &[g.eval_batch])?;
        let out = self.run(&self.exes.eval, &[&wb, &xb, &yb])?;
        if out.len() != 2 {
            bail!("eval returned {} outputs, want 2", out.len());
        }
        Ok((Self::scalar_f32(&out[0])?, Self::scalar_f32(&out[1])?))
    }

    /// ‖∇F̃_k(w; v)‖² on one batch (Theorem 1 diagnostic).
    #[allow(clippy::too_many_arguments)]
    pub fn grad_norm(
        &self,
        w: &[f32],
        x: &[f32],
        y: &[i32],
        v: &[f32],
        lambda: f32,
        mu: f32,
        gamma: f32,
    ) -> Result<f32> {
        let g = &self.geom;
        let wb = self.buf_f32(w, &[g.n])?;
        let xb = self.buf_f32(x, &[g.train_batch, g.input_dim])?;
        let yb = self.buf_i32(y, &[g.train_batch])?;
        let vb = self.buf_f32(v, &[g.m])?;
        let args = [
            &wb,
            &xb,
            &yb,
            &vb,
            &self.dsign_buf,
            &self.sidx_buf,
            &self.scalar(lambda)?,
            &self.scalar(mu)?,
            &self.scalar(gamma)?,
        ];
        let out = self.run(&self.exes.grad_norm, &args)?;
        Self::scalar_f32(&out[0])
    }
}
