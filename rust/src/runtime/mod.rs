//! L3 ↔ L2 bridge: PJRT client, artifact manifest, compiled executables.
//!
//! Python runs only at build time (`make artifacts`); everything here
//! consumes the AOT HLO text it produced.

pub mod exec;
pub mod manifest;

pub use exec::{BatchedExecutables, Geometry, ModelExecutables, ModelRuntime, Runtime};
pub use manifest::{ArtifactInfo, Manifest};
