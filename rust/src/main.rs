//! pFed1BS leader binary.
//!
//! Subcommands map 1:1 to the paper's evaluation artifacts (DESIGN.md §7)
//! plus the multi-process transport roles (DESIGN.md §12):
//!
//! ```text
//! pfed1bs train        --alg pfed1bs --dataset mnist [--rounds N --seed S …]
//! pfed1bs table1                         # capability matrix (paper Table 1)
//! pfed1bs table2       [--datasets a,b --algs x,y --seeds k --rounds N]
//! pfed1bs fig3-4       [--rounds N --diagnostics]
//! pfed1bs fig-a1       [--values 5,10,15,20]
//! pfed1bs fig-a2       [--values 5,10,20,25,30]
//! pfed1bs fig-a3
//! pfed1bs table-a1     [--seeds k --rounds N]
//! pfed1bs bound        [--dataset mnist --m N …]   # Theorem-1 constants
//! pfed1bs info                           # artifact manifest summary
//! pfed1bs perf-compare [--baseline BENCH_BASELINE.json --reports . --class ARCH]
//! pfed1bs serve        --listen tcp:0.0.0.0:7171 [--check-consensus …]
//! pfed1bs edge         --connect tcp:ROOT:7171 --listen unix:/tmp/e0.sock
//! pfed1bs client-fleet --connect tcp:HOST:7171 [--lo A --hi B --conns C]
//! pfed1bs loadgen      --connect tcp:HOST:7171 [--clients 10000 …]
//! ```

use anyhow::{bail, Result};

use pfed1bs::config::{RunConfig, ServeConfig, ServeRole};
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::{self, runner::Lab};
use pfed1bs::util::cli::Args;

fn main() {
    pfed1bs::util::log::init_from_env();
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "train" => cmd_train(&args),
        "table1" => {
            experiments::print_table1();
            Ok(())
        }
        "table2" => cmd_table2(&args),
        "fig3-4" | "fig34" => cmd_fig34(&args),
        "fig-a1" => cmd_fig_a1(&args),
        "fig-a2" => cmd_fig_a2(&args),
        "fig-a3" => cmd_fig_a3(&args),
        "table-a1" => cmd_table_a1(&args),
        "bound" => cmd_bound(&args),
        "info" => cmd_info(&args),
        "perf-compare" => cmd_perf_compare(&args),
        "serve" => cmd_role(ServeRole::Root, &args),
        "edge" => cmd_role(ServeRole::Edge, &args),
        "client-fleet" | "fleet" => cmd_role(ServeRole::Fleet, &args),
        "loadgen" => cmd_role(ServeRole::Loadgen, &args),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` — try `pfed1bs help`"),
    }
}

const HELP: &str = "\
pfed1bs — Personalized Federated Learning via One-Bit Random Sketching (AAAI 2026)

USAGE: pfed1bs <subcommand> [--key value …]

subcommands:
  train      one training run        (--alg --dataset --rounds --seed …)
  table1     capability matrix       (paper Table 1)
  table2     accuracy + comm cost    (paper Table 2)
  fig3-4     MNIST convergence curves (paper Figs. 3 & 4)
  fig-a1     participation sweep S   (appendix Fig. 1)
  fig-a2     local-steps sweep R     (appendix Fig. 2)
  fig-a3     FHT vs dense Gaussian   (appendix Fig. 3)
  table-a1   λ/μ/γ sensitivity       (appendix Table 1)
  bound      Theorem-1 constants + predicted neighborhood for a config
  info       artifact manifest summary
  perf-compare  gate BENCH_*.json vs the committed baseline (DESIGN.md §14)
                (--baseline BENCH_BASELINE.json --reports . --class ARCH;
                 PFED1BS_UPDATE_BASELINE=1 re-pins the current class)

multi-process transport roles (DESIGN.md §12 — no artifacts needed):
  serve         root server      (--listen tcp:H:P|unix:/path  --clients K
                                  --participating S --rounds T --m M --seed S
                                  --check-consensus  --quorum Q
                                  --staleness-decay D)
  edge          edge aggregator  (--connect UPSTREAM --listen FLEET-SIDE
                                  --lo A --hi B --edge-id E)
  client-fleet  N mock clients   (--connect EP --lo A --hi B --conns C)
  loadgen       throughput probe (--connect EP --clients 10000 --conns C;
                                  reports rounds/sec + p99 uplink-to-absorb
                                  latency as BENCH_loadgen.json)
  role knobs:   --timeout-ms MS  --max-frame-mb MB  --want-ack

common options: --artifacts-dir artifacts  --results-dir results
                --seed N  --seeds K  --rounds N  --dataset name
                --device-batch B  pack up to B clients per device dispatch
                                  (0 = auto: PFED1BS_DEVICE_BATCH env, else 1;
                                   bit-identical for any B — DESIGN.md §15)
scenario knobs: --over-select N  --deadline-ms MS  --dropout-prob P
                --latency zero|fixed:MS|uniform:LO:HI|lognormal:MED:SIGMA
                --topology flat|edge:E  --edge-dropout-prob P
                --quorum Q  --max-staleness A  --staleness-decay D
                --churn-prob P  --churn-period W
hostile knobs:  --attack none|signflip:F|scale:F:GAMMA|collude:F
                --trim-frac F  --mom-groups G  --error-feedback true
                (robust tallies + EF — DESIGN.md §16)
run `make artifacts` once before any train/table/fig subcommand.
";

fn cmd_perf_compare(args: &Args) -> Result<()> {
    let baseline = args.str_or("baseline", "BENCH_BASELINE.json");
    let reports = args.str_or("reports", ".");
    let class = args.str_or("class", std::env::consts::ARCH);
    args.reject_unknown()?;
    pfed1bs::bench_harness::compare::run(&baseline, &reports, &class)
}

fn cmd_role(role: ServeRole, args: &Args) -> Result<()> {
    let cfg = ServeConfig::from_args(role, args)?;
    args.reject_unknown()?;
    pfed1bs::serve::run(&cfg)
}

fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts-dir", "artifacts")
}

fn parse_datasets(spec: &str) -> Result<Vec<DatasetName>> {
    spec.split(',')
        .map(|s| {
            DatasetName::parse(s.trim())
                .ok_or_else(|| anyhow::anyhow!("unknown dataset `{s}`"))
        })
        .collect()
}

fn parse_usizes(spec: &str) -> Result<Vec<usize>> {
    spec.split(',')
        .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow::anyhow!("{s}: {e}")))
        .collect()
}

fn cmd_train(args: &Args) -> Result<()> {
    let dataset = DatasetName::parse(&args.str_or("dataset", "mnist"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let mut cfg = RunConfig::preset(dataset);
    cfg.apply_args(args)?;
    args.reject_unknown()?;
    let lab = Lab::new(&cfg.artifacts_dir)?;
    println!("run: {}", cfg.summary());
    let results_dir = cfg.results_dir.clone();
    let alg_name = cfg.algorithm.clone();
    let result = lab.run_with_diagnostics(cfg.clone(), args.flag("diagnostics"))?;
    let csv = format!("{results_dir}/train_{alg_name}_{}.csv", dataset.as_str());
    result.history.write_csv(&csv, &cfg.summary())?;
    println!(
        "final: acc={:.4} loss={:.4} mean_round_mb={:.4}  (history: {csv})",
        result.final_accuracy, result.final_loss, result.mean_round_mb
    );
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let mut opts = experiments::table2::Table2Options {
        seeds: args.parse_or("seeds", 3usize)?,
        rounds: args.parse_or("rounds", 0usize)?,
        results_dir: args.str_or("results-dir", "results"),
        ..Default::default()
    };
    if let Some(ds) = args.get("datasets") {
        opts.datasets = parse_datasets(ds)?;
    }
    if let Some(al) = args.get("algs") {
        opts.algorithms = al.split(',').map(|s| s.trim().to_string()).collect();
    }
    let lab = Lab::new(&artifacts_dir(args))?;
    args.reject_unknown()?;
    experiments::table2::run(&lab, &opts)?;
    Ok(())
}

fn cmd_fig34(args: &Args) -> Result<()> {
    let mut opts = experiments::convergence::ConvergenceOptions {
        rounds: args.parse_or("rounds", 0usize)?,
        seed: args.parse_or("seed", 17u64)?,
        diagnostics: args.flag("diagnostics"),
        results_dir: args.str_or("results-dir", "results"),
        ..Default::default()
    };
    if let Some(ds) = args.get("dataset") {
        opts.dataset = DatasetName::parse(ds).ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    }
    if let Some(al) = args.get("algs") {
        opts.algorithms = al.split(',').map(|s| s.trim().to_string()).collect();
    }
    let lab = Lab::new(&artifacts_dir(args))?;
    args.reject_unknown()?;
    experiments::convergence::run(&lab, &opts)
}

fn ablation_opts(args: &Args) -> Result<experiments::ablations::AblationOptions> {
    let mut opts = experiments::ablations::AblationOptions {
        rounds: args.parse_or("rounds", 0usize)?,
        seed: args.parse_or("seed", 17u64)?,
        results_dir: args.str_or("results-dir", "results"),
        ..Default::default()
    };
    if let Some(ds) = args.get("dataset") {
        opts.dataset = DatasetName::parse(ds).ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    }
    Ok(opts)
}

fn cmd_fig_a1(args: &Args) -> Result<()> {
    let opts = ablation_opts(args)?;
    let values = parse_usizes(&args.str_or("values", "5,10,15,20"))?;
    let lab = Lab::new(&artifacts_dir(args))?;
    args.reject_unknown()?;
    experiments::ablations::participation(&lab, &opts, &values)
}

fn cmd_fig_a2(args: &Args) -> Result<()> {
    let opts = ablation_opts(args)?;
    let values = parse_usizes(&args.str_or("values", "5,10,20,25,30"))?;
    let lab = Lab::new(&artifacts_dir(args))?;
    args.reject_unknown()?;
    experiments::ablations::local_steps(&lab, &opts, &values)
}

fn cmd_fig_a3(args: &Args) -> Result<()> {
    let opts = ablation_opts(args)?;
    let lab = Lab::new(&artifacts_dir(args))?;
    args.reject_unknown()?;
    experiments::ablations::projection(&lab, &opts)
}

fn cmd_table_a1(args: &Args) -> Result<()> {
    let mut opts = experiments::sensitivity::SensitivityOptions {
        rounds: args.parse_or("rounds", 0usize)?,
        seeds: args.parse_or("seeds", 2usize)?,
        seed: args.parse_or("seed", 17u64)?,
        results_dir: args.str_or("results-dir", "results"),
        ..Default::default()
    };
    if let Some(ds) = args.get("dataset") {
        opts.dataset = DatasetName::parse(ds).ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    }
    let lab = Lab::new(&artifacts_dir(args))?;
    args.reject_unknown()?;
    experiments::sensitivity::run(&lab, &opts)
}

fn cmd_bound(args: &Args) -> Result<()> {
    let dataset = DatasetName::parse(&args.str_or("dataset", "mnist"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let mut cfg = RunConfig::preset(dataset);
    cfg.apply_args(args)?;
    args.reject_unknown()?;
    let manifest = pfed1bs::runtime::Manifest::load(&cfg.artifacts_dir)?;
    let info = manifest.get("client_step", dataset.model_variant())?;
    let geom = pfed1bs::runtime::Geometry {
        n: info.n,
        npad: info.npad,
        m: info.m,
        input_dim: info.input_dim,
        classes: info.classes,
        train_batch: info.train_batch,
        eval_batch: info.eval_batch,
    };
    print!("{}", pfed1bs::analysis::report(&cfg, &geom));
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let manifest = pfed1bs::runtime::Manifest::load(artifacts_dir(args))?;
    args.reject_unknown()?;
    println!("artifacts: {} records", manifest.len());
    for variant in manifest.variants() {
        let info = manifest.get("client_step", &variant)?;
        println!(
            "  {variant}: n={} n'={} m={} d={} classes={} batch={} eval_batch={}",
            info.n, info.npad, info.m, info.input_dim, info.classes,
            info.train_batch, info.eval_batch
        );
        let widths = manifest.batch_sizes(&variant);
        if !widths.is_empty() {
            let ws: Vec<String> = widths.iter().map(|b| b.to_string()).collect();
            println!("    cohort batch widths: {}", ws.join(", "));
        }
    }
    Ok(())
}
