//! Run configuration: paper presets + file + CLI overrides.
//!
//! Precedence (lowest to highest): dataset preset ← config file
//! (`--config path`, key=value lines) ← individual CLI flags.

pub mod parser;
pub mod serve;

pub use serve::{Endpoint, ServeConfig, ServeRole};

use anyhow::{bail, Result};

use crate::comm::LatencyModel;
use crate::data::{DatasetName, Partition};
use crate::util::cli::Args;

/// Which projection realizes Φ (Appendix Fig. 3 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectionKind {
    /// structured SRHT (the paper's FHT-based operator)
    Fht,
    /// dense Gaussian matrix (the O(mn) baseline the FHT replaces)
    DenseGaussian,
}

impl ProjectionKind {
    /// Parse a config value: `fht | dense` (and common synonyms).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fht" | "srht" => ProjectionKind::Fht,
            "dense" | "gaussian" | "dense-gaussian" => ProjectionKind::DenseGaussian,
            other => bail!("unknown projection `{other}` (fht|dense)"),
        })
    }

    /// Canonical config-key spelling (inverse of [`ProjectionKind::parse`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            ProjectionKind::Fht => "fht",
            ProjectionKind::DenseGaussian => "dense",
        }
    }
}

/// Server aggregation topology (DESIGN.md §11).
///
/// `Flat` is the paper's single aggregator. `Edge { edges: E }` places E
/// edge aggregators between the clients and the root: each edge streams
/// its assigned clients' uplinks into its own O(m) aggregator shard in
/// arrival order, ships one compact merge frame
/// ([`Payload::TallyFrame`]) to the root, and the root merges the shards
/// in canonical edge order (0, 1, …, E−1). For every exact aggregation
/// kind (the fixed-point one-bit tallies) the merged result is
/// bit-identical to the flat server — the shard-parallel license of
/// DESIGN.md §9, cashed in.
///
/// The client→edge assignment is *derived*, never persisted: client `k`
/// reports to edge `k mod E` (stable across rounds, checkpoint-free).
///
/// [`Payload::TallyFrame`]: crate::comm::Payload::TallyFrame
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Topology {
    /// single server aggregator — today's engine, byte-for-byte
    #[default]
    Flat,
    /// client → edge → root hierarchy with this many edge aggregators
    Edge {
        /// number of edge aggregators E (≥ 1)
        edges: usize,
    },
}

impl Topology {
    /// Parse a config value: `flat | edge:E`.
    pub fn parse(s: &str) -> Result<Topology> {
        let topo = match s.to_ascii_lowercase().as_str() {
            "flat" => Topology::Flat,
            other => match other.strip_prefix("edge:") {
                Some(e) => Topology::Edge {
                    edges: e
                        .parse()
                        .map_err(|err| anyhow::anyhow!("topology `{s}`: bad edge count: {err}"))?,
                },
                None => bail!("unknown topology `{s}` (flat|edge:E)"),
            },
        };
        topo.validate()?;
        Ok(topo)
    }

    /// Reject degenerate shapes (an `edge:0` hierarchy has nowhere to
    /// route uplinks).
    pub fn validate(&self) -> Result<()> {
        if let Topology::Edge { edges } = self {
            if *edges == 0 {
                bail!("topology edge:0 — need at least one edge aggregator");
            }
        }
        Ok(())
    }

    /// One-line form for run summaries (inverse of [`Topology::parse`]).
    pub fn summary(&self) -> String {
        match self {
            Topology::Flat => "flat".to_string(),
            Topology::Edge { edges } => format!("edge:{edges}"),
        }
    }

    /// Number of edge aggregators: 0 under `flat` (no edge tier), E
    /// under `edge:E` — the metrics CSV's `edges` column.
    pub fn edges(&self) -> usize {
        match self {
            Topology::Flat => 0,
            Topology::Edge { edges } => *edges,
        }
    }

    /// How many aggregator shards the round engine folds into: 1 under
    /// `flat`, E under `edge:E`.
    pub fn shards(&self) -> usize {
        self.edges().max(1)
    }

    /// The derived client→edge assignment: client `k` reports to edge
    /// `k mod E` (always 0 under `flat`). Derived, never persisted.
    pub fn edge_of(&self, client: usize) -> usize {
        match self {
            Topology::Flat => 0,
            Topology::Edge { edges } => client % edges,
        }
    }
}

/// Byzantine attack model for hostile-fleet runs (DESIGN.md §16).
///
/// An attack designates a deterministic adversarial fraction of each
/// round's computing clients — drawn statelessly per `(seed, t, k)`
/// like the churn/outage lifecycle draws, so `Attack::None` consumes
/// zero RNG draws and leaves every honest trace byte-for-byte — and
/// corrupts the adversaries' uplink payloads *after* honest local
/// compute, at the wire boundary. Local personalized state stays
/// honest: the attack is on the channel's content, not the client's
/// own training.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum Attack {
    /// honest fleet — today's behavior, bit-for-bit
    #[default]
    None,
    /// adversaries flip every sign bit of their uplink (negate dense
    /// lanes), the classic sign-flipping Byzantine attack
    SignFlip {
        /// adversarial fraction F of each round's computing clients
        frac: f64,
    },
    /// adversaries rescale their uplink by γ (flip-and-amplify when
    /// γ < 0). One-bit `Signs` payloads carry no magnitude, so only
    /// the sign of γ can bite there: γ < 0 flips, γ > 0 is absorbed
    /// by sign().
    Scale {
        /// adversarial fraction F
        frac: f64,
        /// the multiplier γ applied to the uplink
        gamma: f64,
    },
    /// adversaries replace their uplink with ONE shared malicious
    /// sketch, derived statelessly per `(seed, t)` — the coordinated
    /// worst case for a majority vote
    Collude {
        /// adversarial fraction F
        frac: f64,
    },
}

impl Attack {
    /// Parse a config value: `none | signflip:F | scale:F:GAMMA |
    /// collude:F`.
    pub fn parse(s: &str) -> Result<Attack> {
        let lower = s.to_ascii_lowercase();
        let num = |part: &str, what: &str| -> Result<f64> {
            part.parse()
                .map_err(|e| anyhow::anyhow!("attack `{s}`: bad {what}: {e}"))
        };
        let attack = if lower == "none" {
            Attack::None
        } else if let Some(f) = lower.strip_prefix("signflip:") {
            Attack::SignFlip { frac: num(f, "fraction")? }
        } else if let Some(rest) = lower.strip_prefix("scale:") {
            let Some((f, g)) = rest.split_once(':') else {
                bail!("attack `{s}`: scale needs `scale:F:GAMMA`");
            };
            Attack::Scale { frac: num(f, "fraction")?, gamma: num(g, "gamma")? }
        } else if let Some(f) = lower.strip_prefix("collude:") {
            Attack::Collude { frac: num(f, "fraction")? }
        } else {
            bail!("unknown attack `{s}` (none|signflip:F|scale:F:GAMMA|collude:F)");
        };
        attack.validate()?;
        Ok(attack)
    }

    /// Reject fractions outside [0, 1) and non-finite multipliers.
    pub fn validate(&self) -> Result<()> {
        let frac = self.fraction();
        if !(0.0..1.0).contains(&frac) {
            bail!("attack fraction must be in [0, 1) (got {frac})");
        }
        if let Attack::Scale { gamma, .. } = self {
            if !gamma.is_finite() {
                bail!("attack scale gamma must be finite (got {gamma})");
            }
        }
        Ok(())
    }

    /// The adversarial fraction F (0 for `none`).
    pub fn fraction(&self) -> f64 {
        match self {
            Attack::None => 0.0,
            Attack::SignFlip { frac }
            | Attack::Scale { frac, .. }
            | Attack::Collude { frac } => *frac,
        }
    }

    /// Does this attack actually mark adversaries? A zero fraction is
    /// the honest fleet spelled out.
    pub fn is_active(&self) -> bool {
        self.fraction() > 0.0
    }

    /// One-line form for run summaries (inverse of [`Attack::parse`]).
    pub fn summary(&self) -> String {
        match self {
            Attack::None => "none".to_string(),
            Attack::SignFlip { frac } => format!("signflip:{frac}"),
            Attack::Scale { frac, gamma } => format!("scale:{frac}:{gamma}"),
            Attack::Collude { frac } => format!("collude:{frac}"),
        }
    }
}

/// Full configuration of one federated training run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// which (synthetic) dataset the run trains on
    pub dataset: DatasetName,
    /// algorithm name, resolved by `algorithms::build`
    pub algorithm: String,
    /// K — total clients
    pub clients: usize,
    /// S — participating clients per round
    pub participating: usize,
    /// T — communication rounds
    pub rounds: usize,
    /// R — local SGD steps per round
    pub local_steps: usize,
    /// η — client learning rate
    pub eta: f32,
    /// λ — sign-alignment strength (paper grid-search value 5e-4)
    pub lambda: f32,
    /// μ — l2 penalty (paper 1e-5)
    pub mu: f32,
    /// γ — smoothing temperature (paper 1e4)
    pub gamma: f32,
    /// m/n compression ratio (paper fixes 0.1)
    pub sketch_ratio: f64,
    /// classes per client under label-shard partitioning
    pub shards_per_client: usize,
    /// Dirichlet alpha; used when `partition == "dirichlet"`
    pub dirichlet_alpha: f64,
    /// partition scheme: `label-shards | dirichlet | iid`
    pub partition: String,
    /// which projection realizes Φ (Appendix Fig. 3 ablation)
    pub projection: ProjectionKind,
    /// the run seed every RNG stream derives from
    pub seed: u64,
    /// evaluate every this many rounds (and always at the last round)
    pub eval_every: usize,
    /// server-side learning rate for sign-vote baselines (OBDA)
    pub server_lr: f32,
    /// zSignFed perturbation scale
    pub zsign_noise: f32,
    /// worker threads for the data-parallel client phase (0 = auto:
    /// `PFED1BS_CLIENT_THREADS` env var, else available parallelism);
    /// results are bit-identical for any value
    pub client_threads: usize,
    /// cohort device-batch width B: up to B clients advance per PJRT
    /// dispatch through the `*_batched` artifacts (DESIGN.md §15).
    /// 0 = auto: `PFED1BS_DEVICE_BATCH` env var, else 1. Like
    /// `client_threads`, results are bit-identical for any value —
    /// 1 runs today's per-client path byte-for-byte.
    pub device_batch: usize,
    /// extra clients selected beyond S each round (over-selection: the
    /// round still closes after S deliveries, so stragglers beyond the
    /// target are cut — DESIGN.md §9). 0 = exactly S, the default.
    pub over_select: usize,
    /// per-round uplink deadline in simulated ms; arrivals after it are
    /// cut as stragglers. 0 = no deadline (the default).
    pub deadline_ms: f64,
    /// probability a selected client drops out of a round (unreachable
    /// after the broadcast: no local work, no uplink). 0 = never.
    pub dropout_prob: f64,
    /// per-client uplink service-time distribution (`zero`, `fixed:MS`,
    /// `uniform:LO:HI`, `lognormal:MEDIAN:SIGMA`)
    pub latency: LatencyModel,
    /// server aggregation topology: `flat` (single aggregator, the
    /// default) or `edge:E` (E edge aggregators between clients and the
    /// root — DESIGN.md §11)
    pub topology: Topology,
    /// probability that a whole edge aggregator misses the round
    /// deadline (its accepted uplinks are demoted to cut stragglers and
    /// the delivered-set weights renormalize over the surviving edges —
    /// DESIGN.md §11). Requires `topology = edge:E`; 0 = never.
    pub edge_dropout_prob: f64,
    /// uplinks that close a round (quorum close, DESIGN.md §13): the
    /// round ends as soon as this many uplinks are accepted instead of
    /// waiting for the full target S. 0 = sentinel for "the whole
    /// cohort" (today's barrier), which is also what an explicit
    /// `quorum = participating` means.
    pub quorum: usize,
    /// how many rounds late a computed uplink may arrive and still be
    /// buffered into the next round's aggregator instead of being cut
    /// (DESIGN.md §13). 0 = late uplinks are cut, today's behavior.
    pub max_staleness: usize,
    /// per-round-of-age weight decay for buffered late uplinks: a
    /// `age`-rounds-late uplink carries raw mass `p_k · decay^age`
    /// before renormalization (DESIGN.md §13). Must be in (0, 1];
    /// irrelevant while `max_staleness = 0`.
    pub staleness_decay: f64,
    /// probability a client sits out an entire availability wave
    /// (churn: devices leaving and rejoining the fleet mid-run —
    /// DESIGN.md §13). Drawn statelessly per `(seed, wave, client)`,
    /// so it composes with `dropout_prob` without consuming channel
    /// draws. 0 = never.
    pub churn_prob: f64,
    /// rounds per availability wave: a churned-out client is gone for
    /// `churn_period` consecutive rounds, then redrawn. Ignored while
    /// `churn_prob = 0`.
    pub churn_period: usize,
    /// Byzantine attack model: `none` (honest fleet, the default) or
    /// `signflip:F | scale:F:GAMMA | collude:F` — adversaries corrupt
    /// their uplink after honest local compute (DESIGN.md §16)
    pub attack: Attack,
    /// fraction trimmed from each end of the per-coordinate sorted
    /// client contributions under the robust `TrimmedVote` tally
    /// (DESIGN.md §16). 0 = plain vote, bit-for-bit.
    pub trim_frac: f64,
    /// median-of-means group count G for the robust `MedianOfMeans`
    /// tally (client k → group k mod G). 1 = plain vote, bit-for-bit.
    pub mom_groups: usize,
    /// pFed1BS error feedback: carry each client's one-bit quantization
    /// residual of the sketch into its next round's compression
    /// (Bergou-style EF for the biased sign compressor). Off =
    /// byte-identical runs and v2-layout checkpoints.
    pub error_feedback: bool,
    /// directory holding the AOT HLO artifacts (`make artifacts`)
    pub artifacts_dir: String,
    /// directory experiment CSVs/tables are written to
    pub results_dir: String,
}

impl RunConfig {
    /// Paper-aligned preset for a dataset (Experimental Setup + grid
    /// search values; rounds scaled to this CPU testbed, DESIGN.md §2).
    pub fn preset(dataset: DatasetName) -> RunConfig {
        // horizons scaled to this CPU testbed (paper: 100-300 rounds on
        // GPU); global baselines need the longer mlp784 horizon to mature
        let (rounds, local_steps, eta) = match dataset {
            DatasetName::Mnist => (100, 10, 0.1),
            DatasetName::Fmnist => (100, 10, 0.1),
            DatasetName::Svhn => (50, 5, 0.08),
            DatasetName::Cifar10 => (50, 5, 0.08),
            DatasetName::Cifar100 => (50, 5, 0.08),
        };
        RunConfig {
            dataset,
            algorithm: "pfed1bs".to_string(),
            clients: 20,
            participating: 20,
            rounds,
            local_steps,
            eta,
            lambda: 5e-4,
            mu: 1e-5,
            gamma: 1e4,
            sketch_ratio: 0.1,
            shards_per_client: if dataset == DatasetName::Cifar100 { 10 } else { 2 },
            dirichlet_alpha: 0.3,
            partition: "label-shards".to_string(),
            projection: ProjectionKind::Fht,
            seed: 17,
            eval_every: 5,
            server_lr: 0.02,
            // c = zsign_noise · mean|Δ| (see zsignfed.rs on why mean)
            zsign_noise: 2.0,
            client_threads: 0,
            device_batch: 0,
            over_select: 0,
            deadline_ms: 0.0,
            dropout_prob: 0.0,
            latency: LatencyModel::Zero,
            topology: Topology::Flat,
            edge_dropout_prob: 0.0,
            quorum: 0,
            max_staleness: 0,
            staleness_decay: 0.5,
            churn_prob: 0.0,
            churn_period: 10,
            attack: Attack::None,
            trim_frac: 0.0,
            mom_groups: 1,
            error_feedback: false,
            artifacts_dir: "artifacts".to_string(),
            results_dir: "results".to_string(),
        }
    }

    /// Apply CLI overrides on top of this config.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(path) = args.get("config") {
            let kv = parser::parse_file(path)?;
            self.apply_pairs(kv.iter().map(|(k, v)| (k.as_str(), v.as_str())))?;
        }
        let cli_pairs: Vec<(String, String)> = args
            .all()
            .filter(|(k, _)| *k != "config")
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        self.apply_pairs(cli_pairs.iter().map(|(k, v)| (k.as_str(), v.as_str())))?;
        self.validate()
    }

    /// Apply key=value pairs; unknown keys are errors (typo safety).
    pub fn apply_pairs<'a, I: Iterator<Item = (&'a str, &'a str)>>(
        &mut self,
        pairs: I,
    ) -> Result<()> {
        for (k, v) in pairs {
            self.apply_one(k, v)?;
        }
        Ok(())
    }

    fn apply_one(&mut self, key: &str, val: &str) -> Result<()> {
        macro_rules! num {
            () => {
                val.parse().map_err(|e| anyhow::anyhow!("{key}={val}: {e}"))?
            };
        }
        match key {
            "dataset" => {
                self.dataset = DatasetName::parse(val)
                    .ok_or_else(|| anyhow::anyhow!("unknown dataset `{val}`"))?
            }
            "alg" | "algorithm" => self.algorithm = val.to_string(),
            "clients" => self.clients = num!(),
            "participating" | "s" => self.participating = num!(),
            "rounds" | "t" => self.rounds = num!(),
            "local-steps" | "local_steps" | "r" => self.local_steps = num!(),
            "eta" | "lr" => self.eta = num!(),
            "lambda" => self.lambda = num!(),
            "mu" => self.mu = num!(),
            "gamma" => self.gamma = num!(),
            "sketch-ratio" | "sketch_ratio" => self.sketch_ratio = num!(),
            "shards-per-client" | "shards_per_client" => self.shards_per_client = num!(),
            "dirichlet-alpha" | "dirichlet_alpha" => self.dirichlet_alpha = num!(),
            "partition" => self.partition = val.to_string(),
            "projection" => self.projection = ProjectionKind::parse(val)?,
            "seed" => self.seed = num!(),
            "eval-every" | "eval_every" => self.eval_every = num!(),
            "server-lr" | "server_lr" => self.server_lr = num!(),
            "zsign-noise" | "zsign_noise" => self.zsign_noise = num!(),
            "threads" | "client-threads" | "client_threads" => self.client_threads = num!(),
            "device-batch" | "device_batch" => self.device_batch = num!(),
            "over-select" | "over_select" => self.over_select = num!(),
            "deadline-ms" | "deadline_ms" => self.deadline_ms = num!(),
            "dropout-prob" | "dropout_prob" => self.dropout_prob = num!(),
            "latency" => self.latency = LatencyModel::parse(val)?,
            "topology" => self.topology = Topology::parse(val)?,
            "edge-dropout-prob" | "edge_dropout_prob" => self.edge_dropout_prob = num!(),
            "quorum" => self.quorum = num!(),
            "max-staleness" | "max_staleness" => self.max_staleness = num!(),
            "staleness-decay" | "staleness_decay" => self.staleness_decay = num!(),
            "churn-prob" | "churn_prob" => self.churn_prob = num!(),
            "churn-period" | "churn_period" => self.churn_period = num!(),
            "attack" => self.attack = Attack::parse(val)?,
            "trim-frac" | "trim_frac" => self.trim_frac = num!(),
            "mom-groups" | "mom_groups" => self.mom_groups = num!(),
            "error-feedback" | "error_feedback" => {
                self.error_feedback = match val {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    other => bail!("error-feedback={other}: expected on|off"),
                }
            }
            "artifacts-dir" | "artifacts_dir" => self.artifacts_dir = val.to_string(),
            "results-dir" | "results_dir" => self.results_dir = val.to_string(),
            other => bail!("unknown config key `{other}`"),
        }
        Ok(())
    }

    /// Reject configurations the round loop cannot run (degenerate
    /// sizes, unknown partitions, inconsistent scenario knobs).
    pub fn validate(&self) -> Result<()> {
        if self.clients == 0 {
            bail!("clients must be > 0");
        }
        if self.participating == 0 || self.participating > self.clients {
            bail!(
                "participating must be in 1..={} (got {})",
                self.clients,
                self.participating
            );
        }
        if self.local_steps == 0 || self.rounds == 0 {
            bail!("rounds and local-steps must be > 0");
        }
        if !(0.0..=1.0).contains(&self.sketch_ratio) || self.sketch_ratio <= 0.0 {
            bail!("sketch-ratio must be in (0, 1]");
        }
        if self.eta <= 0.0 {
            bail!("eta must be > 0");
        }
        match self.partition.as_str() {
            "label-shards" | "dirichlet" | "iid" => {}
            p => bail!("unknown partition `{p}` (label-shards|dirichlet|iid)"),
        }
        if self.participating + self.over_select > self.clients {
            bail!(
                "over-selection needs participating + over_select <= clients \
                 ({} + {} > {})",
                self.participating,
                self.over_select,
                self.clients
            );
        }
        if !(0.0..1.0).contains(&self.dropout_prob) {
            bail!("dropout-prob must be in [0, 1) (got {})", self.dropout_prob);
        }
        if !self.deadline_ms.is_finite() || self.deadline_ms < 0.0 {
            bail!("deadline-ms must be finite and >= 0 (got {})", self.deadline_ms);
        }
        if self.deadline_ms > 0.0 && self.latency == LatencyModel::Zero {
            // legal but degenerate: everything arrives at t=0 and the
            // deadline can never fire — not an error, just pointless
            crate::debug!("deadline-ms set with zero latency: no straggler can exist");
        }
        self.latency.validate()?;
        self.topology.validate()?;
        if !(0.0..1.0).contains(&self.edge_dropout_prob) {
            bail!(
                "edge-dropout-prob must be in [0, 1) (got {})",
                self.edge_dropout_prob
            );
        }
        if self.edge_dropout_prob > 0.0 && self.topology == Topology::Flat {
            bail!("edge-dropout-prob needs topology=edge:E (flat has no edge tier)");
        }
        if self.quorum > self.participating {
            bail!(
                "quorum must be <= participating ({} > {}); 0 means the whole cohort",
                self.quorum,
                self.participating
            );
        }
        if !(self.staleness_decay > 0.0 && self.staleness_decay <= 1.0)
            || !self.staleness_decay.is_finite()
        {
            bail!("staleness-decay must be in (0, 1] (got {})", self.staleness_decay);
        }
        if !(0.0..1.0).contains(&self.churn_prob) {
            bail!("churn-prob must be in [0, 1) (got {})", self.churn_prob);
        }
        if self.churn_period == 0 {
            bail!("churn-period must be >= 1 rounds");
        }
        self.attack.validate()?;
        if !(0.0..0.5).contains(&self.trim_frac) {
            bail!("trim-frac must be in [0, 0.5) (got {})", self.trim_frac);
        }
        if self.mom_groups == 0 {
            bail!("mom-groups must be >= 1 (1 means the plain vote)");
        }
        if self.trim_frac > 0.0 && self.mom_groups > 1 {
            bail!(
                "trim-frac and mom-groups select competing robust tallies — set one, \
                 not both"
            );
        }
        Ok(())
    }

    /// Materialize the configured partition scheme.
    pub fn make_partition(&self) -> Partition {
        match self.partition.as_str() {
            "dirichlet" => Partition::Dirichlet {
                alpha: self.dirichlet_alpha,
                min_share: 0.05,
            },
            "iid" => Partition::Iid,
            _ => Partition::LabelShards {
                per_client: self.shards_per_client,
            },
        }
    }

    /// One-line summary for logs and result-file headers.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "alg={} dataset={} K={} S={} T={} R={} eta={} lambda={} mu={} gamma={} m/n={} partition={} projection={} seed={}",
            self.algorithm,
            self.dataset.as_str(),
            self.clients,
            self.participating,
            self.rounds,
            self.local_steps,
            self.eta,
            self.lambda,
            self.mu,
            self.gamma,
            self.sketch_ratio,
            self.partition,
            self.projection.as_str(),
            self.seed
        );
        if self.topology != Topology::Flat {
            s.push_str(&format!(" topology={}", self.topology.summary()));
        }
        if self.effective_device_batch() > 1 {
            s.push_str(&format!(" device-batch={}", self.effective_device_batch()));
        }
        if self.trim_frac > 0.0 {
            s.push_str(&format!(" trim-frac={}", self.trim_frac));
        }
        if self.mom_groups > 1 {
            s.push_str(&format!(" mom-groups={}", self.mom_groups));
        }
        if self.error_feedback {
            s.push_str(" error-feedback=on");
        }
        if self.has_scenario() {
            s.push_str(&format!(
                " over={} deadline={}ms dropout={} latency={}",
                self.over_select,
                self.deadline_ms,
                self.dropout_prob,
                self.latency.summary()
            ));
            if self.edge_dropout_prob > 0.0 {
                s.push_str(&format!(" edge-dropout={}", self.edge_dropout_prob));
            }
            if self.quorum_active() {
                s.push_str(&format!(
                    " quorum={}/{}",
                    self.effective_quorum(),
                    self.participating
                ));
            }
            if self.max_staleness > 0 {
                s.push_str(&format!(
                    " max-staleness={} staleness-decay={}",
                    self.max_staleness, self.staleness_decay
                ));
            }
            if self.churn_prob > 0.0 {
                s.push_str(&format!(
                    " churn-prob={} churn-period={}",
                    self.churn_prob, self.churn_period
                ));
            }
            if self.attack.is_active() {
                s.push_str(&format!(" attack={}", self.attack.summary()));
            }
        }
        s
    }

    /// The number of accepted uplinks that closes a round: the `quorum`
    /// knob, with 0 (and anything >= S) meaning the full cohort S —
    /// today's barrier.
    pub fn effective_quorum(&self) -> usize {
        if self.quorum == 0 {
            self.participating
        } else {
            self.quorum.min(self.participating)
        }
    }

    /// The cohort device-batch width the runtime should load: the
    /// `device_batch` knob, with 0 (auto) deferring to the
    /// `PFED1BS_DEVICE_BATCH` env var and finally to 1 (per-client
    /// dispatch, today's path). A perf knob like `client_threads` — it is
    /// NOT a scenario and never changes results.
    pub fn effective_device_batch(&self) -> usize {
        if self.device_batch > 0 {
            return self.device_batch;
        }
        std::env::var("PFED1BS_DEVICE_BATCH")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&b| b >= 1)
            .unwrap_or(1)
    }

    /// Does the quorum knob actually close rounds early? An explicit
    /// `quorum = participating` is the barrier spelled out, not a
    /// scenario.
    pub fn quorum_active(&self) -> bool {
        self.effective_quorum() < self.participating
    }

    /// Any client-lifecycle scenario knob set away from its default?
    pub fn has_scenario(&self) -> bool {
        self.over_select > 0
            || self.deadline_ms > 0.0
            || self.dropout_prob > 0.0
            || self.latency != LatencyModel::Zero
            || self.edge_dropout_prob > 0.0
            || self.quorum_active()
            || self.max_staleness > 0
            || self.churn_prob > 0.0
            || self.attack.is_active()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_matches_paper_hyperparameters() {
        let c = RunConfig::preset(DatasetName::Mnist);
        assert_eq!(c.clients, 20);
        assert!((c.lambda - 5e-4).abs() < 1e-12);
        assert!((c.mu - 1e-5).abs() < 1e-12);
        assert!((c.gamma - 1e4).abs() < 1e-3);
        assert!((c.sketch_ratio - 0.1).abs() < 1e-12);
        assert_eq!(c.shards_per_client, 2);
        assert_eq!(RunConfig::preset(DatasetName::Cifar100).shards_per_client, 10);
    }

    #[test]
    fn overrides_apply() {
        let mut c = RunConfig::preset(DatasetName::Mnist);
        c.apply_pairs(
            [
                ("rounds", "5"),
                ("alg", "fedavg"),
                ("lambda", "0.01"),
                ("s", "7"),
                ("threads", "4"),
            ]
            .into_iter(),
        )
        .unwrap();
        assert_eq!(c.rounds, 5);
        assert_eq!(c.algorithm, "fedavg");
        assert!((c.lambda - 0.01).abs() < 1e-9);
        assert_eq!(c.participating, 7);
        assert_eq!(c.client_threads, 4);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = RunConfig::preset(DatasetName::Mnist);
        assert!(c.apply_pairs([("bogus", "1")].into_iter()).is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = RunConfig::preset(DatasetName::Mnist);
        c.participating = 100;
        assert!(c.validate().is_err());
        c.participating = 10;
        c.validate().unwrap();
        c.sketch_ratio = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn scenario_knobs_parse_and_validate() {
        let mut c = RunConfig::preset(DatasetName::Mnist);
        assert!(!c.has_scenario());
        c.apply_pairs(
            [
                ("participating", "12"),
                ("over-select", "4"),
                ("deadline-ms", "25"),
                ("dropout-prob", "0.2"),
                ("latency", "lognormal:10:0.5"),
            ]
            .into_iter(),
        )
        .unwrap();
        assert_eq!(c.over_select, 4);
        assert_eq!(c.deadline_ms, 25.0);
        assert_eq!(c.dropout_prob, 0.2);
        assert_eq!(c.latency, LatencyModel::LogNormal { median_ms: 10.0, sigma: 0.5 });
        assert!(c.has_scenario());
        c.validate().unwrap();
        let s = c.summary();
        assert!(s.contains("over=4") && s.contains("lognormal:10:0.5"), "{s}");

        // over-selection must fit the fleet
        c.over_select = 9; // 12 + 9 > 20
        assert!(c.validate().is_err());
        c.over_select = 0;
        c.dropout_prob = 1.0;
        assert!(c.validate().is_err());
        c.dropout_prob = 0.0;
        c.deadline_ms = -1.0;
        assert!(c.validate().is_err());
        c.deadline_ms = 0.0;
        c.validate().unwrap();
        assert!(c.apply_pairs([("latency", "bogus")].into_iter()).is_err());
    }

    #[test]
    fn topology_parses_validates_and_summarizes() {
        assert_eq!(Topology::parse("flat").unwrap(), Topology::Flat);
        assert_eq!(
            Topology::parse("edge:4").unwrap(),
            Topology::Edge { edges: 4 }
        );
        for bad in ["edge:0", "edge:", "edge:x", "mesh", "edge:-1"] {
            assert!(Topology::parse(bad).is_err(), "{bad} should be rejected");
        }
        for s in ["flat", "edge:1", "edge:16"] {
            assert_eq!(Topology::parse(s).unwrap().summary(), s);
        }
        // derived assignment and shard counts
        let t = Topology::Edge { edges: 3 };
        assert_eq!((t.edges(), t.shards()), (3, 3));
        assert_eq!((t.edge_of(0), t.edge_of(4), t.edge_of(5)), (0, 1, 2));
        assert_eq!((Topology::Flat.edges(), Topology::Flat.shards()), (0, 1));
        assert_eq!(Topology::Flat.edge_of(17), 0);

        let mut c = RunConfig::preset(DatasetName::Mnist);
        c.apply_pairs([("topology", "edge:4")].into_iter()).unwrap();
        assert_eq!(c.topology, Topology::Edge { edges: 4 });
        c.validate().unwrap();
        assert!(c.summary().contains("topology=edge:4"), "{}", c.summary());
        // edge topology alone is NOT a lifecycle scenario: default knobs
        // must still reduce to the barrier round plan
        assert!(!c.has_scenario());

        // edge-dropout needs the edge tier and a sane probability
        c.edge_dropout_prob = 0.25;
        c.validate().unwrap();
        assert!(c.has_scenario());
        assert!(c.summary().contains("edge-dropout=0.25"), "{}", c.summary());
        c.edge_dropout_prob = 1.0;
        assert!(c.validate().is_err());
        c.edge_dropout_prob = 0.25;
        c.topology = Topology::Flat;
        assert!(c.validate().is_err(), "edge-dropout under flat must be rejected");
    }

    #[test]
    fn quorum_and_staleness_knobs_parse_validate_and_summarize() {
        let mut c = RunConfig::preset(DatasetName::Mnist);
        assert_eq!(c.effective_quorum(), c.participating, "0 means the whole cohort");
        assert!(!c.quorum_active() && !c.has_scenario());

        // an explicit quorum = S is the barrier spelled out: no scenario
        c.apply_pairs([("participating", "12"), ("quorum", "12")].into_iter()).unwrap();
        c.validate().unwrap();
        assert_eq!(c.effective_quorum(), 12);
        assert!(!c.quorum_active() && !c.has_scenario());

        c.apply_pairs(
            [("quorum", "8"), ("max-staleness", "2"), ("staleness-decay", "0.25")].into_iter(),
        )
        .unwrap();
        c.validate().unwrap();
        assert!(c.quorum_active() && c.has_scenario());
        let s = c.summary();
        assert!(
            s.contains("quorum=8/12") && s.contains("max-staleness=2"),
            "{s}"
        );
        assert!(s.contains("staleness-decay=0.25"), "{s}");

        // quorum beyond the cohort is a config error
        c.quorum = 13;
        assert!(c.validate().is_err());
        c.quorum = 8;
        c.staleness_decay = 0.0;
        assert!(c.validate().is_err());
        c.staleness_decay = 1.5;
        assert!(c.validate().is_err());
        c.staleness_decay = 1.0;
        c.validate().unwrap();

        // churn: a probability per availability wave
        c.apply_pairs([("churn-prob", "0.3"), ("churn-period", "5")].into_iter()).unwrap();
        c.validate().unwrap();
        assert!(c.summary().contains("churn-prob=0.3 churn-period=5"), "{}", c.summary());
        c.churn_prob = 1.0;
        assert!(c.validate().is_err());
        c.churn_prob = 0.3;
        c.churn_period = 0;
        assert!(c.validate().is_err());

        // max-staleness alone (no quorum) is still a scenario: deadline
        // stragglers become buffered arrivals
        let mut d = RunConfig::preset(DatasetName::Mnist);
        d.max_staleness = 1;
        d.validate().unwrap();
        assert!(d.has_scenario());
        // staleness-decay alone is NOT: it gates nothing by itself
        let mut e = RunConfig::preset(DatasetName::Mnist);
        e.staleness_decay = 0.9;
        e.validate().unwrap();
        assert!(!e.has_scenario());
    }

    #[test]
    fn device_batch_knob_parses_and_stays_out_of_scenarios() {
        let mut c = RunConfig::preset(DatasetName::Mnist);
        assert_eq!(c.device_batch, 0, "preset defaults to auto");
        c.apply_pairs([("device-batch", "32")].into_iter()).unwrap();
        assert_eq!(c.device_batch, 32);
        assert_eq!(c.effective_device_batch(), 32);
        c.validate().unwrap();
        // a perf knob, not a scenario: batched execution is bit-identical
        assert!(!c.has_scenario());
        assert!(c.summary().contains("device-batch=32"), "{}", c.summary());
        c.apply_pairs([("device_batch", "1")].into_iter()).unwrap();
        assert_eq!(c.effective_device_batch(), 1);
        assert!(!c.summary().contains("device-batch"), "{}", c.summary());
        assert!(c.apply_pairs([("device-batch", "x")].into_iter()).is_err());
        // auto (0) resolves to env/1 but never to 0
        c.device_batch = 0;
        assert!(c.effective_device_batch() >= 1);
    }

    #[test]
    fn attack_and_robust_knobs_parse_validate_and_summarize() {
        // attack grammar: none | signflip:F | scale:F:GAMMA | collude:F
        assert_eq!(Attack::parse("none").unwrap(), Attack::None);
        assert_eq!(
            Attack::parse("signflip:0.4").unwrap(),
            Attack::SignFlip { frac: 0.4 }
        );
        assert_eq!(
            Attack::parse("scale:0.25:-1").unwrap(),
            Attack::Scale { frac: 0.25, gamma: -1.0 }
        );
        assert_eq!(
            Attack::parse("collude:0.3").unwrap(),
            Attack::Collude { frac: 0.3 }
        );
        for bad in [
            "signflip",
            "signflip:x",
            "signflip:1.0",
            "signflip:-0.1",
            "scale:0.2",
            "scale:0.2:inf",
            "collude:2",
            "ddos:0.5",
        ] {
            assert!(Attack::parse(bad).is_err(), "{bad} should be rejected");
        }
        for s in ["none", "signflip:0.4", "scale:0.25:-1", "collude:0.3"] {
            assert_eq!(Attack::parse(s).unwrap().summary(), s);
        }
        assert!(!Attack::None.is_active());
        assert!(!Attack::SignFlip { frac: 0.0 }.is_active(), "F=0 is honest");
        assert!(Attack::Collude { frac: 0.3 }.is_active());

        let mut c = RunConfig::preset(DatasetName::Mnist);
        assert_eq!(c.attack, Attack::None);
        assert_eq!((c.trim_frac, c.mom_groups, c.error_feedback), (0.0, 1, false));
        assert!(!c.has_scenario());

        c.apply_pairs(
            [("attack", "signflip:0.4"), ("trim-frac", "0.3"), ("error-feedback", "on")]
                .into_iter(),
        )
        .unwrap();
        c.validate().unwrap();
        assert!(c.has_scenario(), "an active attack is a scenario");
        let s = c.summary();
        assert!(s.contains("attack=signflip:0.4"), "{s}");
        assert!(s.contains("trim-frac=0.3") && s.contains("error-feedback=on"), "{s}");

        // the two robust tallies are mutually exclusive
        c.apply_pairs([("mom-groups", "5")].into_iter()).unwrap();
        assert!(c.validate().is_err(), "trim-frac + mom-groups must conflict");
        c.trim_frac = 0.0;
        c.validate().unwrap();
        assert!(c.summary().contains("mom-groups=5"), "{}", c.summary());

        // bounds
        c.trim_frac = 0.5;
        c.mom_groups = 1;
        assert!(c.validate().is_err(), "trim-frac=0.5 leaves no majority");
        c.trim_frac = 0.0;
        c.mom_groups = 0;
        assert!(c.validate().is_err());
        c.mom_groups = 1;
        c.validate().unwrap();
        assert!(c.apply_pairs([("error-feedback", "maybe")].into_iter()).is_err());
        assert!(c.apply_pairs([("attack", "signflip:0.5x")].into_iter()).is_err());

        // off-defaults keep the honest summary clean
        let d = RunConfig::preset(DatasetName::Mnist);
        let ds = d.summary();
        assert!(
            !ds.contains("attack") && !ds.contains("trim") && !ds.contains("error-feedback"),
            "{ds}"
        );
    }

    #[test]
    fn partition_construction() {
        let mut c = RunConfig::preset(DatasetName::Mnist);
        assert!(matches!(c.make_partition(), Partition::LabelShards { per_client: 2 }));
        c.partition = "dirichlet".into();
        assert!(matches!(c.make_partition(), Partition::Dirichlet { .. }));
        c.partition = "iid".into();
        assert!(matches!(c.make_partition(), Partition::Iid));
    }

    #[test]
    fn summary_contains_key_fields() {
        let c = RunConfig::preset(DatasetName::Cifar10);
        let s = c.summary();
        assert!(s.contains("cifar10"));
        assert!(s.contains("K=20"));
    }
}
