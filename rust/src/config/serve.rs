//! Configuration of the multi-process transport roles (`pfed1bs serve`
//! / `edge` / `client-fleet` / `loadgen` — DESIGN.md §12): endpoint
//! addressing, listen/connect knobs, and validation.

use anyhow::{bail, ensure, Result};

use crate::comm::transport::stream::Tuning;
use crate::util::cli::Args;

/// A socket address in either family: `tcp:HOST:PORT` or `unix:/PATH`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// TCP, e.g. `tcp:127.0.0.1:7171` (any `std::net::ToSocketAddrs`
    /// host:port string)
    Tcp(String),
    /// Unix-domain socket path, e.g. `unix:/tmp/pf1b.sock`
    Unix(String),
}

impl Endpoint {
    /// Parse the CLI spelling: `tcp:HOST:PORT | unix:/PATH`.
    pub fn parse(s: &str) -> Result<Endpoint> {
        if let Some(addr) = s.strip_prefix("tcp:") {
            let Some((host, port)) = addr.rsplit_once(':') else {
                bail!("endpoint `{s}`: expected tcp:HOST:PORT");
            };
            ensure!(!host.is_empty(), "endpoint `{s}`: empty host");
            port.parse::<u16>()
                .map_err(|e| anyhow::anyhow!("endpoint `{s}`: bad port `{port}`: {e}"))?;
            Ok(Endpoint::Tcp(addr.to_string()))
        } else if let Some(path) = s.strip_prefix("unix:") {
            ensure!(!path.is_empty(), "endpoint `{s}`: empty socket path");
            Ok(Endpoint::Unix(path.to_string()))
        } else {
            bail!("endpoint `{s}`: expected tcp:HOST:PORT or unix:/PATH")
        }
    }

    /// Canonical spelling (inverse of [`Endpoint::parse`]).
    pub fn summary(&self) -> String {
        match self {
            Endpoint::Tcp(addr) => format!("tcp:{addr}"),
            Endpoint::Unix(path) => format!("unix:{path}"),
        }
    }
}

/// Which transport role this process plays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeRole {
    /// aggregation root: listens, selects cohorts, owns the consensus
    Root,
    /// edge aggregator: connects upstream to the root, listens for its
    /// client range, ships one merge frame per round
    Edge,
    /// N mock clients multiplexed over one process, connecting to a
    /// root or edge
    Fleet,
    /// load generator: a large mock fleet with per-uplink ACK latency
    /// measurement, reporting rounds/sec and p99 as JSON
    Loadgen,
}

impl ServeRole {
    /// The subcommand spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            ServeRole::Root => "serve",
            ServeRole::Edge => "edge",
            ServeRole::Fleet => "client-fleet",
            ServeRole::Loadgen => "loadgen",
        }
    }
}

/// Configuration of one transport-role process (DESIGN.md §12).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// which role this process plays
    pub role: ServeRole,
    /// where to listen (root, edge)
    pub listen: Option<Endpoint>,
    /// where to connect (edge, fleet, loadgen)
    pub connect: Option<Endpoint>,
    /// K — fleet size the root plans rounds over
    pub clients: usize,
    /// S — clients selected per round
    pub participating: usize,
    /// T — rounds to run before sending BYE
    pub rounds: usize,
    /// sketch length m (consensus bits)
    pub m: usize,
    /// run seed (selections, mock sketches)
    pub seed: u64,
    /// first client id this process simulates (fleet/loadgen) or
    /// expects (edge)
    pub lo: u32,
    /// one past the last client id; 0 = through the whole fleet
    pub hi: u32,
    /// connections a fleet/loadgen spreads its clients over
    pub conns: usize,
    /// this edge aggregator's id (metering/labeling only)
    pub edge_id: u32,
    /// per-frame read/write deadline in milliseconds
    pub timeout_ms: u64,
    /// hard frame-size cap in MiB
    pub max_frame_mb: usize,
    /// root only: after the last round, recompute the consensus
    /// in-process and fail unless the socket run matches bit for bit
    pub check_consensus: bool,
    /// fleet/loadgen: request an ACK per absorbed uplink (the
    /// uplink-to-absorb latency probe)
    pub want_ack: bool,
    /// root only: uplinks that close a round (0 = the whole cohort, the
    /// barrier protocol). Below `participating`, the root closes at
    /// quorum and the remaining `S − quorum` designated-late uplinks
    /// join the NEXT round's tally at weight `staleness_decay`
    /// (DESIGN.md §13)
    pub quorum: usize,
    /// root only: vote weight of a one-round-stale designated-late
    /// uplink, in (0, 1]
    pub staleness_decay: f64,
}

impl ServeConfig {
    /// Programmatic defaults for `role` (what `from_args` starts from).
    pub fn new(role: ServeRole) -> ServeConfig {
        ServeConfig {
            role,
            listen: None,
            connect: None,
            clients: if role == ServeRole::Loadgen { 10_000 } else { 64 },
            participating: 16,
            rounds: 3,
            m: 1024,
            seed: 17,
            lo: 0,
            hi: 0,
            conns: if role == ServeRole::Loadgen { 4 } else { 1 },
            edge_id: 0,
            timeout_ms: 10_000,
            max_frame_mb: 64,
            check_consensus: false,
            want_ack: role == ServeRole::Loadgen,
            quorum: 0,
            staleness_decay: 0.5,
        }
    }

    /// The round-close threshold `quorum` resolves to (0 = whole cohort).
    pub fn effective_quorum(&self) -> usize {
        if self.quorum == 0 {
            self.participating
        } else {
            self.quorum.min(self.participating)
        }
    }

    /// Whether the root closes rounds before the full cohort lands.
    pub fn quorum_active(&self) -> bool {
        self.effective_quorum() < self.participating
    }

    /// Build from CLI arguments (see `pfed1bs help` for the knobs).
    pub fn from_args(role: ServeRole, args: &Args) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::new(role);
        if let Some(ep) = args.get("listen") {
            cfg.listen = Some(Endpoint::parse(ep)?);
        }
        if let Some(ep) = args.get("connect") {
            cfg.connect = Some(Endpoint::parse(ep)?);
        }
        cfg.clients = args.parse_or("clients", cfg.clients)?;
        cfg.participating = args.parse_or("participating", cfg.participating)?;
        cfg.rounds = args.parse_or("rounds", cfg.rounds)?;
        cfg.m = args.parse_or("m", cfg.m)?;
        cfg.seed = args.parse_or("seed", cfg.seed)?;
        cfg.lo = args.parse_or("lo", cfg.lo)?;
        cfg.hi = args.parse_or("hi", cfg.hi)?;
        cfg.conns = args.parse_or("conns", cfg.conns)?;
        cfg.edge_id = args.parse_or("edge-id", cfg.edge_id)?;
        cfg.timeout_ms = args.parse_or("timeout-ms", cfg.timeout_ms)?;
        cfg.max_frame_mb = args.parse_or("max-frame-mb", cfg.max_frame_mb)?;
        cfg.check_consensus = cfg.check_consensus || args.flag("check-consensus");
        cfg.want_ack = cfg.want_ack || args.flag("want-ack");
        cfg.quorum = args.parse_or("quorum", cfg.quorum)?;
        cfg.staleness_decay = args.parse_or("staleness-decay", cfg.staleness_decay)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Reject configurations the role cannot run.
    pub fn validate(&self) -> Result<()> {
        match self.role {
            ServeRole::Root => {
                ensure!(self.listen.is_some(), "serve needs --listen tcp:…|unix:…");
                ensure!(self.connect.is_none(), "serve does not take --connect");
            }
            ServeRole::Edge => {
                ensure!(self.listen.is_some(), "edge needs --listen (its fleet side)");
                ensure!(self.connect.is_some(), "edge needs --connect (its root side)");
            }
            ServeRole::Fleet | ServeRole::Loadgen => {
                ensure!(
                    self.connect.is_some(),
                    "{} needs --connect tcp:…|unix:…",
                    self.role.as_str()
                );
                ensure!(self.listen.is_none(), "{} does not listen", self.role.as_str());
            }
        }
        ensure!(self.clients > 0, "clients must be > 0");
        ensure!(
            self.participating > 0 && self.participating <= self.clients,
            "participating must be in 1..={} (got {})",
            self.clients,
            self.participating
        );
        ensure!(self.rounds > 0, "rounds must be > 0");
        ensure!(self.m > 0, "m must be > 0");
        ensure!(self.conns >= 1, "conns must be >= 1");
        ensure!(
            self.quorum <= self.participating,
            "quorum must be <= participating {} (got {}; 0 means the whole cohort)",
            self.participating,
            self.quorum
        );
        ensure!(
            self.staleness_decay > 0.0
                && self.staleness_decay <= 1.0
                && self.staleness_decay.is_finite(),
            "staleness-decay must be in (0, 1] (got {})",
            self.staleness_decay
        );
        ensure!(self.timeout_ms >= 1, "timeout-ms must be >= 1");
        ensure!(self.max_frame_mb >= 1, "max-frame-mb must be >= 1");
        if self.hi != 0 {
            ensure!(self.lo < self.hi, "need lo < hi (got {}..{})", self.lo, self.hi);
        }
        let span = if self.hi == 0 {
            self.clients.saturating_sub(self.lo as usize)
        } else {
            (self.hi - self.lo) as usize
        };
        ensure!(
            span >= self.conns,
            "range {}..{} holds {span} clients — fewer than --conns {}",
            self.lo,
            if self.hi == 0 { self.clients as u32 } else { self.hi },
            self.conns
        );
        Ok(())
    }

    /// One-line summary for startup logs.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "role={} K={} S={} T={} m={} seed={}",
            self.role.as_str(),
            self.clients,
            self.participating,
            self.rounds,
            self.m,
            self.seed
        );
        if let Some(ep) = &self.listen {
            s.push_str(&format!(" listen={}", ep.summary()));
        }
        if let Some(ep) = &self.connect {
            s.push_str(&format!(" connect={}", ep.summary()));
        }
        if self.lo != 0 || self.hi != 0 {
            s.push_str(&format!(" range={}..{}", self.lo, self.hi));
        }
        if self.conns != 1 {
            s.push_str(&format!(" conns={}", self.conns));
        }
        if self.role == ServeRole::Edge {
            s.push_str(&format!(" edge-id={}", self.edge_id));
        }
        if self.quorum_active() {
            s.push_str(&format!(
                " quorum={}/{} staleness-decay={}",
                self.effective_quorum(),
                self.participating,
                self.staleness_decay
            ));
        }
        if self.check_consensus {
            s.push_str(" check-consensus");
        }
        s
    }

    /// The socket tuning these knobs describe.
    pub fn tuning(&self) -> Tuning {
        Tuning {
            read_timeout: Some(std::time::Duration::from_millis(self.timeout_ms)),
            write_timeout: Some(std::time::Duration::from_millis(self.timeout_ms)),
            max_frame: self.max_frame_mb << 20,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_parses_both_families_and_round_trips() {
        assert_eq!(
            Endpoint::parse("tcp:127.0.0.1:7171").unwrap(),
            Endpoint::Tcp("127.0.0.1:7171".into())
        );
        assert_eq!(
            Endpoint::parse("unix:/tmp/pf1b.sock").unwrap(),
            Endpoint::Unix("/tmp/pf1b.sock".into())
        );
        for s in ["tcp:localhost:0", "unix:/x/y.sock"] {
            assert_eq!(Endpoint::parse(s).unwrap().summary(), s);
        }
        for bad in ["tcp:", "tcp:hostonly", "tcp::7", "tcp:h:notaport", "tcp:h:70000", "unix:", "7171", "udp:x:1"] {
            assert!(Endpoint::parse(bad).is_err(), "{bad} should be rejected");
        }
    }

    fn args(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn role_requirements_are_enforced() {
        // root must listen, not connect
        assert!(ServeConfig::from_args(ServeRole::Root, &args(&[])).is_err());
        let root =
            ServeConfig::from_args(ServeRole::Root, &args(&["--listen", "unix:/tmp/a.sock"]))
                .unwrap();
        assert_eq!(root.listen, Some(Endpoint::Unix("/tmp/a.sock".into())));
        assert!(ServeConfig::from_args(
            ServeRole::Root,
            &args(&["--listen", "unix:/a", "--connect", "unix:/b"])
        )
        .is_err());

        // edge needs both sides
        assert!(
            ServeConfig::from_args(ServeRole::Edge, &args(&["--listen", "unix:/a"])).is_err()
        );
        let edge = ServeConfig::from_args(
            ServeRole::Edge,
            &args(&["--listen", "unix:/a", "--connect", "tcp:h:1", "--edge-id", "2"]),
        )
        .unwrap();
        assert_eq!(edge.edge_id, 2);

        // fleet/loadgen connect only
        assert!(ServeConfig::from_args(ServeRole::Fleet, &args(&[])).is_err());
        let fleet =
            ServeConfig::from_args(ServeRole::Fleet, &args(&["--connect", "tcp:h:1"])).unwrap();
        assert!(!fleet.want_ack, "plain fleets do not request ACKs by default");
        let gen =
            ServeConfig::from_args(ServeRole::Loadgen, &args(&["--connect", "tcp:h:1"])).unwrap();
        assert!(gen.want_ack, "loadgen measures uplink-to-absorb via ACKs");
        assert_eq!((gen.clients, gen.conns), (10_000, 4));
    }

    #[test]
    fn knobs_apply_and_validate() {
        let cfg = ServeConfig::from_args(
            ServeRole::Root,
            &args(&[
                "--listen", "tcp:127.0.0.1:0", "--clients", "128", "--participating", "32",
                "--rounds", "5", "--m", "4096", "--seed", "3", "--timeout-ms", "2500",
                "--max-frame-mb", "8", "--check-consensus",
            ]),
        )
        .unwrap();
        assert_eq!((cfg.clients, cfg.participating, cfg.rounds), (128, 32, 5));
        assert_eq!((cfg.m, cfg.seed), (4096, 3));
        assert!(cfg.check_consensus);
        let t = cfg.tuning();
        assert_eq!(t.max_frame, 8 << 20);
        assert_eq!(t.read_timeout, Some(std::time::Duration::from_millis(2500)));
        let s = cfg.summary();
        assert!(s.contains("role=serve") && s.contains("K=128") && s.contains("check-consensus"), "{s}");

        // degenerate shapes
        for bad in [
            vec!["--listen", "tcp:h:1", "--participating", "0"],
            vec!["--listen", "tcp:h:1", "--participating", "65"],
            vec!["--listen", "tcp:h:1", "--rounds", "0"],
            vec!["--listen", "tcp:h:1", "--m", "0"],
            vec!["--listen", "tcp:h:1", "--lo", "5", "--hi", "5"],
        ] {
            assert!(ServeConfig::from_args(ServeRole::Root, &args(&bad)).is_err(), "{bad:?}");
        }
        // a loadgen range must cover its connection count
        assert!(ServeConfig::from_args(
            ServeRole::Loadgen,
            &args(&["--connect", "tcp:h:1", "--lo", "0", "--hi", "2", "--conns", "4"])
        )
        .is_err());
    }

    #[test]
    fn quorum_knobs_parse_validate_and_summarize() {
        let base = ["--listen", "tcp:127.0.0.1:0", "--participating", "16"];
        let cfg = ServeConfig::from_args(ServeRole::Root, &args(&base)).unwrap();
        assert_eq!(cfg.quorum, 0, "default quorum is the whole-cohort sentinel");
        assert_eq!(cfg.effective_quorum(), 16);
        assert!(!cfg.quorum_active());
        assert!(!cfg.summary().contains("quorum"), "barrier runs stay quiet");

        let mut a = base.to_vec();
        a.extend(["--quorum", "12", "--staleness-decay", "0.25"]);
        let cfg = ServeConfig::from_args(ServeRole::Root, &args(&a)).unwrap();
        assert_eq!(cfg.effective_quorum(), 12);
        assert!(cfg.quorum_active());
        let s = cfg.summary();
        assert!(s.contains("quorum=12/16") && s.contains("staleness-decay=0.25"), "{s}");

        // quorum == participating is explicit-barrier, not quorum mode
        let mut a = base.to_vec();
        a.extend(["--quorum", "16"]);
        let cfg = ServeConfig::from_args(ServeRole::Root, &args(&a)).unwrap();
        assert!(!cfg.quorum_active());

        for bad in [
            vec!["--quorum", "17"],
            vec!["--staleness-decay", "0"],
            vec!["--staleness-decay", "1.5"],
        ] {
            let mut a = base.to_vec();
            a.extend(bad.iter().copied());
            assert!(ServeConfig::from_args(ServeRole::Root, &args(&a)).is_err(), "{bad:?}");
        }
    }
}
