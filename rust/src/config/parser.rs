//! Config-file parser: `key = value` lines, `#` comments, optional
//! `[section]` headers that prefix keys as `section.key` (flattened TOML
//! subset — serde/toml are unavailable offline, DESIGN.md §2).

use anyhow::{bail, Context, Result};

/// Parse a config file into ordered (key, value) pairs.
pub fn parse_file(path: &str) -> Result<Vec<(String, String)>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    parse_str(&text).with_context(|| format!("parsing {path}"))
}

/// Parse config text. Later keys override earlier ones downstream (the
/// consumer applies them in order).
pub fn parse_str(text: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header `{raw}`", lineno + 1);
            };
            section = name.trim().to_string();
            continue;
        }
        let Some((key, val)) = line.split_once('=') else {
            bail!("line {}: expected `key = value`, got `{raw}`", lineno + 1);
        };
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.push((full_key, unquote(val.trim())));
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside quotes
    let mut in_quote = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_quote = !in_quote,
            '#' if !in_quote => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> String {
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_pairs() {
        let kv = parse_str("a = 1\nb=two\n  c  =  3.5  ").unwrap();
        assert_eq!(
            kv,
            vec![
                ("a".into(), "1".into()),
                ("b".into(), "two".into()),
                ("c".into(), "3.5".into())
            ]
        );
    }

    #[test]
    fn comments_and_blanks() {
        let kv = parse_str("# header\n\na = 1  # trailing\n   \n").unwrap();
        assert_eq!(kv, vec![("a".into(), "1".into())]);
    }

    #[test]
    fn sections_prefix_keys() {
        let kv = parse_str("[train]\nrounds = 10\n[data]\nseed = 3").unwrap();
        assert_eq!(
            kv,
            vec![
                ("train.rounds".into(), "10".into()),
                ("data.seed".into(), "3".into())
            ]
        );
    }

    #[test]
    fn quoted_values_keep_hashes() {
        let kv = parse_str("path = \"a#b\"").unwrap();
        assert_eq!(kv, vec![("path".into(), "a#b".into())]);
    }

    #[test]
    fn errors_report_line_numbers() {
        let err = parse_str("ok = 1\nnot a pair").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        let err = parse_str("[oops").unwrap_err().to_string();
        assert!(err.contains("unterminated"), "{err}");
        assert!(parse_str("= nokey").is_err());
    }

    #[test]
    fn order_preserved_for_override_semantics() {
        let kv = parse_str("a = 1\na = 2").unwrap();
        assert_eq!(kv[0].1, "1");
        assert_eq!(kv[1].1, "2");
    }
}
