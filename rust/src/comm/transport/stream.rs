//! Socket-backed byte streams: framed connections over TCP or
//! Unix-domain sockets, and the loopback [`StreamTransport`] that pushes
//! every coordinator frame through a real OS socket (DESIGN.md §12).
//!
//! Three small layers:
//!
//! * [`Listener`] / [`connect`] — endpoint-polymorphic bind/accept/dial
//!   (with a retry window on connect, since the server side of a
//!   multi-process run may not be listening yet).
//! * [`FramedConn`] — one stream + the framing of `frame.rs`, with sent
//!   and received byte counters and a `split_reader` for the
//!   reader-thread pattern the serve roles use.
//! * [`StreamTransport`] — a [`Transport`] whose peer is a spawned
//!   reflector thread on the other end of a real loopback socket: it
//!   answers the handshake, then echoes every frame byte-for-byte. The
//!   engine's payloads genuinely traverse the framing layer, the OS
//!   socket buffers, and the strict decoder — and the adopted payload is
//!   whatever came back. Client-tier metering counts exactly the codec
//!   [`frame_bytes`] like `SimNetwork`, so a clean socket round is
//!   bit-identical to the clean simulated round; the envelope's extra
//!   bytes are reported separately via
//!   [`Transport::wire_overhead`](super::Transport::wire_overhead).

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::comm::codec::{frame_bytes, Payload};
use crate::comm::ledger::{Direction, Ledger, RoundBytes};
use crate::comm::network::{dropout_draw, lifecycle_rng, LatencyModel};
use crate::comm::transport::frame::{
    encode_body, kind_name, read_body, read_frame, write_frame, Frame, Hello, PeerRole, Welcome,
    DEFAULT_MAX_FRAME, KIND_BYE,
};
use crate::comm::transport::Transport;
use crate::config::Endpoint;
use crate::util::rng::Rng;

/// Socket tuning knobs shared by every role: per-frame read/write
/// deadlines and the hard frame-size cap (DESIGN.md §12). A peer that
/// stalls mid-frame longer than the read timeout yields `Err`, not a
/// hang.
#[derive(Clone, Debug)]
pub struct Tuning {
    /// read deadline per `read` call (`None` = block forever)
    pub read_timeout: Option<Duration>,
    /// write deadline per `write` call (`None` = block forever)
    pub write_timeout: Option<Duration>,
    /// hard cap on a frame body's length (checked before allocation)
    pub max_frame: usize,
}

impl Default for Tuning {
    fn default() -> Tuning {
        Tuning {
            read_timeout: Some(Duration::from_secs(10)),
            write_timeout: Some(Duration::from_secs(10)),
            max_frame: DEFAULT_MAX_FRAME,
        }
    }
}

/// The object-safe byte-stream surface both socket families implement;
/// what [`FramedConn`] is generic over at runtime.
pub trait NetStream: Read + Write + Send {
    /// Apply the tuning's read/write deadlines to this stream.
    fn apply_tuning(&self, t: &Tuning) -> io::Result<()>;
    /// An independently owned handle to the same stream (reader threads).
    fn try_clone_stream(&self) -> io::Result<Box<dyn NetStream>>;
    /// Close both directions, unblocking any reader on the peer or on a
    /// cloned handle.
    fn shutdown_stream(&self) -> io::Result<()>;
}

impl NetStream for TcpStream {
    fn apply_tuning(&self, t: &Tuning) -> io::Result<()> {
        self.set_read_timeout(t.read_timeout)?;
        self.set_write_timeout(t.write_timeout)?;
        // frames are latency-measured request/response units; Nagle
        // batching would put scheduler noise into the loadgen p99
        self.set_nodelay(true)
    }
    fn try_clone_stream(&self) -> io::Result<Box<dyn NetStream>> {
        Ok(Box::new(self.try_clone()?))
    }
    fn shutdown_stream(&self) -> io::Result<()> {
        self.shutdown(std::net::Shutdown::Both)
    }
}

#[cfg(unix)]
impl NetStream for UnixStream {
    fn apply_tuning(&self, t: &Tuning) -> io::Result<()> {
        self.set_read_timeout(t.read_timeout)?;
        self.set_write_timeout(t.write_timeout)
    }
    fn try_clone_stream(&self) -> io::Result<Box<dyn NetStream>> {
        Ok(Box::new(self.try_clone()?))
    }
    fn shutdown_stream(&self) -> io::Result<()> {
        self.shutdown(std::net::Shutdown::Both)
    }
}

/// A bound listening socket for either endpoint family.
pub enum Listener {
    /// TCP listener
    Tcp(TcpListener),
    /// Unix-domain listener (a stale socket file at the path is removed
    /// before binding)
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    /// Bind `ep` and start listening.
    pub fn bind(ep: &Endpoint) -> Result<Listener> {
        match ep {
            Endpoint::Tcp(addr) => Ok(Listener::Tcp(
                TcpListener::bind(addr).with_context(|| format!("binding tcp:{addr}"))?,
            )),
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                // a crashed previous run leaves its socket file behind;
                // rebinding the same path must not require manual cleanup
                let _ = std::fs::remove_file(path);
                Ok(Listener::Unix(
                    UnixListener::bind(path).with_context(|| format!("binding unix:{path}"))?,
                ))
            }
            #[cfg(not(unix))]
            Endpoint::Unix(path) => {
                bail!("unix endpoint `{path}` is not supported on this platform")
            }
        }
    }

    /// Accept one connection and wrap it in a framed, tuned connection.
    pub fn accept(&self, tuning: &Tuning) -> Result<FramedConn> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept().context("accepting tcp connection")?;
                FramedConn::new(Box::new(s), tuning)
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept().context("accepting unix connection")?;
                FramedConn::new(Box::new(s), tuning)
            }
        }
    }

    /// As [`Listener::accept`], but give up after `deadline` so a peer
    /// that never dials cannot hang a server forever (polls the
    /// listener in non-blocking mode).
    pub fn accept_deadline(&self, tuning: &Tuning, deadline: Duration) -> Result<FramedConn> {
        let until = Instant::now() + deadline;
        self.set_nonblocking(true)?;
        let out = loop {
            let attempt = match self {
                Listener::Tcp(l) => l.accept().map(|(s, _)| Box::new(s) as Box<dyn NetStream>),
                #[cfg(unix)]
                Listener::Unix(l) => l.accept().map(|(s, _)| Box::new(s) as Box<dyn NetStream>),
            };
            match attempt {
                Ok(s) => break FramedConn::new(s, tuning),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if Instant::now() >= until {
                        break Err(anyhow::anyhow!(
                            "no peer connected within {deadline:?}"
                        ));
                    }
                    thread::sleep(Duration::from_millis(20));
                }
                Err(e) => break Err(e).context("accepting connection"),
            }
        };
        self.set_nonblocking(false)?;
        out
    }

    fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb)?,
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(nb)?,
        }
        Ok(())
    }

    /// The endpoint this listener is actually bound to — resolves the
    /// ephemeral port of a `tcp:…:0` bind, so tests and examples can
    /// hand the real address to their peers.
    pub fn local_endpoint(&self) -> Result<Endpoint> {
        match self {
            Listener::Tcp(l) => Ok(Endpoint::Tcp(l.local_addr()?.to_string())),
            #[cfg(unix)]
            Listener::Unix(l) => {
                let addr = l.local_addr()?;
                let path = addr
                    .as_pathname()
                    .and_then(|p| p.to_str())
                    .ok_or_else(|| anyhow::anyhow!("unix listener has no pathname"))?;
                Ok(Endpoint::Unix(path.to_string()))
            }
        }
    }
}

/// Dial `ep`, retrying for up to `retry_for` (the server side of a
/// multi-process launch may bind a moment later than the client starts).
/// Retries sleep a jittered 25–75 ms between attempts — a fleet of
/// clients dialing one freshly-launched root must not stampede the
/// backlog in lockstep — and both the sleep and (on TCP) the in-flight
/// connect are capped at the remaining budget, so the call cannot
/// overshoot `retry_for` by a stuck connect.
pub fn connect(ep: &Endpoint, tuning: &Tuning, retry_for: Duration) -> Result<FramedConn> {
    let deadline = Instant::now() + retry_for;
    // process-local jitter stream: distinct per client process, no
    // bearing on protocol determinism (retry timing only)
    let mut jitter = std::process::id() as u64 ^ 0x4A49_5454; // "JITT"
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        let attempt: io::Result<Box<dyn NetStream>> = match ep {
            Endpoint::Tcp(addr) => {
                tcp_connect_within(addr, remaining).map(|s| Box::new(s) as _)
            }
            // Unix-domain connects are local and effectively instant
            // (std has no connect_timeout for them); the refused-path
            // case fails immediately rather than blocking
            #[cfg(unix)]
            Endpoint::Unix(path) => UnixStream::connect(path).map(|s| Box::new(s) as _),
            #[cfg(not(unix))]
            Endpoint::Unix(path) => Err(io::Error::new(
                io::ErrorKind::Unsupported,
                format!("unix endpoint `{path}` is not supported on this platform"),
            )),
        };
        match attempt {
            Ok(s) => return FramedConn::new(s, tuning),
            Err(e) => {
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() || e.kind() == io::ErrorKind::Unsupported {
                    return Err(e).with_context(|| format!("connecting to {}", ep.summary()));
                }
                let pause =
                    Duration::from_millis(25 + crate::util::rng::splitmix64(&mut jitter) % 51);
                thread::sleep(pause.min(remaining));
            }
        }
    }
}

/// TCP dial bounded by `budget`: resolves the address and tries each
/// candidate with `connect_timeout`, so a blackholed route cannot hold
/// the retry loop past its deadline. A zero budget still gets a 1 ms
/// floor — `connect_timeout` rejects a zero duration outright.
fn tcp_connect_within(addr: &str, budget: Duration) -> io::Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let budget = budget.max(Duration::from_millis(1));
    let mut last = None;
    for sa in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sa, budget) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| {
        io::Error::new(io::ErrorKind::InvalidInput, format!("`{addr}` resolved to no addresses"))
    }))
}

/// One tuned socket speaking the length-prefixed framing, with byte
/// counters for both directions.
pub struct FramedConn {
    stream: Box<dyn NetStream>,
    max_frame: usize,
    sent: u64,
    received: u64,
}

impl FramedConn {
    /// Wrap a raw stream: applies the tuning's deadlines and frame cap.
    pub fn new(stream: Box<dyn NetStream>, tuning: &Tuning) -> Result<FramedConn> {
        stream.apply_tuning(tuning).context("applying socket timeouts")?;
        Ok(FramedConn { stream, max_frame: tuning.max_frame, sent: 0, received: 0 })
    }

    /// Send one frame; returns its wire size (prefix + body).
    pub fn send(&mut self, f: &Frame) -> Result<usize> {
        let n = write_frame(&mut self.stream, f)?;
        self.sent += n as u64;
        Ok(n)
    }

    /// Receive one frame (strict decode, capped allocation).
    pub fn recv(&mut self) -> Result<Frame> {
        let (f, n) = read_frame(&mut self.stream, self.max_frame)?;
        self.received += n as u64;
        Ok(f)
    }

    /// Receive one raw frame body (capped allocation, no decode) — the
    /// zero-copy receive path: callers parse it with
    /// [`decode_body_borrowed`](super::frame::decode_body_borrowed) and
    /// absorb payloads straight out of the returned buffer. Metered
    /// identically to [`recv`](Self::recv) (prefix + body).
    pub fn recv_body(&mut self) -> Result<Vec<u8>> {
        let body = read_body(&mut self.stream, self.max_frame)?;
        self.received += 4 + body.len() as u64;
        Ok(body)
    }

    /// Send one already-encoded frame body verbatim (prefix + body, one
    /// `write_all`, flushed) — the forwarding path: a relay that received
    /// a body via [`recv_body`](Self::recv_body) re-ships the exact
    /// bytes, like the reflector does, so forwarded frames are
    /// byte-identical to the originals. Metered identically to
    /// [`send`](Self::send); returns the wire size.
    pub fn send_body(&mut self, body: &[u8]) -> Result<usize> {
        let mut out = Vec::with_capacity(4 + body.len());
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(body);
        self.stream.write_all(&out).context("writing raw frame body")?;
        self.stream.flush().context("flushing raw frame body")?;
        self.sent += out.len() as u64;
        Ok(out.len())
    }

    /// Client side of the versioned handshake: send `hello`, expect a
    /// [`Frame::Welcome`] back.
    pub fn handshake_client(&mut self, hello: &Hello) -> Result<Welcome> {
        self.send(&Frame::Hello(hello.clone()))?;
        match self.recv().context("waiting for WELCOME")? {
            Frame::Welcome(w) => Ok(w),
            f => bail!("handshake: expected WELCOME, peer sent {}", kind_name(f.kind())),
        }
    }

    /// Server side of the versioned handshake: expect a [`Frame::Hello`],
    /// reply with `welcome`, and hand the hello to the caller.
    pub fn handshake_server(&mut self, welcome: &Welcome) -> Result<Hello> {
        let hello = match self.recv().context("waiting for HELLO")? {
            Frame::Hello(h) => h,
            f => bail!("handshake: expected HELLO, peer sent {}", kind_name(f.kind())),
        };
        self.send(&Frame::Welcome(welcome.clone()))?;
        Ok(hello)
    }

    /// An independently owned read handle on the same socket, with its
    /// own counters — the serve roles park one in a reader thread while
    /// the original keeps writing.
    pub fn split_reader(&self) -> Result<FramedConn> {
        Ok(FramedConn {
            stream: self.stream.try_clone_stream().context("cloning stream for reader")?,
            max_frame: self.max_frame,
            sent: 0,
            received: 0,
        })
    }

    /// Bytes written on this handle.
    pub fn bytes_sent(&self) -> u64 {
        self.sent
    }

    /// Bytes read on this handle.
    pub fn bytes_received(&self) -> u64 {
        self.received
    }

    /// Close both directions (also unblocks a parked `split_reader`).
    pub fn shutdown(&self) -> io::Result<()> {
        self.stream.shutdown_stream()
    }
}

/// The reflector: answers one HELLO with a parameter-free WELCOME, then
/// echoes every frame back **byte-for-byte** (it never re-encodes — a
/// pure channel) until BYE or EOF.
fn reflect_stream<S: Read + Write>(mut s: S, max_frame: usize) -> Result<()> {
    let body = read_body(&mut s, max_frame)?;
    if body.first() != Some(&super::frame::KIND_HELLO) {
        bail!("reflector: expected HELLO");
    }
    write_frame(
        &mut s,
        &Frame::Welcome(Welcome { m: 0, seed: 0, rounds: 0, participating: 0, clients: 0 }),
    )?;
    loop {
        let body = match read_body(&mut s, max_frame) {
            Ok(b) => b,
            Err(_) => break, // peer closed (or died) — reflector's job is done
        };
        if body.first() == Some(&KIND_BYE) {
            break;
        }
        let mut out = Vec::with_capacity(4 + body.len());
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&body);
        s.write_all(&out)?;
        s.flush()?;
    }
    Ok(())
}

/// A [`Transport`] over a real loopback socket (DESIGN.md §12).
///
/// Construction spawns a reflector thread, binds an ephemeral listener,
/// connects to it, and completes the versioned handshake. Every
/// coordinator send then becomes a framed round trip: the payload is
/// encoded, enveloped, written to the OS socket, read back, strictly
/// decoded, and **the returned payload is what the engine adopts** — so
/// the golden codec bytes demonstrably survive a real socket, not just a
/// function call.
///
/// Metering: client-tier counters record exactly the codec
/// [`frame_bytes`] per delivery (the transport-independent cost the
/// paper reports — same numbers as `SimNetwork`); envelope bytes (length
/// prefixes, frame headers, handshake) are tracked separately and
/// surfaced by [`Transport::wire_overhead`]. Lifecycle draws use the
/// same `(seed, k)`-keyed streams as `SimNetwork::channel`, so scenario
/// plans are transport-independent too.
pub struct StreamTransport {
    conn: FramedConn,
    reflector: Option<thread::JoinHandle<()>>,
    /// the run's byte ledger (rounds closed by `end_round`)
    pub ledger: Ledger,
    shards: Vec<RoundBytes>,
    lifecycle: Vec<Rng>,
    seed: u64,
    round: u32,
    codec_bytes: u64,
}

impl StreamTransport {
    /// Loopback transport over an ephemeral TCP socket on 127.0.0.1.
    pub fn loopback(seed: u64, tuning: &Tuning) -> Result<StreamTransport> {
        let listener =
            TcpListener::bind("127.0.0.1:0").context("binding loopback listener")?;
        let ep = Endpoint::Tcp(listener.local_addr()?.to_string());
        let max = tuning.max_frame;
        let reflector = thread::Builder::new()
            .name("pfed1bs-reflector".into())
            .spawn(move || {
                if let Ok((s, _)) = listener.accept() {
                    let _ = reflect_stream(s, max);
                }
            })
            .context("spawning reflector thread")?;
        Self::finish_loopback(seed, tuning, &ep, reflector)
    }

    /// Loopback transport over a Unix-domain socket at `path` (exercises
    /// the UDS family end to end).
    #[cfg(unix)]
    pub fn loopback_unix(seed: u64, tuning: &Tuning, path: &str) -> Result<StreamTransport> {
        let _ = std::fs::remove_file(path);
        let listener =
            UnixListener::bind(path).with_context(|| format!("binding unix:{path}"))?;
        let ep = Endpoint::Unix(path.to_string());
        let max = tuning.max_frame;
        let reflector = thread::Builder::new()
            .name("pfed1bs-reflector".into())
            .spawn(move || {
                if let Ok((s, _)) = listener.accept() {
                    let _ = reflect_stream(s, max);
                }
            })
            .context("spawning reflector thread")?;
        Self::finish_loopback(seed, tuning, &ep, reflector)
    }

    fn finish_loopback(
        seed: u64,
        tuning: &Tuning,
        ep: &Endpoint,
        reflector: thread::JoinHandle<()>,
    ) -> Result<StreamTransport> {
        let mut conn = connect(ep, tuning, Duration::from_secs(5))?;
        conn.handshake_client(&Hello {
            role: PeerRole::Fleet,
            lo: 0,
            hi: 0,
            m: 0,
            want_ack: false,
        })?;
        Ok(StreamTransport {
            conn,
            reflector: Some(reflector),
            ledger: Ledger::new(),
            shards: Vec::new(),
            lifecycle: Vec::new(),
            seed,
            round: 0,
            codec_bytes: 0,
        })
    }

    fn shard_mut(&mut self, k: usize) -> &mut RoundBytes {
        while self.shards.len() <= k {
            self.shards.push(RoundBytes::default());
        }
        &mut self.shards[k]
    }

    fn lifecycle_mut(&mut self, k: usize) -> &mut Rng {
        while self.lifecycle.len() <= k {
            let next = self.lifecycle.len();
            self.lifecycle.push(lifecycle_rng(self.seed, next));
        }
        &mut self.lifecycle[k]
    }

    /// Push one frame through the socket and adopt the payload the peer
    /// returns; the echo must be the same kind, round, and peer id.
    fn roundtrip(&mut self, f: Frame) -> Result<Payload> {
        let sent = encode_body(&f);
        self.conn.send(&f)?;
        let echoed = self.conn.recv()?;
        let got = encode_body(&echoed);
        // kind, round, and peer live in the first 9 body bytes; a
        // mismatch means the channel delivered someone else's frame
        if sent[..9.min(sent.len())] != got[..9.min(got.len())] {
            bail!(
                "loopback peer answered a {} frame with {}",
                kind_name(f.kind()),
                kind_name(echoed.kind())
            );
        }
        match echoed {
            Frame::Downlink { payload, .. }
            | Frame::Uplink { payload, .. }
            | Frame::Tally { payload, .. } => Ok(payload),
            f => bail!("loopback peer echoed a payload-free {} frame", kind_name(f.kind())),
        }
    }
}

impl Transport for StreamTransport {
    fn downlink_to(&mut self, k: usize, payload: &Payload) -> Result<Payload> {
        let got = self.roundtrip(Frame::Downlink {
            round: self.round,
            client: k as u32,
            payload: payload.clone(),
        })?;
        let n = frame_bytes(payload) as u64;
        self.codec_bytes += n;
        let sh = self.shard_mut(k);
        sh.downlink += n;
        sh.downlink_msgs += 1;
        Ok(got)
    }

    fn uplink_from(&mut self, k: usize, payload: &Payload) -> Result<Payload> {
        let got = self.roundtrip(Frame::Uplink {
            round: self.round,
            client: k as u32,
            payload: payload.clone(),
        })?;
        let n = frame_bytes(payload) as u64;
        self.codec_bytes += n;
        let sh = self.shard_mut(k);
        sh.uplink += n;
        sh.uplink_msgs += 1;
        Ok(got)
    }

    fn edge_downlink(&mut self, edge: usize, payload: &Payload) -> Result<Payload> {
        let got = self.roundtrip(Frame::Downlink {
            round: self.round,
            client: edge as u32,
            payload: payload.clone(),
        })?;
        let n = frame_bytes(payload);
        self.codec_bytes += n as u64;
        self.ledger.record_edge(Direction::Downlink, n);
        Ok(got)
    }

    fn edge_uplink(&mut self, edge: usize, payload: &Payload) -> Result<Payload> {
        let frame = match payload {
            Payload::TallyFrame(_) => Frame::Tally {
                round: self.round,
                edge: edge as u32,
                payload: payload.clone(),
            },
            // non-tally edge traffic (e.g. dense baselines) rides the
            // generic uplink envelope
            _ => Frame::Uplink { round: self.round, client: edge as u32, payload: payload.clone() },
        };
        let got = self.roundtrip(frame)?;
        let n = frame_bytes(payload);
        self.codec_bytes += n as u64;
        self.ledger.record_edge(Direction::Uplink, n);
        Ok(got)
    }

    fn draw_dropout(&mut self, k: usize, p: f64) -> bool {
        dropout_draw(self.lifecycle_mut(k), p)
    }

    fn draw_latency(&mut self, k: usize, model: &LatencyModel) -> f64 {
        model.draw(self.lifecycle_mut(k))
    }

    fn end_round(&mut self) -> RoundBytes {
        let StreamTransport { shards, ledger, .. } = self;
        for sh in shards.iter_mut() {
            ledger.merge_shard(std::mem::take(sh));
        }
        self.round += 1;
        self.ledger.end_round()
    }

    fn total_bytes(&self) -> u64 {
        self.ledger.total_bytes() + self.shards.iter().map(|s| s.total()).sum::<u64>()
    }

    fn wire_overhead(&self) -> u64 {
        // everything that crossed the socket beyond the codec payloads
        // themselves: length prefixes, frame headers, the handshake —
        // in both directions
        (self.conn.bytes_sent() + self.conn.bytes_received())
            .saturating_sub(2 * self.codec_bytes)
    }
}

impl Drop for StreamTransport {
    fn drop(&mut self) {
        let _ = self.conn.send(&Frame::Bye);
        let _ = self.conn.shutdown();
        if let Some(h) = self.reflector.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::network::SimNetwork;
    use crate::sketch::bitpack::SignVec;

    fn signs(m: usize) -> Payload {
        Payload::Signs(SignVec::from_fn(m, |i| i % 3 == 0))
    }

    #[test]
    fn loopback_round_trip_is_lossless_over_a_real_socket() {
        let mut t = StreamTransport::loopback(7, &Tuning::default()).unwrap();
        let p = signs(130);
        assert_eq!(t.uplink_from(3, &p).unwrap(), p);
        assert_eq!(t.downlink_to(5, &p).unwrap(), p);
        let dense = Payload::Dense(vec![1.0, -2.5, 0.25]);
        assert_eq!(t.downlink_to(0, &dense).unwrap(), dense);
        let tally = Payload::TallyFrame(crate::comm::codec::TallyFrame {
            absorbed: 2,
            loss_sum: 0.5,
            scalar: -3,
            quanta: vec![i128::MAX, -1, 0],
            groups: Vec::new(),
        });
        assert_eq!(t.edge_uplink(1, &tally).unwrap(), tally);
        assert!(t.wire_overhead() > 0, "envelope bytes must be visible");
    }

    #[test]
    fn metering_is_bit_identical_to_sim_network() {
        // the same operation sequence on both transports must meter the
        // same RoundBytes — the DESIGN.md §12 bit-identity contract
        let mut sim = SimNetwork::new(11);
        let mut sock = StreamTransport::loopback(11, &Tuning::default()).unwrap();
        let p = signs(257);
        let tally = Payload::TallyFrame(crate::comm::codec::TallyFrame {
            absorbed: 4,
            loss_sum: 1.0,
            scalar: 0,
            quanta: vec![5; 257],
            groups: Vec::new(),
        });
        for net in [&mut sim as &mut dyn Transport, &mut sock as &mut dyn Transport] {
            for k in 0..6 {
                net.downlink_to(k, &p).unwrap();
            }
            for k in [2usize, 0, 4] {
                net.uplink_from(k, &p).unwrap();
            }
            net.edge_downlink(0, &p).unwrap();
            net.edge_uplink(0, &tally).unwrap();
        }
        assert_eq!(sim.total_bytes(), sock.total_bytes());
        let a = Transport::end_round(&mut sim);
        let b = sock.end_round();
        assert_eq!(a, b);
    }

    #[test]
    fn lifecycle_draws_match_sim_network_streams() {
        // scenario plans must be transport-independent: same (seed, k)
        // streams, same draw order ⇒ same dropouts and latencies
        let model = LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 9.0 };
        let mut sim = SimNetwork::new(23);
        let mut sock = StreamTransport::loopback(23, &Tuning::default()).unwrap();
        for k in [0usize, 3, 1, 3, 0] {
            assert_eq!(
                sim.channel(k).draw_dropout(0.4),
                sock.draw_dropout(k, 0.4),
                "dropout draw diverged for client {k}"
            );
            assert_eq!(
                sim.channel(k).draw_latency(&model),
                sock.draw_latency(k, &model),
                "latency draw diverged for client {k}"
            );
        }
    }

    #[cfg(unix)]
    #[test]
    fn unix_family_loopback_works() {
        let path = std::env::temp_dir().join("pfed1bs-test-uds.sock");
        let path = path.to_str().unwrap().to_string();
        let mut t = StreamTransport::loopback_unix(3, &Tuning::default(), &path).unwrap();
        let p = signs(64);
        assert_eq!(t.uplink_from(0, &p).unwrap(), p);
        let r = t.end_round();
        assert_eq!(r.uplink, 13);
        drop(t);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn raw_body_send_recv_round_trips_byte_identically() {
        use crate::comm::transport::frame::decode_body_borrowed;
        use crate::comm::transport::frame::FrameView;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let ep = Endpoint::Tcp(listener.local_addr().unwrap().to_string());
        let t = Tuning::default();
        let dial = thread::spawn({
            let t = t.clone();
            move || connect(&ep, &t, Duration::from_secs(5)).unwrap()
        });
        let (s, _) = listener.accept().unwrap();
        let mut server = FramedConn::new(Box::new(s), &t).unwrap();
        let mut client = dial.join().unwrap();

        let f = Frame::Uplink { round: 1, client: 2, payload: signs(130) };
        let wrote = client.send(&f).unwrap();
        let body = server.recv_body().unwrap();
        assert_eq!(body, encode_body(&f), "raw body must be the exact encoded body");
        assert_eq!(server.bytes_received(), wrote as u64);
        let FrameView::Uplink { round: 1, client: 2, payload } =
            decode_body_borrowed(&body).unwrap()
        else {
            panic!("wrong frame kind off the wire")
        };
        assert_eq!(payload.to_owned(), signs(130));

        // forwarding the raw body re-ships the exact bytes
        let shipped = server.send_body(&body).unwrap();
        assert_eq!(shipped, wrote);
        assert_eq!(server.bytes_sent(), wrote as u64);
        assert_eq!(client.recv().unwrap(), f);
    }

    #[test]
    fn connect_times_out_with_context() {
        // a TCP port nobody listens on (bind then drop releases it)
        let port = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let ep = Endpoint::Tcp(format!("127.0.0.1:{port}"));
        let started = Instant::now();
        let err = connect(&ep, &Tuning::default(), Duration::from_millis(120)).unwrap_err();
        let elapsed = started.elapsed();
        assert!(format!("{err:#}").contains("connecting to"), "{err:#}");
        // the jittered backoff sleeps and the in-flight connect are both
        // capped at the remaining budget: no overshoot past deadline +
        // scheduler slack, and the retry loop actually paused between
        // attempts rather than hot-spinning through the whole window
        assert!(
            elapsed < Duration::from_millis(120 + 500),
            "connect overshot its retry budget: {elapsed:?}"
        );
    }
}
