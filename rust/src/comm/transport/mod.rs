//! Transport abstraction: the engine's view of "a network".
//!
//! Every byte the coordinator has ever "sent" moved through the
//! in-process [`SimNetwork`]; this module names the contract that made
//! that swappable and cashes it in (ROADMAP: from *simulation of*
//! millions of users to *serving* them). The [`Transport`] trait covers
//! exactly the surface the round engine uses:
//!
//! * client-tier delivery ([`Transport::downlink_to`] /
//!   [`Transport::uplink_from`]) and edge-tier delivery
//!   ([`Transport::edge_downlink`] / [`Transport::edge_uplink`]) — each
//!   takes a codec [`Payload`] and returns the payload **as delivered**
//!   (the caller must adopt the returned value; a real channel may
//!   corrupt, a strict decoder may reject);
//! * per-peer byte metering compatible with [`RoundBytes`] — the unit
//!   is the codec [`frame_bytes`](crate::comm::codec::frame_bytes) of
//!   the payload, *not* any envelope a concrete transport wraps around
//!   it, so cost numbers are transport-independent and comparable to
//!   the paper's;
//! * scenario lifecycle draws ([`Transport::draw_dropout`] /
//!   [`Transport::draw_latency`]) from `(seed, k)`-keyed streams shared
//!   across impls, so a scenario plan replays identically on any
//!   transport.
//!
//! Two implementations ship: [`SimNetwork`] (the default — byte-for-byte
//! unchanged, all golden traces hold) and
//! [`stream::StreamTransport`], which pushes every frame through a real
//! TCP or Unix-domain socket using the length-prefixed framing of
//! [`frame`]. DESIGN.md §12 states the bit-identity argument.

pub mod frame;
pub mod stream;

pub use frame::{Frame, Hello, PeerRole, Welcome, DEFAULT_MAX_FRAME, PROTOCOL_VERSION};
pub use stream::{connect, FramedConn, Listener, NetStream, StreamTransport, Tuning};

use anyhow::Result;

use crate::comm::codec::Payload;
use crate::comm::ledger::RoundBytes;
use crate::comm::network::{LatencyModel, SimNetwork};

/// What the round engine needs from a network. See the module docs for
/// the delivery/metering/lifecycle contract each method must honor.
pub trait Transport {
    /// Server/edge → client `k`; returns the payload as delivered.
    /// Broadcasts are one call per recipient (delivered copies are what
    /// the paper's accounting counts — DESIGN.md §5).
    fn downlink_to(&mut self, k: usize, payload: &Payload) -> Result<Payload>;

    /// Client `k` → server/edge; returns the payload as delivered.
    fn uplink_from(&mut self, k: usize, payload: &Payload) -> Result<Payload>;

    /// Root → edge aggregator `edge` (hierarchical fan-out, DESIGN.md
    /// §11). Metered in the edge-tier columns, never the client tier.
    fn edge_downlink(&mut self, edge: usize, payload: &Payload) -> Result<Payload>;

    /// Edge aggregator `edge` → root (one merge frame per round).
    fn edge_uplink(&mut self, edge: usize, payload: &Payload) -> Result<Payload>;

    /// Does client `k` drop out of the current round? Must draw from the
    /// canonical `(seed, k)` lifecycle stream; `p == 0` consumes nothing.
    fn draw_dropout(&mut self, k: usize, p: f64) -> bool;

    /// Client `k`'s uplink service time (ms) under `model`, from the
    /// same lifecycle stream; draw-free models consume nothing.
    fn draw_latency(&mut self, k: usize, model: &LatencyModel) -> f64;

    /// Merge per-peer shards and close the round; returns its totals.
    fn end_round(&mut self) -> RoundBytes;

    /// All bytes metered so far (closed rounds plus open shards).
    fn total_bytes(&self) -> u64;

    /// Bytes a concrete transport moved *beyond* the metered codec
    /// frames (length prefixes, envelopes, handshakes). Zero for the
    /// in-process simulation; a socket transport reports its real
    /// framing cost here so the metered numbers stay comparable.
    fn wire_overhead(&self) -> u64 {
        0
    }
}

// Inherent methods win method resolution on a concrete `SimNetwork`, so
// existing call sites (and all golden byte tests) are untouched; generic
// `N: Transport` contexts resolve through this impl, which delegates
// straight back to those inherent methods.
impl Transport for SimNetwork {
    fn downlink_to(&mut self, k: usize, payload: &Payload) -> Result<Payload> {
        SimNetwork::downlink_to(self, k, payload)
    }

    fn uplink_from(&mut self, k: usize, payload: &Payload) -> Result<Payload> {
        SimNetwork::uplink_from(self, k, payload)
    }

    fn edge_downlink(&mut self, edge: usize, payload: &Payload) -> Result<Payload> {
        SimNetwork::edge_downlink(self, edge, payload)
    }

    fn edge_uplink(&mut self, edge: usize, payload: &Payload) -> Result<Payload> {
        SimNetwork::edge_uplink(self, edge, payload)
    }

    fn draw_dropout(&mut self, k: usize, p: f64) -> bool {
        self.channel(k).draw_dropout(p)
    }

    fn draw_latency(&mut self, k: usize, model: &LatencyModel) -> f64 {
        self.channel(k).draw_latency(model)
    }

    fn end_round(&mut self) -> RoundBytes {
        SimNetwork::end_round(self)
    }

    fn total_bytes(&self) -> u64 {
        SimNetwork::total_bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::bitpack::SignVec;

    // a function generic over the trait — the shape the round engine has
    fn pingpong<N: Transport>(net: &mut N, m: usize) -> (Payload, RoundBytes) {
        let p = Payload::Signs(SignVec::from_fn(m, |i| i % 2 == 0));
        let echoed = net.uplink_from(0, &p).unwrap();
        net.downlink_to(1, &p).unwrap();
        (echoed, net.end_round())
    }

    #[test]
    fn sim_network_satisfies_the_trait_with_unchanged_metering() {
        let mut net = SimNetwork::new(3);
        let (echoed, r) = pingpong(&mut net, 64);
        assert_eq!(echoed, Payload::Signs(SignVec::from_fn(64, |i| i % 2 == 0)));
        assert_eq!((r.uplink, r.downlink), (13, 13));
        assert_eq!(Transport::wire_overhead(&net), 0, "simulation has no envelope");
    }

    #[test]
    fn trait_lifecycle_draws_equal_inherent_ones() {
        let model = LatencyModel::Uniform { lo_ms: 0.0, hi_ms: 4.0 };
        let mut a = SimNetwork::new(7);
        let mut b = SimNetwork::new(7);
        for k in [0usize, 2, 2, 1] {
            assert_eq!(Transport::draw_dropout(&mut a, k, 0.5), b.channel(k).draw_dropout(0.5));
            assert_eq!(
                Transport::draw_latency(&mut a, k, &model),
                b.channel(k).draw_latency(&model)
            );
        }
    }
}
