//! Length-prefixed wire framing for socket transports (DESIGN.md §12).
//!
//! Every frame on a stream is `u32 LE body length | body`; the body is
//! `kind byte | kind-specific fields`, and the payload-carrying kinds
//! embed one golden-tested codec frame (codec.rs) verbatim — the framing
//! layer is a pure envelope around the bytes `SimNetwork` already
//! meters, which is what makes the bit-identity argument of
//! DESIGN.md §12 a layering fact rather than a test hope:
//!
//! ```text
//! HELLO    01 | magic "PF1B" | version u16 | role u8 | lo u32 | hi u32 | m u32 | flags u8
//! WELCOME  02 | magic "PF1B" | version u16 | m u32 | seed u64 | rounds u32 | participating u32 | clients u32
//! DOWNLINK 03 | round u32 | client u32 | codec frame
//! UPLINK   04 | round u32 | client u32 | codec frame
//! TALLY    05 | round u32 | edge u32   | codec frame (must be tag-4 TallyFrame)
//! ACK      06 | round u32 | client u32
//! BYE      07
//! ```
//!
//! All integers are little-endian, matching the codec. Decoding is
//! strict: exact body lengths, known kinds/roles/flags only, magic and
//! version checked on both handshake kinds, and the length prefix is
//! capped **before** any allocation — a hostile or corrupt peer yields
//! `Err`, never a panic or an unbounded `Vec`.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::comm::codec::{decode, encode, Payload, PayloadView, TallyFrameView};

/// Handshake magic: the first bytes a peer must present after the
/// kind byte. Anything else is not a pFed1BS endpoint.
pub const MAGIC: [u8; 4] = *b"PF1B";

/// Wire protocol version, bumped on any framing change. Peers with a
/// different version are rejected during the handshake.
pub const PROTOCOL_VERSION: u16 = 1;

/// Default hard cap on a single frame's body length. Generous (the
/// largest honest frame is a TallyFrame: 9 + 33 + 16·m bytes, ~1.6 MB
/// at m = 10^5) but finite, so a malicious length prefix cannot drive
/// an allocation.
pub const DEFAULT_MAX_FRAME: usize = 64 << 20;

/// Frame kind: client/edge → root greeting.
pub const KIND_HELLO: u8 = 1;
/// Frame kind: root → peer handshake reply carrying the run parameters.
pub const KIND_WELCOME: u8 = 2;
/// Frame kind: server → client payload (consensus broadcast / notify).
pub const KIND_DOWNLINK: u8 = 3;
/// Frame kind: client → server payload (one-bit sketch).
pub const KIND_UPLINK: u8 = 4;
/// Frame kind: edge → root merge frame (must carry a `TallyFrame`).
pub const KIND_TALLY: u8 = 5;
/// Frame kind: root → client absorb acknowledgment (loadgen latency).
pub const KIND_ACK: u8 = 6;
/// Frame kind: orderly shutdown notice (no body fields).
pub const KIND_BYE: u8 = 7;

/// Hello flag bit: the peer wants a [`Frame::Ack`] after each of its
/// uplinks is absorbed (how loadgen measures uplink-to-absorb latency).
pub const FLAG_WANT_ACK: u8 = 1;

/// Human-readable name of a frame kind (for error messages).
pub fn kind_name(kind: u8) -> &'static str {
    match kind {
        KIND_HELLO => "HELLO",
        KIND_WELCOME => "WELCOME",
        KIND_DOWNLINK => "DOWNLINK",
        KIND_UPLINK => "UPLINK",
        KIND_TALLY => "TALLY",
        KIND_ACK => "ACK",
        KIND_BYE => "BYE",
        _ => "UNKNOWN",
    }
}

/// Who a connecting peer claims to be in its [`Hello`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerRole {
    /// a multiplexed fleet of simulated clients (`pfed1bs client-fleet`)
    Fleet,
    /// an edge aggregator relaying a client range (`pfed1bs edge`)
    Edge,
    /// a load-generation fleet that wants per-uplink ACKs
    Loadgen,
}

impl PeerRole {
    /// Wire byte for this role.
    pub fn as_u8(self) -> u8 {
        match self {
            PeerRole::Fleet => 0,
            PeerRole::Edge => 1,
            PeerRole::Loadgen => 2,
        }
    }

    /// Parse a wire byte; unknown roles are a handshake error.
    pub fn from_u8(b: u8) -> Result<PeerRole> {
        Ok(match b {
            0 => PeerRole::Fleet,
            1 => PeerRole::Edge,
            2 => PeerRole::Loadgen,
            other => bail!("hello frame: unknown peer role {other}"),
        })
    }
}

/// The peer → root greeting: who the peer is and which client ids it
/// multiplexes. `hi = 0` means "every client the root has"; `m = 0`
/// means the peer takes the sketch dimension from the [`Welcome`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hello {
    /// what kind of peer this connection carries
    pub role: PeerRole,
    /// first client id served over this connection (inclusive)
    pub lo: u32,
    /// one past the last client id (exclusive); 0 ⇒ the full fleet
    pub hi: u32,
    /// expected sketch dimension; 0 ⇒ unpinned (adopt the root's)
    pub m: u32,
    /// request a [`Frame::Ack`] after each absorbed uplink
    pub want_ack: bool,
}

/// The root → peer handshake reply: the run parameters every peer needs
/// to replicate selections and mock sketches deterministically.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Welcome {
    /// sketch dimension m
    pub m: u32,
    /// the run seed all mock streams derive from
    pub seed: u64,
    /// total rounds T the root will drive
    pub rounds: u32,
    /// uplinks absorbed per round (S)
    pub participating: u32,
    /// total fleet size K
    pub clients: u32,
}

/// A decoded stream frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// peer → root greeting
    Hello(Hello),
    /// root → peer handshake reply
    Welcome(Welcome),
    /// server → client payload
    Downlink {
        /// round index
        round: u32,
        /// recipient client id
        client: u32,
        /// the codec payload, embedded verbatim
        payload: Payload,
    },
    /// client → server payload
    Uplink {
        /// round index
        round: u32,
        /// sender client id
        client: u32,
        /// the codec payload, embedded verbatim
        payload: Payload,
    },
    /// edge → root merge frame
    Tally {
        /// round index
        round: u32,
        /// sender edge id
        edge: u32,
        /// must be [`Payload::TallyFrame`] (enforced on decode)
        payload: Payload,
    },
    /// root → client absorb acknowledgment
    Ack {
        /// round index
        round: u32,
        /// the client whose uplink was absorbed
        client: u32,
    },
    /// orderly shutdown notice
    Bye,
}

impl Frame {
    /// This frame's wire kind byte.
    pub fn kind(&self) -> u8 {
        match self {
            Frame::Hello(_) => KIND_HELLO,
            Frame::Welcome(_) => KIND_WELCOME,
            Frame::Downlink { .. } => KIND_DOWNLINK,
            Frame::Uplink { .. } => KIND_UPLINK,
            Frame::Tally { .. } => KIND_TALLY,
            Frame::Ack { .. } => KIND_ACK,
            Frame::Bye => KIND_BYE,
        }
    }
}

/// A stream frame decoded without copying its payload: the
/// payload-carrying kinds borrow the body buffer through
/// [`PayloadView`], so a server can absorb an uplink or merge frame
/// straight out of its receive buffer (DESIGN.md §14). Control frames
/// carry a few fixed fields and decode owned — they were always
/// copy-free. `Tally` holds a [`TallyFrameView`] directly, so the
/// TallyFrame-payload rule is a type-level fact here.
#[derive(Clone, Debug)]
pub enum FrameView<'a> {
    /// peer → root greeting
    Hello(Hello),
    /// root → peer handshake reply
    Welcome(Welcome),
    /// server → client payload
    Downlink {
        /// round index
        round: u32,
        /// recipient client id
        client: u32,
        /// the borrowed codec payload
        payload: PayloadView<'a>,
    },
    /// client → server payload
    Uplink {
        /// round index
        round: u32,
        /// sender client id
        client: u32,
        /// the borrowed codec payload
        payload: PayloadView<'a>,
    },
    /// edge → root merge frame
    Tally {
        /// round index
        round: u32,
        /// sender edge id
        edge: u32,
        /// the borrowed merge frame (kind enforced on decode)
        payload: TallyFrameView<'a>,
    },
    /// root → client absorb acknowledgment
    Ack {
        /// round index
        round: u32,
        /// the client whose uplink was absorbed
        client: u32,
    },
    /// orderly shutdown notice
    Bye,
}

impl<'a> FrameView<'a> {
    /// This frame's wire kind byte.
    pub fn kind(&self) -> u8 {
        match self {
            FrameView::Hello(_) => KIND_HELLO,
            FrameView::Welcome(_) => KIND_WELCOME,
            FrameView::Downlink { .. } => KIND_DOWNLINK,
            FrameView::Uplink { .. } => KIND_UPLINK,
            FrameView::Tally { .. } => KIND_TALLY,
            FrameView::Ack { .. } => KIND_ACK,
            FrameView::Bye => KIND_BYE,
        }
    }

    /// Materialize an owned [`Frame`] — bit-identical to running the
    /// owned [`decode_body`] on the same body.
    pub fn to_frame(&self) -> Frame {
        match self {
            FrameView::Hello(h) => Frame::Hello(h.clone()),
            FrameView::Welcome(w) => Frame::Welcome(w.clone()),
            FrameView::Downlink { round, client, payload } => Frame::Downlink {
                round: *round,
                client: *client,
                payload: payload.to_owned(),
            },
            FrameView::Uplink { round, client, payload } => Frame::Uplink {
                round: *round,
                client: *client,
                payload: payload.to_owned(),
            },
            FrameView::Tally { round, edge, payload } => Frame::Tally {
                round: *round,
                edge: *edge,
                payload: Payload::TallyFrame(payload.to_frame()),
            },
            FrameView::Ack { round, client } => Frame::Ack { round: *round, client: *client },
            FrameView::Bye => Frame::Bye,
        }
    }
}

fn put_magic_version(out: &mut Vec<u8>) {
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&PROTOCOL_VERSION.to_le_bytes());
}

fn check_magic_version(b: &[u8]) -> Result<()> {
    if b[0..4] != MAGIC {
        bail!("handshake magic {:02x?} is not {:02x?} — not a pFed1BS peer", &b[0..4], MAGIC);
    }
    let v = u16::from_le_bytes(b[4..6].try_into().unwrap());
    if v != PROTOCOL_VERSION {
        bail!("protocol version mismatch: ours is {PROTOCOL_VERSION}, peer sent {v}");
    }
    Ok(())
}

fn u32_at(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(b[at..at + 4].try_into().unwrap())
}

/// Encode a frame body (everything after the u32 length prefix).
pub fn encode_body(f: &Frame) -> Vec<u8> {
    match f {
        Frame::Hello(h) => {
            let mut out = Vec::with_capacity(21);
            out.push(KIND_HELLO);
            put_magic_version(&mut out);
            out.push(h.role.as_u8());
            out.extend_from_slice(&h.lo.to_le_bytes());
            out.extend_from_slice(&h.hi.to_le_bytes());
            out.extend_from_slice(&h.m.to_le_bytes());
            out.push(if h.want_ack { FLAG_WANT_ACK } else { 0 });
            out
        }
        Frame::Welcome(w) => {
            let mut out = Vec::with_capacity(31);
            out.push(KIND_WELCOME);
            put_magic_version(&mut out);
            out.extend_from_slice(&w.m.to_le_bytes());
            out.extend_from_slice(&w.seed.to_le_bytes());
            out.extend_from_slice(&w.rounds.to_le_bytes());
            out.extend_from_slice(&w.participating.to_le_bytes());
            out.extend_from_slice(&w.clients.to_le_bytes());
            out
        }
        Frame::Downlink { round, client, payload }
        | Frame::Uplink { round, client, payload }
        | Frame::Tally { round, edge: client, payload } => {
            let codec = encode(payload);
            let mut out = Vec::with_capacity(9 + codec.len());
            out.push(f.kind());
            out.extend_from_slice(&round.to_le_bytes());
            out.extend_from_slice(&client.to_le_bytes());
            out.extend_from_slice(&codec);
            out
        }
        Frame::Ack { round, client } => {
            let mut out = Vec::with_capacity(9);
            out.push(KIND_ACK);
            out.extend_from_slice(&round.to_le_bytes());
            out.extend_from_slice(&client.to_le_bytes());
            out
        }
        Frame::Bye => vec![KIND_BYE],
    }
}

/// Decode a frame body. Strict: exact lengths per kind, magic/version
/// validated on handshake kinds, codec payloads decoded by the strict
/// codec, and `TALLY` must carry a [`Payload::TallyFrame`]. Never
/// panics, never reads past the slice.
pub fn decode_body(body: &[u8]) -> Result<Frame> {
    let Some(&kind) = body.first() else {
        bail!("empty frame body");
    };
    match kind {
        KIND_HELLO => {
            if body.len() != 21 {
                bail!("hello frame: expected 21 bytes, got {}", body.len());
            }
            check_magic_version(&body[1..7])?;
            let role = PeerRole::from_u8(body[7])?;
            let flags = body[20];
            if flags & !FLAG_WANT_ACK != 0 {
                bail!("hello frame: unknown flag bits {flags:#04x}");
            }
            Ok(Frame::Hello(Hello {
                role,
                lo: u32_at(body, 8),
                hi: u32_at(body, 12),
                m: u32_at(body, 16),
                want_ack: flags & FLAG_WANT_ACK != 0,
            }))
        }
        KIND_WELCOME => {
            if body.len() != 31 {
                bail!("welcome frame: expected 31 bytes, got {}", body.len());
            }
            check_magic_version(&body[1..7])?;
            Ok(Frame::Welcome(Welcome {
                m: u32_at(body, 7),
                seed: u64::from_le_bytes(body[11..19].try_into().unwrap()),
                rounds: u32_at(body, 19),
                participating: u32_at(body, 23),
                clients: u32_at(body, 27),
            }))
        }
        KIND_DOWNLINK | KIND_UPLINK | KIND_TALLY => {
            // 9 header bytes + the codec's own 5-byte minimum frame
            if body.len() < 14 {
                bail!("{} frame too short ({} bytes)", kind_name(kind), body.len());
            }
            let round = u32_at(body, 1);
            let peer = u32_at(body, 5);
            let payload = decode(&body[9..])
                .with_context(|| format!("{} frame payload", kind_name(kind)))?;
            Ok(match kind {
                KIND_DOWNLINK => Frame::Downlink { round, client: peer, payload },
                KIND_UPLINK => Frame::Uplink { round, client: peer, payload },
                _ => {
                    if !matches!(payload, Payload::TallyFrame(_)) {
                        bail!("tally frame must carry a TallyFrame payload");
                    }
                    Frame::Tally { round, edge: peer, payload }
                }
            })
        }
        KIND_ACK => {
            if body.len() != 9 {
                bail!("ack frame: expected 9 bytes, got {}", body.len());
            }
            Ok(Frame::Ack { round: u32_at(body, 1), client: u32_at(body, 5) })
        }
        KIND_BYE => {
            if body.len() != 1 {
                bail!("bye frame: expected 1 byte, got {}", body.len());
            }
            Ok(Frame::Bye)
        }
        other => bail!("unknown frame kind {other}"),
    }
}

/// Decode a frame body into a borrowing [`FrameView`]: validation is
/// the owned [`decode_body`]'s exactly (strict lengths, known kinds,
/// magic/version on handshakes, TALLY must carry a tally payload), but
/// payload-carrying kinds borrow the body instead of materializing
/// word/lane vectors. Never panics, never reads past the slice.
pub fn decode_body_borrowed(body: &[u8]) -> Result<FrameView<'_>> {
    let Some(&kind) = body.first() else {
        bail!("empty frame body");
    };
    match kind {
        KIND_DOWNLINK | KIND_UPLINK | KIND_TALLY => {
            // 9 header bytes + the codec's own 5-byte minimum frame
            if body.len() < 14 {
                bail!("{} frame too short ({} bytes)", kind_name(kind), body.len());
            }
            let round = u32_at(body, 1);
            let peer = u32_at(body, 5);
            let payload = Payload::decode_borrowed(&body[9..])
                .with_context(|| format!("{} frame payload", kind_name(kind)))?;
            Ok(match kind {
                KIND_DOWNLINK => FrameView::Downlink { round, client: peer, payload },
                KIND_UPLINK => FrameView::Uplink { round, client: peer, payload },
                _ => {
                    let PayloadView::TallyFrame(tally) = payload else {
                        bail!("tally frame must carry a TallyFrame payload");
                    };
                    FrameView::Tally { round, edge: peer, payload: tally }
                }
            })
        }
        // control frames carry no payload — the owned decoder is already
        // copy-free for them, so delegate and re-wrap
        _ => Ok(match decode_body(body)? {
            Frame::Hello(h) => FrameView::Hello(h),
            Frame::Welcome(w) => FrameView::Welcome(w),
            Frame::Ack { round, client } => FrameView::Ack { round, client },
            Frame::Bye => FrameView::Bye,
            // payload kinds were matched above; decode_body cannot
            // return them from this arm
            f => bail!("unexpected {} frame in control path", kind_name(f.kind())),
        }),
    }
}

/// Read one raw frame body off a stream: length prefix, cap check
/// **before** allocation, then an exact body read. A short read
/// (truncated frame, mid-frame disconnect) or an oversized prefix is an
/// `Err`; the stream should be considered dead afterwards.
pub fn read_body<R: Read>(r: &mut R, max_frame: usize) -> Result<Vec<u8>> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4).context("reading frame length prefix")?;
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 {
        bail!("zero-length frame");
    }
    if len > max_frame {
        // reject BEFORE allocating: a hostile 0xFFFFFFFF prefix must not
        // reserve 4 GB
        bail!("frame length {len} exceeds the {max_frame}-byte cap");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)
        .with_context(|| format!("reading {len}-byte frame body"))?;
    Ok(body)
}

/// Read and decode one frame; returns the frame and the total bytes it
/// occupied on the wire (4-byte prefix + body).
pub fn read_frame<R: Read>(r: &mut R, max_frame: usize) -> Result<(Frame, usize)> {
    let body = read_body(r, max_frame)?;
    let frame = decode_body(&body)?;
    Ok((frame, 4 + body.len()))
}

/// Write one frame (prefix + body, single `write_all`, flushed); returns
/// the bytes put on the wire.
pub fn write_frame<W: Write>(w: &mut W, f: &Frame) -> Result<usize> {
    let body = encode_body(f);
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    w.write_all(&out)
        .with_context(|| format!("writing {} frame", kind_name(f.kind())))?;
    w.flush().context("flushing frame")?;
    Ok(out.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::bitpack::SignVec;
    use std::io::Cursor;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    fn unhex(s: &str) -> Vec<u8> {
        (0..s.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
            .collect()
    }

    /// Byte-exact golden bodies. Hand-written, not regenerated from the
    /// encoder under test: any change here is a wire-protocol break.
    #[test]
    fn golden_frame_bodies() {
        let cases: [(Frame, &str); 5] = [
            // HELLO: fleet, lo=0, hi=64, m=1024, no flags.
            // 01 | "PF1B" | version 1 le | role 0 | 0 le | 64 le | 1024 le | 00
            (
                Frame::Hello(Hello { role: PeerRole::Fleet, lo: 0, hi: 64, m: 1024, want_ack: false }),
                "0150463142010000000000004000000000040000 00",
            ),
            // HELLO: loadgen wanting ACKs, clients [8, 16), m unpinned
            (
                Frame::Hello(Hello { role: PeerRole::Loadgen, lo: 8, hi: 16, m: 0, want_ack: true }),
                "0150463142010002080000001000000000000000 01",
            ),
            // WELCOME: m=130, seed=7, rounds=3, S=16, K=64
            // 02 | "PF1B" | version 1 le | 130 le | 7 u64 le | 3 le | 16 le | 64 le
            (
                Frame::Welcome(Welcome { m: 130, seed: 7, rounds: 3, participating: 16, clients: 64 }),
                "0250463142010082000000070000000000000000 0300000010000000 40000000",
            ),
            // UPLINK round 2, client 7, signs m=64 all +1 (codec golden)
            (
                Frame::Uplink {
                    round: 2,
                    client: 7,
                    payload: Payload::Signs(SignVec::from_signs(&[1.0f32; 64])),
                },
                "04020000000700000002400000 00ffffffffffffffff",
            ),
            (Frame::Ack { round: 2, client: 7 }, "060200000007000000"),
        ];
        for (f, want) in &cases {
            let want: String = want.split_whitespace().collect();
            assert_eq!(hex(&encode_body(f)), want, "golden encode: {f:?}");
            assert_eq!(&decode_body(&unhex(&want)).unwrap(), f, "golden decode");
        }
        assert_eq!(hex(&encode_body(&Frame::Bye)), "07");
        assert_eq!(decode_body(&[KIND_BYE]).unwrap(), Frame::Bye);
    }

    #[test]
    fn stream_round_trip_reports_wire_bytes() {
        let f = Frame::Downlink {
            round: 9,
            client: 3,
            payload: Payload::Signs(SignVec::from_fn(65, |i| i % 2 == 0)),
        };
        let mut buf = Vec::new();
        let wrote = write_frame(&mut buf, &f).unwrap();
        assert_eq!(wrote, buf.len());
        let (got, read) = read_frame(&mut Cursor::new(&buf), DEFAULT_MAX_FRAME).unwrap();
        assert_eq!(got, f);
        assert_eq!(read, wrote);
    }

    #[test]
    fn oversized_prefix_rejected_before_allocation() {
        // 4 GB length prefix against a 1 KB cap: must fail on the prefix
        // alone, without trying to read (or allocate) the body
        let mut buf = u32::MAX.to_le_bytes().to_vec();
        buf.extend_from_slice(&[0u8; 8]);
        let err = read_frame(&mut Cursor::new(&buf), 1024).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
        assert!(read_frame(&mut Cursor::new(&0u32.to_le_bytes()[..]), 1024).is_err());
    }

    #[test]
    fn handshake_magic_and_version_enforced() {
        let hello = Frame::Hello(Hello {
            role: PeerRole::Edge,
            lo: 0,
            hi: 0,
            m: 0,
            want_ack: false,
        });
        let good = encode_body(&hello);
        assert_eq!(decode_body(&good).unwrap(), hello);
        let mut bad_magic = good.clone();
        bad_magic[1] = b'X';
        assert!(decode_body(&bad_magic).unwrap_err().to_string().contains("magic"));
        let mut bad_version = good.clone();
        bad_version[5] = 99;
        assert!(decode_body(&bad_version).unwrap_err().to_string().contains("version"));
        let mut bad_role = good.clone();
        bad_role[7] = 9;
        assert!(decode_body(&bad_role).is_err());
        let mut bad_flags = good;
        bad_flags[20] = 0x80;
        assert!(decode_body(&bad_flags).unwrap_err().to_string().contains("flag"));
    }

    #[test]
    fn borrowed_body_decode_matches_owned_for_every_kind() {
        use crate::comm::codec::TallyFrame;
        let frames = [
            Frame::Hello(Hello { role: PeerRole::Fleet, lo: 0, hi: 4, m: 64, want_ack: true }),
            Frame::Welcome(Welcome { m: 64, seed: 1, rounds: 2, participating: 3, clients: 4 }),
            Frame::Downlink {
                round: 1,
                client: 2,
                payload: Payload::Signs(SignVec::from_fn(65, |i| i % 2 == 0)),
            },
            Frame::Uplink {
                round: 3,
                client: 4,
                payload: Payload::ScaledSigns {
                    signs: SignVec::from_fn(63, |i| i % 3 == 0),
                    scale: 0.25,
                },
            },
            Frame::Tally {
                round: 5,
                edge: 6,
                payload: Payload::TallyFrame(TallyFrame {
                    absorbed: 2,
                    loss_sum: 0.5,
                    scalar: -3,
                    quanta: vec![7, -9],
                    groups: Vec::new(),
                }),
            },
            Frame::Ack { round: 7, client: 8 },
            Frame::Bye,
        ];
        for f in &frames {
            let body = encode_body(f);
            let view = decode_body_borrowed(&body).unwrap();
            assert_eq!(&view.to_frame(), f, "borrowed decode mismatch: {f:?}");
            assert_eq!(&decode_body(&body).unwrap(), f);
        }
        // and both decoders reject the same malformed bodies
        for bad in [&[][..], &[99][..], &[KIND_UPLINK, 0, 0][..]] {
            assert!(decode_body(bad).is_err());
            assert!(decode_body_borrowed(bad).is_err());
        }
    }

    #[test]
    fn tally_kind_requires_tally_payload() {
        // a TALLY envelope around a signs payload is a protocol violation
        let mut body = vec![KIND_TALLY];
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&encode(&Payload::Signs(SignVec::from_signs(&[1.0f32; 64]))));
        assert!(decode_body(&body).unwrap_err().to_string().contains("TallyFrame"));
        assert!(decode_body_borrowed(&body).unwrap_err().to_string().contains("TallyFrame"));
    }
}
