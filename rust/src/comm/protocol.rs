//! Typed protocol messages of the phased round API (DESIGN.md §3).
//!
//! Algorithm 1 is an explicit message-passing protocol: the server
//! broadcasts a [`Downlink`] to every participant, each client answers
//! with an [`Uplink`]. Wrapping [`Payload`] in direction-typed envelopes
//! keeps the sketch/transport boundary explicit (the FedSKETCH lesson):
//! a future socket or sharded-server transport replaces how these
//! messages move without touching any algorithm.

use crate::comm::codec::Payload;

/// Server → client message for one round. The coordinator transports it
/// through the recipient's channel, so each participant receives its own
/// (independently noise-corrupted, per-recipient-metered) copy.
#[derive(Clone, Debug, PartialEq)]
pub struct Downlink {
    /// round t this broadcast belongs to
    pub round: usize,
    /// the broadcast content
    pub payload: Payload,
}

impl Downlink {
    /// Wrap a payload as round `round`'s server broadcast.
    pub fn new(round: usize, payload: Payload) -> Downlink {
        Downlink { round, payload }
    }
}

/// Client → server message for one round. Produced by the client phase;
/// the coordinator replaces `payload` with the channel-delivered copy
/// before the server aggregation phase sees it.
#[derive(Clone, Debug, PartialEq)]
pub struct Uplink {
    /// round t this upload belongs to
    pub round: usize,
    /// the upload content
    pub payload: Payload,
}

impl Uplink {
    /// Wrap a payload as a client's round-`round` upload.
    pub fn new(round: usize, payload: Payload) -> Uplink {
        Uplink { round, payload }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::bitpack::SignVec;

    #[test]
    fn messages_carry_round_and_payload() {
        let d = Downlink::new(3, Payload::Signs(SignVec::from_signs(&[1.0, -1.0])));
        assert_eq!(d.round, 3);
        assert_eq!(d.payload.len(), 2);
        let u = Uplink::new(3, Payload::Dense(vec![0.5]));
        assert_eq!(u.round, 3);
        assert_eq!(u.payload, Payload::Dense(vec![0.5]));
    }
}
