//! Simulated bidirectional communication substrate: wire codecs, exact
//! byte ledger, and an in-process network with optional bit-flip noise.

pub mod codec;
pub mod ledger;
pub mod network;

pub use codec::{decode, encode, frame_bytes, Payload};
pub use ledger::{Direction, Ledger, RoundBytes};
pub use network::SimNetwork;
