//! Bidirectional communication substrate: wire codecs, typed protocol
//! messages, exact per-client byte shards merged into one ledger, an
//! in-process network with independent per-link bit-flip noise
//! (DESIGN.md §5) plus per-link latency/dropout lifecycle streams for
//! the event-driven round engine (DESIGN.md §9), and the [`transport`]
//! abstraction with a real socket transport (length-prefixed frames over
//! TCP or Unix-domain sockets — DESIGN.md §12).

pub mod codec;
pub mod ledger;
pub mod network;
pub mod protocol;
pub mod transport;

pub use codec::{decode, encode, frame_bytes, Payload, TallyFrame};
pub use ledger::{Direction, Ledger, RoundBytes};
pub use network::{Channel, LatencyModel, SimNetwork};
pub use protocol::{Downlink, Uplink};
pub use transport::{StreamTransport, Transport, Tuning};
