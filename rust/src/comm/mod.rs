//! Simulated bidirectional communication substrate: wire codecs, typed
//! protocol messages, exact per-client byte shards merged into one
//! ledger, and an in-process network with independent per-link bit-flip
//! noise (DESIGN.md §5) plus per-link latency/dropout lifecycle streams
//! for the event-driven round engine (DESIGN.md §9).

pub mod codec;
pub mod ledger;
pub mod network;
pub mod protocol;

pub use codec::{decode, encode, frame_bytes, Payload, TallyFrame};
pub use ledger::{Direction, Ledger, RoundBytes};
pub use network::{Channel, LatencyModel, SimNetwork};
pub use protocol::{Downlink, Uplink};
