//! Communication ledger: exact per-round byte accounting.
//!
//! Table 2's "Cost (MB)" column comes from here. Convention (verified in
//! DESIGN.md §5 against the paper's own reduction percentages): uplink is
//! counted per participating client, downlink is counted per participating
//! client too (the broadcast is delivered S times).

/// Direction of a message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Uplink,
    Downlink,
}

/// Byte counters for one communication round.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundBytes {
    pub uplink: u64,
    pub downlink: u64,
    pub uplink_msgs: u32,
    pub downlink_msgs: u32,
}

impl RoundBytes {
    pub fn total(&self) -> u64 {
        self.uplink + self.downlink
    }

    pub fn total_mb(&self) -> f64 {
        self.total() as f64 / (1024.0 * 1024.0)
    }

    /// Fold another counter into this one. Integer sums commute and are
    /// exact, so merging per-client shards in any order yields totals
    /// byte-identical to serial metering (DESIGN.md §5).
    pub fn absorb(&mut self, other: RoundBytes) {
        self.uplink += other.uplink;
        self.downlink += other.downlink;
        self.uplink_msgs += other.uplink_msgs;
        self.downlink_msgs += other.downlink_msgs;
    }
}

/// Accumulating ledger across rounds.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    rounds: Vec<RoundBytes>,
    current: RoundBytes,
}

impl Ledger {
    pub fn new() -> Self {
        Ledger::default()
    }

    /// Record one message of `bytes` in `dir` within the current round.
    pub fn record(&mut self, dir: Direction, bytes: usize) {
        match dir {
            Direction::Uplink => {
                self.current.uplink += bytes as u64;
                self.current.uplink_msgs += 1;
            }
            Direction::Downlink => {
                self.current.downlink += bytes as u64;
                self.current.downlink_msgs += 1;
            }
        }
    }

    /// Fold a per-client channel shard into the current round.
    pub fn merge_shard(&mut self, shard: RoundBytes) {
        self.current.absorb(shard);
    }

    /// Close the current round and start a new one; returns the closed one.
    pub fn end_round(&mut self) -> RoundBytes {
        let done = self.current;
        self.rounds.push(done);
        self.current = RoundBytes::default();
        done
    }

    pub fn rounds(&self) -> &[RoundBytes] {
        &self.rounds
    }

    pub fn total_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.total()).sum::<u64>() + self.current.total()
    }

    /// Mean per-round cost in MB over completed rounds (Table 2 metric).
    pub fn mean_round_mb(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.total_mb()).sum::<f64>() / self.rounds.len() as f64
    }

    /// Percent reduction vs a reference per-round cost (the ↓xx.x% column).
    pub fn reduction_vs(&self, reference_mb: f64) -> f64 {
        if reference_mb <= 0.0 {
            return 0.0;
        }
        100.0 * (1.0 - self.mean_round_mb() / reference_mb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_rounds() {
        let mut l = Ledger::new();
        l.record(Direction::Uplink, 100);
        l.record(Direction::Uplink, 50);
        l.record(Direction::Downlink, 25);
        let r = l.end_round();
        assert_eq!(r.uplink, 150);
        assert_eq!(r.downlink, 25);
        assert_eq!(r.uplink_msgs, 2);
        assert_eq!(r.downlink_msgs, 1);
        assert_eq!(r.total(), 175);
        assert_eq!(l.rounds().len(), 1);
    }

    #[test]
    fn mean_round_mb() {
        let mut l = Ledger::new();
        l.record(Direction::Uplink, 1024 * 1024);
        l.end_round();
        l.record(Direction::Uplink, 3 * 1024 * 1024);
        l.end_round();
        assert!((l.mean_round_mb() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn reduction_percentage() {
        let mut l = Ledger::new();
        l.record(Direction::Uplink, 1024 * 1024); // 1 MB/round
        l.end_round();
        // vs 32 MB reference: 96.875% reduction (the OBDA ratio)
        assert!((l.reduction_vs(32.0) - 96.875).abs() < 1e-6);
    }

    #[test]
    fn totals_include_open_round() {
        let mut l = Ledger::new();
        l.record(Direction::Downlink, 10);
        assert_eq!(l.total_bytes(), 10);
        l.end_round();
        l.record(Direction::Uplink, 5);
        assert_eq!(l.total_bytes(), 15);
    }

    #[test]
    fn empty_ledger_is_zero() {
        let l = Ledger::new();
        assert_eq!(l.mean_round_mb(), 0.0);
        assert_eq!(l.total_bytes(), 0);
    }

    #[test]
    fn shard_merge_equals_serial_recording() {
        // two clients metered on separate shards vs one serial ledger
        let mut shard_a = RoundBytes::default();
        shard_a.uplink += 100;
        shard_a.uplink_msgs += 1;
        shard_a.downlink += 40;
        shard_a.downlink_msgs += 1;
        let mut shard_b = RoundBytes::default();
        shard_b.uplink += 7;
        shard_b.uplink_msgs += 1;

        let mut sharded = Ledger::new();
        sharded.merge_shard(shard_b); // merge order must not matter
        sharded.merge_shard(shard_a);

        let mut serial = Ledger::new();
        serial.record(Direction::Uplink, 100);
        serial.record(Direction::Downlink, 40);
        serial.record(Direction::Uplink, 7);

        assert_eq!(sharded.end_round(), serial.end_round());
    }
}
