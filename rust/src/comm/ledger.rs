//! Communication ledger: exact per-round byte accounting.
//!
//! Table 2's "Cost (MB)" column comes from here. Convention (verified in
//! DESIGN.md §5 against the paper's own reduction percentages): uplink is
//! counted per participating client, downlink is counted per participating
//! client too (the broadcast is delivered S times).
//!
//! Under the hierarchical topology (DESIGN.md §11) the ledger additionally
//! meters the edge tier — `edge_up` (edge → root merge frames) and
//! `edge_down` (root → edge broadcast fan-out) — kept in separate columns
//! so the client-tier numbers stay directly comparable to the flat server
//! (they are byte-identical by construction). Both fields stay zero under
//! the default `flat` topology.

/// Direction of a message, relative to the aggregation root.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// toward the server/root (client → edge, or edge → root)
    Uplink,
    /// away from the server/root (root → edge, or edge/server → client)
    Downlink,
}

/// Byte counters for one communication round.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundBytes {
    /// client-tier uplink bytes (client → server, or client → edge)
    pub uplink: u64,
    /// client-tier downlink bytes (server/edge → client)
    pub downlink: u64,
    /// client-tier uplink message count
    pub uplink_msgs: u32,
    /// client-tier downlink message count
    pub downlink_msgs: u32,
    /// edge-tier uplink bytes: edge → root merge frames (DESIGN.md §11)
    pub edge_up: u64,
    /// edge-tier downlink bytes: root → edge broadcast fan-out
    pub edge_down: u64,
    /// edge → root merge-frame count (the CSV's `edge_merges` column)
    pub edge_up_msgs: u32,
    /// root → edge fan-out message count
    pub edge_down_msgs: u32,
}

impl RoundBytes {
    /// All bytes this round, both tiers.
    pub fn total(&self) -> u64 {
        self.uplink + self.downlink + self.edge_up + self.edge_down
    }

    /// [`RoundBytes::total`] in MiB (the Table 2 unit).
    pub fn total_mb(&self) -> f64 {
        self.total() as f64 / (1024.0 * 1024.0)
    }

    /// Fold another counter into this one. Integer sums commute and are
    /// exact, so merging per-client shards in any order yields totals
    /// byte-identical to serial metering (DESIGN.md §5).
    pub fn absorb(&mut self, other: RoundBytes) {
        self.uplink += other.uplink;
        self.downlink += other.downlink;
        self.uplink_msgs += other.uplink_msgs;
        self.downlink_msgs += other.downlink_msgs;
        self.edge_up += other.edge_up;
        self.edge_down += other.edge_down;
        self.edge_up_msgs += other.edge_up_msgs;
        self.edge_down_msgs += other.edge_down_msgs;
    }
}

/// Accumulating ledger across rounds.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    rounds: Vec<RoundBytes>,
    current: RoundBytes,
}

impl Ledger {
    /// Empty ledger with one open round.
    pub fn new() -> Self {
        Ledger::default()
    }

    /// Record one client-tier message of `bytes` in `dir` within the
    /// current round.
    pub fn record(&mut self, dir: Direction, bytes: usize) {
        match dir {
            Direction::Uplink => {
                self.current.uplink += bytes as u64;
                self.current.uplink_msgs += 1;
            }
            Direction::Downlink => {
                self.current.downlink += bytes as u64;
                self.current.downlink_msgs += 1;
            }
        }
    }

    /// Record one edge-tier message (edge ↔ root — DESIGN.md §11) of
    /// `bytes` in `dir` within the current round.
    pub fn record_edge(&mut self, dir: Direction, bytes: usize) {
        match dir {
            Direction::Uplink => {
                self.current.edge_up += bytes as u64;
                self.current.edge_up_msgs += 1;
            }
            Direction::Downlink => {
                self.current.edge_down += bytes as u64;
                self.current.edge_down_msgs += 1;
            }
        }
    }

    /// Fold a per-client channel shard into the current round.
    pub fn merge_shard(&mut self, shard: RoundBytes) {
        self.current.absorb(shard);
    }

    /// Close the current round and start a new one; returns the closed one.
    pub fn end_round(&mut self) -> RoundBytes {
        let done = self.current;
        self.rounds.push(done);
        self.current = RoundBytes::default();
        done
    }

    /// Closed rounds, oldest first.
    pub fn rounds(&self) -> &[RoundBytes] {
        &self.rounds
    }

    /// Total bytes across closed rounds plus the open one, both tiers.
    pub fn total_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.total()).sum::<u64>() + self.current.total()
    }

    /// Mean per-round cost in MB over completed rounds (Table 2 metric).
    pub fn mean_round_mb(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.total_mb()).sum::<f64>() / self.rounds.len() as f64
    }

    /// Percent reduction vs a reference per-round cost (the ↓xx.x% column).
    pub fn reduction_vs(&self, reference_mb: f64) -> f64 {
        if reference_mb <= 0.0 {
            return 0.0;
        }
        100.0 * (1.0 - self.mean_round_mb() / reference_mb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_rounds() {
        let mut l = Ledger::new();
        l.record(Direction::Uplink, 100);
        l.record(Direction::Uplink, 50);
        l.record(Direction::Downlink, 25);
        let r = l.end_round();
        assert_eq!(r.uplink, 150);
        assert_eq!(r.downlink, 25);
        assert_eq!(r.uplink_msgs, 2);
        assert_eq!(r.downlink_msgs, 1);
        assert_eq!(r.total(), 175);
        assert_eq!(l.rounds().len(), 1);
    }

    #[test]
    fn mean_round_mb() {
        let mut l = Ledger::new();
        l.record(Direction::Uplink, 1024 * 1024);
        l.end_round();
        l.record(Direction::Uplink, 3 * 1024 * 1024);
        l.end_round();
        assert!((l.mean_round_mb() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn reduction_percentage() {
        let mut l = Ledger::new();
        l.record(Direction::Uplink, 1024 * 1024); // 1 MB/round
        l.end_round();
        // vs 32 MB reference: 96.875% reduction (the OBDA ratio)
        assert!((l.reduction_vs(32.0) - 96.875).abs() < 1e-6);
    }

    #[test]
    fn totals_include_open_round() {
        let mut l = Ledger::new();
        l.record(Direction::Downlink, 10);
        assert_eq!(l.total_bytes(), 10);
        l.end_round();
        l.record(Direction::Uplink, 5);
        assert_eq!(l.total_bytes(), 15);
    }

    #[test]
    fn edge_tier_meters_separately_and_sums_into_totals() {
        let mut l = Ledger::new();
        l.record(Direction::Uplink, 100);
        l.record_edge(Direction::Uplink, 40); // edge → root merge frame
        l.record_edge(Direction::Downlink, 7); // root → edge fan-out
        let r = l.end_round();
        assert_eq!((r.uplink, r.downlink), (100, 0));
        assert_eq!((r.edge_up, r.edge_down), (40, 7));
        assert_eq!((r.edge_up_msgs, r.edge_down_msgs), (1, 1));
        assert_eq!(r.total(), 147, "edge tier must count toward the round total");
        // flat rounds leave the edge columns at zero
        let flat = Ledger::new().end_round();
        assert_eq!((flat.edge_up, flat.edge_down), (0, 0));
        // absorb folds both tiers
        let mut a = r;
        a.absorb(r);
        assert_eq!((a.edge_up, a.edge_up_msgs), (80, 2));
    }

    #[test]
    fn empty_ledger_is_zero() {
        let l = Ledger::new();
        assert_eq!(l.mean_round_mb(), 0.0);
        assert_eq!(l.total_bytes(), 0);
    }

    #[test]
    fn shard_merge_equals_serial_recording() {
        // two clients metered on separate shards vs one serial ledger
        let mut shard_a = RoundBytes::default();
        shard_a.uplink += 100;
        shard_a.uplink_msgs += 1;
        shard_a.downlink += 40;
        shard_a.downlink_msgs += 1;
        let mut shard_b = RoundBytes::default();
        shard_b.uplink += 7;
        shard_b.uplink_msgs += 1;

        let mut sharded = Ledger::new();
        sharded.merge_shard(shard_b); // merge order must not matter
        sharded.merge_shard(shard_a);

        let mut serial = Ledger::new();
        serial.record(Direction::Uplink, 100);
        serial.record(Direction::Downlink, 40);
        serial.record(Direction::Uplink, 7);

        assert_eq!(sharded.end_round(), serial.end_round());
    }
}
