//! Simulated server<->client transport.
//!
//! All traffic is encoded to real wire frames (codec.rs) and metered by
//! the ledger before being "delivered" — so byte counts are measurements,
//! not formulas, and any future swap to a socket transport keeps the same
//! call sites. Optionally injects bit-flip noise into one-bit frames to
//! model the unreliable links of the paper's motivating IoT/V2X settings
//! (used by the `iot_bandwidth_budget` example's noisy-channel mode).

use anyhow::Result;

use crate::comm::codec::{decode, encode, Payload};
use crate::comm::ledger::{Direction, Ledger};
use crate::util::rng::Rng;

/// In-process simulated network with exact byte metering.
pub struct SimNetwork {
    pub ledger: Ledger,
    /// probability that each bit of a one-bit payload flips in transit
    pub bit_flip_prob: f64,
    rng: Rng,
}

impl SimNetwork {
    pub fn new(seed: u64) -> Self {
        SimNetwork {
            ledger: Ledger::new(),
            bit_flip_prob: 0.0,
            rng: Rng::new(seed ^ 0x4E45_5457_u64), // "NETW"
        }
    }

    pub fn with_bit_flips(mut self, p: f64) -> Self {
        self.bit_flip_prob = p;
        self
    }

    /// Client k -> server.
    pub fn send_uplink(&mut self, payload: &Payload) -> Result<Payload> {
        self.transmit(Direction::Uplink, payload)
    }

    /// Server -> one client (a broadcast is one call per recipient; the
    /// paper's accounting counts delivered copies — DESIGN.md §5).
    pub fn send_downlink(&mut self, payload: &Payload) -> Result<Payload> {
        self.transmit(Direction::Downlink, payload)
    }

    /// Broadcast to `recipients` clients; returns the delivered payloads.
    pub fn broadcast_downlink(
        &mut self,
        payload: &Payload,
        recipients: usize,
    ) -> Result<Vec<Payload>> {
        (0..recipients).map(|_| self.send_downlink(payload)).collect()
    }

    pub fn end_round(&mut self) -> crate::comm::ledger::RoundBytes {
        self.ledger.end_round()
    }

    fn transmit(&mut self, dir: Direction, payload: &Payload) -> Result<Payload> {
        let frame = encode(payload);
        self.ledger.record(dir, frame.len());
        let mut delivered = decode(&frame)?;
        if self.bit_flip_prob > 0.0 {
            self.corrupt(&mut delivered);
        }
        Ok(delivered)
    }

    fn corrupt(&mut self, payload: &mut Payload) {
        let flip = |rng: &mut Rng, signs: &mut [f32], p: f64| {
            for s in signs.iter_mut() {
                if rng.f64() < p {
                    *s = -*s;
                }
            }
        };
        match payload {
            Payload::Signs(v) => flip(&mut self.rng, v, self.bit_flip_prob),
            Payload::ScaledSigns { signs, .. } => flip(&mut self.rng, signs, self.bit_flip_prob),
            Payload::Dense(_) => {} // full-precision links modeled clean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metering_matches_frames() {
        let mut net = SimNetwork::new(0);
        let up = Payload::Signs(vec![1.0; 128]);
        let down = Payload::Dense(vec![0.5; 10]);
        net.send_uplink(&up).unwrap();
        net.send_downlink(&down).unwrap();
        let r = net.end_round();
        assert_eq!(r.uplink, 5 + 16); // 128 bits -> 16 bytes + header
        assert_eq!(r.downlink, 5 + 40);
    }

    #[test]
    fn clean_channel_is_lossless() {
        let mut net = SimNetwork::new(1);
        let p = Payload::ScaledSigns { signs: vec![1.0, -1.0, 1.0], scale: 2.0 };
        let got = net.send_uplink(&p).unwrap();
        assert_eq!(got, p);
    }

    #[test]
    fn broadcast_counts_per_recipient() {
        let mut net = SimNetwork::new(2);
        let v = Payload::Signs(vec![1.0; 64]);
        net.broadcast_downlink(&v, 20).unwrap();
        let r = net.end_round();
        assert_eq!(r.downlink_msgs, 20);
        assert_eq!(r.downlink, 20 * (5 + 8));
    }

    #[test]
    fn noisy_channel_flips_about_p_bits() {
        let mut net = SimNetwork::new(3).with_bit_flips(0.25);
        let n = 10_000;
        let sent = Payload::Signs(vec![1.0; n]);
        let got = match net.send_uplink(&sent).unwrap() {
            Payload::Signs(v) => v,
            _ => unreachable!(),
        };
        let flipped = got.iter().filter(|&&s| s < 0.0).count();
        let frac = flipped as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.03, "flip rate {frac}");
    }

    #[test]
    fn dense_payloads_not_corrupted() {
        let mut net = SimNetwork::new(4).with_bit_flips(0.5);
        let p = Payload::Dense(vec![1.0, 2.0, 3.0]);
        assert_eq!(net.send_downlink(&p).unwrap(), p);
    }
}
