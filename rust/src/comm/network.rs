//! Simulated server<->client transport: one metered channel per client.
//!
//! All traffic is encoded to real wire frames (codec.rs) and metered by
//! the recipient's channel shard before being "delivered" — so byte
//! counts are measurements, not formulas, and any future swap to a
//! socket transport keeps the same call sites. Each client link carries
//! its own noise RNG: under bit-flip noise (the unreliable IoT/V2X links
//! of the paper's motivating setting) every recipient of a broadcast
//! receives an *independently* corrupted copy, and the sender's own
//! state is never touched. Corruption operates directly on the packed
//! [`SignVec`](crate::sketch::bitpack::SignVec) words via masked XOR
//! (one RNG draw per live bit, in bit
//! order, so the noise stream is identical to a ±1-lane walk); padding
//! bits beyond m are never flipped. Per-round byte accounting merges
//! the per-client shards into the [`Ledger`]; integer sums commute, so
//! the merged totals are byte-identical to serial metering
//! (DESIGN.md §5).

use anyhow::{bail, ensure, Result};

use crate::comm::codec::{encode, Payload};
use crate::comm::ledger::{Direction, Ledger, RoundBytes};
use crate::util::rng::{splitmix64, Rng};

/// A client link's uplink service-time distribution (milliseconds) — the
/// heterogeneous edge fleets of the scenario engine (DESIGN.md §9).
/// Draws come from the channel's own lifecycle stream (keyed by
/// `(seed, k)` alone), so a client's latency trace is independent of
/// every other link and of delivery order.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum LatencyModel {
    /// every uplink arrives instantly (the default: rounds are barriers,
    /// no lifecycle draws are consumed)
    #[default]
    Zero,
    /// constant service time (no draws consumed)
    Fixed { ms: f64 },
    /// uniform in [lo, hi) — bounded jitter
    Uniform { lo_ms: f64, hi_ms: f64 },
    /// exp(ln median + σ·N(0,1)) — the heavy-tailed stragglers of real
    /// device fleets
    LogNormal { median_ms: f64, sigma: f64 },
}

impl LatencyModel {
    /// Parse a scenario-knob string:
    /// `zero | fixed:MS | uniform:LO:HI | lognormal:MEDIAN:SIGMA`.
    pub fn parse(s: &str) -> Result<LatencyModel> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |x: &str| -> Result<f64> {
            x.parse()
                .map_err(|e| anyhow::anyhow!("latency `{s}`: bad number `{x}`: {e}"))
        };
        let model = match parts.as_slice() {
            ["zero"] | ["none"] => LatencyModel::Zero,
            ["fixed", ms] => LatencyModel::Fixed { ms: num(ms)? },
            ["uniform", lo, hi] => LatencyModel::Uniform { lo_ms: num(lo)?, hi_ms: num(hi)? },
            ["lognormal", med, sig] => {
                LatencyModel::LogNormal { median_ms: num(med)?, sigma: num(sig)? }
            }
            _ => bail!(
                "unknown latency model `{s}` (zero|fixed:MS|uniform:LO:HI|lognormal:MEDIAN:SIGMA)"
            ),
        };
        model.validate()?;
        Ok(model)
    }

    /// Reject degenerate parameters (negative or non-finite times,
    /// inverted ranges): an `inf`/NaN service time would scramble the
    /// engine's deterministic arrival order instead of failing loudly.
    pub fn validate(&self) -> Result<()> {
        match *self {
            LatencyModel::Zero => {}
            LatencyModel::Fixed { ms } => ensure!(
                ms.is_finite() && ms >= 0.0,
                "fixed latency must be finite and >= 0"
            ),
            LatencyModel::Uniform { lo_ms, hi_ms } => ensure!(
                hi_ms.is_finite() && (0.0..=hi_ms).contains(&lo_ms),
                "uniform latency needs finite 0 <= lo <= hi (got {lo_ms}..{hi_ms})"
            ),
            LatencyModel::LogNormal { median_ms, sigma } => ensure!(
                median_ms.is_finite() && median_ms > 0.0 && sigma.is_finite() && sigma >= 0.0,
                "lognormal latency needs finite median > 0 and sigma >= 0"
            ),
        }
        Ok(())
    }

    /// One-line form for run summaries (inverse of `parse`).
    pub fn summary(&self) -> String {
        match *self {
            LatencyModel::Zero => "zero".to_string(),
            LatencyModel::Fixed { ms } => format!("fixed:{ms}"),
            LatencyModel::Uniform { lo_ms, hi_ms } => format!("uniform:{lo_ms}:{hi_ms}"),
            LatencyModel::LogNormal { median_ms, sigma } => {
                format!("lognormal:{median_ms}:{sigma}")
            }
        }
    }

    /// One service-time draw from `lifecycle` (milliseconds). Draw-free
    /// models consume nothing from the stream. Every transport uses this
    /// single implementation, so scenario traces are
    /// transport-independent (DESIGN.md §12).
    pub fn draw(&self, lifecycle: &mut Rng) -> f64 {
        match *self {
            LatencyModel::Zero => 0.0,
            LatencyModel::Fixed { ms } => ms,
            LatencyModel::Uniform { lo_ms, hi_ms } => lo_ms + (hi_ms - lo_ms) * lifecycle.f64(),
            LatencyModel::LogNormal { median_ms, sigma } => {
                (median_ms.ln() + sigma * lifecycle.normal() as f64).exp()
            }
        }
    }
}

/// The canonical lifecycle stream of client `k` under `seed` — keyed by
/// `(seed, k)` alone, shared by every [`Transport`](crate::comm::transport::Transport)
/// impl so dropout/latency traces are identical across transports.
pub(crate) fn lifecycle_rng(seed: u64, client: usize) -> Rng {
    let mut l = seed
        ^ 0x4C49_4645_u64 // "LIFE"
        ^ (client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    Rng::new(splitmix64(&mut l))
}

/// One dropout draw from a lifecycle stream; `p == 0` consumes nothing.
pub(crate) fn dropout_draw(lifecycle: &mut Rng, p: f64) -> bool {
    p > 0.0 && lifecycle.f64() < p
}

/// One client's link to the server: its own byte shard, noise stream,
/// and lifecycle (latency/dropout) stream.
#[derive(Clone, Debug)]
pub struct Channel {
    shard: RoundBytes,
    rng: Rng,
    /// latency/dropout draws — a stream SEPARATE from the noise RNG, so
    /// enabling scenario knobs cannot shift corruption patterns (the
    /// noise golden tests stay valid verbatim)
    lifecycle: Rng,
}

impl Channel {
    fn new(seed: u64, client: usize) -> Channel {
        // independent, client-indexed noise stream: per-link corruption
        // must not depend on delivery order or on other links
        let mut s = seed
            ^ 0x4E45_5457_u64 // "NETW"
            ^ (client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let rng = Rng::new(splitmix64(&mut s));
        let lifecycle = lifecycle_rng(seed, client);
        Channel { shard: RoundBytes::default(), rng, lifecycle }
    }

    /// Draw this round's uplink service time from the link's own
    /// lifecycle stream. Deterministic in `(seed, k, draw index)`;
    /// draw-free models consume nothing.
    pub fn draw_latency(&mut self, model: &LatencyModel) -> f64 {
        model.draw(&mut self.lifecycle)
    }

    /// Does this client drop out of the current round (unreachable after
    /// the broadcast: no local work, no uplink)? `p == 0` consumes no
    /// draw, so default configs leave the stream untouched.
    pub fn draw_dropout(&mut self, p: f64) -> bool {
        dropout_draw(&mut self.lifecycle, p)
    }

    /// Bytes metered on this link in the current (open) round.
    pub fn shard(&self) -> RoundBytes {
        self.shard
    }

    fn take_shard(&mut self) -> RoundBytes {
        std::mem::take(&mut self.shard)
    }

    fn transmit(&mut self, dir: Direction, payload: &Payload, flip_prob: f64) -> Result<Payload> {
        let frame = encode(payload);
        match dir {
            Direction::Uplink => {
                self.shard.uplink += frame.len() as u64;
                self.shard.uplink_msgs += 1;
            }
            Direction::Downlink => {
                self.shard.downlink += frame.len() as u64;
                self.shard.downlink_msgs += 1;
            }
        }
        // validate + deliver through the zero-copy decoder, then
        // materialize: decode_borrowed(..).to_owned() is bit-identical
        // to the owned decode, so every simulated delivery exercises the
        // borrowed wire path the socket transport uses (DESIGN.md §14)
        let mut delivered = Payload::decode_borrowed(&frame)?.to_owned();
        if flip_prob > 0.0 {
            self.corrupt(&mut delivered, flip_prob);
        }
        Ok(delivered)
    }

    fn corrupt(&mut self, payload: &mut Payload, p: f64) {
        // masked XOR on the packed words: each live bit draws once from
        // this link's stream (ascending bit order); tail bits stay zero
        let rng = &mut self.rng;
        match payload {
            Payload::Signs(z) => z.flip_bits_where(|_| rng.f64() < p),
            Payload::ScaledSigns { signs, .. } => signs.flip_bits_where(|_| rng.f64() < p),
            // full-precision client links and the edge↔root datacenter
            // tier are modeled clean
            Payload::Dense(_) | Payload::TallyFrame(_) => {}
        }
    }
}

/// In-process simulated network: per-client channels with exact byte
/// metering, merged into one ledger at round end, plus a clean metered
/// edge↔root tier for the hierarchical topology (DESIGN.md §11).
pub struct SimNetwork {
    /// the run's byte ledger (rounds closed by [`SimNetwork::end_round`])
    pub ledger: Ledger,
    /// probability that each bit of a one-bit payload flips in transit
    pub bit_flip_prob: f64,
    seed: u64,
    channels: Vec<Channel>,
}

impl SimNetwork {
    /// Fresh network; per-client channel streams derive from `seed`.
    pub fn new(seed: u64) -> Self {
        SimNetwork {
            ledger: Ledger::new(),
            bit_flip_prob: 0.0,
            seed,
            channels: Vec::new(),
        }
    }

    /// Builder: enable bit-flip noise on one-bit client links.
    pub fn with_bit_flips(mut self, p: f64) -> Self {
        self.bit_flip_prob = p;
        self
    }

    /// The channel of client `k` (links materialize deterministically on
    /// first use; the stream depends only on the seed and `k`).
    pub fn channel(&mut self, k: usize) -> &mut Channel {
        while self.channels.len() <= k {
            let next = self.channels.len();
            self.channels.push(Channel::new(self.seed, next));
        }
        &mut self.channels[k]
    }

    /// Server -> client `k`. A broadcast is one call per recipient (the
    /// paper's accounting counts delivered copies — DESIGN.md §5), each
    /// corrupted independently by that recipient's link.
    pub fn downlink_to(&mut self, k: usize, payload: &Payload) -> Result<Payload> {
        let p = self.bit_flip_prob;
        self.channel(k).transmit(Direction::Downlink, payload, p)
    }

    /// Client `k` -> server.
    pub fn uplink_from(&mut self, k: usize, payload: &Payload) -> Result<Payload> {
        let p = self.bit_flip_prob;
        self.channel(k).transmit(Direction::Uplink, payload, p)
    }

    /// Edge aggregator `_edge` -> root: one merge frame per round
    /// (DESIGN.md §11). The edge↔root tier models datacenter links —
    /// metered exactly (real encoded frames, like every other tier) but
    /// clean and instant; metering lands in the ledger's `edge_up`
    /// columns, never in the client-tier counters.
    pub fn edge_uplink(&mut self, _edge: usize, payload: &Payload) -> Result<Payload> {
        let frame = encode(payload);
        self.ledger.record_edge(Direction::Uplink, frame.len());
        Ok(Payload::decode_borrowed(&frame)?.to_owned())
    }

    /// Root -> edge aggregator `_edge`: the broadcast fan-out hop of the
    /// hierarchical downlink (root → edge → client — DESIGN.md §11).
    pub fn edge_downlink(&mut self, _edge: usize, payload: &Payload) -> Result<Payload> {
        let frame = encode(payload);
        self.ledger.record_edge(Direction::Downlink, frame.len());
        Ok(Payload::decode_borrowed(&frame)?.to_owned())
    }

    /// Merge every channel's shard and close the round; returns the
    /// round's merged totals.
    pub fn end_round(&mut self) -> RoundBytes {
        for ch in &mut self.channels {
            let shard = ch.take_shard();
            self.ledger.merge_shard(shard);
        }
        self.ledger.end_round()
    }

    /// Total bytes across closed rounds plus all open shards.
    pub fn total_bytes(&self) -> u64 {
        self.ledger.total_bytes()
            + self.channels.iter().map(|c| c.shard.total()).sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::bitpack::SignVec;

    fn ones(n: usize) -> Payload {
        Payload::Signs(SignVec::from_signs(&vec![1.0f32; n]))
    }

    #[test]
    fn metering_matches_frames() {
        let mut net = SimNetwork::new(0);
        let up = ones(128);
        let down = Payload::Dense(vec![0.5; 10]);
        net.uplink_from(0, &up).unwrap();
        net.downlink_to(1, &down).unwrap();
        let r = net.end_round();
        assert_eq!(r.uplink, 5 + 16); // 128 bits -> 16 bytes + header
        assert_eq!(r.downlink, 5 + 40);
    }

    #[test]
    fn clean_channel_is_lossless() {
        let mut net = SimNetwork::new(1);
        let p = Payload::ScaledSigns {
            signs: SignVec::from_signs(&[1.0, -1.0, 1.0]),
            scale: 2.0,
        };
        let got = net.uplink_from(3, &p).unwrap();
        assert_eq!(got, p);
    }

    #[test]
    fn broadcast_counts_per_recipient() {
        let mut net = SimNetwork::new(2);
        let v = ones(64);
        for k in 0..20 {
            net.downlink_to(k, &v).unwrap();
        }
        let r = net.end_round();
        assert_eq!(r.downlink_msgs, 20);
        assert_eq!(r.downlink, 20 * (5 + 8));
    }

    #[test]
    fn shards_meter_per_client_and_merge_exactly() {
        let mut net = SimNetwork::new(7);
        let sig = ones(64); // 5 + 8 bytes
        net.uplink_from(0, &sig).unwrap();
        net.uplink_from(0, &sig).unwrap();
        net.uplink_from(1, &sig).unwrap();
        net.downlink_to(1, &sig).unwrap();
        assert_eq!(net.channel(0).shard().uplink_msgs, 2);
        assert_eq!(net.channel(0).shard().uplink, 2 * 13);
        assert_eq!(net.channel(1).shard().uplink_msgs, 1);
        assert_eq!(net.channel(1).shard().downlink_msgs, 1);
        assert_eq!(net.total_bytes(), 4 * 13);
        let r = net.end_round();
        assert_eq!(r.uplink, 3 * 13);
        assert_eq!(r.downlink, 13);
        assert_eq!(r.uplink_msgs, 3);
        assert_eq!(r.downlink_msgs, 1);
        // shards reset after the merge
        assert_eq!(net.channel(0).shard(), RoundBytes::default());
    }

    #[test]
    fn edge_tier_is_clean_metered_and_separate_from_client_tier() {
        use crate::comm::codec::{frame_bytes, TallyFrame};
        // even under heavy client-link noise the edge↔root tier delivers
        // frames verbatim and meters into its own columns
        let mut net = SimNetwork::new(9).with_bit_flips(0.5);
        let frame = Payload::TallyFrame(TallyFrame {
            absorbed: 3,
            loss_sum: 1.25,
            scalar: -7,
            quanta: vec![i128::MAX, i128::MIN, 0, 42],
            groups: Vec::new(),
        });
        let got = net.edge_uplink(0, &frame).unwrap();
        assert_eq!(got, frame, "edge links must be lossless");
        let down = ones(64);
        net.edge_downlink(1, &down).unwrap();
        net.uplink_from(0, &ones(64)).unwrap(); // client tier, for contrast
        let r = net.end_round();
        assert_eq!(r.edge_up, frame_bytes(&frame) as u64);
        assert_eq!(r.edge_down, 13);
        assert_eq!((r.edge_up_msgs, r.edge_down_msgs), (1, 1));
        assert_eq!(r.uplink_msgs, 1, "client tier must not see edge traffic");
        assert_eq!(r.uplink, 13);
        assert_eq!(r.downlink_msgs, 0);
    }

    #[test]
    fn noisy_channel_flips_about_p_bits() {
        let mut net = SimNetwork::new(3).with_bit_flips(0.25);
        let n = 10_000;
        let sent = ones(n);
        let got = match net.uplink_from(0, &sent).unwrap() {
            Payload::Signs(v) => v,
            _ => unreachable!(),
        };
        let flipped = got.iter_signs().filter(|&s| s < 0.0).count();
        let frac = flipped as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.03, "flip rate {frac}");
    }

    #[test]
    fn packed_corruption_never_touches_padding_bits() {
        // m=65: one live bit in the tail word, 63 padding bits. With
        // p=1.0 every live bit flips and every padding bit must stay 0,
        // or downstream word-level equality/popcounts would drift.
        let mut net = SimNetwork::new(5).with_bit_flips(1.0);
        let sent = ones(65);
        let got = match net.downlink_to(0, &sent).unwrap() {
            Payload::Signs(v) => v,
            _ => unreachable!(),
        };
        assert_eq!(got, SignVec::from_signs(&[-1.0f32; 65]));
        assert_eq!(got.words()[1], 0, "corruption leaked into tail padding");
        let Payload::Signs(sent_sv) = &sent else { unreachable!() };
        assert_eq!(sent_sv.hamming(&got), 65);
    }

    #[test]
    fn recipients_receive_independently_corrupted_copies() {
        // the IoT/V2X setting: per-link noise is independent, so two
        // recipients of the same broadcast see different corruption
        let mut net = SimNetwork::new(4).with_bit_flips(0.5);
        let sent = ones(256);
        let a = net.downlink_to(0, &sent).unwrap();
        let b = net.downlink_to(1, &sent).unwrap();
        assert_ne!(a, b, "two links produced identical corruption");
        assert_ne!(a, sent);
        assert_ne!(b, sent);
        // and a link's stream is deterministic in (seed, k) alone
        let mut net2 = SimNetwork::new(4).with_bit_flips(0.5);
        let b2 = net2.downlink_to(1, &sent).unwrap();
        assert_eq!(b, b2);
    }

    #[test]
    fn dense_payloads_not_corrupted() {
        let mut net = SimNetwork::new(4).with_bit_flips(0.5);
        let p = Payload::Dense(vec![1.0, 2.0, 3.0]);
        assert_eq!(net.downlink_to(0, &p).unwrap(), p);
    }

    #[test]
    fn latency_model_parses_and_validates() {
        assert_eq!(LatencyModel::parse("zero").unwrap(), LatencyModel::Zero);
        assert_eq!(
            LatencyModel::parse("fixed:5").unwrap(),
            LatencyModel::Fixed { ms: 5.0 }
        );
        assert_eq!(
            LatencyModel::parse("uniform:2:20").unwrap(),
            LatencyModel::Uniform { lo_ms: 2.0, hi_ms: 20.0 }
        );
        assert_eq!(
            LatencyModel::parse("lognormal:10:0.5").unwrap(),
            LatencyModel::LogNormal { median_ms: 10.0, sigma: 0.5 }
        );
        for bad in [
            "bogus",
            "fixed",
            "fixed:-1",
            "uniform:9:2",
            "lognormal:0:1",
            // non-finite times would poison the arrival sort/deadline math
            "fixed:inf",
            "uniform:0:inf",
            "lognormal:nan:1",
        ] {
            assert!(LatencyModel::parse(bad).is_err(), "{bad} should be rejected");
        }
        // summary round-trips
        for s in ["zero", "fixed:5", "uniform:2:20", "lognormal:10:0.5"] {
            assert_eq!(LatencyModel::parse(s).unwrap().summary(), s);
        }
    }

    #[test]
    fn lifecycle_draws_are_per_link_deterministic_and_independent() {
        let model = LatencyModel::Uniform { lo_ms: 1.0, hi_ms: 9.0 };
        let mut net = SimNetwork::new(11);
        let a: Vec<f64> = (0..8).map(|_| net.channel(0).draw_latency(&model)).collect();
        let b: Vec<f64> = (0..8).map(|_| net.channel(1).draw_latency(&model)).collect();
        assert_ne!(a, b, "two links produced identical latency traces");
        assert!(a.iter().all(|&t| (1.0..9.0).contains(&t)));
        // deterministic in (seed, k) alone — independent of other links'
        // draw order
        let mut net2 = SimNetwork::new(11);
        let b2: Vec<f64> = (0..8).map(|_| net2.channel(1).draw_latency(&model)).collect();
        assert_eq!(b, b2);
    }

    #[test]
    fn dropout_rate_is_calibrated_and_zero_consumes_nothing() {
        let mut net = SimNetwork::new(13);
        let n = 20_000;
        let hits = (0..n).filter(|_| net.channel(0).draw_dropout(0.3)).count();
        let frac = hits as f64 / n as f64;
        assert!((frac - 0.3).abs() < 0.02, "dropout rate {frac}");
        // p = 0 and the Zero latency model must not consume draws: the
        // next real draw matches a fresh channel's first draw
        let mut gated = SimNetwork::new(17);
        assert!(!gated.channel(2).draw_dropout(0.0));
        assert_eq!(gated.channel(2).draw_latency(&LatencyModel::Zero), 0.0);
        assert_eq!(
            gated.channel(2).draw_latency(&LatencyModel::Fixed { ms: 3.0 }),
            3.0
        );
        let first = gated
            .channel(2)
            .draw_latency(&LatencyModel::Uniform { lo_ms: 0.0, hi_ms: 1.0 });
        let mut fresh = SimNetwork::new(17);
        let fresh_first = fresh
            .channel(2)
            .draw_latency(&LatencyModel::Uniform { lo_ms: 0.0, hi_ms: 1.0 });
        assert_eq!(first, fresh_first, "draw-free paths consumed lifecycle state");
    }

    #[test]
    fn lifecycle_draws_do_not_shift_noise_streams() {
        // corruption after heavy lifecycle use must equal corruption on a
        // fresh network: the two streams are fully separate
        let sent = ones(256);
        let mut quiet = SimNetwork::new(23).with_bit_flips(0.3);
        let want = quiet.downlink_to(0, &sent).unwrap();
        let mut busy = SimNetwork::new(23).with_bit_flips(0.3);
        for _ in 0..100 {
            busy.channel(0).draw_dropout(0.5);
            busy.channel(0)
                .draw_latency(&LatencyModel::LogNormal { median_ms: 5.0, sigma: 1.0 });
        }
        assert_eq!(busy.downlink_to(0, &sent).unwrap(), want);
    }
}
