//! Simulated server<->client transport: one metered channel per client.
//!
//! All traffic is encoded to real wire frames (codec.rs) and metered by
//! the recipient's channel shard before being "delivered" — so byte
//! counts are measurements, not formulas, and any future swap to a
//! socket transport keeps the same call sites. Each client link carries
//! its own noise RNG: under bit-flip noise (the unreliable IoT/V2X links
//! of the paper's motivating setting) every recipient of a broadcast
//! receives an *independently* corrupted copy, and the sender's own
//! state is never touched. Corruption operates directly on the packed
//! [`SignVec`] words via masked XOR (one RNG draw per live bit, in bit
//! order, so the noise stream is identical to a ±1-lane walk); padding
//! bits beyond m are never flipped. Per-round byte accounting merges
//! the per-client shards into the [`Ledger`]; integer sums commute, so
//! the merged totals are byte-identical to serial metering
//! (DESIGN.md §5).

use anyhow::Result;

use crate::comm::codec::{decode, encode, Payload};
use crate::comm::ledger::{Direction, Ledger, RoundBytes};
use crate::util::rng::{splitmix64, Rng};

/// One client's link to the server: its own byte shard and noise stream.
#[derive(Clone, Debug)]
pub struct Channel {
    shard: RoundBytes,
    rng: Rng,
}

impl Channel {
    fn new(seed: u64, client: usize) -> Channel {
        // independent, client-indexed noise stream: per-link corruption
        // must not depend on delivery order or on other links
        let mut s = seed
            ^ 0x4E45_5457_u64 // "NETW"
            ^ (client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Channel { shard: RoundBytes::default(), rng: Rng::new(splitmix64(&mut s)) }
    }

    /// Bytes metered on this link in the current (open) round.
    pub fn shard(&self) -> RoundBytes {
        self.shard
    }

    fn take_shard(&mut self) -> RoundBytes {
        std::mem::take(&mut self.shard)
    }

    fn transmit(&mut self, dir: Direction, payload: &Payload, flip_prob: f64) -> Result<Payload> {
        let frame = encode(payload);
        match dir {
            Direction::Uplink => {
                self.shard.uplink += frame.len() as u64;
                self.shard.uplink_msgs += 1;
            }
            Direction::Downlink => {
                self.shard.downlink += frame.len() as u64;
                self.shard.downlink_msgs += 1;
            }
        }
        let mut delivered = decode(&frame)?;
        if flip_prob > 0.0 {
            self.corrupt(&mut delivered, flip_prob);
        }
        Ok(delivered)
    }

    fn corrupt(&mut self, payload: &mut Payload, p: f64) {
        // masked XOR on the packed words: each live bit draws once from
        // this link's stream (ascending bit order); tail bits stay zero
        let rng = &mut self.rng;
        match payload {
            Payload::Signs(z) => z.flip_bits_where(|_| rng.f64() < p),
            Payload::ScaledSigns { signs, .. } => signs.flip_bits_where(|_| rng.f64() < p),
            Payload::Dense(_) => {} // full-precision links modeled clean
        }
    }
}

/// In-process simulated network: per-client channels with exact byte
/// metering, merged into one ledger at round end.
pub struct SimNetwork {
    pub ledger: Ledger,
    /// probability that each bit of a one-bit payload flips in transit
    pub bit_flip_prob: f64,
    seed: u64,
    channels: Vec<Channel>,
}

impl SimNetwork {
    pub fn new(seed: u64) -> Self {
        SimNetwork {
            ledger: Ledger::new(),
            bit_flip_prob: 0.0,
            seed,
            channels: Vec::new(),
        }
    }

    pub fn with_bit_flips(mut self, p: f64) -> Self {
        self.bit_flip_prob = p;
        self
    }

    /// The channel of client `k` (links materialize deterministically on
    /// first use; the stream depends only on the seed and `k`).
    pub fn channel(&mut self, k: usize) -> &mut Channel {
        while self.channels.len() <= k {
            let next = self.channels.len();
            self.channels.push(Channel::new(self.seed, next));
        }
        &mut self.channels[k]
    }

    /// Server -> client `k`. A broadcast is one call per recipient (the
    /// paper's accounting counts delivered copies — DESIGN.md §5), each
    /// corrupted independently by that recipient's link.
    pub fn downlink_to(&mut self, k: usize, payload: &Payload) -> Result<Payload> {
        let p = self.bit_flip_prob;
        self.channel(k).transmit(Direction::Downlink, payload, p)
    }

    /// Client `k` -> server.
    pub fn uplink_from(&mut self, k: usize, payload: &Payload) -> Result<Payload> {
        let p = self.bit_flip_prob;
        self.channel(k).transmit(Direction::Uplink, payload, p)
    }

    /// Merge every channel's shard and close the round; returns the
    /// round's merged totals.
    pub fn end_round(&mut self) -> RoundBytes {
        for ch in &mut self.channels {
            let shard = ch.take_shard();
            self.ledger.merge_shard(shard);
        }
        self.ledger.end_round()
    }

    /// Total bytes across closed rounds plus all open shards.
    pub fn total_bytes(&self) -> u64 {
        self.ledger.total_bytes()
            + self.channels.iter().map(|c| c.shard.total()).sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::bitpack::SignVec;

    fn ones(n: usize) -> Payload {
        Payload::Signs(SignVec::from_signs(&vec![1.0f32; n]))
    }

    #[test]
    fn metering_matches_frames() {
        let mut net = SimNetwork::new(0);
        let up = ones(128);
        let down = Payload::Dense(vec![0.5; 10]);
        net.uplink_from(0, &up).unwrap();
        net.downlink_to(1, &down).unwrap();
        let r = net.end_round();
        assert_eq!(r.uplink, 5 + 16); // 128 bits -> 16 bytes + header
        assert_eq!(r.downlink, 5 + 40);
    }

    #[test]
    fn clean_channel_is_lossless() {
        let mut net = SimNetwork::new(1);
        let p = Payload::ScaledSigns {
            signs: SignVec::from_signs(&[1.0, -1.0, 1.0]),
            scale: 2.0,
        };
        let got = net.uplink_from(3, &p).unwrap();
        assert_eq!(got, p);
    }

    #[test]
    fn broadcast_counts_per_recipient() {
        let mut net = SimNetwork::new(2);
        let v = ones(64);
        for k in 0..20 {
            net.downlink_to(k, &v).unwrap();
        }
        let r = net.end_round();
        assert_eq!(r.downlink_msgs, 20);
        assert_eq!(r.downlink, 20 * (5 + 8));
    }

    #[test]
    fn shards_meter_per_client_and_merge_exactly() {
        let mut net = SimNetwork::new(7);
        let sig = ones(64); // 5 + 8 bytes
        net.uplink_from(0, &sig).unwrap();
        net.uplink_from(0, &sig).unwrap();
        net.uplink_from(1, &sig).unwrap();
        net.downlink_to(1, &sig).unwrap();
        assert_eq!(net.channel(0).shard().uplink_msgs, 2);
        assert_eq!(net.channel(0).shard().uplink, 2 * 13);
        assert_eq!(net.channel(1).shard().uplink_msgs, 1);
        assert_eq!(net.channel(1).shard().downlink_msgs, 1);
        assert_eq!(net.total_bytes(), 4 * 13);
        let r = net.end_round();
        assert_eq!(r.uplink, 3 * 13);
        assert_eq!(r.downlink, 13);
        assert_eq!(r.uplink_msgs, 3);
        assert_eq!(r.downlink_msgs, 1);
        // shards reset after the merge
        assert_eq!(net.channel(0).shard(), RoundBytes::default());
    }

    #[test]
    fn noisy_channel_flips_about_p_bits() {
        let mut net = SimNetwork::new(3).with_bit_flips(0.25);
        let n = 10_000;
        let sent = ones(n);
        let got = match net.uplink_from(0, &sent).unwrap() {
            Payload::Signs(v) => v,
            _ => unreachable!(),
        };
        let flipped = got.iter_signs().filter(|&s| s < 0.0).count();
        let frac = flipped as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.03, "flip rate {frac}");
    }

    #[test]
    fn packed_corruption_never_touches_padding_bits() {
        // m=65: one live bit in the tail word, 63 padding bits. With
        // p=1.0 every live bit flips and every padding bit must stay 0,
        // or downstream word-level equality/popcounts would drift.
        let mut net = SimNetwork::new(5).with_bit_flips(1.0);
        let sent = ones(65);
        let got = match net.downlink_to(0, &sent).unwrap() {
            Payload::Signs(v) => v,
            _ => unreachable!(),
        };
        assert_eq!(got, SignVec::from_signs(&[-1.0f32; 65]));
        assert_eq!(got.words()[1], 0, "corruption leaked into tail padding");
        let Payload::Signs(sent_sv) = &sent else { unreachable!() };
        assert_eq!(sent_sv.hamming(&got), 65);
    }

    #[test]
    fn recipients_receive_independently_corrupted_copies() {
        // the IoT/V2X setting: per-link noise is independent, so two
        // recipients of the same broadcast see different corruption
        let mut net = SimNetwork::new(4).with_bit_flips(0.5);
        let sent = ones(256);
        let a = net.downlink_to(0, &sent).unwrap();
        let b = net.downlink_to(1, &sent).unwrap();
        assert_ne!(a, b, "two links produced identical corruption");
        assert_ne!(a, sent);
        assert_ne!(b, sent);
        // and a link's stream is deterministic in (seed, k) alone
        let mut net2 = SimNetwork::new(4).with_bit_flips(0.5);
        let b2 = net2.downlink_to(1, &sent).unwrap();
        assert_eq!(b, b2);
    }

    #[test]
    fn dense_payloads_not_corrupted() {
        let mut net = SimNetwork::new(4).with_bit_flips(0.5);
        let p = Payload::Dense(vec![1.0, 2.0, 3.0]);
        assert_eq!(net.downlink_to(0, &p).unwrap(), p);
    }
}
