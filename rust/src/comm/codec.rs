//! Wire codecs: how each algorithm serializes its payloads.
//!
//! Every algorithm's uplink/downlink traffic goes through a codec so the
//! ledger measures *actual encoded bytes*, not a formula. Encoded frames
//! are self-describing: 1 tag byte + u32 element count + payload.
//!
//! One-bit payloads carry a packed [`SignVec`] (DESIGN.md §8), so
//! encode/decode of sign traffic is a near-memcpy of the u64 words — no
//! ±1 f32 lanes are materialized at the transport boundary. The wire
//! format itself is unchanged from the f32-lane era (little-endian
//! words, bit set ⇔ +1, `sign(0) := +1`): the byte-exact golden tests
//! below pin it, because the Table 2 communication-cost claims rest on
//! these exact frames.

use anyhow::{bail, Result};

use crate::sketch::bitpack::{packed_bytes, SignVec, SignVecView};

/// An edge aggregator's merge frame: the exact fixed-point tally shard
/// it streamed its clients' uplinks into, shipped edge → root once per
/// round (DESIGN.md §11). O(m) regardless of how many clients the edge
/// absorbed — the hierarchical server never forwards raw uplinks.
///
/// The quanta are the 64.64 fixed-point integers of
/// [`VoteAccumulator`]/[`ScalarTally`] (DESIGN.md §9), so a root that
/// merges decoded frames in canonical edge order reproduces the flat
/// server's tally bit-for-bit. `absorbed`/`loss_sum` carry the shard's
/// round bookkeeping; personalized write-backs are simulation
/// bookkeeping and never travel in frames.
///
/// [`VoteAccumulator`]: crate::sketch::bitpack::VoteAccumulator
/// [`ScalarTally`]: crate::sketch::bitpack::ScalarTally
#[derive(Clone, Debug, PartialEq)]
pub struct TallyFrame {
    /// uplinks this shard absorbed (delivered only — cut stragglers and
    /// dropouts never count)
    pub absorbed: u32,
    /// Σ of the shard's delivered round-start losses (f64 bits)
    pub loss_sum: f64,
    /// companion scalar tally quanta (OBDA's step scale, OBCSAA's norm
    /// target); 0 for kinds without one
    pub scalar: i128,
    /// per-bit tally quanta, length m
    pub quanta: Vec<i128>,
    /// per-group partial tallies of the robust kinds (DESIGN.md §16).
    /// A frame carries EITHER flat `quanta` OR `groups`, never both:
    /// empty here means a plain tag-4 frame, byte-identical to the
    /// pre-robust wire format; non-empty means a tag-5 frame whose
    /// groups all carry the same m quanta.
    pub groups: Vec<GroupFrame>,
}

impl TallyFrame {
    /// Logical sketch length m, whichever section carries it.
    pub fn m(&self) -> usize {
        match self.groups.first() {
            Some(g) => g.quanta.len(),
            None => self.quanta.len(),
        }
    }
}

/// One group's partial tally inside a grouped (tag-5) merge frame: the
/// exact per-bit i128 quanta plus how many uplinks the group absorbed
/// on this shard — everything [`GroupedTally::merge_group_quanta`]
/// needs to fold the shard in bit-for-bit.
///
/// [`GroupedTally::merge_group_quanta`]: crate::sketch::bitpack::GroupedTally::merge_group_quanta
#[derive(Clone, Debug, PartialEq)]
pub struct GroupFrame {
    /// uplinks this group absorbed on this shard
    pub absorbed: u32,
    /// the group's per-bit tally quanta, length m
    pub quanta: Vec<i128>,
}

/// A decoded payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// full-precision vector (FedAvg and full-model downlinks)
    Dense(Vec<f32>),
    /// packed ±1 sign vector (OBDA/zSignFed uplinks, pFed1BS both
    /// directions)
    Signs(SignVec),
    /// packed sign vector with one f32 scale (EDEN/FedBAT: α·sign(x))
    ScaledSigns { signs: SignVec, scale: f32 },
    /// edge → root merge frame of the hierarchical topology
    /// (DESIGN.md §11)
    TallyFrame(TallyFrame),
}

impl Payload {
    /// Logical element count (lanes, bits, or tally quanta).
    pub fn len(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::Signs(z) => z.m(),
            Payload::ScaledSigns { signs, .. } => signs.m(),
            Payload::TallyFrame(f) => f.m(),
        }
    }

    /// True when the payload carries zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decode a wire frame into a borrowing [`PayloadView`] — validation
    /// is byte-for-byte the owned [`decode`]'s (strict exact-length
    /// frames, unknown tags rejected, never panics, never reads past the
    /// buffer), but no word or lane vectors are materialized: the view
    /// reads straight out of `bytes`. This is the zero-copy receive path
    /// for stream-transport buffers and simulated-network deliveries
    /// (DESIGN.md §14).
    pub fn decode_borrowed(bytes: &[u8]) -> Result<PayloadView<'_>> {
        if bytes.len() < 5 {
            bail!("frame too short ({} bytes)", bytes.len());
        }
        let tag = bytes[0];
        let len = u32::from_le_bytes(bytes[1..5].try_into().unwrap()) as usize;
        match tag {
            TAG_DENSE => {
                let need = 5 + 4 * len;
                if bytes.len() != need {
                    bail!("dense frame: expected {need} bytes, got {}", bytes.len());
                }
                Ok(PayloadView::Dense(DenseView { bytes: &bytes[5..] }))
            }
            TAG_SIGNS => {
                let need = 5 + packed_bytes(len);
                if bytes.len() != need {
                    bail!("signs frame: expected {need} bytes, got {}", bytes.len());
                }
                Ok(PayloadView::Signs(SignVecView::new(&bytes[5..], len)))
            }
            TAG_SCALED => {
                let need = 9 + packed_bytes(len);
                if bytes.len() != need {
                    bail!("scaled frame: expected {need} bytes, got {}", bytes.len());
                }
                let scale = f32::from_le_bytes(bytes[5..9].try_into().unwrap());
                Ok(PayloadView::ScaledSigns {
                    signs: SignVecView::new(&bytes[9..], len),
                    scale,
                })
            }
            TAG_TALLY => {
                let need = 33 + 16 * len;
                if bytes.len() != need {
                    bail!("tally frame: expected {need} bytes, got {}", bytes.len());
                }
                Ok(PayloadView::TallyFrame(TallyFrameView {
                    absorbed: u32::from_le_bytes(bytes[5..9].try_into().unwrap()),
                    loss_sum: f64::from_le_bytes(bytes[9..17].try_into().unwrap()),
                    scalar: i128::from_le_bytes(bytes[17..33].try_into().unwrap()),
                    quanta: &bytes[33..],
                    groups: &[],
                    group_m: 0,
                    group_count: 0,
                }))
            }
            TAG_GROUPED => {
                let (g, need) = grouped_frame_need(bytes, len)?;
                if bytes.len() != need {
                    bail!("grouped tally frame: expected {need} bytes, got {}", bytes.len());
                }
                Ok(PayloadView::TallyFrame(TallyFrameView {
                    absorbed: u32::from_le_bytes(bytes[5..9].try_into().unwrap()),
                    loss_sum: f64::from_le_bytes(bytes[9..17].try_into().unwrap()),
                    scalar: i128::from_le_bytes(bytes[17..33].try_into().unwrap()),
                    quanta: &[],
                    groups: &bytes[37..],
                    group_m: len,
                    group_count: g,
                }))
            }
            t => bail!("unknown payload tag {t}"),
        }
    }
}

/// Validate a grouped (tag-5) frame header: reads the group count and
/// returns `(g, exact frame size)`. All arithmetic is checked so an
/// adversarial `m × g` product can only produce `Err`, never an
/// overflow panic or a bogus small size that over-reads the buffer.
fn grouped_frame_need(bytes: &[u8], m: usize) -> Result<(usize, usize)> {
    if bytes.len() < 37 {
        bail!("grouped tally frame too short ({} bytes)", bytes.len());
    }
    let g = u32::from_le_bytes(bytes[33..37].try_into().unwrap()) as usize;
    if g == 0 {
        bail!("grouped tally frame with zero groups");
    }
    let need = 16usize
        .checked_mul(m)
        .and_then(|q| q.checked_add(4))
        .and_then(|stride| stride.checked_mul(g))
        .and_then(|body| body.checked_add(37));
    match need {
        Some(need) => Ok((g, need)),
        None => bail!("grouped tally frame size overflows (m={m}, groups={g})"),
    }
}

/// Borrowed view of a dense frame body: f32 lanes decode on access from
/// the little-endian wire bytes.
#[derive(Clone, Copy, Debug)]
pub struct DenseView<'a> {
    bytes: &'a [u8],
}

impl<'a> DenseView<'a> {
    /// Lane count.
    pub fn len(&self) -> usize {
        self.bytes.len() / 4
    }

    /// True when the view carries zero lanes.
    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// Lane i, decoded from its four little-endian wire bytes.
    #[inline]
    pub fn lane(&self, i: usize) -> f32 {
        f32::from_le_bytes(self.bytes[4 * i..4 * i + 4].try_into().unwrap())
    }

    /// Materialize the owned lane vector (bit-identical to [`decode`]).
    pub fn to_vec(self) -> Vec<f32> {
        self.bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

/// Borrowed view of an edge → root merge frame: the fixed-offset header
/// fields decode eagerly, the m×16-byte quanta stay on the wire buffer
/// and decode per index through [`quantum`](Self::quantum), so a root
/// can [`merge_quanta`] a shard without materializing its i128 vector.
///
/// [`merge_quanta`]: crate::sketch::bitpack::VoteAccumulator::merge_quanta
#[derive(Clone, Copy, Debug)]
pub struct TallyFrameView<'a> {
    /// uplinks this shard absorbed
    pub absorbed: u32,
    /// Σ of the shard's delivered round-start losses (f64 bits)
    pub loss_sum: f64,
    /// companion scalar tally quanta
    pub scalar: i128,
    quanta: &'a [u8],
    groups: &'a [u8],
    group_m: usize,
    group_count: usize,
}

impl<'a> TallyFrameView<'a> {
    /// Number of flat tally quanta carried (the shard's m for tag-4
    /// frames; 0 for grouped frames).
    pub fn quanta_len(&self) -> usize {
        self.quanta.len() / 16
    }

    /// The i-th fixed-point tally quantum, decoded from its sixteen
    /// little-endian wire bytes — bit-exact, as in the owned decode.
    #[inline]
    pub fn quantum(&self, i: usize) -> i128 {
        i128::from_le_bytes(self.quanta[16 * i..16 * i + 16].try_into().unwrap())
    }

    /// Number of group partials carried (0 for plain tag-4 frames).
    pub fn group_count(&self) -> usize {
        self.group_count
    }

    /// Logical sketch length m, whichever section carries it — the
    /// borrowed twin of [`TallyFrame::m`].
    pub fn m(&self) -> usize {
        if self.group_count > 0 {
            self.group_m
        } else {
            self.quanta_len()
        }
    }

    /// Wire byte stride of one group record: absorbed u32 + m quanta.
    fn group_stride(&self) -> usize {
        4 + 16 * self.group_m
    }

    /// Uplinks group `g` absorbed on this shard.
    #[inline]
    pub fn group_absorbed(&self, g: usize) -> u32 {
        let lo = g * self.group_stride();
        u32::from_le_bytes(self.groups[lo..lo + 4].try_into().unwrap())
    }

    /// The i-th quantum of group `g`, decoded bit-exact off the wire.
    #[inline]
    pub fn group_quantum(&self, g: usize, i: usize) -> i128 {
        let lo = g * self.group_stride() + 4 + 16 * i;
        i128::from_le_bytes(self.groups[lo..lo + 16].try_into().unwrap())
    }

    /// Materialize the owned [`TallyFrame`].
    pub fn to_frame(self) -> TallyFrame {
        TallyFrame {
            absorbed: self.absorbed,
            loss_sum: self.loss_sum,
            scalar: self.scalar,
            quanta: (0..self.quanta_len()).map(|i| self.quantum(i)).collect(),
            groups: (0..self.group_count)
                .map(|g| GroupFrame {
                    absorbed: self.group_absorbed(g),
                    quanta: (0..self.group_m).map(|i| self.group_quantum(g, i)).collect(),
                })
                .collect(),
        }
    }
}

/// A payload decoded without copying: every variant borrows the wire
/// buffer and decodes elements on access (DESIGN.md §14). Validation is
/// identical to the owned [`decode`]; only materialization is deferred,
/// so `Payload::decode_borrowed(b)?.to_owned()` equals `decode(b)?`
/// bit-for-bit on every frame the owned path accepts, and errors on
/// exactly the frames it rejects.
#[derive(Clone, Copy, Debug)]
pub enum PayloadView<'a> {
    /// full-precision lanes over wire bytes
    Dense(DenseView<'a>),
    /// packed ±1 sign bits over wire bytes (tail-masked on read)
    Signs(SignVecView<'a>),
    /// packed sign bits plus the decoded f32 scale
    ScaledSigns {
        /// the packed sign bits
        signs: SignVecView<'a>,
        /// the decoded scale α
        scale: f32,
    },
    /// edge → root merge frame with lazily decoded quanta
    TallyFrame(TallyFrameView<'a>),
}

impl<'a> PayloadView<'a> {
    /// Logical element count (lanes, bits, or tally quanta).
    pub fn len(&self) -> usize {
        match self {
            PayloadView::Dense(v) => v.len(),
            PayloadView::Signs(z) => z.m(),
            PayloadView::ScaledSigns { signs, .. } => signs.m(),
            PayloadView::TallyFrame(f) => f.m(),
        }
    }

    /// True when the payload carries zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize an owned [`Payload`] — bit-identical to running the
    /// owned [`decode`] on the same frame.
    pub fn to_owned(self) -> Payload {
        match self {
            PayloadView::Dense(v) => Payload::Dense(v.to_vec()),
            PayloadView::Signs(z) => Payload::Signs(z.to_owned()),
            PayloadView::ScaledSigns { signs, scale } => {
                Payload::ScaledSigns { signs: signs.to_owned(), scale }
            }
            PayloadView::TallyFrame(f) => Payload::TallyFrame(f.to_frame()),
        }
    }
}

const TAG_DENSE: u8 = 1;
const TAG_SIGNS: u8 = 2;
const TAG_SCALED: u8 = 3;
const TAG_TALLY: u8 = 4;
const TAG_GROUPED: u8 = 5;

fn put_words(out: &mut Vec<u8>, z: &SignVec) {
    for w in z.words() {
        out.extend_from_slice(&w.to_le_bytes());
    }
}

fn get_words(bytes: &[u8], m: usize) -> SignVec {
    let words: Vec<u64> = bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    SignVec::from_words(words, m)
}

/// Encode a payload to its wire frame.
pub fn encode(p: &Payload) -> Vec<u8> {
    match p {
        Payload::Dense(v) => {
            let mut out = Vec::with_capacity(5 + 4 * v.len());
            out.push(TAG_DENSE);
            out.extend_from_slice(&(v.len() as u32).to_le_bytes());
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
            out
        }
        Payload::Signs(z) => {
            let mut out = Vec::with_capacity(5 + z.byte_len());
            out.push(TAG_SIGNS);
            out.extend_from_slice(&(z.m() as u32).to_le_bytes());
            put_words(&mut out, z);
            out
        }
        Payload::ScaledSigns { signs, scale } => {
            let mut out = Vec::with_capacity(9 + signs.byte_len());
            out.push(TAG_SCALED);
            out.extend_from_slice(&(signs.m() as u32).to_le_bytes());
            out.extend_from_slice(&scale.to_le_bytes());
            put_words(&mut out, signs);
            out
        }
        Payload::TallyFrame(f) => {
            if f.groups.is_empty() {
                // tag | m u32 | absorbed u32 | loss_sum f64 bits | scalar
                // i128 | quanta i128 × m — all little-endian. i128 LE
                // bytes round-trip exactly, so the frame carries the
                // shard's fixed-point state without any precision cliff.
                let mut out = Vec::with_capacity(33 + 16 * f.quanta.len());
                out.push(TAG_TALLY);
                out.extend_from_slice(&(f.quanta.len() as u32).to_le_bytes());
                out.extend_from_slice(&f.absorbed.to_le_bytes());
                out.extend_from_slice(&f.loss_sum.to_le_bytes());
                out.extend_from_slice(&f.scalar.to_le_bytes());
                for q in &f.quanta {
                    out.extend_from_slice(&q.to_le_bytes());
                }
                out
            } else {
                // tag | m u32 | absorbed u32 | loss_sum f64 bits |
                // scalar i128 | g u32 | g × (absorbed u32 | quanta i128
                // × m) — the grouped shard state of the robust tallies
                // (DESIGN.md §16). A frame carries either section, never
                // both, so plain frames keep their tag-4 bytes.
                debug_assert!(
                    f.quanta.is_empty(),
                    "grouped tally frames must not carry flat quanta"
                );
                let m = f.m();
                let mut out =
                    Vec::with_capacity(37 + f.groups.len() * (4 + 16 * m));
                out.push(TAG_GROUPED);
                out.extend_from_slice(&(m as u32).to_le_bytes());
                out.extend_from_slice(&f.absorbed.to_le_bytes());
                out.extend_from_slice(&f.loss_sum.to_le_bytes());
                out.extend_from_slice(&f.scalar.to_le_bytes());
                out.extend_from_slice(&(f.groups.len() as u32).to_le_bytes());
                for grp in &f.groups {
                    debug_assert_eq!(
                        grp.quanta.len(),
                        m,
                        "every group of a frame carries the same m"
                    );
                    out.extend_from_slice(&grp.absorbed.to_le_bytes());
                    for q in &grp.quanta {
                        out.extend_from_slice(&q.to_le_bytes());
                    }
                }
                out
            }
        }
    }
}

/// Decode a wire frame back to a payload. Returns `Err` (never panics,
/// never reads past the frame) on malformed input: unknown tags,
/// truncated or over-long frames. Sign frames with garbage bits beyond
/// m are canonicalized (tail masked) on adoption.
pub fn decode(bytes: &[u8]) -> Result<Payload> {
    if bytes.len() < 5 {
        bail!("frame too short ({} bytes)", bytes.len());
    }
    let tag = bytes[0];
    let len = u32::from_le_bytes(bytes[1..5].try_into().unwrap()) as usize;
    match tag {
        TAG_DENSE => {
            let need = 5 + 4 * len;
            if bytes.len() != need {
                bail!("dense frame: expected {need} bytes, got {}", bytes.len());
            }
            let v = bytes[5..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Payload::Dense(v))
        }
        TAG_SIGNS => {
            let need = 5 + packed_bytes(len);
            if bytes.len() != need {
                bail!("signs frame: expected {need} bytes, got {}", bytes.len());
            }
            Ok(Payload::Signs(get_words(&bytes[5..], len)))
        }
        TAG_SCALED => {
            let need = 9 + packed_bytes(len);
            if bytes.len() != need {
                bail!("scaled frame: expected {need} bytes, got {}", bytes.len());
            }
            let scale = f32::from_le_bytes(bytes[5..9].try_into().unwrap());
            Ok(Payload::ScaledSigns { signs: get_words(&bytes[9..], len), scale })
        }
        TAG_TALLY => {
            let need = 33 + 16 * len;
            if bytes.len() != need {
                bail!("tally frame: expected {need} bytes, got {}", bytes.len());
            }
            let absorbed = u32::from_le_bytes(bytes[5..9].try_into().unwrap());
            let loss_sum = f64::from_le_bytes(bytes[9..17].try_into().unwrap());
            let scalar = i128::from_le_bytes(bytes[17..33].try_into().unwrap());
            let quanta = bytes[33..]
                .chunks_exact(16)
                .map(|c| i128::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Payload::TallyFrame(TallyFrame {
                absorbed,
                loss_sum,
                scalar,
                quanta,
                groups: Vec::new(),
            }))
        }
        TAG_GROUPED => {
            let (g, need) = grouped_frame_need(bytes, len)?;
            if bytes.len() != need {
                bail!("grouped tally frame: expected {need} bytes, got {}", bytes.len());
            }
            let absorbed = u32::from_le_bytes(bytes[5..9].try_into().unwrap());
            let loss_sum = f64::from_le_bytes(bytes[9..17].try_into().unwrap());
            let scalar = i128::from_le_bytes(bytes[17..33].try_into().unwrap());
            let stride = 4 + 16 * len;
            let groups = (0..g)
                .map(|gi| {
                    let lo = 37 + gi * stride;
                    GroupFrame {
                        absorbed: u32::from_le_bytes(bytes[lo..lo + 4].try_into().unwrap()),
                        quanta: bytes[lo + 4..lo + stride]
                            .chunks_exact(16)
                            .map(|c| i128::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    }
                })
                .collect();
            Ok(Payload::TallyFrame(TallyFrame {
                absorbed,
                loss_sum,
                scalar,
                quanta: Vec::new(),
                groups,
            }))
        }
        t => bail!("unknown payload tag {t}"),
    }
}

/// Frame size without encoding (for planning / assertions).
pub fn frame_bytes(p: &Payload) -> usize {
    match p {
        Payload::Dense(v) => 5 + 4 * v.len(),
        Payload::Signs(z) => 5 + packed_bytes(z.m()),
        Payload::ScaledSigns { signs, .. } => 9 + packed_bytes(signs.m()),
        Payload::TallyFrame(f) if f.groups.is_empty() => 33 + 16 * f.quanta.len(),
        Payload::TallyFrame(f) => 37 + f.groups.len() * (4 + 16 * f.m()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn rand_sign_lanes(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 }).collect()
    }

    fn rand_signs(rng: &mut Rng, n: usize) -> SignVec {
        SignVec::from_fn(n, |_| rng.f32() < 0.5)
    }

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn dense_round_trip() {
        check("codec_dense_round_trip", 30, |rng| {
            let n = rng.below(1000);
            let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let p = Payload::Dense(v);
            let bytes = encode(&p);
            if bytes.len() != frame_bytes(&p) {
                return Err("frame_bytes mismatch".into());
            }
            if decode(&bytes).map_err(|e| e.to_string())? != p {
                return Err("round trip".into());
            }
            Ok(())
        });
    }

    #[test]
    fn signs_round_trip_and_compression() {
        check("codec_signs_round_trip", 30, |rng| {
            let n = rng.below(2000) + 1;
            let p = Payload::Signs(rand_signs(rng, n));
            let bytes = encode(&p);
            if decode(&bytes).map_err(|e| e.to_string())? != p {
                return Err("round trip".into());
            }
            // ~32x smaller than dense for large n
            if n >= 640 && bytes.len() * 16 > 4 * n + 5 {
                return Err(format!("poor compression: {} bytes for n={n}", bytes.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn scaled_signs_round_trip() {
        let mut rng = Rng::new(3);
        let p = Payload::ScaledSigns { signs: rand_signs(&mut rng, 100), scale: 0.0123 };
        assert_eq!(decode(&encode(&p)).unwrap(), p);
    }

    fn rand_tally(rng: &mut Rng, m: usize) -> TallyFrame {
        let wide = |rng: &mut Rng| {
            // exercise both i128 halves, signs included (build in u128
            // to keep the shift overflow-free, then reinterpret)
            ((rng.next_u64() as u128) << 64 | rng.next_u64() as u128) as i128
        };
        TallyFrame {
            absorbed: rng.next_u32(),
            loss_sum: rng.f64() * 10.0,
            scalar: wide(rng),
            quanta: (0..m).map(|_| wide(rng)).collect(),
            groups: Vec::new(),
        }
    }

    fn rand_grouped(rng: &mut Rng, m: usize) -> TallyFrame {
        let wide = |rng: &mut Rng| {
            ((rng.next_u64() as u128) << 64 | rng.next_u64() as u128) as i128
        };
        let g = rng.below(6) + 1;
        TallyFrame {
            absorbed: rng.next_u32(),
            loss_sum: rng.f64() * 10.0,
            scalar: wide(rng),
            quanta: Vec::new(),
            groups: (0..g)
                .map(|_| GroupFrame {
                    absorbed: rng.next_u32(),
                    quanta: (0..m).map(|_| wide(rng)).collect(),
                })
                .collect(),
        }
    }

    #[test]
    fn tally_frame_round_trip_is_exact() {
        // the edge→root merge frame must carry the fixed-point shard
        // state bit-for-bit: i128 quanta, f64 loss bits, counts
        check("codec_tally_round_trip", 40, |rng| {
            let m = rng.below(300);
            let p = Payload::TallyFrame(rand_tally(rng, m));
            let bytes = encode(&p);
            if bytes.len() != frame_bytes(&p) {
                return Err("frame_bytes mismatch".into());
            }
            if decode(&bytes).map_err(|e| e.to_string())? != p {
                return Err("tally frame round trip".into());
            }
            Ok(())
        });
    }

    #[test]
    fn grouped_tally_frame_round_trip_is_exact() {
        // the robust kinds' grouped shard state (tag 5) must round-trip
        // every group's i128 quanta and absorb count bit-for-bit
        check("codec_grouped_round_trip", 40, |rng| {
            let m = rng.below(200);
            let p = Payload::TallyFrame(rand_grouped(rng, m));
            let bytes = encode(&p);
            if bytes.len() != frame_bytes(&p) {
                return Err("frame_bytes mismatch".into());
            }
            if decode(&bytes).map_err(|e| e.to_string())? != p {
                return Err("grouped frame round trip".into());
            }
            Ok(())
        });
    }

    #[test]
    fn grouped_frame_rejects_zero_groups_and_overflowing_sizes() {
        // g=0 has no legitimate producer (encode picks tag 4 for group-
        // less frames), so the decoders reject it instead of creating a
        // second wire spelling of the same payload
        let mut zero_g = vec![TAG_GROUPED];
        zero_g.extend_from_slice(&1u32.to_le_bytes()); // m = 1
        zero_g.extend_from_slice(&[0u8; 28]); // absorbed, loss, scalar
        zero_g.extend_from_slice(&0u32.to_le_bytes()); // g = 0
        assert_eq!(zero_g.len(), 37);
        assert!(decode(&zero_g).is_err());
        assert!(Payload::decode_borrowed(&zero_g).is_err());

        // an adversarial m × g product that overflows usize must Err,
        // not panic or wrap into a small bogus size
        let mut huge = vec![TAG_GROUPED];
        huge.extend_from_slice(&u32::MAX.to_le_bytes()); // m
        huge.extend_from_slice(&[0u8; 28]);
        huge.extend_from_slice(&u32::MAX.to_le_bytes()); // g
        assert!(decode(&huge).is_err());
        assert!(Payload::decode_borrowed(&huge).is_err());
    }

    #[test]
    fn packed_and_lane_constructions_encode_identically() {
        // the SignVec refactor must not move a single wire byte: packing
        // at construction and packing-at-encode are the same frame
        check("codec_pack_equivalence", 30, |rng| {
            let n = rng.below(300) + 1;
            let lanes = rand_sign_lanes(rng, n);
            let a = encode(&Payload::Signs(SignVec::from_signs(&lanes)));
            let b = encode(&Payload::Signs(SignVec::from_fn(n, |i| lanes[i] >= 0.0)));
            if a != b {
                return Err("construction path changed wire bytes".into());
            }
            Ok(())
        });
    }

    #[test]
    fn exact_wire_sizes() {
        // the communication-cost claims in Table 2 rest on these sizes
        let ones = |n: usize| SignVec::from_signs(&vec![1.0f32; n]);
        assert_eq!(encode(&Payload::Dense(vec![0.0; 100])).len(), 5 + 400);
        assert_eq!(encode(&Payload::Signs(ones(64))).len(), 5 + 8);
        assert_eq!(encode(&Payload::Signs(ones(65))).len(), 5 + 16);
        assert_eq!(
            encode(&Payload::ScaledSigns { signs: ones(64), scale: 1.0 }).len(),
            9 + 8
        );
    }

    /// Byte-exact golden frames for all three tags, including the
    /// tail-bit cases m = 63 / 64 / 65. These hex strings are the wire
    /// format: any change here is a protocol break and invalidates the
    /// Table 2 communication-cost accounting. Do not regenerate them
    /// from the encoder under test — they are written out by hand.
    #[test]
    fn golden_wire_frames() {
        let cases: [(Payload, &str); 8] = [
            // tag 1 (dense), [1.0, -2.5]:
            // 01 | len=2 le | 1.0 = 0x3f800000 le | -2.5 = 0xc0200000 le
            (Payload::Dense(vec![1.0, -2.5]), "01020000000000803f000020c0"),
            // tag 2 (signs), m=63, +1 at i % 3 == 0:
            // word0 = Σ_{k=0..20} 8^k = (2^63−1)/7 = 0x1249249249249249
            // (le bytes 49 92 24 49 92 24 49 12); bit 63 is beyond m and
            // stays clear
            (
                Payload::Signs(SignVec::from_fn(63, |i| i % 3 == 0)),
                "023f0000004992244992244912",
            ),
            // tag 2 (signs), m=64, all +1: exactly one full word
            (
                Payload::Signs(SignVec::from_signs(&[1.0f32; 64])),
                "0240000000ffffffffffffffff",
            ),
            // tag 2 (signs), m=65, +1 at even i: word0 = 0x5555…,
            // one bit spills into word1 (bit 64 set, 63 padding zeros)
            (
                Payload::Signs(SignVec::from_fn(65, |i| i % 2 == 0)),
                "024100000055555555555555550100000000000000",
            ),
            // tag 3 (scaled signs), m=65, scale=0.5, +1 at odd i:
            // 03 | len=0x41 le | 0.5 = 0x3f000000 le | word0 = 0xaaaa…,
            // word1 = 0 (bit 64 is even → −1)
            (
                Payload::ScaledSigns {
                    signs: SignVec::from_fn(65, |i| i % 2 == 1),
                    scale: 0.5,
                },
                "03410000000000003faaaaaaaaaaaaaaaa0000000000000000",
            ),
            // tag 4 (tally frame), m=2, absorbed=2, loss_sum=0.5,
            // scalar=+3, quanta [+1, −2]:
            // 04 | m=2 le | absorbed=2 le | 0.5 = 0x3fe0…0 f64 le |
            // 3 as i128 le | 1 as i128 le | −2 = 0xff…fe as i128 le
            (
                Payload::TallyFrame(TallyFrame {
                    absorbed: 2,
                    loss_sum: 0.5,
                    scalar: 3,
                    quanta: vec![1, -2],
                    groups: vec![],
                }),
                "040200000002000000000000000000e03f\
                 03000000000000000000000000000000\
                 01000000000000000000000000000000\
                 feffffffffffffffffffffffffffffff",
            ),
            // tag 4, scalar-only shard (m=0, nothing absorbed, −1 scalar)
            (
                Payload::TallyFrame(TallyFrame {
                    absorbed: 0,
                    loss_sum: 0.0,
                    scalar: -1,
                    quanta: vec![],
                    groups: vec![],
                }),
                "0400000000000000000000000000000000\
                 ffffffffffffffffffffffffffffffff",
            ),
            // tag 5 (grouped tally frame), m=1, absorbed=3, loss=0,
            // scalar=0, two groups {absorbed=2, quanta [+5]} and
            // {absorbed=1, quanta [−1]}:
            // 05 | m=1 le | absorbed=3 le | 0.0 f64 | 0 i128 | g=2 le |
            // 2 le | 5 i128 le | 1 le | −1 i128 le
            (
                Payload::TallyFrame(TallyFrame {
                    absorbed: 3,
                    loss_sum: 0.0,
                    scalar: 0,
                    quanta: vec![],
                    groups: vec![
                        GroupFrame { absorbed: 2, quanta: vec![5] },
                        GroupFrame { absorbed: 1, quanta: vec![-1] },
                    ],
                }),
                "0501000000030000000000000000000000\
                 00000000000000000000000000000000\
                 02000000\
                 0200000005000000000000000000000000000000\
                 01000000ffffffffffffffffffffffffffffffff",
            ),
        ];
        for (p, want) in &cases {
            assert_eq!(&hex(&encode(p)), want, "golden frame encode: {p:?}");
            let bytes: Vec<u8> = (0..want.len())
                .step_by(2)
                .map(|i| u8::from_str_radix(&want[i..i + 2], 16).unwrap())
                .collect();
            assert_eq!(&decode(&bytes).unwrap(), p, "golden frame decode");
            assert_eq!(frame_bytes(p), bytes.len());
        }
    }

    #[test]
    fn corrupt_frames_rejected() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[9, 0, 0, 0, 0]).is_err()); // bad tag
        let mut ok = encode(&Payload::Dense(vec![1.0, 2.0]));
        ok.pop(); // truncate
        assert!(decode(&ok).is_err());
    }

    #[test]
    fn decode_never_panics_on_arbitrary_bytes() {
        // fuzz-style: decode must return Err (or a length-consistent Ok)
        // on arbitrary byte strings — no panic, no over-read
        check("codec_fuzz_arbitrary", 300, |rng| {
            let len = rng.below(80);
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
            match decode(&bytes) {
                Err(_) => Ok(()),
                Ok(p) => {
                    // an accidental valid frame must account for every
                    // input byte — anything else means an over- or
                    // under-read of the buffer
                    if frame_bytes(&p) == bytes.len() {
                        Ok(())
                    } else {
                        Err(format!(
                            "decoded {} bytes as a {}-byte frame",
                            bytes.len(),
                            frame_bytes(&p)
                        ))
                    }
                }
            }
        });
    }

    #[test]
    fn decode_rejects_truncations_and_survives_mutations() {
        check("codec_fuzz_mutations", 150, |rng| {
            // a random valid frame of a random kind
            let n = rng.below(200) + 1;
            let p = match rng.below(5) {
                0 => Payload::Dense((0..n).map(|_| rng.normal()).collect()),
                1 => Payload::Signs(rand_signs(rng, n)),
                2 => Payload::ScaledSigns { signs: rand_signs(rng, n), scale: rng.f32() },
                3 => Payload::TallyFrame(rand_tally(rng, n)),
                _ => Payload::TallyFrame(rand_grouped(rng, n)),
            };
            let frame = encode(&p);

            // every strict truncation must be rejected (the header's
            // exact-length contract)
            let cut = rng.below(frame.len());
            if decode(&frame[..cut]).is_ok() {
                return Err(format!("truncation to {cut} bytes accepted"));
            }

            // a single-byte mutation must never panic; header mutations
            // that happen to stay self-consistent may decode as a
            // different (valid) payload, but must account for exactly
            // the frame's bytes
            let idx = rng.below(frame.len());
            let mut mutated = frame.clone();
            mutated[idx] ^= 1u8 << rng.below(8);
            match decode(&mutated) {
                Err(_) => Ok(()),
                Ok(q) => {
                    if frame_bytes(&q) == mutated.len() {
                        Ok(())
                    } else {
                        Err("mutated frame decoded inconsistently".into())
                    }
                }
            }
        });
    }

    #[test]
    fn borrowed_decode_matches_owned_on_unaligned_and_dirty_buffers() {
        check("codec_borrowed_identity", 80, |rng| {
            let n = rng.below(200) + 1;
            let p = match rng.below(5) {
                0 => Payload::Dense((0..n).map(|_| rng.normal()).collect()),
                1 => Payload::Signs(rand_signs(rng, n)),
                2 => Payload::ScaledSigns { signs: rand_signs(rng, n), scale: rng.f32() },
                3 => Payload::TallyFrame(rand_tally(rng, n)),
                _ => Payload::TallyFrame(rand_grouped(rng, n)),
            };
            let mut frame = encode(&p);

            // dirty the tail: a sign frame may arrive with garbage bits
            // beyond m — both decoders must canonicalize identically
            if matches!(p, Payload::Signs(_) | Payload::ScaledSigns { .. }) && n % 64 != 0 {
                *frame.last_mut().unwrap() |= 0xF0;
            }
            let owned = decode(&frame).map_err(|e| e.to_string())?;

            // re-home the frame at every alignment class: the view's
            // unaligned word reads must not care where the buffer sits
            let off = rng.below(8) + 1;
            let mut shifted = vec![0x5Au8; off];
            shifted.extend_from_slice(&frame);
            let view = Payload::decode_borrowed(&shifted[off..]).map_err(|e| e.to_string())?;
            if view.len() != owned.len() {
                return Err("borrowed len mismatch".into());
            }
            if view.to_owned() != owned {
                return Err("borrowed decode disagrees with owned".into());
            }
            // spot-check the lazy accessors against the owned payload
            match (&view, &owned) {
                (PayloadView::Dense(v), Payload::Dense(w)) => {
                    let i = rng.below(n);
                    if v.lane(i).to_bits() != w[i].to_bits() {
                        return Err(format!("dense lane {i} mismatch"));
                    }
                }
                (PayloadView::Signs(v), Payload::Signs(z)) => {
                    let i = rng.below(n);
                    if v.bit(i) != z.bit(i) || v.sign(i) != z.sign(i) {
                        return Err(format!("sign bit {i} mismatch"));
                    }
                }
                (
                    PayloadView::ScaledSigns { scale: a, .. },
                    Payload::ScaledSigns { scale: b, .. },
                ) => {
                    if a.to_bits() != b.to_bits() {
                        return Err("scale bits mismatch".into());
                    }
                }
                (PayloadView::TallyFrame(v), Payload::TallyFrame(f)) => {
                    let i = rng.below(n);
                    if f.groups.is_empty() {
                        if v.quantum(i) != f.quanta[i] || v.absorbed != f.absorbed {
                            return Err(format!("tally quantum {i} mismatch"));
                        }
                    } else {
                        if v.group_count() != f.groups.len() || v.m() != f.m() {
                            return Err("grouped section shape mismatch".into());
                        }
                        let g = rng.below(f.groups.len());
                        if v.group_absorbed(g) != f.groups[g].absorbed
                            || v.group_quantum(g, i) != f.groups[g].quanta[i]
                        {
                            return Err(format!("group {g} quantum {i} mismatch"));
                        }
                    }
                    if v.loss_sum.to_bits() != f.loss_sum.to_bits() || v.scalar != f.scalar {
                        return Err("tally header mismatch".into());
                    }
                }
                _ => return Err("borrowed decode picked the wrong kind".into()),
            }
            Ok(())
        });
    }

    #[test]
    fn borrowed_decode_never_panics_and_agrees_with_owned_on_fuzz() {
        // mirror of the owned fuzz suite: on arbitrary, truncated, and
        // mutated byte strings the borrowed decoder must never panic and
        // must accept/reject exactly the frames the owned decoder does
        check("codec_borrowed_fuzz", 300, |rng| {
            let bytes: Vec<u8> = match rng.below(3) {
                // arbitrary garbage
                0 => {
                    let len = rng.below(80);
                    (0..len).map(|_| rng.next_u32() as u8).collect()
                }
                // truncated valid frame
                1 => {
                    let n = rng.below(120) + 1;
                    let frame = encode(&match rng.below(3) {
                        0 => Payload::Signs(rand_signs(rng, n)),
                        1 => Payload::TallyFrame(rand_tally(rng, n)),
                        _ => Payload::TallyFrame(rand_grouped(rng, n)),
                    });
                    let cut = rng.below(frame.len());
                    frame[..cut].to_vec()
                }
                // single-byte mutation of a valid frame
                _ => {
                    let n = rng.below(120) + 1;
                    let mut frame = encode(&Payload::Signs(rand_signs(rng, n)));
                    let idx = rng.below(frame.len());
                    frame[idx] ^= 1u8 << rng.below(8);
                    frame
                }
            };
            match (decode(&bytes), Payload::decode_borrowed(&bytes)) {
                (Err(_), Err(_)) => Ok(()),
                (Ok(p), Ok(v)) => {
                    if v.to_owned() == p {
                        Ok(())
                    } else {
                        Err("decoders accept but disagree".into())
                    }
                }
                (Ok(_), Err(e)) => Err(format!("borrowed rejected a valid frame: {e}")),
                (Err(e), Ok(_)) => Err(format!("borrowed accepted what owned rejects: {e}")),
            }
        });
    }

    #[test]
    fn empty_payloads() {
        let p = Payload::Dense(vec![]);
        assert_eq!(decode(&encode(&p)).unwrap(), p);
        assert!(p.is_empty());
    }
}
