//! Wire codecs: how each algorithm serializes its payloads.
//!
//! Every algorithm's uplink/downlink traffic goes through a codec so the
//! ledger measures *actual encoded bytes*, not a formula. Encoded frames
//! are self-describing: 1 tag byte + u32 element count + payload.

use anyhow::{bail, Result};

use crate::sketch::bitpack::{pack_signs, packed_bytes, unpack_signs};

/// A decoded payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// full-precision vector (FedAvg and full-model downlinks)
    Dense(Vec<f32>),
    /// ±1 sign vector (OBDA/zSignFed uplinks, pFed1BS both directions)
    Signs(Vec<f32>),
    /// sign vector with one f32 scale (EDEN/FedBAT: α·sign(x))
    ScaledSigns { signs: Vec<f32>, scale: f32 },
}

impl Payload {
    pub fn len(&self) -> usize {
        match self {
            Payload::Dense(v) | Payload::Signs(v) => v.len(),
            Payload::ScaledSigns { signs, .. } => signs.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

const TAG_DENSE: u8 = 1;
const TAG_SIGNS: u8 = 2;
const TAG_SCALED: u8 = 3;

/// Encode a payload to its wire frame.
pub fn encode(p: &Payload) -> Vec<u8> {
    match p {
        Payload::Dense(v) => {
            let mut out = Vec::with_capacity(5 + 4 * v.len());
            out.push(TAG_DENSE);
            out.extend_from_slice(&(v.len() as u32).to_le_bytes());
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
            out
        }
        Payload::Signs(v) => {
            let words = pack_signs(v);
            let mut out = Vec::with_capacity(5 + words.len() * 8);
            out.push(TAG_SIGNS);
            out.extend_from_slice(&(v.len() as u32).to_le_bytes());
            for w in words {
                out.extend_from_slice(&w.to_le_bytes());
            }
            out
        }
        Payload::ScaledSigns { signs, scale } => {
            let words = pack_signs(signs);
            let mut out = Vec::with_capacity(9 + words.len() * 8);
            out.push(TAG_SCALED);
            out.extend_from_slice(&(signs.len() as u32).to_le_bytes());
            out.extend_from_slice(&scale.to_le_bytes());
            for w in words {
                out.extend_from_slice(&w.to_le_bytes());
            }
            out
        }
    }
}

/// Decode a wire frame back to a payload.
pub fn decode(bytes: &[u8]) -> Result<Payload> {
    if bytes.len() < 5 {
        bail!("frame too short ({} bytes)", bytes.len());
    }
    let tag = bytes[0];
    let len = u32::from_le_bytes(bytes[1..5].try_into().unwrap()) as usize;
    match tag {
        TAG_DENSE => {
            let need = 5 + 4 * len;
            if bytes.len() != need {
                bail!("dense frame: expected {need} bytes, got {}", bytes.len());
            }
            let v = bytes[5..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Payload::Dense(v))
        }
        TAG_SIGNS => {
            let need = 5 + packed_bytes(len);
            if bytes.len() != need {
                bail!("signs frame: expected {need} bytes, got {}", bytes.len());
            }
            let words: Vec<u64> = bytes[5..]
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Payload::Signs(unpack_signs(&words, len)))
        }
        TAG_SCALED => {
            let need = 9 + packed_bytes(len);
            if bytes.len() != need {
                bail!("scaled frame: expected {need} bytes, got {}", bytes.len());
            }
            let scale = f32::from_le_bytes(bytes[5..9].try_into().unwrap());
            let words: Vec<u64> = bytes[9..]
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Payload::ScaledSigns { signs: unpack_signs(&words, len), scale })
        }
        t => bail!("unknown payload tag {t}"),
    }
}

/// Frame size without encoding (for planning / assertions).
pub fn frame_bytes(p: &Payload) -> usize {
    match p {
        Payload::Dense(v) => 5 + 4 * v.len(),
        Payload::Signs(v) => 5 + packed_bytes(v.len()),
        Payload::ScaledSigns { signs, .. } => 9 + packed_bytes(signs.len()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn rand_signs(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| if rng.f32() < 0.5 { 1.0 } else { -1.0 }).collect()
    }

    #[test]
    fn dense_round_trip() {
        check("codec_dense_round_trip", 30, |rng| {
            let n = rng.below(1000);
            let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let p = Payload::Dense(v);
            let bytes = encode(&p);
            if bytes.len() != frame_bytes(&p) {
                return Err("frame_bytes mismatch".into());
            }
            if decode(&bytes).map_err(|e| e.to_string())? != p {
                return Err("round trip".into());
            }
            Ok(())
        });
    }

    #[test]
    fn signs_round_trip_and_compression() {
        check("codec_signs_round_trip", 30, |rng| {
            let n = rng.below(2000) + 1;
            let p = Payload::Signs(rand_signs(rng, n));
            let bytes = encode(&p);
            if decode(&bytes).map_err(|e| e.to_string())? != p {
                return Err("round trip".into());
            }
            // ~32x smaller than dense for large n
            if n >= 640 && bytes.len() * 16 > 4 * n + 5 {
                return Err(format!("poor compression: {} bytes for n={n}", bytes.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn scaled_signs_round_trip() {
        let mut rng = Rng::new(3);
        let p = Payload::ScaledSigns { signs: rand_signs(&mut rng, 100), scale: 0.0123 };
        assert_eq!(decode(&encode(&p)).unwrap(), p);
    }

    #[test]
    fn exact_wire_sizes() {
        // the communication-cost claims in Table 2 rest on these sizes
        assert_eq!(encode(&Payload::Dense(vec![0.0; 100])).len(), 5 + 400);
        assert_eq!(encode(&Payload::Signs(vec![1.0; 64])).len(), 5 + 8);
        assert_eq!(encode(&Payload::Signs(vec![1.0; 65])).len(), 5 + 16);
        assert_eq!(
            encode(&Payload::ScaledSigns { signs: vec![1.0; 64], scale: 1.0 }).len(),
            9 + 8
        );
    }

    #[test]
    fn corrupt_frames_rejected() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[9, 0, 0, 0, 0]).is_err()); // bad tag
        let mut ok = encode(&Payload::Dense(vec![1.0, 2.0]));
        ok.pop(); // truncate
        assert!(decode(&ok).is_err());
    }

    #[test]
    fn empty_payloads() {
        let p = Payload::Dense(vec![]);
        assert_eq!(decode(&encode(&p)).unwrap(), p);
        assert!(p.is_empty());
    }
}
