//! Property/fuzz tests for the socket transport (DESIGN.md §12). No
//! PJRT runtime needed: these hammer the envelope framing with garbage,
//! truncations, and mutations (a malformed or truncated peer must yield
//! `Err` — never a panic, never an unbounded allocation), then run real
//! multi-process rounds over loopback TCP and assert the socket path is
//! bit-identical to the in-process reference:
//!
//! * the protocol-level golden vote (`prop_coordinator.rs`'s analytic
//!   consensus) replayed through `StreamTransport::loopback`, where every
//!   uplink traverses a real OS socket — same words, same byte ledger;
//! * `serve` + `client-fleet` over TCP (flat and client→edge→root
//!   shapes) reproducing [`reference_consensus`] bit for bit;
//! * a small `loadgen` smoke checking the rounds/sec + p99
//!   uplink-to-absorb report is coherent.

use std::io::Cursor;
use std::thread;

use pfed1bs::comm::codec::{frame_bytes, Payload, TallyFrame};
use pfed1bs::comm::transport::frame::{
    decode_body, encode_body, kind_name, read_frame, write_frame, Frame, Hello, PeerRole, Welcome,
    DEFAULT_MAX_FRAME,
};
use pfed1bs::comm::transport::stream::Listener;
use pfed1bs::comm::{SimNetwork, StreamTransport, Transport, Tuning};
use pfed1bs::config::{Endpoint, ServeConfig, ServeRole};
use pfed1bs::serve::{
    reference_consensus, reference_consensus_quorum, run_edge_on, run_fleet, run_loadgen,
    run_root_on,
};
use pfed1bs::sketch::bitpack::{SignVec, VoteAccumulator};
use pfed1bs::util::proptest::check;
use pfed1bs::util::rng::Rng;

fn rand_signs(rng: &mut Rng, m: usize) -> SignVec {
    SignVec::from_fn(m, |_| rng.f32() < 0.5)
}

fn rand_i128(rng: &mut Rng) -> i128 {
    (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128) as i128
}

fn rand_payload(rng: &mut Rng) -> Payload {
    match rng.below(3) {
        0 => Payload::Signs(rand_signs(rng, 1 + rng.below(300))),
        1 => Payload::Dense((0..1 + rng.below(64)).map(|_| rng.f32()).collect()),
        _ => Payload::ScaledSigns {
            signs: rand_signs(rng, 1 + rng.below(300)),
            scale: rng.f32() + 0.01,
        },
    }
}

fn rand_frame(rng: &mut Rng) -> Frame {
    match rng.below(7) {
        0 => Frame::Hello(Hello {
            role: [PeerRole::Fleet, PeerRole::Edge, PeerRole::Loadgen][rng.below(3)],
            lo: rng.next_u32() >> 16,
            hi: rng.next_u32() >> 16,
            m: rng.next_u32() >> 16,
            want_ack: rng.f32() < 0.5,
        }),
        1 => Frame::Welcome(Welcome {
            m: rng.next_u32() >> 12,
            seed: rng.next_u64(),
            rounds: rng.next_u32() >> 20,
            participating: rng.next_u32() >> 20,
            clients: rng.next_u32() >> 16,
        }),
        2 => Frame::Downlink {
            round: rng.next_u32() >> 20,
            client: rng.next_u32() >> 16,
            payload: rand_payload(rng),
        },
        3 => Frame::Uplink {
            round: rng.next_u32() >> 20,
            client: rng.next_u32() >> 16,
            payload: rand_payload(rng),
        },
        4 => Frame::Tally {
            round: rng.next_u32() >> 20,
            edge: rng.next_u32() >> 24,
            payload: Payload::TallyFrame(TallyFrame {
                absorbed: rng.next_u32() >> 20,
                loss_sum: rng.f64(),
                scalar: rand_i128(rng),
                quanta: (0..1 + rng.below(40)).map(|_| rand_i128(rng)).collect(),
                groups: Vec::new(),
            }),
        },
        5 => Frame::Ack { round: rng.next_u32() >> 20, client: rng.next_u32() >> 16 },
        _ => Frame::Bye,
    }
}

/// One fixed frame of every kind (plus payload variety), for the
/// deterministic truncation/mutation sweeps.
fn sample_frames() -> Vec<Frame> {
    vec![
        Frame::Hello(Hello { role: PeerRole::Edge, lo: 0, hi: 32, m: 130, want_ack: true }),
        Frame::Welcome(Welcome { m: 130, seed: 17, rounds: 3, participating: 16, clients: 64 }),
        Frame::Downlink {
            round: 2,
            client: 7,
            payload: Payload::Signs(SignVec::from_fn(130, |i| i % 2 == 0)),
        },
        Frame::Uplink {
            round: 2,
            client: 7,
            payload: Payload::ScaledSigns {
                signs: SignVec::from_fn(66, |i| i % 3 == 0),
                scale: 0.25,
            },
        },
        Frame::Downlink { round: 0, client: 0, payload: Payload::Dense(vec![1.5, -2.5, 0.0]) },
        Frame::Tally {
            round: 1,
            edge: 3,
            payload: Payload::TallyFrame(TallyFrame {
                absorbed: 5,
                loss_sum: 1.25,
                scalar: -7,
                quanta: vec![i128::MAX, i128::MIN, 0, 1, -1],
                groups: Vec::new(),
            }),
        },
        Frame::Ack { round: 9, client: 1023 },
        Frame::Bye,
    ]
}

#[test]
fn random_frames_round_trip_the_envelope() {
    check("frame_round_trip", 200, |rng| {
        let f = rand_frame(rng);
        let body = encode_body(&f);
        let back = decode_body(&body).map_err(|e| format!("{e:#}"))?;
        if back != f {
            return Err(format!("body round trip changed a {} frame", kind_name(f.kind())));
        }
        let mut wire = Vec::new();
        let wrote = write_frame(&mut wire, &f).map_err(|e| format!("{e:#}"))?;
        if wrote != wire.len() {
            return Err(format!("write_frame reported {wrote} of {} bytes", wire.len()));
        }
        let (got, read) =
            read_frame(&mut Cursor::new(&wire), DEFAULT_MAX_FRAME).map_err(|e| format!("{e:#}"))?;
        if got != f || read != wire.len() {
            return Err("wire round trip diverged".into());
        }
        Ok(())
    });
}

#[test]
fn arbitrary_garbage_never_panics_or_over_reads() {
    check("frame_garbage", 500, |rng| {
        let buf: Vec<u8> = (0..rng.below(256)).map(|_| rng.next_u32() as u8).collect();
        // must return (Ok or Err), never panic; an Ok must fit the buffer
        if let Ok((_, n)) = read_frame(&mut Cursor::new(&buf), DEFAULT_MAX_FRAME) {
            if n > buf.len() {
                return Err(format!("claimed {n} bytes from a {}-byte buffer", buf.len()));
            }
        }
        let _ = decode_body(&buf);
        Ok(())
    });
}

#[test]
fn every_strict_prefix_of_a_valid_frame_errs() {
    for f in sample_frames() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &f).unwrap();
        // a peer disconnecting mid-frame at ANY byte is an error, never a hang
        for cut in 0..wire.len() {
            assert!(
                read_frame(&mut Cursor::new(&wire[..cut]), DEFAULT_MAX_FRAME).is_err(),
                "prefix {cut}/{} of a {} frame decoded",
                wire.len(),
                kind_name(f.kind())
            );
        }
    }
}

#[test]
fn oversized_length_prefix_errs_before_allocating() {
    // a hostile 4 GiB length prefix against a 1 KiB cap: the cap check
    // must fire on the prefix alone, before any body allocation
    let mut wire = u32::MAX.to_le_bytes().to_vec();
    wire.extend_from_slice(&[0u8; 16]);
    let err = read_frame(&mut Cursor::new(&wire), 1024).unwrap_err();
    assert!(format!("{err:#}").contains("cap"), "got: {err:#}");
    // one byte past the cap is rejected even with the body present
    let mut wire = 1025u32.to_le_bytes().to_vec();
    wire.resize(4 + 1025, 0);
    assert!(read_frame(&mut Cursor::new(&wire), 1024).is_err());
    // a zero-length body is malformed, not an empty read loop
    assert!(read_frame(&mut Cursor::new(&0u32.to_le_bytes()[..]), 1024).is_err());
}

#[test]
fn single_byte_mutations_never_panic() {
    let frames = sample_frames();
    check("frame_mutation", 400, |rng| {
        let f = &frames[rng.below(frames.len())];
        let mut wire = Vec::new();
        write_frame(&mut wire, f).unwrap();
        let i = rng.below(wire.len());
        wire[i] ^= (1 + rng.below(255)) as u8;
        // any single-byte corruption: Ok or Err, never panic or over-read
        if let Ok((_, n)) = read_frame(&mut Cursor::new(&wire), DEFAULT_MAX_FRAME) {
            if n > wire.len() {
                return Err(format!("claimed {n} bytes from a {}-byte buffer", wire.len()));
            }
        }
        let _ = decode_body(&wire[4..]);
        Ok(())
    });
}

/// `prop_coordinator.rs`'s analytic golden vote, replayed with every
/// uplink traversing a real OS socket: `StreamTransport::loopback` must
/// deliver the same payloads, meter the same bytes, and sign the same
/// consensus words bit-for-bit as the clean-channel `SimNetwork`.
#[test]
fn golden_vote_and_wire_bytes_over_a_real_socket() {
    let m = 130; // three words, 2-bit tail
    let mut sock = StreamTransport::loopback(7, &Tuning::default()).unwrap();
    let mut sim = SimNetwork::new(7);
    let sketches = [
        SignVec::from_fn(m, |i| i % 2 == 0),
        SignVec::from_fn(m, |i| i % 3 == 0),
        SignVec::from_fn(m, |_| true),
    ];
    let weights = [0.5f32, 0.25, 0.25];
    let mut acc = VoteAccumulator::new(m);
    for (k, (z, &w)) in sketches.iter().zip(&weights).enumerate() {
        let up = Payload::Signs(z.clone());
        let via_sock = sock.uplink_from(k, &up).unwrap();
        let via_sim = sim.uplink_from(k, &up).unwrap();
        assert_eq!(via_sock, via_sim, "socket delivery diverged from the clean channel");
        assert_eq!(frame_bytes(&via_sock), 5 + 24, "130 bits -> 3 words -> 24 bytes + header");
        let Payload::Signs(got) = via_sock else { panic!("uplink changed payload kind") };
        acc.absorb(&got, w);
    }
    let socket_bytes = sock.end_round();
    let sim_bytes = sim.end_round();
    assert_eq!(socket_bytes, sim_bytes, "byte ledgers diverged");
    assert_eq!(socket_bytes.uplink, 3 * (5 + 24));
    assert_eq!(socket_bytes.uplink_msgs, 3);
    assert!(sock.wire_overhead() > 0, "the envelope tax must be visible, separately");

    // the analytic consensus: +1 iff i is even or divisible by 3 (the
    // exact 0.0 tie at odd multiples of 3 breaks toward +1)
    let want = SignVec::from_fn(m, |i| i % 2 == 0 || i % 3 == 0);
    let got = acc.finish();
    assert_eq!(got, want, "vote words diverged from the analytic consensus");
    let w0 = (0..64u64).fold(0u64, |a, i| if i % 2 == 0 || i % 3 == 0 { a | 1 << i } else { a });
    assert_eq!(got.words()[0], w0);
    assert_eq!(got.words()[2], 0b11);
}

fn role_cfg(role: ServeRole) -> ServeConfig {
    let mut cfg = ServeConfig::new(role);
    cfg.clients = 48;
    cfg.participating = 12;
    cfg.rounds = 3;
    cfg.m = 192;
    cfg.seed = 23;
    cfg
}

#[test]
fn serve_plus_fleet_over_tcp_matches_the_in_process_reference() {
    let listener = Listener::bind(&Endpoint::parse("tcp:127.0.0.1:0").unwrap()).unwrap();
    let ep = listener.local_endpoint().unwrap();
    let mut root_cfg = role_cfg(ServeRole::Root);
    root_cfg.check_consensus = true; // the run itself asserts bit-identity
    let mut fleet_cfg = role_cfg(ServeRole::Fleet);
    fleet_cfg.connect = Some(ep);
    fleet_cfg.conns = 3;
    let fleet = thread::spawn(move || run_fleet(&fleet_cfg));
    let report = run_root_on(&listener, &root_cfg).unwrap();
    fleet.join().unwrap().unwrap();
    assert_eq!(report.consensus, reference_consensus(23, 192, 48, 12, 3));
    assert_eq!(report.absorbed, 3 * 12, "every selected sketch absorbed, every round");
    assert_eq!(report.tally_bytes, 0, "no edges in the flat shape");
    assert!(report.uplink_bytes > 0 && report.downlink_bytes > 0);
}

/// DESIGN.md §13 over a real socket: with `--quorum 8` of 12 the root
/// closes each round after the first 8 selected clients plus the
/// previous round's 4 designated lates (absorbed one round stale at
/// `staleness_decay`), and the final round's lates are drained without
/// entering any tally. `check_consensus` inside the run asserts
/// bit-identity against [`reference_consensus_quorum`]; the assertions
/// here re-check it from the outside and pin the absorb ledger.
#[test]
fn serve_plus_fleet_with_a_quorum_closes_rounds_without_the_stragglers() {
    let listener = Listener::bind(&Endpoint::parse("tcp:127.0.0.1:0").unwrap()).unwrap();
    let ep = listener.local_endpoint().unwrap();
    let mut root_cfg = role_cfg(ServeRole::Root);
    root_cfg.quorum = 8;
    root_cfg.check_consensus = true;
    let mut fleet_cfg = role_cfg(ServeRole::Fleet);
    fleet_cfg.connect = Some(ep);
    fleet_cfg.conns = 3;
    let fleet = thread::spawn(move || run_fleet(&fleet_cfg));
    let report = run_root_on(&listener, &root_cfg).unwrap();
    fleet.join().unwrap().unwrap();
    assert_eq!(
        report.consensus,
        reference_consensus_quorum(23, 192, 48, 12, 3, 8, 0.5),
        "socket quorum run diverged from the in-process quorum replay"
    );
    assert_ne!(
        report.consensus,
        reference_consensus(23, 192, 48, 12, 3),
        "quorum 8 of 12 must genuinely change the tally vs the barrier run"
    );
    // rounds 0..2 absorb their 8-client quorum; rounds 1..2 also absorb
    // the previous round's 4 lates; round 2's 4 lates drain untallied
    assert_eq!(report.absorbed, 8 * 3 + 4 * 2, "quorum absorb ledger");
    assert_eq!(report.tally_bytes, 0, "no edges in the flat shape");
    // every selected client still answers every downlink it received —
    // the drained final lates are metered too, so the uplink ledger is
    // the full 12 sketches/round regardless of quorum
    assert!(report.uplink_bytes > 0 && report.downlink_bytes > 0);
}

#[test]
fn serve_plus_edge_plus_fleet_matches_the_in_process_reference() {
    let root_l = Listener::bind(&Endpoint::parse("tcp:127.0.0.1:0").unwrap()).unwrap();
    let root_ep = root_l.local_endpoint().unwrap();
    let edge_l = Listener::bind(&Endpoint::parse("tcp:127.0.0.1:0").unwrap()).unwrap();
    let edge_ep = edge_l.local_endpoint().unwrap();

    let mut root_cfg = role_cfg(ServeRole::Root);
    root_cfg.clients = 40;
    root_cfg.participating = 10;
    root_cfg.seed = 29;
    root_cfg.m = 160;
    root_cfg.check_consensus = true;

    // the edge fronts clients 0..24; clients 24..40 connect straight to root
    let mut edge_cfg = ServeConfig::new(ServeRole::Edge);
    edge_cfg.connect = Some(root_ep.clone());
    edge_cfg.lo = 0;
    edge_cfg.hi = 24;
    edge_cfg.edge_id = 3;
    let edge = thread::spawn(move || run_edge_on(&edge_l, &edge_cfg));

    let mut near = role_cfg(ServeRole::Fleet);
    near.connect = Some(edge_ep);
    near.lo = 0;
    near.hi = 24;
    near.conns = 2;
    let near = thread::spawn(move || run_fleet(&near));

    let mut far = role_cfg(ServeRole::Fleet);
    far.connect = Some(root_ep);
    far.lo = 24;
    far.hi = 40;
    far.conns = 1;
    let far = thread::spawn(move || run_fleet(&far));

    let report = run_root_on(&root_l, &root_cfg).unwrap();
    edge.join().unwrap().unwrap();
    near.join().unwrap().unwrap();
    far.join().unwrap().unwrap();

    assert_eq!(report.consensus, reference_consensus(29, 160, 40, 10, 3));
    assert_eq!(report.absorbed, 3 * 10);
    assert!(report.tally_bytes > 0, "the edge must answer with merge frames");
}

#[test]
fn loadgen_smoke_reports_coherent_throughput_and_latency() {
    let listener = Listener::bind(&Endpoint::parse("tcp:127.0.0.1:0").unwrap()).unwrap();
    let ep = listener.local_endpoint().unwrap();
    let mut root_cfg = ServeConfig::new(ServeRole::Root);
    root_cfg.clients = 200;
    root_cfg.participating = 50;
    root_cfg.rounds = 3;
    root_cfg.m = 256;
    root_cfg.seed = 31;
    root_cfg.check_consensus = true;
    let mut gen_cfg = ServeConfig::new(ServeRole::Loadgen); // want_ack defaults on
    gen_cfg.clients = 200;
    gen_cfg.connect = Some(ep);
    gen_cfg.conns = 4;
    gen_cfg.rounds = 3;
    gen_cfg.participating = 50;
    gen_cfg.m = 256;
    gen_cfg.seed = 31;
    let gen = thread::spawn(move || run_loadgen(&gen_cfg));
    run_root_on(&listener, &root_cfg).unwrap();
    let report = gen.join().unwrap().unwrap();
    assert_eq!(report.rounds, 3);
    assert_eq!(report.uplinks, 3 * 50, "one uplink per selected client per round");
    assert!(report.rounds_per_sec > 0.0);
    assert!(report.p50_uplink_to_absorb_ms > 0.0, "ACKs must time the absorb path");
    assert!(report.p99_uplink_to_absorb_ms >= report.p50_uplink_to_absorb_ms);
    let json = report.to_json();
    assert!(json.contains("\"p99_uplink_to_absorb_ms\"") && json.contains("\"rounds_per_sec\""));
}
