//! Kernel bit-exactness at PRODUCTION geometry (DESIGN.md §10): the
//! blocked/fused/threaded FWHT paths against the retained scalar
//! reference at the real model sizes (n′ = 2¹⁷ — past the 2¹² tile, so
//! the cross phase, the padding-boundary tile, and the banded threaded
//! mode all actually execute), plus the fused SRHT pipeline end-to-end.
//! The golden trace and the per-round byte assertions rest on these
//! identities; small-size sweeps live in the sketch module's unit tests.

use pfed1bs::sketch::fwht::scalar;
use pfed1bs::sketch::{
    fwht_batch, fwht_batch_threaded, fwht_inplace, fwht_normalized, fwht_threaded,
    fwht_threaded_normalized, SrhtOperator,
};
use pfed1bs::util::rng::Rng;

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for i in 0..got.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{what}: lane {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn blocked_and_threaded_match_scalar_at_model_size() {
    let n = 1usize << 17; // mlp784's n' — 32 tiles + a 7-stage cross phase
    let mut rng = Rng::new(41);
    let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();

    let mut want = x.clone();
    scalar::fwht_inplace(&mut want);
    let mut got = x.clone();
    fwht_inplace(&mut got);
    assert_bits_eq(&got, &want, "unnormalized 2^17");

    let mut wantn = x.clone();
    scalar::fwht_normalized(&mut wantn);
    let mut gotn = x.clone();
    fwht_normalized(&mut gotn);
    assert_bits_eq(&gotn, &wantn, "normalized 2^17");

    for threads in [1usize, 2, 3, 8] {
        let mut gt = x.clone();
        fwht_threaded_normalized(&mut gt, threads);
        assert_bits_eq(&gt, &wantn, &format!("threaded normalized t={threads}"));
        let mut gu = x.clone();
        fwht_threaded(&mut gu, threads);
        assert_bits_eq(&gu, &want, &format!("threaded unnormalized t={threads}"));
    }
}

#[test]
fn batch_matches_loop_at_scale_for_any_thread_count() {
    let (bsz, n) = (6usize, 1usize << 14);
    let mut rng = Rng::new(43);
    let xs: Vec<f32> = (0..bsz * n).map(|_| rng.normal()).collect();
    let mut want = xs.clone();
    for x in want.chunks_exact_mut(n) {
        scalar::fwht_normalized(x);
    }
    let mut got = xs.clone();
    fwht_batch(&mut got, n);
    assert_bits_eq(&got, &want, "batch serial");
    for threads in [2usize, 5, 16] {
        let mut gott = xs.clone();
        fwht_batch_threaded(&mut gott, n, threads);
        assert_bits_eq(&gott, &want, &format!("batch t={threads}"));
    }
}

/// The fused SRHT pipeline at the mlp784 geometry: pad-boundary tile,
/// fused prologue/epilogue, direct SignVec packing, threaded adjoint —
/// all bit-identical to the spelled-out scalar-reference pipeline.
#[test]
fn srht_pipeline_bit_identical_at_mlp784_geometry() {
    let (n, m) = (101_770usize, 10_177usize);
    let op = SrhtOperator::from_seed(9, n, m);
    assert_eq!(op.npad, 1 << 17);
    let mut rng = Rng::new(47);
    let w: Vec<f32> = (0..n).map(|_| 0.1 * rng.normal()).collect();

    // reference: explicit prologue, scalar transform, separate epilogue
    let mut rot = vec![0.0f32; op.npad];
    for i in 0..n {
        rot[i] = w[i] * op.dsign[i];
    }
    scalar::fwht_normalized(&mut rot);

    let fwd = op.forward(&w);
    for j in 0..m {
        let want = rot[op.sidx[j] as usize] * op.scale;
        assert_eq!(fwd[j].to_bits(), want.to_bits(), "forward lane {j}");
    }

    // fused subsample+sign packing vs the f32 sign path
    let packed = op.sketch_sign_packed(&w);
    assert_eq!(packed.to_signs(), op.sketch_sign(&w), "packed sketch parity");

    // adjoint, serial vs worker pool
    let v: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
    let serial = op.adjoint(&v);
    let mut refbuf = vec![0.0f32; op.npad];
    for (&i, &val) in op.sidx.iter().zip(&v) {
        refbuf[i as usize] = val * op.scale;
    }
    scalar::fwht_normalized(&mut refbuf);
    for j in 0..n {
        let want = refbuf[j] * op.dsign[j];
        assert_eq!(serial[j].to_bits(), want.to_bits(), "adjoint lane {j}");
    }
    for threads in [2usize, 4] {
        assert_eq!(op.adjoint_threaded(&v, threads), serial, "adjoint t={threads}");
    }

    // rotate paths share the plan; borrowed view == owned result
    let owned = op.rotate(&w);
    assert_bits_eq(&owned, &rot, "rotate vs reference");
    op.rotate_with(&w, |y| assert_bits_eq(y, &rot, "rotate_with view"));
    let back = op.rotate_inverse(&owned);
    assert_eq!(op.rotate_inverse_threaded(&owned, 4), back, "rotate_inverse threaded");
}

/// Tiny-m sketches over the big transform: SignVec word-boundary
/// geometries (m = 63/64/65) packed straight off the 2^17 rotated
/// scratch keep the canonical zero tail and f32 parity.
#[test]
fn fused_packing_dirty_tail_at_model_size() {
    let n = 1usize << 17;
    let mut rng = Rng::new(53);
    let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    for m in [63usize, 64, 65] {
        let op = SrhtOperator::from_seed(500 + m as u64, n, m);
        let packed = op.sketch_sign_packed(&w);
        assert_eq!(packed.m(), m);
        assert_eq!(packed.to_signs(), op.sketch_sign(&w), "parity m={m}");
        if m % 64 != 0 {
            let last = *packed.words().last().unwrap();
            assert_eq!(last >> (m % 64), 0, "dirty tail m={m}");
        }
    }
}
