//! Tentpole acceptance (DESIGN.md §12): a full pFed1BS training round —
//! real PJRT compute, real SRHT sketches — driven through the
//! socket-backed `StreamTransport` on loopback must be bit-identical to
//! the clean-channel `SimNetwork` run: same consensus words, same
//! personalized models, same client-tier byte counts, same losses. The
//! only permitted difference is the envelope tax, surfaced separately by
//! `wire_overhead()`.
//!
//! Requires `make artifacts` (skips gracefully otherwise), like the rest
//! of the integration tier. The no-artifacts complement lives in
//! `prop_transport.rs` (protocol-level golden + serve/fleet smoke).

use pfed1bs::algorithms;
use pfed1bs::comm::{RoundBytes, StreamTransport, Transport, Tuning};
use pfed1bs::config::RunConfig;
use pfed1bs::coordinator::Coordinator;
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::Lab;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

fn short_cfg() -> RunConfig {
    let mut cfg = RunConfig::preset(DatasetName::Mnist);
    cfg.algorithm = "pfed1bs".to_string();
    cfg.rounds = 3;
    cfg.local_steps = 5;
    cfg.eval_every = 3;
    cfg.seed = 47;
    cfg
}

/// One full run over the given transport; returns everything the
/// bit-identity comparison needs.
struct Snapshot {
    losses: Vec<f64>,
    bytes: Vec<RoundBytes>,
    final_accuracy: f64,
    consensus: Vec<u64>,
    models: Vec<Vec<f32>>,
}

fn run_over<N: Transport>(lab: &Lab, cfg: RunConfig, net: N) -> (Snapshot, N) {
    let model = lab.model_for(&cfg).unwrap();
    let mut alg = algorithms::build("pfed1bs").unwrap();
    let mut coord = Coordinator::with_transport(cfg, &model, net);
    let result = coord.run(alg.as_mut()).unwrap();
    let snap = Snapshot {
        losses: result.history.records.iter().map(|r| r.train_loss).collect(),
        bytes: result.history.records.iter().map(|r| r.bytes).collect(),
        final_accuracy: result.final_accuracy,
        consensus: alg.consensus_packed().unwrap().words().to_vec(),
        models: alg.snapshot(),
    };
    (snap, coord.net)
}

fn assert_identical(sim: &Snapshot, sock: &Snapshot, shape: &str) {
    assert_eq!(sim.losses, sock.losses, "{shape}: losses diverged over the socket");
    assert_eq!(sim.bytes, sock.bytes, "{shape}: per-round byte ledgers diverged");
    assert_eq!(sim.final_accuracy, sock.final_accuracy, "{shape}: accuracy diverged");
    assert_eq!(
        sim.consensus, sock.consensus,
        "{shape}: consensus words must be bit-identical across transports"
    );
    assert_eq!(sim.models, sock.models, "{shape}: personalized models diverged");
}

#[test]
fn socket_transport_run_is_bit_identical_to_sim_network() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");
    let cfg = short_cfg();
    let sock_net = StreamTransport::loopback(cfg.seed, &Tuning::default()).unwrap();
    let (sim, _) = run_over(&lab, cfg.clone(), pfed1bs::comm::SimNetwork::new(cfg.seed));
    let (sock, net) = run_over(&lab, cfg, sock_net);
    assert_identical(&sim, &sock, "flat");
    assert!(
        net.wire_overhead() > 0,
        "every frame crossed a real socket, so the envelope tax must show"
    );
}

#[test]
fn socket_transport_edge_topology_ships_tally_frames_bit_identically() {
    if !artifacts_available() {
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");
    let mut cfg = short_cfg();
    cfg.apply_pairs([("topology", "edge:4")].into_iter()).unwrap();
    cfg.validate().unwrap();
    let sock_net = StreamTransport::loopback(cfg.seed, &Tuning::default()).unwrap();
    let (sim, _) = run_over(&lab, cfg.clone(), pfed1bs::comm::SimNetwork::new(cfg.seed));
    let (sock, net) = run_over(&lab, cfg, sock_net);
    assert_identical(&sim, &sock, "edge:4");
    // the edge tier actually crossed the wire: merge frames are metered
    assert!(sock.bytes.iter().all(|b| b.edge_up_msgs == 4), "4 merge frames per round");
    assert!(net.wire_overhead() > 0);
}
