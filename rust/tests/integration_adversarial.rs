//! Adversarial training battery: Byzantine clients injected at the
//! uplink boundary versus the three sign-tally aggregators.
//!
//! Every attack in the matrix (`signflip`, `scale`, `collude`) is run
//! against every aggregation kind (plain `Vote`, `TrimmedVote`,
//! `MedianOfMeans`); the robust tallies must hold an accuracy floor
//! relative to the clean baseline, while the unprotected majority vote
//! must measurably degrade under a heavy sign-flip fleet.
//!
//! Requires `make artifacts` (skips gracefully otherwise). PJRT handles
//! are not Send/Sync, so each #[test] builds its own Lab.

use pfed1bs::config::{Attack, RunConfig};
use pfed1bs::coordinator::RunResult;
use pfed1bs::data::DatasetName;
use pfed1bs::experiments::Lab;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

fn hostile_cfg() -> RunConfig {
    let mut cfg = RunConfig::preset(DatasetName::Mnist);
    cfg.algorithm = "pfed1bs".to_string();
    cfg.rounds = 4;
    cfg.local_steps = 5;
    cfg.eval_every = 3;
    cfg.seed = 41;
    cfg
}

fn with_attack(mut cfg: RunConfig, spec: &str) -> RunConfig {
    cfg.attack = Attack::parse(spec).unwrap_or_else(|e| panic!("{spec}: {e:#}"));
    cfg
}

/// Total consensus churn over the run: sum of per-round sign flips in
/// the broadcast consensus (the stability metric from DESIGN.md §8).
fn total_flips(result: &RunResult) -> usize {
    result
        .history
        .records
        .iter()
        .filter_map(|r| r.consensus_flips)
        .sum()
}

fn total_adversaries(result: &RunResult) -> usize {
    result.history.records.iter().map(|r| r.adversaries).sum()
}

#[test]
fn vote_degrades_under_signflip_while_robust_tallies_hold() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");

    // Clean baseline: no attack, plain majority vote.
    let clean = lab.run(hostile_cfg()).unwrap_or_else(|e| panic!("clean: {e:#}"));
    assert!(
        clean.final_accuracy > 0.60,
        "clean baseline below floor: {:.3}",
        clean.final_accuracy
    );
    assert!(
        clean.history.records.iter().all(|r| r.adversaries == 0),
        "clean run must record zero adversaries every round"
    );

    // (a) heavy sign-flip fleet vs the unprotected majority vote:
    // either the personalized accuracy drops or the consensus churns
    // far more than the converging clean run — both are the visible
    // signatures of a corrupted tally.
    let attacked = lab
        .run(with_attack(hostile_cfg(), "signflip:0.4"))
        .unwrap_or_else(|e| panic!("signflip vote: {e:#}"));
    assert!(
        total_adversaries(&attacked) > 0,
        "signflip:0.4 marked no adversaries across the run"
    );
    let acc_degraded = attacked.final_accuracy < clean.final_accuracy - 0.02;
    let consensus_churned = total_flips(&attacked) > (2 * total_flips(&clean)).max(4);
    assert!(
        acc_degraded || consensus_churned,
        "plain Vote showed no damage under signflip:0.4 \
         (acc {:.3} vs clean {:.3}, flips {} vs clean {})",
        attacked.final_accuracy,
        clean.final_accuracy,
        total_flips(&attacked),
        total_flips(&clean)
    );

    // (b) full matrix: each attack at F = 0.25 against each robust
    // tally must stay within a fixed margin of the clean baseline.
    let floor = clean.final_accuracy - 0.15;
    for spec in ["signflip:0.25", "scale:0.25:-1", "collude:0.25"] {
        // Plain Vote row: must run to completion and mark adversaries
        // (no accuracy floor — Vote is the unprotected baseline).
        let vote = lab
            .run(with_attack(hostile_cfg(), spec))
            .unwrap_or_else(|e| panic!("{spec} vote: {e:#}"));
        assert!(
            total_adversaries(&vote) > 0,
            "{spec}: vote run marked no adversaries"
        );

        // Coordinate-wise trimmed vote.
        let mut trimmed_cfg = with_attack(hostile_cfg(), spec);
        trimmed_cfg.trim_frac = 0.3;
        let trimmed = lab
            .run(trimmed_cfg)
            .unwrap_or_else(|e| panic!("{spec} trimmed: {e:#}"));
        assert!(
            trimmed.final_accuracy > floor,
            "{spec}: trimmed vote accuracy {:.3} below floor {:.3}",
            trimmed.final_accuracy,
            floor
        );

        // Median-of-means over 5 client groups.
        let mut mom_cfg = with_attack(hostile_cfg(), spec);
        mom_cfg.mom_groups = 5;
        let mom = lab
            .run(mom_cfg)
            .unwrap_or_else(|e| panic!("{spec} mom: {e:#}"));
        assert!(
            mom.final_accuracy > floor,
            "{spec}: median-of-means accuracy {:.3} below floor {:.3}",
            mom.final_accuracy,
            floor
        );
    }
}

#[test]
fn robust_tallies_match_clean_vote_without_adversaries() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");

    // With no attack armed, trim = 0 and groups = 1 reduce bit-for-bit
    // to the plain vote, so the training trajectory is identical.
    let vote = lab.run(hostile_cfg()).unwrap_or_else(|e| panic!("vote: {e:#}"));

    let mut trim0 = hostile_cfg();
    trim0.trim_frac = 0.0;
    trim0.mom_groups = 1;
    let reduced = lab.run(trim0).unwrap_or_else(|e| panic!("reduced: {e:#}"));
    assert_eq!(
        vote.final_accuracy, reduced.final_accuracy,
        "trim=0/groups=1 must reproduce the plain vote exactly"
    );
    let losses = |r: &RunResult| -> Vec<f64> {
        r.history.records.iter().map(|x| x.train_loss).collect()
    };
    assert_eq!(losses(&vote), losses(&reduced));

    // A robust tally on an honest fleet still has to learn: trimming
    // 30% of an all-honest cohort costs accuracy, not correctness.
    let mut trimmed_cfg = hostile_cfg();
    trimmed_cfg.trim_frac = 0.3;
    let trimmed = lab
        .run(trimmed_cfg)
        .unwrap_or_else(|e| panic!("honest trimmed: {e:#}"));
    assert!(
        trimmed.final_accuracy > 0.50,
        "honest trimmed vote below floor: {:.3}",
        trimmed.final_accuracy
    );
}

#[test]
fn error_feedback_learns_and_is_deterministic() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let lab = Lab::new("artifacts").expect("lab");

    let mut cfg = hostile_cfg();
    cfg.error_feedback = true;
    let a = lab.run(cfg.clone()).unwrap_or_else(|e| panic!("ef a: {e:#}"));
    assert!(
        a.final_accuracy > 0.50,
        "error feedback run below floor: {:.3}",
        a.final_accuracy
    );

    // Same seed, same residual trajectory: byte-identical history.
    let b = lab.run(cfg).unwrap_or_else(|e| panic!("ef b: {e:#}"));
    assert_eq!(a.final_accuracy, b.final_accuracy);
    let losses = |r: &RunResult| -> Vec<f64> {
        r.history.records.iter().map(|x| x.train_loss).collect()
    };
    assert_eq!(losses(&a), losses(&b));

    // Error feedback also composes with a hostile fleet + robust tally.
    let mut hostile = with_attack(hostile_cfg(), "signflip:0.25");
    hostile.error_feedback = true;
    hostile.trim_frac = 0.3;
    let robust = lab
        .run(hostile)
        .unwrap_or_else(|e| panic!("ef hostile: {e:#}"));
    assert!(
        robust.final_accuracy > 0.45,
        "EF + trimmed vote under signflip:0.25 below floor: {:.3}",
        robust.final_accuracy
    );
}
